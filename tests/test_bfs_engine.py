"""Compile-once BFSEngine lifecycle: reuse without retraces, donation
safety, source validation, exchange registry, traversal service."""

import numpy as np
import pytest

from repro.core import (BFSOptions, INF, bfs, plan, register_exchange,
                        unregister_exchange, validate_sources,
                        DENSE_STRATEGIES)
from repro.core import exchange as ex
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph


def _graph(n=600, seed=3, deg=6):
    src, dst = generate("erdos_renyi", n, seed=seed, avg_degree=deg)
    return src, dst, shard_graph(src, dst, n, p=1)


# ---------------------------------------------------------------------------
# engine reuse
# ---------------------------------------------------------------------------

def test_engine_reuse_zero_retraces():
    """A second run with fresh sources must not retrace the kernel, and
    donated init buffers must not alias earlier results."""
    n = 600
    src, dst, g = _graph(n)
    eng = plan(g, BFSOptions(mode="dense"), num_sources=2).compile()
    traces_after_compile = eng.trace_count
    assert traces_after_compile == eng.compile_traces

    r1 = eng.run([0, 5])
    d1_before = r1.dist_host.copy()
    np.testing.assert_array_equal(d1_before, bfs_reference(src, dst, n, [0, 5]))

    r2 = eng.run([7, 123])          # fresh sources: device-only work
    assert eng.trace_count == traces_after_compile
    np.testing.assert_array_equal(r2.dist_host,
                                  bfs_reference(src, dst, n, [7, 123]))
    # r1's buffers were not clobbered by r2's donated init state
    np.testing.assert_array_equal(r1.dist_host, d1_before)


def test_engine_partial_source_batch():
    """An engine compiled for S sources accepts 1..S without retracing;
    empty columns are sliced off the host view."""
    n = 500
    src, dst, g = _graph(n, seed=2, deg=5)
    eng = plan(g, BFSOptions(mode="dense"), num_sources=4).compile()
    traces = eng.trace_count
    got = eng.run([13, 250]).dist_host
    assert got.shape == (n, 2)
    np.testing.assert_array_equal(got, bfs_reference(src, dst, n, [13, 250]))
    assert eng.trace_count == traces


def test_engine_run_async_blocks_lazily():
    n = 400
    src, dst, g = _graph(n, seed=7, deg=5)
    eng = plan(g, BFSOptions(mode="auto", queue_cap=4096)).compile()
    res = eng.run_async([42])
    stats = res.block().stats()      # sync point
    np.testing.assert_array_equal(res.dist_host,
                                  bfs_reference(src, dst, n, [42]))
    assert stats.levels >= 1
    assert stats.visited == int((res.dist_host < int(INF)).sum())


def test_plan_describe_is_static_metadata():
    _, _, g = _graph()
    p = plan(g, BFSOptions(mode="auto"), num_sources=3)
    meta = p.describe()
    assert meta["num_sources"] == 3 and meta["p"] == 1
    assert meta["dense_exchange"] == "alltoall_direct"
    assert meta["n_logical"] == 600


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_source_validation_rejects_bad_ids():
    n = 500
    _, _, g = _graph(n, seed=2, deg=5)
    eng = plan(g, BFSOptions(mode="dense"), num_sources=2).compile()
    with pytest.raises(ValueError, match="outside"):
        eng.run([n])                  # one past the last logical vertex
    with pytest.raises(ValueError, match="outside"):
        eng.run([-3])                 # silently wrapped pre-redesign
    with pytest.raises(ValueError, match="duplicate"):
        eng.run([4, 4])
    with pytest.raises(ValueError, match="capacity"):
        eng.run([1, 2, 3])            # exceeds compiled S=2
    with pytest.raises(ValueError, match="integer"):
        validate_sources([0.5], n)
    # the deprecated wrapper validates before planning
    with pytest.raises(ValueError, match="outside"):
        bfs(g, [n + 7])
    with pytest.raises(ValueError, match="duplicate"):
        bfs(g, [3, 3])


def test_options_validation_raises_value_error():
    with pytest.raises(ValueError, match="mode"):
        BFSOptions(mode="bogus").validate()
    with pytest.raises(ValueError, match="registered"):
        BFSOptions(dense_exchange="nope").validate()
    _, _, g = _graph()
    with pytest.raises(ValueError, match="single source"):
        plan(g, BFSOptions(mode="queue"), num_sources=2)


# ---------------------------------------------------------------------------
# deprecated wrapper
# ---------------------------------------------------------------------------

def test_bfs_wrapper_deprecated_but_equivalent_and_cached():
    from repro.serve.engine_cache import EngineCache, use_default_cache

    n = 600
    src, dst, g = _graph(n)
    want = bfs_reference(src, dst, n, [0])
    with use_default_cache(EngineCache()) as cache:
        with pytest.deprecated_call():
            got, stats = bfs(g, [0], opts=BFSOptions(mode="dense"))
        np.testing.assert_array_equal(got, want)
        assert stats.visited == int((want < int(INF)).sum())
        # second call reuses the cached engine (no second compile, no
        # retrace) from the shared cache
        assert len(cache) == 1
        eng = cache.get(cache.keys()[0])
        traces = eng.trace_count
        with pytest.deprecated_call():
            got2, _ = bfs(g, [77], opts=BFSOptions(mode="dense"))
        assert len(cache) == 1 and eng.trace_count == traces
        assert cache.stats()["misses"] == 1
        np.testing.assert_array_equal(got2, bfs_reference(src, dst, n, [77]))


# ---------------------------------------------------------------------------
# exchange registry
# ---------------------------------------------------------------------------

def test_exchange_registry_views_and_errors():
    assert "alltoall_direct" in DENSE_STRATEGIES
    assert set(ex.QUEUE_STRATEGIES) == {
        "allgather_merge", "alltoall_direct",
        "allgather_merge_compressed", "alltoall_direct_compressed"}
    with pytest.raises(ValueError, match="registered"):
        ex.get_exchange("dense", "missing_strategy")
    with pytest.raises(ValueError, match="kind"):
        register_exchange("neither", "x", lambda *a: 0)


def test_register_exchange_pluggable_strategy():
    """A strategy registered from outside the module is planable and
    correct without touching bfs.py's dispatch."""
    name = "test_alltoall_alias"

    @register_exchange("dense", name,
                       lambda n, p, s, itemsize, axes_sizes: 0.0)
    def _alias(cand, axis):
        return ex.exchange_dense(cand, axis, "alltoall_direct")

    try:
        assert name in DENSE_STRATEGIES
        n = 400
        src, dst, g = _graph(n, seed=7, deg=5)
        eng = plan(g, BFSOptions(mode="dense", dense_exchange=name)).compile()
        np.testing.assert_array_equal(eng.run([0]).dist_host,
                                      bfs_reference(src, dst, n, [0]))
    finally:
        unregister_exchange("dense", name)
    assert name not in DENSE_STRATEGIES


# ---------------------------------------------------------------------------
# traversal service (slot-batched serving over one engine)
# ---------------------------------------------------------------------------

def test_bfs_service_batches_concurrent_requests():
    from repro.serve.bfs_service import BFSService, TraversalRequest

    n = 400
    src, dst, g = _graph(n, seed=5, deg=6)
    svc = BFSService(g, BFSOptions(mode="dense"), batch_slots=3)
    sources = [0, 17, 17, 250, 399]   # more requests than slots + a dupe
    reqs = [TraversalRequest(rid=i, source=s) for i, s in enumerate(sources)]
    for r in reqs:
        svc.submit(r)
    done = svc.run_until_drained()
    assert len(done) == len(reqs) and svc.pool.drained()
    for r in reqs:
        assert r.done
        want = bfs_reference(src, dst, n, [r.source])[:, 0]
        np.testing.assert_array_equal(r.dist, want)
        assert r.visited == int((want < int(INF)).sum())
    # one engine compile serves everything; no retraces while draining
    assert svc.engine.trace_count == svc.engine.compile_traces
    with pytest.raises(ValueError, match="outside"):
        svc.submit(TraversalRequest(rid=9, source=n + 1))


def test_bfs_service_truncated_drain_raises():
    """Satellite: exhausting max_steps with requests still queued must not
    look like a completed drain — and must not leak the stranded requests:
    each is completed with a typed StrandedRequestError and the pool is
    left clean for new work."""
    from repro.serve.bfs_service import BFSService, TraversalRequest
    from repro.serve.resilience.errors import StrandedRequestError

    n = 300
    src, dst, g = _graph(n, seed=8, deg=5)
    svc = BFSService(g, BFSOptions(mode="dense"), batch_slots=1)
    reqs = [TraversalRequest(rid=i, source=s)
            for i, s in enumerate([0, 5, 9])]   # 3 requests, 1 slot
    for r in reqs:
        svc.submit(r)
    with pytest.raises(RuntimeError, match="still pending"):
        svc.run_until_drained(max_steps=1)
    # the survivors are rejected, not leaked: done with a typed error,
    # pool empty, so a stuck drain can't strand callers forever
    stranded = [r for r in reqs if isinstance(r.error, StrandedRequestError)]
    assert {r.source for r in stranded} == {5, 9}
    assert all(r.done for r in stranded)
    assert svc.pool.drained()
    # the pool is clean: fresh work drains normally afterwards
    again = TraversalRequest(rid=9, source=5)
    svc.submit(again)
    rest = svc.run_until_drained()
    assert [r.source for r in rest] == [5] and again.error is None
    # an empty service drains immediately even with max_steps=0
    assert svc.run_until_drained(max_steps=0) == []
