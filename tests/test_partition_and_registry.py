"""Satellite coverage: partition padding-id hardening at the last shard
boundary, exchange-registry error paths and byte-model sanity, legacy
``bfs()`` deprecation + engine-cache eviction."""

import numpy as np
import pytest

from repro.core import (BFSOptions, Partition1D, Partition2D, bfs,
                        get_exchange, plan, register_exchange, select_exchange,
                        unregister_exchange, DENSE_STRATEGIES,
                        EXPAND_ROW_STRATEGIES, EXPAND_ROW_SPARSE_STRATEGIES,
                        FOLD_COL_STRATEGIES, FOLD_COL_SPARSE_STRATEGIES,
                        QUEUE_STRATEGIES)
from repro.core import exchange as ex
from repro.graphs import generate, shard_graph


# ---------------------------------------------------------------------------
# partition padding ids at the last shard boundary (regression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_logical,p", [
    (10, 4),    # last shard half padding
    (5, 4),     # last shard pure padding
    (9, 4),     # n_logical < p*shard_size with one empty tail shard
    (2, 4),     # more shards than logical vertices
    (7, 3),
    (1, 1),
])
def test_partition1d_padding_ids_map_to_valid_shards(n_logical, p):
    part = Partition1D(n_logical, p)
    # every padded id — including [n_logical, p*shard_size) — must resolve
    # to a shard in range without raising, as ints and as arrays
    for v in range(part.n):
        o = part.find_owner(v)
        assert 0 <= o < p
        lid = part.local_id(v)
        assert 0 <= lid < part.shard_size
        assert part.global_id(o, lid) == v
    v = np.arange(part.n)
    owners = np.asarray(part.owner(v))
    assert owners.min() >= 0 and owners.max() < p
    assert part.counts_per_owner(v).sum() == part.n  # bincount never raised


@pytest.mark.parametrize("n_logical,p", [
    (10, 4),    # last shard half padding
    (9, 4),     # one empty tail shard
    (7, 3),
])
def test_queue_bucket_dedupe_sentinel_clears_padding_ids(n_logical, p):
    """Satellite regression: the dedupe sentinel must sit outside the
    *padded* id space [0, n).  Feed duplicate targets covering every
    padded id — including the padding range at the last shard boundary —
    and check each survives exactly once across buckets + local mask."""
    import jax.numpy as jnp
    from repro.core import frontier as fr

    part = Partition1D(n_logical, p)
    ids = np.arange(part.n, dtype=np.int32)
    dst = jnp.asarray(np.concatenate([ids, ids]))        # every id twice
    active = jnp.ones((dst.shape[0],), bool)
    me = jnp.int32(p - 1)                                # the padded shard
    buckets, local_mask, n_sent, overflow = fr.build_queue_buckets(
        dst, active, part, me, cap=part.n, local_update=True, dedupe=True)
    assert not bool(overflow)
    sent = np.asarray(buckets).reshape(-1)
    sent = sent[sent >= 0]
    # remote shards' ids each exactly once, none lost to the sentinel
    want_remote = ids[ids < (p - 1) * part.shard_size]
    np.testing.assert_array_equal(np.sort(sent), want_remote)
    assert int(n_sent) == want_remote.shape[0]
    # locally-owned ids (incl. the padding ids) land in the local mask
    np.testing.assert_array_equal(np.asarray(local_mask),
                                  np.ones(part.shard_size, np.uint8))
    # same contract for the 2-D fold-layout builder: sentinel is the
    # padded fold size, so the maximal fold index dedupes cleanly
    part2 = Partition2D(n_logical, 2, max(1, p // 2))
    fold_ids = np.arange(part2.fold_size, dtype=np.int32)
    dstf = jnp.asarray(np.concatenate([fold_ids, fold_ids]))
    activef = jnp.ones((dstf.shape[0],), bool)
    b2, lm2, ns2, ov2 = fr.build_queue_buckets_2d(
        dstf, activef, part2, jnp.int32(0), cap=part2.fold_size,
        local_update=True, dedupe=True)
    assert not bool(ov2)
    sent2 = np.asarray(b2).reshape(-1)
    sent2 = sent2[sent2 >= 0]
    np.testing.assert_array_equal(np.sort(sent2),
                                  fold_ids[fold_ids >= part2.shard_size])
    np.testing.assert_array_equal(np.asarray(lm2),
                                  np.ones(part2.shard_size, np.uint8))


def test_partition_shard_slicing_clips_to_logical_range():
    part = Partition1D(5, 4)               # shard 3 = [6, 8): pure padding
    full = part.shard_slice(3)
    assert (full.start, full.stop) == (6, 8)
    logical = part.shard_logical_slice(3)
    assert logical.start == logical.stop == 5          # empty, in range
    x = np.arange(part.n_logical)
    assert x[logical].size == 0                        # safe to apply
    assert x[part.shard_logical_slice(2)].tolist() == [4]  # half padding
    with pytest.raises(ValueError, match="shard"):
        part.shard_slice(4)
    # same contract on the 2-D scheme (shared block algebra)
    part2 = Partition2D(5, 2, 2)
    assert part2.shard_logical_slice(3).start == 5
    v = np.arange(part2.n)
    assert np.asarray(part2.fold_index(v)).max() < part2.fold_size


# ---------------------------------------------------------------------------
# exchange registry error paths
# ---------------------------------------------------------------------------

def test_get_exchange_unknown_kind_and_name():
    with pytest.raises(ValueError, match="kind"):
        get_exchange("bogus_kind", "alltoall_direct")
    with pytest.raises(ValueError, match="registered"):
        get_exchange("dense", "no_such_strategy")
    with pytest.raises(ValueError, match="registered"):
        get_exchange("expand_row", "no_such_strategy")
    with pytest.raises(ValueError, match="kind"):
        register_exchange("bogus_kind", "x", lambda *a: 0)
    with pytest.raises(ValueError, match="kind"):
        select_exchange("bogus_kind")


def test_unregister_exchange_is_idempotent():
    name = "tmp_strategy_for_idempotence"
    register_exchange("dense", name, lambda *a: 0.0)(lambda cand, axis: cand)
    assert name in DENSE_STRATEGIES
    unregister_exchange("dense", name)
    assert name not in DENSE_STRATEGIES
    unregister_exchange("dense", name)     # second removal: silent no-op
    unregister_exchange("dense", "never_registered_at_all")


def test_byte_models_monotone_in_n_and_zero_without_peers():
    s, item = 2, 1
    for name in DENSE_STRATEGIES:
        m = get_exchange("dense", name).bytes_model
        assert m(4096, 1, s, item, (1,)) == 0, name       # p=1: no wire
        assert m(8192, 8, s, item, (8,)) >= m(4096, 8, s, item, (8,)), name
    for name in EXPAND_ROW_STRATEGIES:
        m = get_exchange("expand_row", name).bytes_model
        assert m(4096, 1, 1, s, item) == 0, name          # c=1: no row peers
        assert m(8192, 2, 4, s, item) >= m(4096, 2, 4, s, item), name
    for name in FOLD_COL_STRATEGIES:
        m = get_exchange("fold_col", name).bytes_model
        assert m(4096, 1, 1, s, item) == 0, name          # r=1: no col peers
        assert m(8192, 4, 2, s, item) >= m(4096, 4, 2, s, item), name
    for name in QUEUE_STRATEGIES:
        m = get_exchange("queue", name).bytes_model
        assert m(1, 1024, 4) == 0, name                   # p=1: no wire
        assert m(8, 2048, 4) >= m(8, 1024, 4), name       # monotone in cap
    for name in EXPAND_ROW_SPARSE_STRATEGIES:
        m = get_exchange("expand_row_sparse", name).bytes_model
        assert m(4, 1, 1024, 4) == 0, name                # c=1: no row peers
        assert m(2, 4, 2048, 4) >= m(2, 4, 1024, 4), name
    for name in FOLD_COL_SPARSE_STRATEGIES:
        m = get_exchange("fold_col_sparse", name).bytes_model
        assert m(1, 4, 1024, 4) == 0, name                # r=1: no col peers
        assert m(4, 2, 2048, 4) >= m(4, 2, 1024, 4), name


def test_select_exchange_picks_cheapest_by_model():
    # allgather_merge receives (p-1)*n vs alltoall_direct's (p-1)/p*n —
    # auto-selection must never pick the former for p > 1
    st = select_exchange("dense", 4096, 8, 1, 1, (8,))
    assert st.bytes_model(4096, 8, 1, 1, (8,)) <= \
        get_exchange("dense", "allgather_merge").bytes_model(
            4096, 8, 1, 1, (8,))
    # plan-level: "auto" resolves through the same selection
    n = 300
    src, dst = generate("erdos_renyi", n, seed=1, avg_degree=5)
    g = shard_graph(src, dst, n, p=1)
    pl = plan(g, BFSOptions(mode="dense", dense_exchange="auto"))
    assert pl.dense_strategy.name in DENSE_STRATEGIES
    pl2 = plan(g, BFSOptions(mode="dense", expand_exchange="auto",
                             fold_exchange="auto",
                             expand_sparse_exchange="auto",
                             fold_sparse_exchange="auto"), partition="2d")
    assert pl2.expand_strategy.name in EXPAND_ROW_STRATEGIES
    assert pl2.fold_strategy.name in FOLD_COL_STRATEGIES
    assert pl2.expand_sparse_strategy.name in EXPAND_ROW_SPARSE_STRATEGIES
    assert pl2.fold_sparse_strategy.name in FOLD_COL_SPARSE_STRATEGIES
    # off the degenerate 1x1 grid the direct fold is strictly cheaper:
    # (r-1)*cap received vs allgather_merge's (r-1)*r*cap; unrestricted
    # selection lands on its compressed twin (fewer modeled bytes still)
    assert ex.select_exchange("fold_col_sparse", 4, 2, 1024, 4,
                              wire="bytes").name == "alltoall_direct"
    assert ex.select_exchange("fold_col_sparse", 4, 2, 1024,
                              4).name == "alltoall_direct_compressed"


# ---------------------------------------------------------------------------
# deprecated bfs() wrapper + engine-cache eviction
# ---------------------------------------------------------------------------

def test_bfs_wrapper_emits_deprecation_warning():
    n = 80
    src, dst = generate("erdos_renyi", n, seed=0, avg_degree=4)
    g = shard_graph(src, dst, n, p=1)
    with pytest.warns(DeprecationWarning,
                      match=r"bfs\(\) is deprecated.*plan\("):
        bfs(g, [0], opts=BFSOptions(mode="dense", max_levels=4))


def test_bfs_wrapper_shared_cache_evicts_lru():
    """The wrapper's private FIFO memo is gone: engines resolve through
    the shared ``EngineCache``, whose eviction is LRU — a re-touched old
    entry survives an insertion that a FIFO would have evicted it on."""
    from repro.serve.engine_cache import EngineCache, use_default_cache

    n = 64
    src, dst = generate("erdos_renyi", n, seed=2, avg_degree=3)
    g = shard_graph(src, dst, n, p=1)
    # 10 distinct option keys against an 8-entry cap; max_levels keeps
    # each throwaway compile tiny
    variants = [BFSOptions(mode="dense", max_levels=2 + i) for i in range(10)]
    with use_default_cache(EngineCache(max_entries=8)) as cache:
        with pytest.warns(DeprecationWarning):
            bfs(g, [0], opts=variants[0])
        first_key = cache.keys()[0]
        with pytest.warns(DeprecationWarning):
            for o in variants[1:8]:
                bfs(g, [0], opts=o)
        assert len(cache) == 8 and first_key in cache
        with pytest.warns(DeprecationWarning):
            bfs(g, [0], opts=variants[0])  # hit: refreshes LRU recency
        with pytest.warns(DeprecationWarning):
            bfs(g, [0], opts=variants[8])  # 9th key: evicts variants[1]
        assert len(cache) == 8
        assert first_key in cache          # survived — FIFO would drop it
        assert cache.keys()[-1] != first_key
        with pytest.warns(DeprecationWarning):
            bfs(g, [0], opts=variants[9])  # 10th key: evicts variants[2]
        assert first_key in cache
        st = cache.stats()
        assert st["misses"] == 10 and st["hits"] == 1
        assert st["evictions"] == 2 and st["entries"] == 8


def test_options_validate_rejects_unknown_2d_strategies():
    with pytest.raises(ValueError, match="registered"):
        BFSOptions(expand_exchange="nope").validate()
    with pytest.raises(ValueError, match="registered"):
        BFSOptions(fold_exchange="nope").validate()
    with pytest.raises(ValueError, match="registered"):
        BFSOptions(expand_sparse_exchange="nope").validate()
    with pytest.raises(ValueError, match="registered"):
        BFSOptions(fold_sparse_exchange="nope").validate()
