"""Subprocess harness: analytic byte model vs HLO-parsed collective bytes.

Compiles each exchange strategy on 8 forced host devices, parses the
optimized HLO for collective ops, and checks the per-chip received-byte
model in core/exchange.py against what XLA actually emits.  This pins the
paper-reproduction numbers (benchmarks/run.py tables) to compiler ground
truth.  Exits nonzero on mismatch.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch import host_devices  # noqa: E402

host_devices(8)  # must precede the jax import below

import functools  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.core import exchange as ex  # noqa: E402
from repro.core import frontier as fr  # noqa: E402
from repro.core.compat import shard_map  # noqa: E402
from repro.launch.hlo_stats import collective_bytes  # noqa: E402


def compile_and_parse(fn, in_specs, out_specs, arg_shapes, mesh):
    mapped = shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    lowered = jax.jit(mapped).lower(*arg_shapes)
    return collective_bytes(lowered.compile().as_text())


def main():
    devs = jax.devices()
    mesh = Mesh(np.asarray(devs).reshape(8), ("p",))
    p = 8
    n, s = 4096, 4
    cap = 256
    ok = True

    for strategy in ex.DENSE_STRATEGIES:
        fn = functools.partial(ex.exchange_dense, axis="p", strategy=strategy)
        got = compile_and_parse(
            fn, P(None, None), P("p", None),
            (jax.ShapeDtypeStruct((n, s), jnp.uint8),), mesh)
        want = ex.dense_level_bytes(strategy, n, p, s, 1, axes_sizes=[p])
        # HLO counts the op's OUTPUT bytes once per device; relate the two:
        # all-gather output = p*n*s (received (p-1)/p of it); all-to-all
        # output = n*s; reduce-scatter output = n*s/p (bf16 -> 2B items).
        rel = got["total"] / max(want, 1)
        line = (f"dense/{strategy:16s} model={want:>12.0f}B "
                f"hlo_total={got['total']:>12.0f}B ratio={rel:6.3f} {got}")
        print(line)
        # sanity: the model must be within ~2.5x of HLO accounting and the
        # ORDERING must hold (baseline >> direct)
        ok &= 0.2 < rel < 2.6
    base = ex.dense_level_bytes("allgather_merge", n, p, s, 1)
    opt = ex.dense_level_bytes("alltoall_direct", n, p, s, 1)
    ok &= base / opt > p * 0.9  # paper claim: baseline grows ~linearly in p
    # packed-bitset claim: the _packed twin models 8x below its bytes twin
    # (exact here: the 512-vertex shard is word-aligned), and the HLO
    # ratios above already pinned the packed models to compiler output
    packed = ex.dense_level_bytes("alltoall_direct_packed", n, p, s, 1)
    print(f"dense/packed-vs-bytes ratio={opt / packed:.2f} (model)")
    ok &= opt / packed == 8.0

    for strategy in ex.QUEUE_STRATEGIES:
        fn = functools.partial(ex.exchange_queue, axis="p", strategy=strategy)
        if ex.get_exchange("queue", strategy).wire == "compressed":
            # compressed twins ship fixed-size uint8 payloads whose
            # capacity depends on the id range; density 0.5 = range 2*cap
            bc = fr.compressed_capacity(cap, 2 * cap)
            shapes = (jax.ShapeDtypeStruct((p, bc), jnp.uint8),)
            want = ex.queue_level_bytes(strategy, p, cap, 4, density=0.5)
        else:
            shapes = (jax.ShapeDtypeStruct((p, cap), jnp.int32),)
            want = ex.queue_level_bytes(strategy, p, cap)
        got = compile_and_parse(fn, P(None, None), P(None, None), shapes,
                                mesh)
        rel = got["total"] / max(want, 1)
        print(f"queue/{strategy:28s} model={want:>12.0f}B "
              f"hlo_total={got['total']:>12.0f}B ratio={rel:6.3f}")
        ok &= 0.2 < rel < 2.6
    # compressed-wire claim: the _compressed twin models well below its
    # raw-id twin at matched capacity (the sparse-phase byte cut)
    raw = ex.queue_level_bytes("alltoall_direct", p, cap, 4, density=0.5)
    comp = ex.queue_level_bytes("alltoall_direct_compressed", p, cap, 4,
                                density=0.5)
    print(f"queue/compressed-vs-raw ratio={raw / comp:.2f} (model)")
    ok &= raw / comp >= 2.0

    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
