"""Subprocess harness: 2-D edge-partitioned BFS on 4 forced host devices.

Run as: python tests/helpers/grid_bfs.py [--rows 2 --cols 2]
Exits nonzero on any mismatch.  Kept out of the normal pytest process so
the rest of the suite sees a single device (per the dry-run isolation
rule).  Checks every grid shape of 4 devices (2x2, 4x1, 1x4) against the
serial reference, the numpy 2-D phase simulation, and the 1-D engine
(bitwise), plus the r + c < p byte-model claim on the square grid.

The direction-optimizing section runs the erdos_renyi / star / chain /
rmat / small_world families in mode="auto" on the requested grid and the
degenerate 4x1 / 1x4 shapes — bitwise against the 1-D auto engine and the
numpy hybrid-schedule simulation (mode_counts included) — and forces a
queue_cap overflow to prove the dense escalation stays exact and sets the
overflowed flag.

The wire-format section runs the packed-bitset dense pipeline on the
grid: bitwise parity with the bytes path on both partition schemes, the
>= 4x dense bytes/level reduction, ``wire_format="auto"`` resolving to
packed, and packed hybrid (auto-mode) schedule parity.

The serving section runs one multi-graph ``BFSService`` with mixed 1-D
and 2-D lanes over the real device meshes behind a shared
``EngineCache`` — request parity, compile-exactly-once accounting, and
exactness across a budget-forced LRU eviction.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch import host_devices  # noqa: E402

host_devices(4)  # must precede the jax import below

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import BFSOptions, plan  # noqa: E402
from repro.core.ref import bfs_reference, bfs_reference_2d  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402
from repro.launch.mesh import make_grid_mesh  # noqa: E402


def check_grid(r, c, kind, n, sources, seed=0, fold="alltoall_reduce",
               expect_cheaper=None, **gkw):
    # the r+c < p byte win holds for the default (1-byte) fold strategy on
    # a true grid; reduce_scatter's bf16 widening gives the factor back
    if expect_cheaper is None:
        expect_cheaper = r > 1 and c > 1 and fold == "alltoall_reduce"
    p = r * c
    src, dst = generate(kind, n, seed=seed, **gkw)
    g = shard_graph(src, dst, n, p)
    want = bfs_reference(src, dst, n, sources)
    want2 = bfs_reference_2d(src, dst, n, sources, r, c)
    ok = np.array_equal(want, want2)

    mesh2 = make_grid_mesh(r, c)
    eng2 = plan(g, BFSOptions(mode="dense", fold_exchange=fold), mesh=mesh2,
                num_sources=len(sources), partition="2d").compile()
    got2 = eng2.run(sources).dist_host
    ok &= np.array_equal(got2, want)
    # second batch must not retrace
    got2b = eng2.run([s + 1 for s in sources]).dist_host
    ok &= np.array_equal(got2b, bfs_reference(src, dst, n,
                                              [s + 1 for s in sources]))
    ok &= eng2.trace_count == eng2.compile_traces

    mesh1 = Mesh(np.asarray(jax.devices()[:p]).reshape(p), ("p",))
    eng1 = plan(g, BFSOptions(mode="dense"), mesh=mesh1, axis="p",
                num_sources=len(sources)).compile()
    got1 = eng1.run(sources).dist_host
    ok &= np.array_equal(got1, got2)                       # bitwise parity

    st2 = eng2.run([sources[0]]).stats()
    st1 = eng1.run([sources[0]]).stats()
    if expect_cheaper:
        ok &= st2.comm_bytes < st1.comm_bytes              # r+c < p payoff
    print(f"{f'grid/{r}x{c}/{kind}/fold={fold}':55s} levels={st2.levels:4d} "
          f"2d_bytes={st2.comm_bytes:.2e} 1d_bytes={st1.comm_bytes:.2e} "
          f"-> {'OK' if ok else 'MISMATCH'}")
    return ok


def check_grid_auto(r, c, kind, n, source, seed=0, queue_cap=256,
                    expect_sparse=False, **gkw):
    """mode="auto" on the grid: bitwise vs serial reference, the 1-D auto
    engine, and the numpy hybrid simulation (schedule counts included)."""
    p = r * c
    src, dst = generate(kind, n, seed=seed, **gkw)
    g = shard_graph(src, dst, n, p)
    want = bfs_reference(src, dst, n, [source])
    opts = BFSOptions(mode="auto", queue_cap=queue_cap)

    mesh2 = make_grid_mesh(r, c)
    eng2 = plan(g, opts, mesh=mesh2, num_sources=1, partition="2d").compile()
    res = eng2.run([source])
    st = res.stats()
    ok = np.array_equal(res.dist_host, want)

    mesh1 = Mesh(np.asarray(jax.devices()[:p]).reshape(p), ("p",))
    eng1 = plan(g, opts, mesh=mesh1, axis="p", num_sources=1).compile()
    ok &= np.array_equal(eng1.run([source]).dist_host, res.dist_host)

    want2, sched = bfs_reference_2d(src, dst, n, [source], r, c, mode="auto",
                                    queue_cap=queue_cap,
                                    return_schedule=True)
    ok &= np.array_equal(want2, want)
    counts = {k: sum(1 for e in sched if e["kind"] == k)
              for k in ("dense", "queue", "bottom_up")}
    ok &= st.mode_counts == counts and st.levels == len(sched)
    if expect_sparse:   # narrow-frontier family must ride sparse levels
        ok &= st.mode_counts["queue"] >= 1
    ok &= eng2.trace_count == eng2.compile_traces
    print(f"{f'grid-auto/{r}x{c}/{kind}':55s} levels={st.levels:4d} "
          f"modes={st.mode_counts} bytes={st.comm_bytes:.2e} "
          f"-> {'OK' if ok else 'MISMATCH'}")
    return ok


def check_grid_queue_overflow(r, c, n=2000, seed=2, queue_cap=8):
    """Satellite: a forced queue_cap overflow on the device grid must
    escalate to the dense level bitwise-exactly and set overflowed."""
    p = r * c
    src, dst = generate("erdos_renyi", n, seed=seed, avg_degree=10)
    g = shard_graph(src, dst, n, p)
    want = bfs_reference(src, dst, n, [0])
    mesh2 = make_grid_mesh(r, c)
    eng = plan(g, BFSOptions(mode="queue", queue_cap=queue_cap), mesh=mesh2,
               num_sources=1, partition="2d").compile()
    res = eng.run([0])
    st = res.stats()
    ok = np.array_equal(res.dist_host, want) and st.overflowed
    # a roomy cap on the same graph never overflows
    eng_big = plan(g, BFSOptions(mode="queue", queue_cap=n), mesh=mesh2,
                   num_sources=1, partition="2d").compile()
    res_big = eng_big.run([0])
    ok &= np.array_equal(res_big.dist_host, want)
    ok &= not res_big.stats().overflowed
    print(f"{f'grid-queue-overflow/{r}x{c}/cap={queue_cap}':55s} "
          f"levels={st.levels:4d} ovf={st.overflowed} "
          f"-> {'OK' if ok else 'MISMATCH'}")
    return ok


def check_wire_format(r, c, n=2000, seed=5):
    """Packed-bitset wire format on the real device grid: bitwise parity
    with the bytes path and the serial reference on both partition
    schemes, >= 4x fewer dense bytes/level (modeled 8x), auto resolution
    picking packed, and auto-mode (hybrid) parity with the packed
    frontier gather on the bottom-up levels."""
    p = r * c
    src, dst = generate("erdos_renyi", n, seed=seed, avg_degree=8)
    g = shard_graph(src, dst, n, p)
    want = bfs_reference(src, dst, n, [0, 9])
    mesh2 = make_grid_mesh(r, c)
    mesh1 = Mesh(np.asarray(jax.devices()[:p]).reshape(p), ("p",))
    meshes = {"1d": (mesh1, "p"), "2d": (mesh2, None)}

    ok = True
    for kind, (mesh, axis) in meshes.items():
        k_ok = True
        per_level = {}
        for wf in ("bytes", "packed"):
            pl = plan(g, BFSOptions(mode="dense", wire_format=wf),
                      mesh=mesh, axis=axis, num_sources=2, partition=kind)
            eng = pl.compile()
            res = eng.run([0, 9])
            k_ok &= np.array_equal(res.dist_host, want)
            st = res.stats()
            per_level[wf] = st.comm_bytes / max(st.levels, 1)
            k_ok &= eng.trace_count == eng.compile_traces
        ratio = per_level["bytes"] / max(per_level["packed"], 1)
        k_ok &= ratio >= 4                     # tentpole: 8x modeled
        auto_meta = plan(g, BFSOptions(mode="dense", wire_format="auto"),
                         mesh=mesh, axis=axis, num_sources=2,
                         partition=kind).describe()
        # on a degenerate grid one 2-D phase has no peers (models 0 both
        # ways, ties keep bytes) — check the phase that does exchange
        wf_key = ("dense" if kind == "1d" else
                  "fold" if r > 1 else "expand")
        k_ok &= auto_meta["wire_formats"][wf_key] == "packed"
        ok &= k_ok
        print(f"{f'wire/{kind}/{r}x{c}':55s} "
              f"bytes={per_level['bytes']:.0f}B/level "
              f"packed={per_level['packed']:.0f}B/level ratio={ratio:.1f} "
              f"auto={auto_meta['wire_formats'][wf_key]} "
              f"-> {'OK' if k_ok else 'MISMATCH'}")

    # hybrid schedule parity under the packed wire (bottom-up gathers
    # packed words over both grid axes)
    for wf in ("bytes", "packed"):
        eng = plan(g, BFSOptions(mode="auto", wire_format=wf,
                                 queue_cap=1024), mesh=mesh2,
                   num_sources=1, partition="2d").compile()
        res = eng.run([0])
        a_ok = np.array_equal(res.dist_host[:, 0], want[:, 0])
        _, sched = bfs_reference_2d(src, dst, n, [0], r, c, mode="auto",
                                    queue_cap=1024, return_schedule=True)
        counts = {k: sum(1 for e in sched if e["kind"] == k)
                  for k in ("dense", "queue", "bottom_up")}
        a_ok &= res.stats().mode_counts == counts
        ok &= a_ok
        print(f"{f'wire/2d-auto/{r}x{c}/wire={wf}':55s} "
              f"modes={res.stats().mode_counts} "
              f"-> {'OK' if a_ok else 'MISMATCH'}")
    return ok


def check_sparse_wire(r, c, n=2000, seed=6, include_1d=True):
    """Compressed sparse-id wire + visited-sieve on the real device set:
    bitwise parity with the raw-id queue path on both partition schemes,
    the >= 2x sparse bytes/level reduction (delta+varint ids + summary
    gather vs raw int32 ids), ``wire_format="auto"``/``sieve="auto"``
    resolving to compressed+sieve at p=4, and a forced queue_cap
    overflow staying exact under the compressed wire."""
    p = r * c
    src, dst = generate("erdos_renyi", n, seed=seed, avg_degree=8)
    g = shard_graph(src, dst, n, p)
    want = bfs_reference(src, dst, n, [0])
    mesh2 = make_grid_mesh(r, c)
    mesh1 = Mesh(np.asarray(jax.devices()[:p]).reshape(p), ("p",))
    meshes = {"2d": (mesh2, None)}
    if include_1d:
        meshes["1d"] = (mesh1, "p")

    ok = True
    for kind, (mesh, axis) in sorted(meshes.items()):
        k_ok = True
        per_level, hits = {}, {}
        for wf, sv in (("bytes", False), ("compressed", True)):
            eng = plan(g, BFSOptions(mode="queue", wire_format=wf,
                                     sieve=sv, queue_cap=1024),
                       mesh=mesh, axis=axis, num_sources=1,
                       partition=kind).compile()
            res = eng.run([0])
            k_ok &= np.array_equal(res.dist_host[:, 0], want[:, 0])
            st = res.stats()
            per_level[wf] = st.comm_bytes / max(st.levels, 1)
            hits[wf] = st.sieve_hits
            k_ok &= eng.trace_count == eng.compile_traces
        ratio = per_level["bytes"] / max(per_level["compressed"], 1)
        k_ok &= ratio >= 2                 # tentpole: sparse bytes halve
        k_ok &= hits["compressed"] > 0     # the sieve actually dropped ids
        auto_meta = plan(g, BFSOptions(mode="auto", wire_format="auto",
                                       sieve="auto", queue_cap=1024),
                         mesh=mesh, axis=axis, num_sources=1,
                         partition=kind).describe()
        # a degenerate grid's peerless sparse phase models 0 bytes both
        # ways (tie keeps ids) — check the phase that does exchange
        wf_key = ("queue" if kind == "1d" else
                  "fold_sparse" if r > 1 else "expand_sparse")
        k_ok &= auto_meta["wire_formats"][wf_key] == "compressed"
        k_ok &= auto_meta["sieve"] is True
        ok &= k_ok
        print(f"{f'sparse-wire/{kind}/{r}x{c}':55s} "
              f"ids={per_level['bytes']:.0f}B/level "
              f"comp={per_level['compressed']:.0f}B/level ratio={ratio:.1f} "
              f"sieve_hits={hits['compressed']} "
              f"auto={auto_meta['wire_formats'][wf_key]} "
              f"-> {'OK' if k_ok else 'MISMATCH'}")

    # forced overflow under the compressed wire: the dense escalation
    # must stay bitwise exact and flag overflowed
    eng = plan(g, BFSOptions(mode="queue", wire_format="compressed",
                             sieve=True, queue_cap=8), mesh=mesh2,
               num_sources=1, partition="2d").compile()
    res = eng.run([0])
    o_ok = np.array_equal(res.dist_host[:, 0], want[:, 0])
    o_ok &= res.stats().overflowed
    ok &= o_ok
    print(f"{f'sparse-wire/overflow/{r}x{c}/cap=8':55s} "
          f"ovf={res.stats().overflowed} "
          f"-> {'OK' if o_ok else 'MISMATCH'}")
    return ok


def check_multi_graph_serving(r, c, n=2000, seed=1):
    """Multi-tenant serving over real device meshes: one ``BFSService``
    with mixed 1-D (all-p row) and 2-D (r x c grid) lanes behind a
    byte-budgeted shared ``EngineCache``.  Checks request-level parity
    against the serial reference, compile-exactly-once accounting while
    under budget, and exactness across a forced LRU eviction/recompile.
    """
    from repro.core import BFSOptions as _Opts
    from repro.serve.bfs_service import BFSService, TraversalRequest
    from repro.serve.engine_cache import EngineCache

    p = r * c
    mesh1 = Mesh(np.asarray(jax.devices()[:p]).reshape(p), ("p",))
    families = (("erdos_renyi", dict(avg_degree=8)), ("star", {}),
                ("chain", {}), ("rmat", dict(edge_factor=8)))
    data = {}
    cache = EngineCache()
    svc = BFSService(opts=_Opts(mode="dense"), mesh=mesh1, axis="p",
                     batch_slots=2, cache=cache)
    for i, (kind, kw) in enumerate(families):
        src, dst = generate(kind, n, seed=seed + i, **kw)
        g = shard_graph(src, dst, n, p)
        data[kind] = (src, dst)
        if i % 2:                  # alternate partition schemes per lane
            svc.add_graph(kind, g, mesh=make_grid_mesh(r, c),
                          partition="2d")
        else:
            svc.add_graph(kind, g)

    ok = True
    for rnd in range(2):
        reqs = [TraversalRequest(rid=rnd * 100 + i * 10 + j,
                                 source=(13 * j + i + rnd) % n, graph=kind)
                for i, kind in enumerate(data) for j in range(3)]
        for q in reqs:
            svc.submit(q)
        done = svc.run_until_drained()
        ok &= len(done) == len(reqs)
        for q in done:
            src, dst = data[q.graph]
            want = bfs_reference(src, dst, n, [q.source])[:, 0]
            ok &= np.array_equal(q.dist, want)
    st = cache.stats()
    ok &= st["misses"] == len(data)            # one compile per lane plan
    ok &= st["evictions"] == 0
    for kind in data:
        eng = cache.get(svc.lane(kind).plan)
        ok &= eng.trace_count == eng.compile_traces
    print(f"{f'serving/multi-graph/{r}x{c}+1d':55s} lanes={len(data)} "
          f"hits={st['hits']} misses={st['misses']} "
          f"-> {'OK' if ok else 'MISMATCH'}")

    # under a budget that holds ~1.5 engines the round-robin working set
    # must evict and transparently recompile, staying exact
    unit = svc.lane("erdos_renyi").plan.estimated_device_bytes()
    cache_small = EngineCache(max_device_bytes=int(1.5 * unit))
    svc2 = BFSService(opts=_Opts(mode="dense"), mesh=mesh1, axis="p",
                      batch_slots=2, cache=cache_small)
    for kind in ("erdos_renyi", "star", "chain"):
        svc2.add_graph(kind, svc.catalog.get(kind))
    ok2 = True
    for rnd in range(2):
        for i, kind in enumerate(("erdos_renyi", "star", "chain")):
            svc2.submit(TraversalRequest(rid=rnd * 10 + i,
                                         source=rnd + i, graph=kind))
        for q in svc2.run_until_drained():
            src, dst = data[q.graph]
            want = bfs_reference(src, dst, n, [q.source])[:, 0]
            ok2 &= np.array_equal(q.dist, want)
    st2 = cache_small.stats()
    ok2 &= st2["evictions"] >= 1 and st2["misses"] > 3
    ok2 &= st2["device_bytes"] <= cache_small.max_device_bytes
    print(f"{f'serving/eviction-budget/{r}x{c}':55s} "
          f"evictions={st2['evictions']} misses={st2['misses']} "
          f"-> {'OK' if ok2 else 'MISMATCH'}")
    return ok and ok2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=2)
    ap.add_argument("--cols", type=int, default=2)
    args = ap.parse_args()
    assert len(jax.devices()) == 4, jax.devices()

    ok = True
    n = 2000
    # requested grid (CI passes rows=2 cols=2) on the three paper shapes
    for kind, kw in (("erdos_renyi", dict(avg_degree=8)), ("star", {}),
                     ("chain", {})):
        ok &= check_grid(args.rows, args.cols, kind, n, [0, 17], seed=1, **kw)
    # ROADMAP coverage: rmat + small-world through the grid harness
    ok &= check_grid(args.rows, args.cols, "rmat", n, [0, 9], seed=1,
                     edge_factor=8)
    ok &= check_grid(args.rows, args.cols, "small_world", n, [0, 9], seed=1,
                     k=6, beta=0.1)
    # degenerate grids: fold-only (4x1) and expand-only (1x4) columns/rows
    ok &= check_grid(4, 1, "erdos_renyi", n, [0], seed=2, avg_degree=8)
    ok &= check_grid(1, 4, "erdos_renyi", n, [0], seed=2, avg_degree=8)
    # alternative fold strategy end-to-end
    ok &= check_grid(args.rows, args.cols, "erdos_renyi", n, [5], seed=3,
                     fold="reduce_scatter", avg_degree=8)

    # direction-optimizing hybrid on the grid (acceptance: bitwise parity
    # over 2x2 / 4x1 / 1x4 with per-level mode switching)
    for kind, nk, kw in (("erdos_renyi", n, dict(avg_degree=8)),
                         ("star", n, {}),
                         ("chain", 600, dict(expect_sparse=True)),
                         ("rmat", n, dict(edge_factor=8)),
                         ("small_world", n, dict(k=6, beta=0.1))):
        ok &= check_grid_auto(args.rows, args.cols, kind, nk, 0, seed=1, **kw)
    for r, c in ((4, 1), (1, 4)):
        ok &= check_grid_auto(r, c, "erdos_renyi", n, 0, seed=2,
                              avg_degree=8)
        ok &= check_grid_auto(r, c, "chain", 600, 0, seed=2,
                              expect_sparse=True)
    # queue overflow -> dense escalation on the real device grid
    ok &= check_grid_queue_overflow(args.rows, args.cols)
    # packed-bitset wire format: parity + >= 4x dense-byte reduction +
    # auto resolution, 1-D and 2-D, alongside the bytes-path runs above
    ok &= check_wire_format(args.rows, args.cols)
    # compressed sparse-id wire + visited-sieve: parity, >= 2x sparse
    # byte reduction, auto resolution, overflow escalation (2x2 + 4x1)
    ok &= check_sparse_wire(args.rows, args.cols)
    ok &= check_sparse_wire(4, 1, include_1d=False)
    # multi-tenant serving: mixed 1-D/2-D lanes, shared engine cache,
    # compile-once accounting + budget-forced eviction recovery
    ok &= check_multi_graph_serving(args.rows, args.cols)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
