"""Subprocess harness: owner-exchange GraphCast == GSPMD/global reference.

Builds a random graph, runs the plain (global-arrays) graphcast forward
loss and the owner-exchange shard_map version on 8 devices with identical
params, and checks the losses agree to fp32 tolerance.  Also verifies the
routing tables cover every edge exactly once.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch import host_devices  # noqa: E402

host_devices(8)  # must precede the jax import below

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs.base import GNNConfig  # noqa: E402
from repro.graphs.generators import erdos_renyi  # noqa: E402
from repro.models.gnn import dist_graphcast as dg  # noqa: E402
from repro.models.gnn import models as gnn  # noqa: E402


def main():
    p = 8
    n = 512
    cfg = GNNConfig(name="gc-test", kind="graphcast", n_layers=3,
                    d_hidden=32, aggregator="sum", n_vars=5, d_out=5)
    src, dst = erdos_renyi(n, avg_degree=6, seed=3)
    rng = np.random.default_rng(0)
    d_feat = 16
    feats = rng.standard_normal((n, d_feat)).astype(np.float32)
    targets = rng.standard_normal((n, cfg.d_out)).astype(np.float32)

    params = gnn.init_params(cfg, d_feat, jax.random.PRNGKey(1))

    # ---- reference: global arrays, same padding conventions
    e_pad = -(-src.shape[0] // 64) * 64
    es = np.zeros(e_pad, np.int32)
    ed = np.full(e_pad, -1, np.int32)
    es[:src.shape[0]] = src
    ed[:dst.shape[0]] = dst
    ref_batch = {
        "node_feats": jnp.asarray(feats),
        "edge_src": jnp.asarray(es), "edge_dst": jnp.asarray(ed),
        "edge_feats": jnp.ones((e_pad, 4), jnp.float32),
        "valid_nodes": jnp.ones((n,), bool),
        "targets": jnp.asarray(targets),
    }
    ref_loss, _ = gnn.loss_fn(cfg, params, ref_batch)

    # ---- owner-exchange version
    routing = dg.build_routing(src, dst, n, p)
    part = routing["part"]
    n_pad = part.n
    feats_p = part.pad_vertex_array(feats)
    targets_p = part.pad_vertex_array(targets)
    valid = np.arange(n_pad) < n
    batch = {
        "node_feats": jnp.asarray(feats_p),
        "edge_feats": jnp.ones((p * routing["e_cap"], 4), jnp.float32),
        "serve_ids": jnp.asarray(routing["serve_ids"]),
        "src_slot": jnp.asarray(routing["src_slot"]),
        "dst_local": jnp.asarray(routing["dst_local"]),
        "valid_nodes": jnp.asarray(valid),
        "targets": jnp.asarray(targets_p),
    }
    # routing sanity: every edge appears once
    n_routed = int((routing["dst_local"] >= 0).sum())
    assert n_routed == src.shape[0], (n_routed, src.shape[0])

    mesh = Mesh(np.asarray(jax.devices()).reshape(p), ("p",))
    loss_fn = dg.make_loss_fn(cfg, mesh, "p")
    with mesh:
        own_loss, _ = jax.jit(loss_fn)(params, batch)

    ok = np.isclose(float(ref_loss), float(own_loss), rtol=2e-5, atol=2e-5)
    print(f"reference loss={float(ref_loss):.6f} "
          f"owner-exchange loss={float(own_loss):.6f} -> "
          f"{'OK' if ok else 'MISMATCH'}")

    # gradient agreement on a couple of leaves
    g_ref = jax.grad(lambda pr: gnn.loss_fn(cfg, pr, ref_batch)[0])(params)
    with mesh:
        g_own = jax.jit(jax.grad(
            lambda pr: loss_fn(pr, batch)[0]))(params)
    for key in ("enc_h", "dec"):
        a = np.asarray(jax.tree.leaves(g_ref[key])[0])
        b = np.asarray(jax.tree.leaves(g_own[key])[0])
        if not np.allclose(a, b, rtol=5e-4, atol=5e-5):
            print(f"grad mismatch on {key}: {np.abs(a-b).max()}")
            ok = False
    print("grads OK" if ok else "grads MISMATCH")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
