"""Subprocess harness: the HLO plan auditor on good and known-bad plans.

Compiles on 4 forced host devices and checks that

  * a healthy auto-mode plan audits clean (including the two-run
    retrace check), and
  * a deliberately mis-registered queue exchange — the real
    ``alltoall_direct`` impl under a byte model lying 100x low — fails
    the audit with exactly the byte-accounting rule (HA003), and
  * the lie is confined to the report: the traversal itself still
    reaches every vertex.

Exits nonzero on any deviation.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch import host_devices  # noqa: E402

host_devices(4)  # must precede the jax import below

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.analysis import hlo_audit  # noqa: E402
from repro.core import BFSOptions, plan  # noqa: E402
from repro.core import exchange as ex  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402


def main():
    p = 4
    n = 2048
    src, dst = generate("erdos_renyi", n, seed=0)
    g = shard_graph(src, dst, n, p)
    mesh = Mesh(np.asarray(jax.devices()).reshape(p), ("p",))
    ok = True

    # -------- good path: auto plan audits clean, including retrace ----
    engine = plan(g, BFSOptions(mode="auto", wire_format="auto"),
                  mesh=mesh, axis="p").compile()
    rep = hlo_audit.audit_engine(engine, run_check=True)
    print("GOOD", rep.summary())
    for v in rep.violations:
        print("  ", v)
    ok &= rep.ok()

    # -------- known-bad: byte model lies 100x low --------------------
    # Register AFTER the good compile so "auto" selection above cannot
    # pick the liar (it would: it prices cheapest by construction).
    real = ex.get_exchange("queue", "alltoall_direct")
    ex.register_exchange(
        "queue", "alltoall_bad",
        lambda p_, cap, itemsize, density=1.0:
            real.bytes_model(p_, cap, itemsize, density) / 100.0,
    )(real.impl)
    try:
        bad = plan(g, BFSOptions(mode="queue", queue_exchange="alltoall_bad",
                                 wire_format="bytes"),
                   mesh=mesh, axis="p").compile()
        rep_bad = hlo_audit.audit_engine(bad)
        print("BAD ", rep_bad.summary())
        for v in rep_bad.violations:
            print("  ", v)
        ok &= not rep_bad.ok()
        ok &= "HA003" in rep_bad.rules()
        # the audit failure is a pricing lie, not a correctness bug
        res = bad.run([0])
        ok &= int(res.stats().visited) == n
    finally:
        ex.unregister_exchange("queue", "alltoall_bad")

    print("OK" if ok else "MISMATCH")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
