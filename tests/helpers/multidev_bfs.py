"""Subprocess harness: distributed BFS correctness on 8 forced host devices.

Run as: python tests/helpers/multidev_bfs.py
Exits nonzero on any mismatch. Kept out of the normal pytest process so the
rest of the suite sees a single device (per the dry-run isolation rule).
"""

import os
import sys
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.launch import host_devices  # noqa: E402

host_devices(8)  # must precede the jax import below

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import BFSOptions, bfs, plan  # noqa: E402
from repro.core.ref import bfs_reference  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402

warnings.simplefilter("ignore", DeprecationWarning)  # bfs() legacy matrix


def check(name, graph_kind, n, opts, sources, mesh, axis, seed=0, **gkw):
    src, dst = generate(graph_kind, n, seed=seed, **gkw)
    p = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    g = shard_graph(src, dst, n, p)
    want = bfs_reference(src, dst, n, sources)
    got, stats = bfs(g, sources, mesh=mesh, axis=axis, opts=opts)
    ok = np.array_equal(got, want)
    frac = float((got == want).mean())
    print(f"{name:55s} levels={stats.levels:3d} visited={stats.visited:6d} "
          f"bytes={stats.comm_bytes:.2e} modes={stats.mode_counts} "
          f"ovf={stats.overflowed} -> {'OK' if ok else f'MISMATCH ({frac:.4f})'}")
    return ok


def main():
    devs = jax.devices()
    assert len(devs) == 8, devs
    mesh2d = Mesh(np.asarray(devs).reshape(2, 4), ("data", "model"))
    mesh1d = Mesh(np.asarray(devs).reshape(8), ("p",))

    ok = True
    n = 3000
    srcs = [0]
    # every dense strategy, flat and tuple axes, both wire formats (the
    # default wire_format="auto" resolves to the packed twin on a real
    # mesh; "bytes" pins the uint8-mask path so both stay covered)
    for strat in ("allgather_merge", "alltoall_direct", "reduce_scatter",
                  "hierarchical"):
        for wf in ("bytes", "auto"):
            o = BFSOptions(mode="dense", dense_exchange=strat,
                           wire_format=wf)
            ok &= check(f"dense/{strat}/wire={wf}/er/1d", "erdos_renyi", n,
                        o, srcs, mesh1d, "p", avg_degree=8)
            ok &= check(f"dense/{strat}/wire={wf}/er/2d-tuple",
                        "erdos_renyi", n, o, srcs, mesh2d,
                        ("data", "model"), avg_degree=8)
    # batched multi-source dense
    o = BFSOptions(mode="dense")
    ok &= check("dense/multi-source(S=5)/smallworld", "small_world", n, o,
                [0, 7, 123, 999, 2500], mesh1d, "p", k=6, beta=0.1)
    # queue strategies, with/without paper opts
    for strat in ("allgather_merge", "alltoall_direct"):
        for lu in (False, True):
            o = BFSOptions(mode="queue", queue_exchange=strat,
                           local_update=lu, dedupe=lu, queue_cap=2048)
            ok &= check(f"queue/{strat}/lu={int(lu)}/er", "erdos_renyi", n, o,
                        srcs, mesh1d, "p", avg_degree=8)
    # queue overflow -> dense fallback still exact
    o = BFSOptions(mode="queue", queue_cap=8)
    ok &= check("queue/overflow-fallback/er", "erdos_renyi", 1500, o, srcs,
                mesh1d, "p", avg_degree=10)
    # star graph (worst-case imbalance), queue + dense
    ok &= check("dense/star", "star", 2048, BFSOptions(mode="dense"), srcs,
                mesh2d, ("data", "model"))
    ok &= check("queue/star", "star", 2048,
                BFSOptions(mode="queue", queue_cap=4096), srcs, mesh1d, "p")
    # auto (direction-optimizing) on all three paper graph families, with
    # the bottom-up levels riding both frontier-gather wire formats
    for kind, kw in (("erdos_renyi", dict(avg_degree=8)),
                     ("small_world", dict(k=6, beta=0.05)), ("star", {})):
        for wf in ("bytes", "packed"):
            o = BFSOptions(mode="auto", queue_cap=4096, wire_format=wf)
            ok &= check(f"auto/{kind}/wire={wf}", kind, n, o, srcs, mesh2d,
                        ("data", "model"), **kw)
    # rmat (scale-free, like the social graphs of paper §1)
    ok &= check("auto/rmat", "rmat", 2048, BFSOptions(mode="auto", queue_cap=8192),
                srcs, mesh1d, "p", edge_factor=8)
    # disconnected graph: unreachable stay INF
    src, dst = generate("erdos_renyi", 600, seed=3, avg_degree=2)
    g = shard_graph(src, dst, 600, 8)
    want = bfs_reference(src, dst, 600, [0])
    got, _ = bfs(g, [0], mesh=mesh1d, axis="p", opts=BFSOptions(mode="dense"))
    ok &= np.array_equal(got, want)
    print(f"{'dense/disconnected-INF':55s} -> {'OK' if np.array_equal(got, want) else 'MISMATCH'}")

    # compile-once engine on 8 shards: two source batches, zero retraces
    src, dst = generate("erdos_renyi", n, seed=1, avg_degree=8)
    g = shard_graph(src, dst, n, 8)
    eng = plan(g, BFSOptions(mode="dense"), mesh=mesh1d, axis="p",
               num_sources=3).compile()
    e_ok = True
    for batch in ([0, 7, 123], [999, 2500, 5]):
        got = eng.run(batch).dist_host
        e_ok &= np.array_equal(got, bfs_reference(src, dst, n, batch))
    e_ok &= eng.trace_count == eng.compile_traces
    ok &= e_ok
    print(f"{'engine/8shard-reuse-no-retrace':55s} -> "
          f"{'OK' if e_ok else 'MISMATCH'}")

    # packed wire must be bitwise-equal to bytes AND >= 4x cheaper on the
    # dense levels (the tentpole claim on a real 8-device mesh)
    per_level = {}
    for wf in ("bytes", "packed"):
        e = plan(g, BFSOptions(mode="dense", wire_format=wf), mesh=mesh1d,
                 axis="p", num_sources=1).compile()
        r = e.run([0])
        w_ok = np.array_equal(r.dist_host,
                              bfs_reference(src, dst, n, [0]))
        st = r.stats()
        per_level[wf] = st.comm_bytes / max(st.levels, 1)
        ok &= w_ok
    w_ok = per_level["bytes"] / max(per_level["packed"], 1) >= 4
    ok &= w_ok
    print(f"{'dense/wire-reduction-8shard':55s} "
          f"bytes={per_level['bytes']:.0f}B/level "
          f"packed={per_level['packed']:.0f}B/level -> "
          f"{'OK' if w_ok else 'MISMATCH'}")

    # Pallas bsr_spmm expansion per shard inside the 8-device loop (the
    # lifted single-shard restriction), on both wire formats: the packed
    # run consumes kernel-emitted candidate words directly
    nk = 1024
    srck, dstk = generate("erdos_renyi", nk, seed=4, avg_degree=6)
    gk = shard_graph(srck, dstk, nk, 8)
    wantk = bfs_reference(srck, dstk, nk, [0, 17])
    for wf in ("bytes", "packed"):
        e = plan(gk, BFSOptions(mode="dense", use_kernel=True,
                                wire_format=wf), mesh=mesh1d, axis="p",
                 num_sources=2).compile()
        got = e.run([0, 17]).dist_host
        k_ok = (np.array_equal(got, wantk)
                and e.trace_count == e.compile_traces)
        ok &= k_ok
        print(f"{f'kernel/8shard/wire={wf}':55s} -> "
              f"{'OK' if k_ok else 'MISMATCH'}")

    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
