"""Per-architecture smoke tests: reduced config, one real step on CPU,
output-shape + no-NaN asserts.  One test per (arch x representative shape
mode); full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.launch.steps import build_bundle

KEY = jax.random.PRNGKey(0)

LM_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "gnn"]


def _finite(tree):
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all()), "non-finite leaf"


def _run_train(arch_id, shape_name, n_steps=2):
    spec = get_arch(arch_id)
    b = build_bundle(spec, shape_name, reduced=True)
    params = b.init_params(KEY)
    state = b.make_state(params)
    step = jax.jit(b.fn)
    batch = b.make_batch(0)
    losses = []
    for i in range(n_steps):
        state, metrics = step(state, b.make_batch(i))
        losses.append(float(metrics["loss"]))
    _finite(state["params"])
    assert all(np.isfinite(l) for l in losses), losses
    return losses, state


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_train_smoke(arch_id):
    losses, state = _run_train(arch_id, "train_4k")
    # with a 256-token vocab, initial CE should be near log(256)
    assert losses[0] < 3 * np.log(256)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_decode_smoke(arch_id):
    spec = get_arch(arch_id)
    b = build_bundle(spec, "decode_32k", reduced=True)
    params = b.init_params(KEY)
    batch = b.make_batch(0)
    logits, cache = jax.jit(b.fn)(params, batch)
    assert logits.shape == (b.shape.global_batch, b.cfg.vocab)
    _finite(logits)
    # greedy-decode two more tokens through the updated cache
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = jax.jit(b.fn)(params, {"cache": cache,
                                        "pos": batch["pos"],
                                        "last_token": tok})
    _finite(logits2)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_prefill_smoke(arch_id):
    spec = get_arch(arch_id)
    b = build_bundle(spec, "prefill_32k", reduced=True)
    params = b.init_params(KEY)
    logits, cache = jax.jit(b.fn)(params, b.make_batch(0))
    assert logits.shape == (b.shape.global_batch, b.cfg.vocab)
    _finite(logits)
    _finite(cache)


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
@pytest.mark.parametrize("shape_name", ["full_graph_sm", "minibatch_lg",
                                        "molecule"])
def test_gnn_train_smoke(arch_id, shape_name):
    losses, _ = _run_train(arch_id, shape_name)
    assert losses[-1] <= losses[0] * 10  # sane scale, no blow-up


def test_recsys_train_smoke():
    losses, _ = _run_train("deepfm", "train_batch", n_steps=3)
    assert losses[0] < 5.0  # BCE near log(2) at init
    assert losses[-1] < losses[0] + 1.0


def test_recsys_serve_and_retrieval_smoke():
    spec = get_arch("deepfm")
    for shape in ("serve_p99", "retrieval_cand"):
        b = build_bundle(spec, shape, reduced=True)
        params = b.init_params(KEY)
        out = jax.jit(b.fn)(params, b.make_batch(0))
        _finite(out)
        if shape == "serve_p99":
            assert out.shape == (b.shape.batch,)
            assert bool(((out >= 0) & (out <= 1)).all())
        else:
            assert out.shape == (b.shape.n_candidates,)


def test_lm_train_loss_decreases():
    """A few more steps on the smallest arch: loss must actually fall."""
    spec = get_arch("gemma3_12b")
    b = build_bundle(spec, "train_4k", reduced=True)
    params = b.init_params(KEY)
    state = b.make_state(params)
    step = jax.jit(b.fn)
    batch = b.make_batch(0)  # fixed batch -> should overfit fast
    first = last = None
    for i in range(8):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first, (first, last)


def test_moe_dispatch_balance_counts():
    """MoE: every kept assignment lands in the right expert bucket."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import capacity, init_moe_params, moe_apply
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32)
    p = init_moe_params(KEY, 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    out, aux = moe_apply(p, x, cfg)
    assert out.shape == x.shape
    _finite(out)
    assert float(aux["lb_loss"]) > 0.5  # ~1.0 when balanced
    assert int(aux["dropped"]) <= 64 * 2  # sanity


def test_moe_identity_when_experts_equal():
    """If all experts share weights, MoE == dense SwiGLU of that expert
    (gates sum to 1), a strong correctness property of dispatch+combine."""
    from repro.configs.base import MoEConfig
    from repro.layers.core import swiglu
    from repro.models.moe import init_moe_params, moe_apply
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=4.0)
    p = init_moe_params(KEY, 16, cfg, jnp.float32)
    for nm in ("w_gate", "w_up", "w_down"):
        p[nm] = jnp.broadcast_to(p[nm][:1], p[nm].shape)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    out, aux = moe_apply(p, x, cfg)
    want = swiglu(x, p["w_gate"][0], p["w_up"][0], p["w_down"][0])
    assert int(aux["dropped"]) == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
