"""Training substrate: checkpoint/restart, fault injection, compression,
elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.launch.steps import build_bundle
from repro.optim.adamw import AdamWConfig
from repro.train import compress as comp
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import (repartition_graph, repartition_vertex_array,
                                 reshard_state)
from repro.train.trainer import Trainer, TrainerConfig, make_compressed_train_step

KEY = jax.random.PRNGKey(0)


def _state_tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.zeros(4, jnp.bfloat16)},
            "opt": {"step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state_tree()
    mgr.save(10, st)
    restored, step = mgr.restore(st)
    assert step == 10
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        assert str(np.asarray(a).dtype) == str(b.dtype)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state_tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    st = _state_tree()
    mgr.save(5, st)
    mgr.wait()
    _, step = mgr.restore(st)
    assert step == 5


def test_checkpoint_atomicity_partial_write(tmp_path):
    """A stray .tmp dir (simulated crash mid-save) must be invisible."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    st = _state_tree()
    mgr.save(1, st)
    os.makedirs(os.path.join(str(tmp_path), "step_2.tmp"))
    assert mgr.latest_step() == 1


def test_trainer_runs_and_checkpoints(tmp_path):
    spec = get_arch("gcn_cora")
    b = build_bundle(spec, "full_graph_sm", reduced=True)
    t = Trainer(b, TrainerConfig(num_steps=6, ckpt_every=2, log_every=2,
                                 ckpt_dir=str(tmp_path)))
    state = t.run()
    assert t.mgr.latest_step() == 6
    losses = [m["loss"] for m in t.metrics_log if "loss" in m]
    assert losses and all(np.isfinite(l) for l in losses)


def test_trainer_survives_injected_fault(tmp_path):
    """Crash at step 4 -> trainer restores from checkpoint and completes,
    and the post-restart batches replay deterministically."""
    spec = get_arch("gcn_cora")
    b = build_bundle(spec, "full_graph_sm", reduced=True)
    crashed = {"done": False}

    def fault(step):
        if step == 4 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    t = Trainer(b, TrainerConfig(num_steps=8, ckpt_every=2, log_every=1,
                                 ckpt_dir=str(tmp_path)), fault_hook=fault)
    t.run()
    events = [m for m in t.metrics_log if m.get("event") == "restart"]
    assert len(events) == 1 and events[0]["restored_step"] <= 4
    assert t.mgr.latest_step() == 8

    # a clean run must reach the same final loss (deterministic replay)
    t2 = Trainer(b, TrainerConfig(num_steps=8, ckpt_every=2, log_every=1,
                                  ckpt_dir=str(tmp_path) + "_clean"))
    t2.run()
    last = [m["loss"] for m in t.metrics_log if "loss" in m][-1]
    last2 = [m["loss"] for m in t2.metrics_log if "loss" in m][-1]
    assert np.isclose(last, last2, rtol=1e-5), (last, last2)


def test_trainer_resume_from_checkpoint(tmp_path):
    spec = get_arch("gcn_cora")
    b = build_bundle(spec, "full_graph_sm", reduced=True)
    t1 = Trainer(b, TrainerConfig(num_steps=4, ckpt_every=2,
                                  ckpt_dir=str(tmp_path)))
    t1.run()
    t2 = Trainer(b, TrainerConfig(num_steps=8, ckpt_every=2,
                                  ckpt_dir=str(tmp_path)))
    t2.run(resume=True)
    assert t2.mgr.latest_step() == 8


# ------------------------------------------------------------ compression
def test_compress_bf16_roundtrip_close():
    g = {"a": jnp.linspace(-3, 3, 1000, dtype=jnp.float32)}
    cg = comp.compress_bf16(g)
    np.testing.assert_allclose(np.asarray(cg["a"]), np.asarray(g["a"]),
                               rtol=2e-2, atol=2e-2)


def test_compress_topk_error_feedback_conserves_mass():
    g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal(256),
                          jnp.float32)}
    ef = comp.init_error_feedback(g)
    sent, ef2 = comp.compress_topk(g, ef, k_frac=0.25)
    # sent + residual == original
    np.testing.assert_allclose(
        np.asarray(sent["a"], np.float32) + np.asarray(ef2["a"]),
        np.asarray(g["a"]), rtol=1e-6, atol=1e-6)
    nz = int((np.asarray(sent["a"]) != 0).sum())
    assert nz <= 0.3 * 256


def test_compressed_training_still_converges():
    """topk-compressed steps must still fit a tiny regression problem."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    w_true = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
    y = x @ w_true

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {}

    opt = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0)
    for method in ("none", "bf16", "topk"):
        make_state, step = make_compressed_train_step(loss_fn, opt, method,
                                                      k_frac=0.25)
        state = make_state({"w": jnp.zeros(8, jnp.float32)})
        jstep = jax.jit(step)
        first = last = None
        for i in range(60):
            state, m = jstep(state, {"x": x, "y": y})
            if first is None:
                first = float(m["loss"])
            last = float(m["loss"])
        assert last < first * 0.2, (method, first, last)


def test_wire_bytes_model():
    g = {"a": jnp.zeros((1000,), jnp.float32)}
    assert comp.wire_bytes(g, "none") == 4000
    assert comp.wire_bytes(g, "bf16") == 2000
    assert comp.wire_bytes(g, "topk", 1 / 10) == 800


# ---------------------------------------------------------------- elastic
def test_elastic_repartition_graph_preserves_bfs():
    from repro.core import BFSOptions, bfs
    from repro.core.ref import bfs_reference
    from repro.graphs import generate, shard_graph
    n = 600
    src, dst = generate("erdos_renyi", n, seed=5, avg_degree=6)
    g4 = shard_graph(src, dst, n, 4)
    g2 = repartition_graph(g4, 2)
    assert g2.p == 2 and g2.n_edges == g4.n_edges
    want = bfs_reference(src, dst, n, [0])
    # run on 1 device with p=1 derived again (engine-level check)
    g1 = repartition_graph(g4, 1)
    got, _ = bfs(g1, [0], opts=BFSOptions(mode="dense"))
    np.testing.assert_array_equal(got, want)


def test_elastic_vertex_array_roundtrip():
    from repro.core.partition import Partition1D
    old, new = Partition1D(100, 8), Partition1D(100, 3)
    x = np.arange(old.n, dtype=np.float32)
    y = repartition_vertex_array(x, old, new)
    assert y.shape[0] == new.n
    np.testing.assert_array_equal(y[:100], x[:100])


def test_elastic_reshard_state_identity():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1)
    state = _state_tree()
    specs = jax.tree.map(lambda x: P(*([None] * np.ndim(x))), state)
    out = reshard_state(state, mesh, specs)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
