"""Packed-bitset wire format: pack/unpack boundary behavior, padding-bit
containment across OR merges, plan-time packed-vs-bytes resolution, and
single-device engine parity of every wire format (multi-device parity
lives in tests/helpers/multidev_bfs.py and grid_bfs.py)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (BFSOptions, plan, register_exchange,
                        unregister_exchange)
from repro.core import exchange as ex
from repro.core import frontier as fr
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph


def _pack_ref(mask: np.ndarray, n_blocks: int = 1) -> np.ndarray:
    """Independent numpy word packer (LSB-first within each 32-bit word,
    blocked per segment) — no shared code with frontier.pack_bits."""
    total, s = mask.shape
    m = total // n_blocks
    w = -(-m // 32)
    out = np.zeros((n_blocks * w, s), np.uint32)
    for b in range(n_blocks):
        for i in range(m):
            out[b * w + i // 32] |= (
                (mask[b * m + i] > 0).astype(np.uint32) << np.uint32(i % 32))
    return out


# ---------------------------------------------------------------------------
# pack/unpack boundaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n_blocks,s", [
    (1, 1, 1),      # single bit
    (31, 1, 2),     # just below one word
    (32, 1, 1),     # exactly one word
    (33, 1, 1),     # one bit into the second word
    (5, 4, 2),      # n < 32 per block, multiple blocks
    (500, 4, 1),    # the 2000/4 shard size of the grid harness
    (96, 3, 3),     # word-aligned blocks
])
def test_pack_unpack_roundtrip_and_word_layout(m, n_blocks, s):
    rng = np.random.default_rng(m * 1000 + n_blocks)
    mask = (rng.random((m * n_blocks, s)) < 0.4).astype(np.uint8)
    words = np.asarray(fr.pack_bits(jnp.asarray(mask), n_blocks=n_blocks))
    assert words.shape == (n_blocks * fr.packed_words(m), s)
    assert words.dtype == np.uint32
    np.testing.assert_array_equal(words, _pack_ref(mask, n_blocks))
    back = np.asarray(fr.unpack_bits(jnp.asarray(words), m,
                                     n_blocks=n_blocks))
    np.testing.assert_array_equal(back, mask)


def test_pack_unpack_property_random_shapes():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(m=st.integers(1, 200), n_blocks=st.integers(1, 6),
           s=st.integers(1, 3), seed=st.integers(0, 2 ** 16))
    def prop(m, n_blocks, s, seed):
        rng = np.random.default_rng(seed)
        mask = (rng.random((m * n_blocks, s)) < 0.3).astype(np.uint8)
        words = fr.pack_bits(jnp.asarray(mask), n_blocks=n_blocks)
        back = np.asarray(fr.unpack_bits(words, m, n_blocks=n_blocks))
        assert np.array_equal(back, mask)
        assert np.array_equal(np.asarray(words), _pack_ref(mask, n_blocks))

    prop()


def test_padding_bits_never_leak_into_merge():
    """The padding-id word at the last shard boundary: a full-ones mask
    leaves the pad bits of each block's last word zero, an OR merge of
    such words cannot invent them, and unpack drops even *forged* pad
    bits — so a phantom candidate can never surface past the exchange."""
    m, n_blocks, s = 37, 3, 2                   # 37 % 32 = 5 pad-heavy words
    w = fr.packed_words(m)
    ones = np.ones((m * n_blocks, s), np.uint8)
    words = np.asarray(fr.pack_bits(jnp.asarray(ones), n_blocks=n_blocks))
    # pad bits (rows m..w*32 of each block) must be zero even for all-ones
    for b in range(n_blocks):
        last = words[b * w + (m - 1) // 32]
        assert (last >> np.uint32(m % 32)).max() == 0
    # an OR merge across blocks of zero pad bits stays zero
    merged = words[:w] | words[w:2 * w] | words[2 * w:]
    assert np.array_equal(np.asarray(fr.unpack_bits(jnp.asarray(merged), m)),
                          np.ones((m, s), np.uint8))
    # forge every pad bit high: unpack must still drop them all
    forged = words.copy().reshape(n_blocks, w, s)
    forged[:, -1] |= np.uint32(0xFFFFFFFF) << np.uint32(m % 32)
    back = np.asarray(fr.unpack_bits(jnp.asarray(forged.reshape(-1, s)), m,
                                     n_blocks=n_blocks))
    np.testing.assert_array_equal(back, ones)


def test_packed_bottom_up_matches_unpacked():
    rng = np.random.default_rng(3)
    shard, p, s = 37, 4, 2                      # unaligned shard boundary
    n = shard * p
    fglob = (rng.random((n, s)) < 0.5).astype(np.uint8)
    in_src = np.array([0, 36, n - 1, 5, -1, 70], np.int32)
    in_dst = np.array([2, 0, shard - 1, -1, 3, shard], np.int32)
    want = fr.expand_bottom_up(jnp.asarray(fglob), jnp.asarray(in_src),
                               jnp.asarray(in_dst), shard)
    words = fr.pack_bits(jnp.asarray(fglob), n_blocks=p)
    got = fr.expand_bottom_up_packed(words, jnp.asarray(in_src),
                                     jnp.asarray(in_dst), shard,
                                     fr.packed_words(shard))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# registry + plan-time resolution
# ---------------------------------------------------------------------------

def test_packed_strategies_registered_with_8x_models():
    n, p, s = 4096, 8, 2                        # shard 512: exact 8x
    for name in ("allgather_merge", "alltoall_direct"):
        plain = ex.dense_level_bytes(name, n, p, s, 1)
        packed = ex.dense_level_bytes(name + "_packed", n, p, s, 1)
        assert plain / packed == 8.0, name
        assert ex.get_exchange("dense", name + "_packed").wire == "packed"
        assert ex.get_exchange("dense", name).wire == "bytes"
    # bottom-up gather prices the same reduction
    assert (ex.bottomup_level_bytes(n, p, s)
            / ex.bottomup_level_bytes(n, p, s, wire="packed")) == 8.0


def test_select_exchange_wire_filter():
    args = (4096, 8, 1, 1, (8,))
    st_b = ex.select_exchange("dense", *args, wire="bytes")
    st_p = ex.select_exchange("dense", *args, wire="packed")
    assert st_b.wire == "bytes" and st_p.wire == "packed"
    # spanning both formats picks the packed minimum off one device
    assert ex.select_exchange("dense", *args).wire == "packed"
    with pytest.raises(ValueError, match="wire"):
        register_exchange("dense", "bad_wire", lambda *a: 0, wire="zstd")


def test_plan_resolves_wire_format():
    n = 300
    src, dst = generate("erdos_renyi", n, seed=1, avg_degree=5)
    g = shard_graph(src, dst, n, p=1)
    # explicit packed: the _packed twin, even at p=1
    pl = plan(g, BFSOptions(mode="dense", wire_format="packed"))
    assert pl.dense_strategy.name == "alltoall_direct_packed"
    assert pl.bottom_up_wire == "packed"
    assert pl.describe()["wire_formats"]["dense"] == "packed"
    # auto at p=1: nothing on the wire, ties keep bytes (no pack work)
    pl = plan(g, BFSOptions(mode="dense", wire_format="auto"))
    assert pl.dense_strategy.wire == "bytes"
    assert pl.bottom_up_wire == "bytes"
    # explicit _packed strategy name short-circuits wire_format
    pl = plan(g, BFSOptions(mode="dense",
                            dense_exchange="reduce_scatter_packed",
                            wire_format="bytes"))
    assert pl.dense_strategy.wire == "packed"
    # a strategy with no packed twin fails loudly under "packed"
    name = "tmp_bytes_only_strategy"
    register_exchange("dense", name, lambda *a: 0.0)(lambda cand, axis: cand)
    try:
        with pytest.raises(ValueError, match="no packed variant"):
            plan(g, BFSOptions(mode="dense", dense_exchange=name,
                               wire_format="packed"))
        # ... but "auto" degrades to the bytes impl instead of raising
        pl = plan(g, BFSOptions(mode="dense", dense_exchange=name,
                                wire_format="auto"))
        assert pl.dense_strategy.name == name
    finally:
        unregister_exchange("dense", name)
    # 2-D: both phases resolve independently
    pl2 = plan(g, BFSOptions(mode="dense", wire_format="packed"),
               partition="2d")
    assert pl2.expand_strategy.name == "allgather_packed"
    assert pl2.fold_strategy.name == "alltoall_reduce_packed"
    meta = pl2.describe()
    assert meta["wire_formats"]["expand"] == "packed"
    assert meta["wire_formats"]["expand_sparse"] == "ids"
    with pytest.raises(ValueError, match="wire_format"):
        BFSOptions(wire_format="zip").validate()


def test_plan_key_distinguishes_wire_formats():
    n = 200
    src, dst = generate("erdos_renyi", n, seed=2, avg_degree=4)
    g = shard_graph(src, dst, n, p=1)
    kb = plan(g, BFSOptions(mode="dense", wire_format="bytes")).plan_key()
    kp = plan(g, BFSOptions(mode="dense", wire_format="packed")).plan_key()
    ka = plan(g, BFSOptions(mode="dense", wire_format="auto")).plan_key()
    assert kb != kp
    assert ka == kb          # auto resolved to bytes at p=1 -> same engine


# ---------------------------------------------------------------------------
# plan() unsupported-combo rejection (satellite)
# ---------------------------------------------------------------------------

def test_plan_rejects_unsupported_kernel_combos():
    n = 256
    src, dst = generate("erdos_renyi", n, seed=0, avg_degree=4)
    g = shard_graph(src, dst, n, p=1)
    with pytest.raises(ValueError, match="use_kernel"):
        plan(g, BFSOptions(mode="dense", use_kernel=True), partition="2d")
    with pytest.raises(ValueError, match="mode='dense'"):
        plan(g, BFSOptions(mode="queue", use_kernel=True))
    with pytest.raises(ValueError, match="mode='dense'"):
        plan(g, BFSOptions(mode="auto", use_kernel=True))


# ---------------------------------------------------------------------------
# single-device engine parity across wire formats (incl. the kernel path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition", ["1d", "2d"])
@pytest.mark.parametrize("wire", ["bytes", "packed", "auto"])
def test_engine_parity_across_wire_formats(partition, wire):
    n = 500
    src, dst = generate("erdos_renyi", n, seed=4, avg_degree=6)
    g = shard_graph(src, dst, n, p=1)
    want = bfs_reference(src, dst, n, [0, 13])
    eng = plan(g, BFSOptions(mode="dense", wire_format=wire),
               num_sources=2, partition=partition).compile()
    np.testing.assert_array_equal(eng.run([0, 13]).dist_host, want)
    assert eng.trace_count == eng.compile_traces


@pytest.mark.parametrize("wire", ["bytes", "packed"])
def test_auto_mode_parity_across_wire_formats(wire):
    """The hybrid's bottom-up levels ride the packed frontier gather."""
    n = 600
    src, dst = generate("rmat", n, seed=5, edge_factor=6)
    g = shard_graph(src, dst, n, p=1)
    want = bfs_reference(src, dst, n, [0])
    eng = plan(g, BFSOptions(mode="auto", wire_format=wire,
                             queue_cap=4096)).compile()
    res = eng.run([0])
    np.testing.assert_array_equal(res.dist_host, want)
    assert res.stats().mode_counts["bottom_up"] >= 1


@pytest.mark.parametrize("n", [512, 400])   # 512: Pallas bitpack kernel
                                            # (32-aligned); 400: jnp pack
def test_kernel_packed_emission_matches_oracle(n):
    src, dst = generate("erdos_renyi", n, seed=6, avg_degree=6)
    g = shard_graph(src, dst, n, p=1)
    want = bfs_reference(src, dst, n, [0, 7])
    eng = plan(g, BFSOptions(mode="dense", use_kernel=True,
                             wire_format="packed"), num_sources=2).compile()
    np.testing.assert_array_equal(eng.run([0, 7]).dist_host, want)


def test_bitpack_kernel_matches_pack_bits():
    from repro.kernels.bsr_spmm.ops import bitpack_words

    rng = np.random.default_rng(7)
    mask = (rng.random((128, 3)) < 0.5).astype(np.float32)  # spmm-style f32
    got = np.asarray(bitpack_words(jnp.asarray(mask), interpret=True))
    want = np.asarray(fr.pack_bits(jnp.asarray(mask > 0).astype(jnp.uint8)))
    np.testing.assert_array_equal(got, want)


def test_estimated_device_bytes_prices_packed_and_kernel():
    n = 512
    src, dst = generate("erdos_renyi", n, seed=8, avg_degree=5)
    g = shard_graph(src, dst, n, p=1)
    base = plan(g, BFSOptions(mode="dense",
                              wire_format="bytes")).estimated_device_bytes()
    packed = plan(g, BFSOptions(mode="dense",
                                wire_format="packed")
                  ).estimated_device_bytes()
    kernel = plan(g, BFSOptions(mode="dense", use_kernel=True,
                                wire_format="bytes")
                  ).estimated_device_bytes()
    assert packed > base          # the loop-live word array is charged
    assert kernel > base          # resident blocked adjacency is charged
