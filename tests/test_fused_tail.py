"""Fused fold/owner-update tail (ISSUE 9): ``kernels/fold_update``
bit-parity against an independent numpy reference (jnp path and Pallas
interpret path), plan-time resolution of ``use_fused_tail`` (auto / True
/ False, wire preconditions, plan_key and byte-model growth, roofline
rows), engine parity fused vs unfused across graph families x
partitions x modes, and the ``analysis.trace_model`` parser on the
checked-in synthetic profiler trace."""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis import trace_model
from repro.analysis.hlo_audit import variant_name
from repro.core import BFSOptions, plan
from repro.core.frontier import INF, pack_bits
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph
from repro.kernels.fold_update import fold_update

_DATA = os.path.join(os.path.dirname(__file__), "data")
_FIXTURE = os.path.join(_DATA, "synthetic.trace.json.gz")


# ---------------------------------------------------------------------------
# fold_update kernel: jnp and Pallas-interpret paths vs numpy reference
# ---------------------------------------------------------------------------

def _ref_fold_update(words, dist, level):
    """Independent numpy model of the fused tail (no shared code)."""
    w, s = words.shape
    m = dist.shape[0]
    bits = np.zeros((w * 32, s), np.uint8)
    for i in range(w * 32):
        bits[i] = (words[i // 32] >> np.uint32(i % 32)) & 1
    new = (bits[:m] > 0) & (dist == int(INF))
    dist2 = np.where(new, np.int32(level), dist)
    nw = np.zeros((w, s), np.uint32)
    for i in range(m):
        nw[i // 32] |= new[i].astype(np.uint32) << np.uint32(i % 32)
    return dist2, new.astype(np.uint8), nw


@pytest.mark.parametrize("m,s", [
    (32, 1),     # exactly one word
    (96, 2),     # word-aligned, multi-source
    (37, 3),     # ragged: 27 pad bits in the last word
    (1, 1),      # single vertex
    (64, 4),
])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fold_update_matches_reference(m, s, use_pallas):
    rng = np.random.default_rng(m * 10 + s)
    mask = (rng.random((m, s)) < 0.5).astype(np.uint8)
    words = np.asarray(pack_bits(jnp.asarray(mask)))
    dist = np.where(rng.random((m, s)) < 0.5, np.int32(INF),
                    rng.integers(0, 5, (m, s)).astype(np.int32))
    want = _ref_fold_update(words, dist, 7)
    got = fold_update(jnp.asarray(words), jnp.asarray(dist), 7,
                      use_pallas=use_pallas)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)


def test_fold_update_already_discovered_rows_untouched():
    """A set candidate bit on a finite-depth row must not rewrite it."""
    dist = np.array([[3], [int(INF)], [0]], np.int32)
    words = np.asarray(pack_bits(jnp.asarray(
        np.ones((3, 1), np.uint8))))          # every vertex a candidate
    d2, new, nw = fold_update(jnp.asarray(words), jnp.asarray(dist), 9)
    np.testing.assert_array_equal(np.asarray(d2),
                                  [[3], [9], [0]])
    np.testing.assert_array_equal(np.asarray(new), [[0], [1], [0]])
    # only the newly discovered vertex carries into the next generation
    assert int(np.asarray(nw)[0, 0]) == 0b010


def test_fold_update_rejects_mismatched_shapes():
    words = jnp.zeros((2, 1), jnp.uint32)
    with pytest.raises(ValueError, match="packed_words"):
        fold_update(words, jnp.zeros((100, 1), jnp.int32), 1)
    with pytest.raises(ValueError, match="batch"):
        fold_update(words, jnp.zeros((64, 2), jnp.int32), 1)


# ---------------------------------------------------------------------------
# plan-time resolution of use_fused_tail
# ---------------------------------------------------------------------------

def _er_graph(n=400, seed=1):
    src, dst = generate("erdos_renyi", n, seed=seed, avg_degree=5.0)
    return src, dst, shard_graph(src, dst, n, p=1)


def test_fused_tail_resolution_and_metadata():
    _, _, g = _er_graph()
    # explicit True on a packed dense wire resolves on, in both schemes
    for partition in ("1d", "2d"):
        pl = plan(g, BFSOptions(mode="dense", wire_format="packed",
                                use_fused_tail=True), partition=partition)
        assert pl.use_fused_tail
        meta = pl.describe()
        assert meta["use_fused_tail"] is True
        assert meta["roofline"]["dense"]["model"] == "overlap(max)"
        assert variant_name(pl).endswith(":fused")
    # ... and True on a bytes wire is a loud contract violation
    with pytest.raises(ValueError, match="packed"):
        plan(g, BFSOptions(mode="dense", wire_format="bytes",
                           use_fused_tail=True))
    # auto: on for dense/auto modes over a packed wire ...
    assert plan(g, BFSOptions(mode="dense", wire_format="packed",
                              use_fused_tail="auto")).use_fused_tail
    assert plan(g, BFSOptions(mode="auto", wire_format="packed",
                              use_fused_tail="auto")).use_fused_tail
    # ... off for queue mode (no dense tail to fuse) and off when the
    # wire resolves to bytes (auto wire at p=1 keeps bytes)
    assert not plan(g, BFSOptions(mode="queue", wire_format="packed",
                                  use_fused_tail="auto")).use_fused_tail
    pl = plan(g, BFSOptions(mode="dense", wire_format="auto",
                            use_fused_tail="auto"))
    assert not pl.use_fused_tail
    assert not variant_name(pl).endswith(":fused")
    with pytest.raises(ValueError, match="use_fused_tail"):
        BFSOptions(use_fused_tail="maybe").validate()


def test_fused_tail_plan_key_and_device_bytes():
    _, _, g = _er_graph()
    for partition in ("1d", "2d"):
        keys, bytes_ = {}, {}
        for fused in (False, True):
            pl = plan(g, BFSOptions(mode="dense", wire_format="packed",
                                    use_fused_tail=fused),
                      partition=partition)
            keys[fused] = pl.plan_key()
            bytes_[fused] = pl.estimated_device_bytes()
        # distinct compiles in the EngineCache, and the fused plan is
        # charged for its double-buffered generation + kernel scratch
        assert keys[False] != keys[True], partition
        assert bytes_[True] > bytes_[False], partition


def test_fused_roofline_prices_the_eliminated_passes():
    """The fused dense row must model strictly less HBM traffic and a
    strictly smaller per-level step than its unfused twin (that modeled
    delta is what BENCH_latency.json asserts at >= 1.15x)."""
    _, _, g = _er_graph()
    for partition in ("1d", "2d"):
        rows = {}
        for fused in (False, True):
            meta = plan(g, BFSOptions(mode="dense", wire_format="packed",
                                      use_fused_tail=fused),
                        partition=partition).describe()
            rows[fused] = meta["roofline"]["dense"]
        assert rows[True]["hbm_bytes"] < rows[False]["hbm_bytes"]
        assert rows[True]["t_level_s"] < rows[False]["t_level_s"]
        assert rows[False]["model"] == "serial(sum)"
        assert rows[True]["model"] == "overlap(max)"
        # the wire payload is identical — fusion changes compute, not
        # what the collectives ship
        assert rows[True]["wire_bytes"] == rows[False]["wire_bytes"]


# ---------------------------------------------------------------------------
# engine parity: fused vs unfused, bitwise, across families x modes
# ---------------------------------------------------------------------------

_FAMILIES = [
    ("erdos_renyi", 400, {"avg_degree": 5.0}),
    ("star", 300, {}),
    ("chain", 64, {}),                 # one level per vertex: deep loop
    ("rmat", 400, {"edge_factor": 5}),
]


@pytest.mark.parametrize("kind,n,kw", _FAMILIES,
                         ids=[f[0] for f in _FAMILIES])
@pytest.mark.parametrize("partition", ["1d", "2d"])
@pytest.mark.parametrize("mode", ["dense", "auto"])
def test_engine_parity_fused_vs_unfused(kind, n, kw, partition, mode):
    src, dst = generate(kind, n, seed=3, **kw)
    g = shard_graph(src, dst, n, p=1)
    want = bfs_reference(src, dst, n, [0])
    dists = {}
    for fused in (False, True):
        eng = plan(g, BFSOptions(mode=mode, wire_format="packed",
                                 use_fused_tail=fused, queue_cap=2048),
                   num_sources=1, partition=partition).compile()
        res = eng.run([0])
        dists[fused] = res.dist_host
        np.testing.assert_array_equal(dists[fused], want)
        assert eng.trace_count == eng.compile_traces
    np.testing.assert_array_equal(dists[False], dists[True])


def test_engine_parity_fused_multi_source():
    src, dst = generate("erdos_renyi", 500, seed=9, avg_degree=6.0)
    g = shard_graph(src, dst, 500, p=1)
    want = bfs_reference(src, dst, 500, [0, 13, 99])
    eng = plan(g, BFSOptions(mode="dense", wire_format="packed",
                             use_fused_tail=True),
               num_sources=3, partition="2d").compile()
    np.testing.assert_array_equal(eng.run([0, 13, 99]).dist_host, want)


# ---------------------------------------------------------------------------
# trace_model on the checked-in synthetic profiler trace
# ---------------------------------------------------------------------------

def test_classify_op_names():
    assert trace_model.classify("all-to-all.1") == "collective"
    assert trace_model.classify("dynamic-slice_concatenate_fusion") \
        == "expand"
    assert trace_model.classify("bitcast_shift-left_fusion") == "fold"
    assert trace_model.classify("select_dynamic-update-slice_fusion") \
        == "owner_update"
    assert trace_model.classify("copy.3") == "other"


def test_synthetic_trace_loads_and_filters():
    ops = trace_model.load_events(_FIXTURE)
    # 11 real XLA op events survive; the while container, the $-prefixed
    # python frame, the hlo_op-less runtime event and the metadata event
    # are all dropped
    assert len(ops) == 11
    names = {op.hlo_op for op in ops}
    assert "while.12" not in names
    assert "gather.99" not in names
    t = trace_model.phase_timings(ops)
    assert t.n_ops == 11
    assert t.total_s["collective"] == pytest.approx(30e-6)
    assert t.total_s["expand"] == pytest.approx(8e-6)     # gather + iota
    assert t.total_s["fold"] == pytest.approx(9e-6)       # or + bitcast
    assert t.total_s["owner_update"] == pytest.approx(13e-6)
    assert t.total_s["other"] == pytest.approx(7e-6)      # copy
    assert t.span_s == pytest.approx(220e-6)


def test_synthetic_trace_level_segmentation():
    ops = trace_model.load_events(_FIXTURE)
    # with the level count known: cut at the n-1 largest collective gaps
    segs = trace_model.split_levels(ops, n_levels=3)
    assert [len(s) for s in segs] == [4, 4, 3]
    t = trace_model.parse_trace(_FIXTURE, n_levels=3)
    assert len(t.levels) == 3
    assert t.levels[0]["collective"] == pytest.approx(10e-6)
    assert t.levels[1]["collective"] == pytest.approx(12e-6)
    assert t.levels[2]["collective"] == pytest.approx(8e-6)
    # without it, evenly spaced collectives degrade to one segment (the
    # median-gap heuristic needs outlier gaps to cut at)
    assert len(trace_model.split_levels(ops)) == 1


def test_trace_file_resolution_and_cli(tmp_path, capsys):
    # a directory containing *.trace.json.gz resolves to the newest one
    assert trace_model.find_trace_file(_DATA) == _FIXTURE
    with pytest.raises(FileNotFoundError, match="trace"):
        trace_model.find_trace_file(str(tmp_path))
    assert trace_model.main([_FIXTURE, "--levels", "3", "--json"]) == 0
    out = capsys.readouterr().out
    assert '"total_s"' in out and '"levels"' in out
