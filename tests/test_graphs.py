"""Graph generators + partitioned formats."""

import numpy as np
import pytest

from repro.core.partition import Partition1D
from repro.graphs import (block_sparse_adjacency, csr_from_coo, dedupe_edges,
                          erdos_renyi, generate, rmat, shard_graph,
                          small_world, star_graph)


def _degrees(src, n):
    return np.bincount(src, minlength=n)


def test_star_shape():
    src, dst = star_graph(100)
    assert src.shape[0] == 2 * 99  # symmetrized
    deg = _degrees(src, 100)
    assert deg[0] == 99 and (deg[1:] == 1).all()


def test_erdos_renyi_degree_and_symmetry():
    n = 2000
    src, dst = erdos_renyi(n, avg_degree=10, seed=0)
    deg = _degrees(src, n)
    assert abs(deg.mean() - 10) < 1.0
    # symmetrized: edge set closed under reversal
    e = set(zip(src.tolist(), dst.tolist()))
    assert all((d, s) in e for s, d in list(e)[:500])


def test_small_world_no_self_loops_no_dupes():
    src, dst = small_world(500, k=6, beta=0.3, seed=1)
    assert (src != dst).all()
    key = src * 500 + dst
    assert np.unique(key).shape[0] == key.shape[0]


def test_rmat_heavy_tail():
    src, dst = rmat(scale=11, edge_factor=8, seed=0)
    deg = _degrees(src, 1 << 11)
    assert deg.max() > 8 * deg[deg > 0].mean() / 4  # skewed


def test_dedupe_edges():
    src = np.array([0, 0, 1, 2, 2])
    dst = np.array([1, 1, 1, 3, 3])
    s, d = dedupe_edges(src, dst, 4)
    assert s.shape[0] == 2  # (0,1) and (2,3); (1,1) self-loop dropped


def test_shard_graph_partitions_all_edges():
    n, p = 1000, 8
    src, dst = erdos_renyi(n, avg_degree=6, seed=4)
    g = shard_graph(src, dst, n, p)
    assert g.src_local.shape[0] == p
    # every real edge appears exactly once in the out-edge blocks
    cnt = int((g.dst_global >= 0).sum())
    assert cnt == src.shape[0] == g.n_edges
    # local ids are in range and reconstruct global sources per shard
    part = g.part
    for j in range(p):
        mask = g.dst_global[j] >= 0
        assert (g.src_local[j][mask] < part.shard_size).all()
    # in-edge blocks cover the same edge multiset
    assert int((g.in_src_global >= 0).sum()) == src.shape[0]


def test_shard_graph_degrees_match():
    n, p = 512, 4
    src, dst = small_world(n, k=4, beta=0.1, seed=7)
    g = shard_graph(src, dst, n, p)
    want = np.zeros(g.part.n, dtype=np.int64)
    np.add.at(want, dst, 1)
    np.testing.assert_array_equal(g.degrees(), want)


def test_csr_from_coo():
    src = np.array([2, 0, 1, 0])
    dst = np.array([3, 1, 2, 2])
    indptr, idx = csr_from_coo(src, dst, 4)
    assert indptr.tolist() == [0, 2, 3, 4, 4]
    assert sorted(idx[0:2].tolist()) == [1, 2]


def test_block_sparse_adjacency_roundtrip():
    n = 300
    src, dst = erdos_renyi(n, avg_degree=5, seed=9)
    blocks, br, bc, n_pad = block_sparse_adjacency(src, dst, n, block=128)
    assert n_pad % 128 == 0
    dense = np.zeros((n_pad, n_pad), np.float32)
    for k in range(blocks.shape[0]):
        dense[br[k]*128:(br[k]+1)*128, bc[k]*128:(bc[k]+1)*128] = blocks[k]
    want = np.zeros((n_pad, n_pad), np.float32)
    want[src, dst] = 1.0
    np.testing.assert_array_equal(dense, want)


def test_generate_dispatch_and_unknown():
    src, dst = generate("star", 10)
    assert src.shape[0] == 18
    with pytest.raises(KeyError):
        generate("nope", 10)


def test_graph_property_partition_conservation():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(8, 400), p=st.integers(1, 16),
           avg=st.floats(1.0, 8.0), seed=st.integers(0, 99))
    def prop(n, p, avg, seed):
        src, dst = erdos_renyi(n, avg_degree=avg, seed=seed)
        if src.size == 0:
            return
        g = shard_graph(src, dst, n, p)
        # invariant: no edge lost or duplicated by partitioning
        assert int((g.dst_global >= 0).sum()) == src.shape[0]
        # invariant: every out-edge block only holds edges owned by it
        part = Partition1D(n, p)
        for j in range(p):
            m = g.dst_global[j] >= 0
            gids = part.global_id(j, g.src_local[j][m])
            assert (np.asarray(part.owner(gids)) == j).all()

    prop()
