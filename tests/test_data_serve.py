"""Neighbor sampler, prefetching pipeline, continuous-batching server."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.graphs import csr_from_coo, erdos_renyi
from repro.graphs.sampler import NeighborSampler
from repro.data.pipeline import (PrefetchingIterator, graph_minibatch_stream,
                                 lm_token_stream)


def _sampler(n=500, deg=8, seed=0):
    src, dst = erdos_renyi(n, avg_degree=deg, seed=seed)
    indptr, indices = csr_from_coo(src, dst, n)
    return NeighborSampler(indptr, indices), (src, dst, n)


def test_sampler_shapes_and_locality():
    s, (src, dst, n) = _sampler()
    batch = s.sample(np.arange(16), (4, 3), seed=1, n_pad=512, e_pad=512,
                     d_feat=8)
    assert batch["node_feats"].shape == (512, 8)
    n_real = int(batch["valid_nodes"].sum())
    assert n_real == 16 + 16 * 4 + 16 * 4 * 3
    e_mask = batch["edge_dst"] >= 0
    assert int(e_mask.sum()) == 16 * 4 + 16 * 4 * 3
    # every edge endpoint is a valid local node id
    assert (batch["edge_src"][e_mask] < n_real).all()
    assert (batch["edge_dst"][e_mask] < n_real).all()


def test_sampler_edges_are_real_graph_edges():
    s, (src, dst, n) = _sampler()
    adj = set(zip(src.tolist(), dst.tolist()))
    batch = s.sample(np.arange(8), (5,), seed=3, n_pad=256, e_pad=256,
                     d_feat=4)
    gids = batch["global_ids"]
    e_mask = batch["edge_dst"] >= 0
    for es, ed in zip(batch["edge_src"][e_mask], batch["edge_dst"][e_mask]):
        child, parent = int(gids[es]), int(gids[ed])
        assert child == parent or (parent, child) in adj  # parent->child sampled
        # (self-loop only for isolated parents)


def test_sampler_deterministic():
    s, _ = _sampler()
    b1 = s.sample(np.arange(8), (4, 2), seed=42, n_pad=256, e_pad=256, d_feat=4)
    b2 = s.sample(np.arange(8), (4, 2), seed=42, n_pad=256, e_pad=256, d_feat=4)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])


def test_prefetching_iterator_order_and_determinism():
    it = PrefetchingIterator(lambda step: {"v": np.full(3, step)}, prefetch=3)
    got = [next(it) for _ in range(5)]
    it.close()
    assert [s for s, _ in got] == [0, 1, 2, 3, 4]
    assert all((b["v"] == s).all() for s, b in got)


def test_lm_token_stream_resume_replays():
    cfg = get_arch("yi_34b").reduced
    s1 = lm_token_stream(cfg, 2, 8, seed=7, start_step=0)
    batches = dict(next(s1) for _ in range(4))
    s1.close()
    s2 = lm_token_stream(cfg, 2, 8, seed=7, start_step=2)
    step, b = next(s2)
    s2.close()
    assert step == 2
    np.testing.assert_array_equal(b["tokens"], batches[2]["tokens"])


def test_graph_minibatch_stream():
    s, _ = _sampler()
    st = graph_minibatch_stream(s, 8, (3, 2), n_pad=128, e_pad=128, d_feat=4,
                                seed=0)
    step, b = next(st)
    st.close()
    assert step == 0 and b["node_feats"].shape == (128, 4)


def test_continuous_batching_server():
    from repro.models import transformer as tf
    from repro.serve.batcher import Request, Server
    cfg = get_arch("yi_34b").reduced
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, batch_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=5) for i in range(4)]
    for r in reqs:
        srv.submit(r)
    done = srv.run_until_drained(max_steps=200)
    assert len(done) == 4
    for r in reqs:
        assert r.done and len(r.out) == 5
        assert all(0 <= t < cfg.vocab for t in r.out)
    # greedy decode is deterministic: same prompt -> same continuation
    srv2 = Server(cfg, params, batch_slots=2, max_len=32)
    again = Request(rid=9, prompt=reqs[0].prompt, max_new_tokens=5)
    srv2.submit(again)
    srv2.run_until_drained(max_steps=200)
    assert again.out == reqs[0].out
