"""Loop-aware HLO parser regression + decode-attention equivalences."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_parse import (_comp_dot_flops, _split_computations,
                                    _trip_count, loop_aware_stats)
from repro.layers.core import chunked_attention, decode_attention
from repro.kernels.flash_attention.ref import attention_ref

_FAKE_HLO = """HloModule jit_step, is_scheduled=true

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %lhs.1 = f32[8,4]{1,0} constant(0)
  %rhs.1 = f32[4,16]{1,0} constant(0)
  %dot.1 = f32[8,16]{1,0} dot(%lhs.1, %rhs.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag.1 = f32[8,16]{1,0} all-gather(%dot.1), dimensions={0}
}

%cond.1 (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %c = s32[] constant(5)
  %i = s32[] get-tuple-element(%p2), index=0
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%add.1 (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}

ENTRY %main.1 (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %t = (s32[], f32[8,16]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1
  %ar.9 = f32[8,16]{1,0} all-reduce(%a), to_apply=%add.1
}
"""


def test_split_and_trip_count():
    comps = _split_computations(_FAKE_HLO)
    assert comps.get("__entry_name__") == "main.1"
    assert "body.1" in comps and "cond.1" in comps
    assert _trip_count(comps["cond.1"], comps) == 5


def test_loop_weighted_flops_and_bytes():
    st = loop_aware_stats(_FAKE_HLO)
    # dot: 2*8*16*4 = 1024 flops, x5 trips
    assert st["dot_flops"] == 5 * 1024, st
    assert st["collectives"]["all-gather"] == 5 * 512, st
    assert st["collectives"]["all-reduce"] == 512, st


def test_dot_flops_symbol_table():
    lines = [
        "%x = f32[32,64]{1,0} parameter(0)",
        "%d = f32[32,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, "
        "rhs_contracting_dims={0}",
    ]
    assert _comp_dot_flops(lines) == 2 * 32 * 128 * 64


# ------------------------------------------------------- decode attention
def test_decode_attention_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 8, 1, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 2, 256, 64), jnp.float32)
    # kv_len masks the tail; compare against ref on the valid prefix
    got = decode_attention(q, k, v, causal=True, q_offset=199, kv_len=200)
    want = attention_ref(q, k[:, :, :200], v[:, :, :200], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_per_slot_positions():
    """Continuous batching: each sequence at its own depth must equal the
    same sequence evaluated alone at that depth."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, hkv, s, dh = 3, 2, 128, 32
    q = jax.random.normal(ks[0], (b, 4, 1, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, dh), jnp.float32)
    pos = jnp.array([10, 63, 127], jnp.int32)
    got = decode_attention(q, k, v, causal=True, q_offset=pos,
                           kv_len=pos + 1)
    for i in range(b):
        alone = decode_attention(q[i:i+1], k[i:i+1], v[i:i+1], causal=True,
                                 q_offset=int(pos[i]), kv_len=int(pos[i]) + 1)
        np.testing.assert_allclose(np.asarray(got[i:i+1]), np.asarray(alone),
                                   rtol=2e-5, atol=2e-5)


def test_chunked_attention_routes_decode_to_einsum():
    """Sq=1 must produce identical results through chunked_attention."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 1, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 2048, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 2048, 32), jnp.float32)
    a = chunked_attention(q, k, v, causal=True, q_offset=1500, kv_len=1501)
    b_ = decode_attention(q, k, v, causal=True, q_offset=1500, kv_len=1501)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=1e-6, atol=1e-6)
