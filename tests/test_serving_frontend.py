"""Remote serving front-end: wire schema, batch-size bucket routing,
admission control/backpressure, metrics, and the HTTP transport
end-to-end — client traversals bitwise-equal to in-process
``BFSEngine.run`` on 1-D and 2-D lanes, between-rung requests served by
the next-larger bucket with padding stripped, bounded queues rejecting
with 429 instead of hanging, and graceful drain-on-shutdown."""

import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import BFSOptions, plan
from repro.core.engine import normalize_ladder, pick_bucket, plan_ladder
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph
from repro.launch.bfs_client import BFSClient, HTTPStatusError
from repro.serve.bfs_service import BFSService, TraversalRequest
from repro.serve.engine_cache import EngineCache
from repro.serve.frontend import (AdmissionError, BFSFrontend, DrainingError,
                                  LaneGate, RequestError, derive_parents,
                                  parse_traverse_request, serve_http)
from repro.serve.frontend import schema
from repro.serve.frontend.metrics import Histogram, LaneMetrics


def _graph(kind="erdos_renyi", n=160, seed=3, p=1, **kw):
    src, dst = generate(kind, n, seed=seed, **kw)
    return src, dst, shard_graph(src, dst, n, p)


def _service(graphs, ladder=(1, 4), **kw):
    svc = BFSService(opts=BFSOptions(mode="dense"), batch_buckets=ladder,
                     cache=EngineCache(), **kw)
    for name, (g, part) in graphs.items():
        svc.add_graph(name, g, partition=part, mesh=None)
    return svc


# ---------------------------------------------------------------------------
# S-ladder helpers (core/engine.py)
# ---------------------------------------------------------------------------

def test_normalize_ladder_sorts_dedupes_and_validates():
    assert normalize_ladder((8, 1, 8, 64)) == (1, 8, 64)
    assert normalize_ladder([4]) == (4,)
    with pytest.raises(ValueError, match="at least one"):
        normalize_ladder(())
    with pytest.raises(ValueError, match=">= 1"):
        normalize_ladder((1, 0))


def test_pick_bucket_smallest_fitting_rung():
    ladder = (1, 8, 64)
    assert pick_bucket(1, ladder) == 1
    assert pick_bucket(2, ladder) == 8      # between rungs -> next larger
    assert pick_bucket(8, ladder) == 8
    assert pick_bucket(9, ladder) == 64
    with pytest.raises(ValueError, match="largest bucket"):
        pick_bucket(65, ladder)
    with pytest.raises(ValueError, match=">= 1"):
        pick_bucket(0, ladder)


def test_plan_ladder_one_plan_per_rung():
    _, _, g = _graph(n=100)
    plans = plan_ladder(g, BFSOptions(mode="dense"), ladder=(4, 1, 4))
    assert sorted(plans) == [1, 4]
    assert all(plans[s].num_sources == s for s in plans)
    assert plans[1].plan_key() != plans[4].plan_key()
    # rung plans hit the same cache entries as directly built plans
    assert (plans[4].plan_key()
            == plan(g, BFSOptions(mode="dense"), num_sources=4).plan_key())


# ---------------------------------------------------------------------------
# wire schema (frontend/schema.py)
# ---------------------------------------------------------------------------

def test_parse_traverse_request_accepts_minimal_and_full_bodies():
    req = parse_traverse_request(b'{"sources": [3, 1]}')
    assert req == {"graph": None, "sources": [3, 1],
                   "include_parents": False, "deadline_ms": None}
    req = parse_traverse_request(
        b'{"graph": "er", "sources": [0], "include_parents": true}')
    assert req["graph"] == "er" and req["include_parents"] is True


@pytest.mark.parametrize("body,match", [
    (b"not json", "not valid JSON"),
    (b"[1, 2]", "JSON object"),
    (b'{"sources": [1], "extra": 1}', "unknown request field"),
    (b'{"graph": 7, "sources": [1]}', "'graph' must be a string"),
    (b'{"sources": []}', "non-empty list"),
    (b'{"sources": "0"}', "non-empty list"),
    (b'{"sources": [true]}', "must be integers"),
    (b'{"sources": [1.5]}', "must be integers"),
    (b'{"sources": [1], "include_parents": 1}', "must be a boolean"),
])
def test_parse_traverse_request_rejects_with_400(body, match):
    with pytest.raises(RequestError, match=match) as ei:
        parse_traverse_request(body)
    assert ei.value.status == 400


def test_parse_traverse_request_oversized_maps_to_413():
    huge = json.dumps({"sources": list(range(200_000))}).encode()
    assert len(huge) > schema.MAX_BODY_BYTES
    with pytest.raises(RequestError) as ei:
        parse_traverse_request(huge)
    assert ei.value.status == 413
    too_many = json.dumps(
        {"sources": list(range(schema.MAX_SOURCES_PER_REQUEST + 1))}).encode()
    with pytest.raises(RequestError, match="per-request"):
        parse_traverse_request(too_many)


def test_derive_parents_on_known_chain():
    # 0 -> 1 -> 2 (undirected), vertex 3 isolated
    src = np.array([0, 1, 1, 2])
    dst = np.array([1, 0, 2, 1])
    depths = bfs_reference(src, dst, 4, [0])           # (4, 1)
    parents = derive_parents(src, dst, depths)
    np.testing.assert_array_equal(parents[:, 0], [0, 0, 1, -1])
    # multi-source column independence + smallest-parent determinism
    depths2 = bfs_reference(src, dst, 4, [0, 2])
    parents2 = derive_parents(src, dst, depths2)
    np.testing.assert_array_equal(parents2[:, 0], [0, 0, 1, -1])
    np.testing.assert_array_equal(parents2[:, 1], [1, 2, 2, -1])


# ---------------------------------------------------------------------------
# admission control (frontend/admission.py)
# ---------------------------------------------------------------------------

def test_lane_gate_queue_depth_bound_and_recovery():
    gate = LaneGate(max_queue_depth=2, max_inflight_bytes=1 << 20)
    gate.try_admit("a", 10)
    gate.try_admit("b", 10)
    with pytest.raises(AdmissionError) as ei:
        gate.try_admit("c", 10, retry_after_s=0.5)
    assert ei.value.retry_after_s == pytest.approx(1.5)  # scaled by depth
    assert (gate.admitted, gate.rejected) == (2, 1)
    item, cost = gate.pop()
    assert item == "a" and cost == 10                   # FIFO
    # popped-but-unfinished work still counts against the byte budget
    assert gate.inflight() == 2 and gate.depth() == 1
    gate.try_admit("c", 10)                             # queue has room again
    gate.complete(10)
    assert gate.snapshot()["inflight_bytes"] == 20


def test_lane_gate_byte_bound_with_oversized_exception():
    gate = LaneGate(max_queue_depth=8, max_inflight_bytes=100)
    gate.try_admit("big", 90)
    with pytest.raises(AdmissionError, match="in-flight budget"):
        gate.try_admit("more", 20)
    gate.pop()
    gate.complete(90)
    # a single request over the whole budget is admitted when the lane
    # is idle (otherwise it would be permanently unservable)
    gate.try_admit("huge", 500)
    with pytest.raises(AdmissionError):
        gate.try_admit("next", 1)
    gate.pop()
    gate.complete(500)
    assert gate.idle()


def test_lane_gate_close_drains_and_reopens():
    gate = LaneGate(max_queue_depth=2)
    gate.try_admit("a", 1)
    gate.close()
    with pytest.raises(DrainingError):
        gate.try_admit("b", 1)
    assert gate.pop()[0] == "a"          # admitted work still proceeds
    gate.complete(1)
    gate.reopen()
    gate.try_admit("b", 1)
    assert gate.snapshot()["draining"] is False


def test_lane_gate_rejects_bad_bounds():
    with pytest.raises(ValueError, match="max_queue_depth"):
        LaneGate(max_queue_depth=0)
    with pytest.raises(ValueError, match="max_inflight_bytes"):
        LaneGate(max_inflight_bytes=0)


# ---------------------------------------------------------------------------
# metrics (frontend/metrics.py)
# ---------------------------------------------------------------------------

def test_histogram_buckets_quantiles_and_snapshot():
    h = Histogram(bounds=(0.01, 0.1, 1.0))
    for s in (0.005, 0.005, 0.05, 0.5, 5.0):
        h.observe(s)
    assert h.count == 5 and h.counts == [2, 1, 1, 1]
    assert h.quantile(0.4) == 0.01       # upper-bound estimate
    assert h.quantile(0.5) == 0.1        # median (3rd of 5) in bucket 2
    assert h.quantile(0.99) == 1.0       # overflow collapses to last bound
    snap = h.snapshot()
    assert snap["buckets"] == {"le_10ms": 2, "le_100ms": 3,
                               "le_1000ms": 4, "le_inf": 5}
    assert snap["p50_ms"] == 100.0 and snap["count"] == 5
    assert Histogram().quantile(0.5) is None
    with pytest.raises(ValueError, match="increasing"):
        Histogram(bounds=(1.0, 0.5))


def test_lane_metrics_counters_and_ewma():
    m = LaneMetrics()
    assert m.ewma_e2e_s(default=0.25) == 0.25
    m.record_completed(queue_wait_s=0.001, device_s=0.01, e2e_s=0.011,
                       bucket=4, n_sources=3)
    m.record_completed(queue_wait_s=0.002, device_s=0.02, e2e_s=0.022,
                       bucket=4, n_sources=4)
    m.record_rejected()
    m.record_rejected(invalid=True)
    m.record_failed()
    snap = m.snapshot()
    assert snap["completed"] == 2 and snap["sources_served"] == 7
    assert snap["rejected"] == 1 and snap["rejected_invalid"] == 1
    assert snap["failed"] == 1 and snap["buckets"] == {"4": 2}
    assert snap["e2e"]["count"] == 2
    assert m.ewma_e2e_s() == pytest.approx(0.3 * 0.022 + 0.7 * 0.011)


def test_lane_metrics_per_level_device_histogram():
    m = LaneMetrics()
    # no levels reported -> the per-level histogram stays empty
    m.record_completed(queue_wait_s=0.0, device_s=0.01, e2e_s=0.01,
                       bucket=1, n_sources=1)
    assert m.snapshot()["per_level_device"]["count"] == 0
    # 3 levels at 0.6ms device time -> three 0.2ms per-level samples
    m.record_completed(queue_wait_s=0.0, device_s=0.0006, e2e_s=0.001,
                       bucket=1, n_sources=1, levels=3)
    snap = m.snapshot()["per_level_device"]
    assert snap["count"] == 3
    assert snap["p50_ms"] == 0.25        # le_0.25ms sub-ms bucket
    assert snap["p99_ms"] == 0.25


# ---------------------------------------------------------------------------
# BFSService: bucket routing + drain satellites
# ---------------------------------------------------------------------------

def test_service_routes_to_smallest_fitting_bucket():
    src, dst, g = _graph(n=150)
    svc = _service({"er": (g, "1d")}, ladder=(1, 4))
    res, bucket = svc.traverse("er", [5])
    assert bucket == 1
    np.testing.assert_array_equal(res.dist_host,
                                  bfs_reference(src, dst, 150, [5]))
    # between rungs: padded up to bucket 4, response stripped to 3 columns
    res, bucket = svc.traverse("er", [0, 7, 33])
    assert bucket == 4 and res.dist_host.shape == (150, 3)
    np.testing.assert_array_equal(res.dist_host,
                                  bfs_reference(src, dst, 150, [0, 7, 33]))
    # one engine per *used* rung through the shared cache
    assert svc.cache_stats()["misses"] == 2
    # submit-time validation: the 400 family, not device-side errors
    with pytest.raises(ValueError, match="capacity"):
        svc.traverse("er", [0, 1, 2, 3, 4])
    with pytest.raises(ValueError, match="duplicate"):
        svc.traverse("er", [3, 3])
    with pytest.raises(ValueError, match="outside"):
        svc.traverse("er", [150])


def test_service_slot_path_uses_bucket_for_partial_batches():
    """The queued single-source path routes a half-full slot pool to a
    small rung instead of always paying the largest bucket."""
    src, dst, g = _graph(n=120)
    svc = _service({"er": (g, "1d")}, ladder=(1, 4))
    svc.submit(TraversalRequest(rid=0, source=9, graph="er"))
    done = svc.run_until_drained()
    assert len(done) == 1
    np.testing.assert_array_equal(
        done[0].dist, bfs_reference(src, dst, 120, [9])[:, 0])
    st = svc.cache_stats()
    assert st["misses"] == 1             # compiled S=1, not S=4


def test_run_until_drained_timeout_names_pending_lanes():
    from repro.serve.resilience.errors import StrandedRequestError

    _, _, g = _graph(n=100)
    svc = _service({"er": (g, "1d")}, ladder=(1,))
    r0 = TraversalRequest(rid=0, source=0, graph="er")
    r1 = TraversalRequest(rid=1, source=1, graph="er")
    svc.submit(r0)
    svc.submit(r1)
    assert svc.pending_by_lane() == {"er": 2}
    with pytest.raises(RuntimeError, match=r"timeout_s=0.*er: 2") as ei:
        svc.run_until_drained(timeout_s=0)
    assert "still pending" in str(ei.value)
    # stranded requests are rejected with a typed error, never leaked:
    # a caller polling req.done always observes an outcome
    for r in (r0, r1):
        assert r.done and isinstance(r.error, StrandedRequestError)
    assert not svc.pending_by_lane()
    svc.submit(TraversalRequest(rid=2, source=0, graph="er"))
    done = svc.run_until_drained()       # the lane itself is still fine
    assert len(done) == 1 and not svc.pending_by_lane()


# ---------------------------------------------------------------------------
# BFSFrontend: in-process dispatch, 429s, drain
# ---------------------------------------------------------------------------

def test_frontend_traverse_parity_and_metrics():
    src, dst, g = _graph(n=140)
    svc = _service({"er": (g, "1d")}, ladder=(1, 4))
    fe = BFSFrontend(svc, max_queue_depth=4)
    try:
        out = fe.traverse("er", [2, 77, 5], include_parents=True)
        assert out["bucket"] == 4 and out["n"] == 140
        want = bfs_reference(src, dst, 140, [2, 77, 5])
        got = np.asarray(out["depths"], dtype=np.int64).T
        np.testing.assert_array_equal(got, want)
        parents = np.asarray(out["parents"], dtype=np.int64).T
        np.testing.assert_array_equal(
            parents, derive_parents(src, dst, want))
        assert set(out["timing_ms"]) == {"queue_wait", "device", "total"}
        # invalid sources reject at submit and land in the 400 counter
        with pytest.raises(ValueError, match="duplicate"):
            fe.submit("er", [1, 1])
        with pytest.raises(KeyError, match="no serving lane"):
            fe.submit("nope", [0])
        snap = fe.metrics_payload()
        lane = snap["lanes"]["er"]
        assert lane["completed"] == 1 and lane["rejected_invalid"] == 1
        assert lane["e2e"]["count"] == 1 and lane["e2e"]["p50_ms"] > 0
        assert lane["admission"]["admitted"] == 1
        assert snap["engine_cache"]["misses"] == 1
    finally:
        assert fe.shutdown()


def test_frontend_bounded_queue_rejects_with_429():
    """queue bound 1 + parked dispatcher: the second submit must fail
    fast with a retry-after hint, deterministically."""
    _, _, g = _graph(n=100)
    svc = _service({"er": (g, "1d")}, ladder=(1,))
    fe = BFSFrontend(svc, max_queue_depth=1, start_dispatcher=False)
    first = fe.submit("er", [0])
    with pytest.raises(AdmissionError) as ei:
        fe.submit("er", [1])
    assert ei.value.retry_after_s > 0
    assert fe.metrics.lane("er").snapshot()["rejected"] == 1
    fe.start()                           # un-park: the survivor completes
    res = fe.wait(first, timeout_s=60.0)
    np.testing.assert_array_equal(
        res.dist_host[:, 0], bfs_reference(*(_graph(n=100)[:2]), 100,
                                           [0])[:, 0])
    assert fe.shutdown()


def test_frontend_inflight_byte_bound_rejects():
    _, _, g = _graph(n=100)
    svc = _service({"er": (g, "1d")}, ladder=(1,))
    # budget below one response: first request rides the oversized-keep
    # exception, the second rejects on bytes (queue has room for 8)
    fe = BFSFrontend(svc, max_queue_depth=8, max_inflight_mb=1e-6,
                     start_dispatcher=False)
    first = fe.submit("er", [0])
    with pytest.raises(AdmissionError, match="in-flight budget"):
        fe.submit("er", [1])
    fe.start()
    fe.wait(first, timeout_s=60.0)
    assert fe.shutdown()


def test_frontend_drain_rejects_new_work_and_finishes_admitted():
    _, _, g = _graph(n=100)
    svc = _service({"er": (g, "1d")}, ladder=(1,))
    fe = BFSFrontend(svc, max_queue_depth=4, start_dispatcher=False)
    admitted = fe.submit("er", [3])
    fe.start()
    assert fe.shutdown(timeout_s=60.0)   # drains the admitted request
    assert admitted.event.is_set() and admitted.error is None
    with pytest.raises(DrainingError):
        fe.submit("er", [4])
    assert fe.metrics_payload()["draining"] is True


def test_frontend_requires_registered_lanes():
    svc = BFSService(opts=BFSOptions(mode="dense"), cache=EngineCache())
    with pytest.raises(ValueError, match="no lanes"):
        BFSFrontend(svc)


# ---------------------------------------------------------------------------
# HTTP transport end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture()
def http_stack():
    """Two-lane (1-D + 2-D) service behind a live ephemeral-port server."""
    src, dst, g = _graph(n=140, seed=5)
    src2, dst2, g2 = _graph("chain", n=60, seed=0)
    svc = _service({"er": (g, "1d"), "ring": (g2, "2d")}, ladder=(1, 4))
    httpd, fe = serve_http(
        svc, "127.0.0.1", 0,
        graph_specs={"er": {"kind": "erdos_renyi", "n": 140, "seed": 5,
                            "gen_kwargs": {"avg_degree": 6}}})
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    client = BFSClient(f"http://127.0.0.1:{httpd.server_address[1]}",
                       timeout_s=120.0)
    try:
        yield {"client": client, "svc": svc, "fe": fe, "httpd": httpd,
               "er": (src, dst, g), "ring": (src2, dst2, g2),
               "thread": thread}
    finally:
        fe.shutdown(timeout_s=10.0)
        httpd.shutdown()
        httpd.server_close()
        thread.join(timeout=10.0)


def test_http_traverse_bitwise_parity_on_both_partitions(http_stack):
    client = http_stack["client"]
    for name, sources in (("er", [0, 9, 77]), ("ring", [0, 30])):
        src, dst, g = http_stack[name]
        n = http_stack["svc"].lane(name).n_logical
        out = client.traverse(name, sources)
        assert out["bucket"] == 4        # between rungs -> next larger
        assert out["unreached"] == schema.UNREACHED
        got = np.asarray(out["depths"], dtype=np.int64).T
        assert got.shape == (n, len(sources))   # padding stripped
        # bitwise against the in-process engine (the acceptance clause)
        # and the numpy reference
        part = http_stack["svc"].lane(name).plan.partition
        eng = plan(g, BFSOptions(mode="dense"), num_sources=len(sources),
                   partition=part).compile()
        np.testing.assert_array_equal(got, eng.run(sources).dist_host)
        np.testing.assert_array_equal(got, bfs_reference(src, dst, n,
                                                         sources))
    # single-source request rides the S=1 rung
    assert client.traverse("er", [3])["bucket"] == 1


def test_http_parents_ride_along_when_requested(http_stack):
    client = http_stack["client"]
    src, dst, g = http_stack["er"]
    out = client.traverse("er", [4], include_parents=True)
    depths = bfs_reference(src, dst, 140, [4])
    np.testing.assert_array_equal(
        np.asarray(out["parents"], dtype=np.int64).T,
        derive_parents(src, dst, depths))
    assert "parents" not in client.traverse("er", [4])


def test_http_error_mapping(http_stack):
    client = http_stack["client"]
    for sources, status, match in (
            ([1, 1], 400, "duplicate"),         # semantic: submit-time
            ([10**6], 400, "outside"),
            ([], 400, "non-empty"),             # structural: schema
            ([0] * 5000, 400, "per-request")):
        with pytest.raises(HTTPStatusError) as ei:
            client.traverse("er", sources)
        assert ei.value.status == status and match in str(ei.value)
    with pytest.raises(HTTPStatusError) as ei:
        client.traverse("nope", [0])
    assert ei.value.status == 404
    # no graph name on a multi-lane server is ambiguous
    with pytest.raises(HTTPStatusError) as ei:
        client.traverse(None, [0])
    assert ei.value.status == 400
    with pytest.raises(HTTPStatusError) as ei:
        client._request("/v1/missing")
    assert ei.value.status == 404


def test_http_graphs_metrics_and_health(http_stack):
    client = http_stack["client"]
    client.traverse("er", [0, 1])        # populate the histograms
    lanes = {g["name"]: g for g in client.graphs()["graphs"]}
    assert lanes["er"]["buckets"] == [1, 4]
    assert lanes["er"]["spec"]["kind"] == "erdos_renyi"
    assert lanes["ring"]["partition"] == "2d" and "grid" in lanes["ring"]
    m = client.metrics()
    assert m["lanes"]["er"]["e2e"]["count"] >= 1
    assert m["lanes"]["er"]["e2e"]["p50_ms"] > 0
    assert m["lanes"]["er"]["queue_wait"]["count"] >= 1
    assert m["engine_cache"]["hit_rate"] >= 0
    assert client.health()["status"] == "ok"


def test_http_overload_returns_429_with_retry_after():
    """Bounded queue + parked dispatcher over HTTP: the overflow request
    gets a 429 + Retry-After instead of hanging or crashing."""
    _, _, g = _graph(n=100)
    svc = _service({"er": (g, "1d")}, ladder=(1,))
    httpd, fe = serve_http(svc, "127.0.0.1", 0, max_queue_depth=1,
                           start_dispatcher=False)
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    client = BFSClient(f"http://127.0.0.1:{httpd.server_address[1]}")
    first_out, first_err = [], []

    def first():
        try:
            first_out.append(client.traverse("er", [0]))
        except Exception as exc:         # pragma: no cover - assert below
            first_err.append(exc)

    t = threading.Thread(target=first)
    t.start()
    deadline = time.monotonic() + 30
    while fe.gates["er"].depth() == 0:   # wait for the admit, not a sleep
        assert time.monotonic() < deadline
        time.sleep(0.005)
    with pytest.raises(HTTPStatusError) as ei:
        client.traverse("er", [1])
    assert ei.value.status == 429
    assert ei.value.payload["retry_after_s"] > 0
    fe.start()                           # serve the queued survivor
    t.join(timeout=60.0)
    assert not first_err and first_out[0]["bucket"] == 1
    assert client.metrics()["lanes"]["er"]["rejected"] == 1
    httpd.drain_and_stop(timeout_s=10.0)
    thread.join(timeout=10.0)
    assert not thread.is_alive()
    httpd.server_close()


def test_http_shutdown_endpoint_drains_and_stops():
    _, _, g = _graph(n=100)
    svc = _service({"er": (g, "1d")}, ladder=(1,))
    httpd, fe = serve_http(svc, "127.0.0.1", 0)
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    client = BFSClient(f"http://127.0.0.1:{httpd.server_address[1]}")
    client.traverse("er", [0])
    assert client.shutdown() == {"status": "draining"}
    thread.join(timeout=30.0)
    assert not thread.is_alive() and fe.draining
    httpd.server_close()
    with pytest.raises((HTTPStatusError, urllib.error.URLError, OSError)):
        client.traverse("er", [1])
