"""Compressed sparse-id wire format + visited sieve: codec byte-layout
and roundtrip boundaries (vs an independent numpy encoder), the
capacity-overflow boundary, the bitmap-adaptive branch, sieve summary /
lookup semantics, plan-time resolution of the compressed tier, and
single-device engine parity including the overflow->dense escalation
(multi-device parity lives in tests/helpers/grid_bfs.py)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (BFSOptions, plan, register_exchange,
                        unregister_exchange)
from repro.core import exchange as ex
from repro.core import frontier as fr
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph


def _encode_ref(ids, byte_cap, id_range):
    """Independent numpy encoder — no shared code with frontier's
    jnp codec.  Returns ``(buf (byte_cap,) uint8, overflow bool)``."""
    live = sorted(int(i) for i in ids if 0 <= int(i) < id_range)
    out = bytearray()
    prev = 0
    for v in live:
        d = v - prev
        prev = v
        while True:
            b = d & 0x7F
            d >>= 7
            if d:
                out.append(b | 0x80)
            else:
                out.append(b)
                break
    total = 4 + len(out)
    w = -(-id_range // 32)
    bitmap_fits = 4 + 4 * w <= byte_cap
    use_bitmap = bitmap_fits and total > 4 + 4 * w
    hdr = len(live) | (0x80000000 if use_bitmap else 0)
    buf = np.zeros(byte_cap, np.uint8)
    buf[0:4] = np.frombuffer(np.uint32(hdr).tobytes(), np.uint8)
    if use_bitmap:
        words = np.zeros(w, np.uint32)
        for v in live:
            words[v // 32] |= np.uint32(1) << np.uint32(v % 32)
        buf[4:4 + 4 * w] = np.frombuffer(words.tobytes(), np.uint8)
        return buf, False
    payload = np.frombuffer(bytes(out[: max(0, byte_cap - 4)]), np.uint8)
    buf[4:4 + payload.shape[0]] = payload
    return buf, (total > byte_cap and not bitmap_fits)


# ---------------------------------------------------------------------------
# codec byte layout + roundtrip boundaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap,id_range", [
    (1, 1),         # single id
    (5, 40),        # n < 32
    (31, 31),       # just below one bitmap word
    (32, 64),       # exactly one word of range
    (33, 100),      # n % 32 != 0
    (256, 500),     # dense regime: bitmap capacity wins statically
    (64, 4096),     # sparse regime: varints win
])
def test_codec_roundtrip_and_byte_layout(cap, id_range):
    rng = np.random.default_rng(cap * 1000 + id_range)
    byte_cap = fr.compressed_capacity(cap, id_range)
    for frac in (0.0, 0.3, 1.0):    # empty / partial / full frontier
        k = int(round(min(cap, id_range) * frac))
        pick = rng.choice(id_range, size=k, replace=False).astype(np.int32)
        ids = np.full(cap, -1, np.int32)
        ids[:k] = pick              # deliberately unsorted (bucket order)
        buf, ovf = fr.encode_delta_varint(jnp.asarray(ids), byte_cap,
                                          id_range)
        ref_buf, ref_ovf = _encode_ref(ids, byte_cap, id_range)
        assert bool(ovf) == ref_ovf
        assert not ref_ovf          # capacity headroom covers these
        np.testing.assert_array_equal(np.asarray(buf), ref_buf)
        back = np.asarray(fr.decode_delta_varint(buf, cap, id_range))
        want = np.full(cap, -1, np.int32)
        want[:k] = np.sort(pick)
        np.testing.assert_array_equal(back, want)


def test_codec_capacity_overflow_boundary():
    # 8 ids spaced 100000 apart: 3 varint bytes each, 28 total; the range
    # is too wide for a bitmap rescue, so byte_cap 28 fits exactly and 27
    # must raise the overflow flag (the escalation predicate's input)
    id_range = 1 << 20
    ids = jnp.asarray(np.arange(1, 9, dtype=np.int32) * 100000)
    buf, ovf = fr.encode_delta_varint(ids, 28, id_range)
    assert not bool(ovf)
    back = np.asarray(fr.decode_delta_varint(buf, 8, id_range))
    np.testing.assert_array_equal(back, np.arange(1, 9) * 100000)
    _, ovf = fr.encode_delta_varint(ids, 27, id_range)
    assert bool(ovf)


def test_codec_bitmap_rescue_is_overflow_free():
    # every id of a small range: the varint stream would spill, but the
    # bitmap statically fits, so the encoder flips to bitmap mode and
    # overflow stays impossible
    cap = id_range = 96
    byte_cap = fr.compressed_capacity(cap, id_range)
    assert byte_cap == 4 + 4 * fr.packed_words(id_range)
    ids = jnp.asarray(np.arange(id_range, dtype=np.int32))
    buf, ovf = fr.encode_delta_varint(ids, byte_cap, id_range)
    assert not bool(ovf)
    hdr = np.asarray(buf[:4]).view(np.uint32)[0]
    assert hdr >> 31 == 1           # bitmap mode bit
    back = np.asarray(fr.decode_delta_varint(buf, cap, id_range))
    np.testing.assert_array_equal(back, np.arange(id_range))


def test_codec_property_roundtrip():
    hyp = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings, strategies as st

    caps = [1, 5, 32, 33, 64]               # bounded shape set: the jit
    ranges = [1, 31, 64, 500, 4096]         # cache stays warm across draws

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def run(data):
        cap = data.draw(st.sampled_from(caps))
        id_range = data.draw(st.sampled_from(ranges))
        k = data.draw(st.integers(0, min(cap, id_range)))
        pick = sorted(data.draw(st.sets(st.integers(0, id_range - 1),
                                        min_size=k, max_size=k)))
        ids = np.full(cap, -1, np.int32)
        ids[:k] = np.asarray(pick, np.int32)
        byte_cap = fr.compressed_capacity(cap, id_range)
        buf, ovf = fr.encode_delta_varint(jnp.asarray(ids), byte_cap,
                                          id_range)
        if bool(ovf):
            return                  # escalation arm; decode not required
        back = np.asarray(fr.decode_delta_varint(buf, cap, id_range))
        want = np.full(cap, -1, np.int32)
        want[:k] = np.asarray(pick, np.int32)
        np.testing.assert_array_equal(back, want)

    run()


# ---------------------------------------------------------------------------
# visited sieve: summary + lookup semantics
# ---------------------------------------------------------------------------

def test_sieve_summary_and_lookup():
    shard = 2048                    # bucket width 2 under SIEVE_MAX_BITS
    bits, bucket, words = fr.sieve_layout(shard)
    assert bucket == 2 and bits * bucket >= shard
    dist = np.full(shard, int(fr.INF), np.int32)
    dist[0:bucket] = 1              # bucket 0 fully visited
    dist[bucket] = 1                # bucket 1 only half visited
    s0 = np.asarray(fr.sieve_summary(jnp.asarray(dist), bits, bucket))
    empty = np.full(shard, int(fr.INF), np.int32)
    s1 = np.asarray(fr.sieve_summary(jnp.asarray(empty), bits, bucket))
    gwords = jnp.asarray(np.concatenate([s0, s1]))
    gids = jnp.asarray(
        [0, bucket - 1,             # bucket 0 of shard 0: sieved
         bucket,                    # half-visited bucket: must pass
         shard,                     # shard 1, nothing visited: must pass
         -1])                       # padding: never a hit
    hit = np.asarray(fr.sieve_lookup(gwords, gids, shard, bits, bucket,
                                     words))
    np.testing.assert_array_equal(hit, [True, True, False, False, False])


def test_sieve_straddling_pad_counts_visited():
    # a final bucket that straddles the shard end: its pad slots count as
    # visited (they can never be candidates), so visiting the one real
    # vertex completes the bucket
    shard = 2050
    bits, bucket, words = fr.sieve_layout(shard)
    assert bits * bucket > shard
    dist = np.full(shard, int(fr.INF), np.int32)
    dist[(bits - 1) * bucket:] = 1
    s = fr.sieve_summary(jnp.asarray(dist), bits, bucket)
    hit = np.asarray(fr.sieve_lookup(s, jnp.asarray([shard - 1]), shard,
                                     bits, bucket, words))
    assert hit[0]


# ---------------------------------------------------------------------------
# plan-time resolution of the compressed tier + sieve knob
# ---------------------------------------------------------------------------

def _graph(n=300, p=1, seed=1):
    src, dst = generate("erdos_renyi", n, seed=seed, avg_degree=5)
    return src, dst, shard_graph(src, dst, n, p)


def test_wire_format_compressed_resolution():
    _, _, g = _graph()
    pl = plan(g, BFSOptions(mode="queue", wire_format="compressed"))
    assert pl.queue_strategy.name == "alltoall_direct_compressed"
    assert pl.dense_strategy.wire == "packed"   # densest dense tier
    assert pl.describe()["wire_formats"]["queue"] == "compressed"
    # 2-D: both sparse phases resolve their compressed twins
    pl2 = plan(g, BFSOptions(mode="queue", wire_format="compressed"),
               partition="2d")
    assert pl2.expand_sparse_strategy.name == "allgather_compressed"
    assert pl2.fold_sparse_strategy.name == "alltoall_direct_compressed"
    meta = pl2.describe()
    assert meta["wire_formats"]["expand_sparse"] == "compressed"
    assert meta["wire_formats"]["fold_sparse"] == "compressed"
    # "packed" leaves sparse phases on raw ids (no sparse bitset tier)
    pl3 = plan(g, BFSOptions(mode="queue", wire_format="packed"))
    assert pl3.queue_strategy.wire == "bytes"
    assert pl3.describe()["wire_formats"]["queue"] == "ids"
    # a pinned strategy with no compressed twin fails loudly; auto degrades
    name = "tmp_ids_only_queue"
    register_exchange("queue", name,
                      lambda p, cap, itemsize, density=1.0: 0.0)(
        lambda buckets, axis: buckets)
    try:
        with pytest.raises(ValueError, match="no compressed variant"):
            plan(g, BFSOptions(mode="queue", queue_exchange=name,
                               wire_format="compressed"))
        pl4 = plan(g, BFSOptions(mode="queue", queue_exchange=name,
                                 wire_format="auto"))
        assert pl4.queue_strategy.name == name
    finally:
        unregister_exchange("queue", name)


def test_sieve_resolution_and_plan_key():
    _, _, g = _graph()
    pl = plan(g, BFSOptions(mode="queue"))      # sieve="auto", p=1
    assert pl.sieve is False                    # nothing crosses the wire
    pl_on = plan(g, BFSOptions(mode="queue", sieve=True))
    assert pl_on.sieve is True
    assert pl.plan_key() != pl_on.plan_key()    # cache must not mix them
    assert pl_on.describe()["sieve"] is True
    # dense mode and multi-source plans force the sieve off even when asked
    assert plan(g, BFSOptions(mode="dense", sieve=True)).sieve is False
    assert plan(g, BFSOptions(mode="auto", sieve=True),
                num_sources=2).sieve is False
    with pytest.raises(ValueError, match="sieve"):
        BFSOptions(sieve="yes").validate()
    with pytest.raises(ValueError, match="wire_format"):
        BFSOptions(wire_format="zstd").validate()


def test_compressed_models_beat_raw_at_low_density():
    # the registered byte models must price the compressed twin below raw
    # ids at paper-like frontier densities — what auto-selection rides on
    p, cap = 4, 256
    raw = ex.queue_level_bytes("alltoall_direct", p, cap, 4, density=0.5)
    comp = ex.queue_level_bytes("alltoall_direct_compressed", p, cap, 4,
                                density=0.5)
    assert raw / comp >= 2.0
    raw2 = ex.grid_sparse_level_bytes("allgather", "alltoall_direct",
                                      2, 2, cap, 4, density=0.5)
    comp2 = ex.grid_sparse_level_bytes(
        "allgather_compressed", "alltoall_direct_compressed",
        2, 2, cap, 4, density=0.5)
    assert raw2 / comp2 >= 2.0


# ---------------------------------------------------------------------------
# single-device engine parity (multi-device: tests/helpers/grid_bfs.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition", ["1d", "2d"])
@pytest.mark.parametrize("mode", ["queue", "auto"])
def test_engine_parity_compressed_sieve(partition, mode):
    n = 500
    src, dst, g = _graph(n=n, seed=4)
    want = bfs_reference(src, dst, n, [0])
    eng = plan(g, BFSOptions(mode=mode, wire_format="compressed",
                             sieve=True, queue_cap=512),
               partition=partition).compile()
    res = eng.run([0])
    np.testing.assert_array_equal(res.dist_host, want)
    assert eng.trace_count == eng.compile_traces
    assert res.run_stats.to_host()["sieve_hits"] >= 0


@pytest.mark.parametrize("partition", ["1d", "2d"])
def test_overflow_escalation_stays_exact_compressed(partition):
    # a queue_cap far below the frontier forces the overflow->dense
    # escalation arm with the compressed wire + sieve active
    # (local_update off so candidates actually enqueue at p=1)
    n = 400
    src, dst, g = _graph(n=n, seed=4)
    want = bfs_reference(src, dst, n, [0])
    eng = plan(g, BFSOptions(mode="queue", wire_format="compressed",
                             sieve=True, queue_cap=8, local_update=False),
               partition=partition).compile()
    res = eng.run([0])
    np.testing.assert_array_equal(res.dist_host, want)
    assert res.stats().overflowed
