"""Multi-tenant serving subsystem: ``plan_key`` canonicalization,
``EngineCache`` (counters, byte-budget LRU eviction, pinning, thread-safe
get-or-compile), ``GraphCatalog`` and the rewritten multi-graph
``BFSService`` — parity against dedicated per-graph engines over mixed
1-D / 2-D lanes, and the compile-exactly-once acceptance criterion."""

import threading

import numpy as np
import pytest

import jax

from repro.core import BFSOptions, plan
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph, to_2d
from repro.serve.bfs_service import BFSService, TraversalRequest
from repro.serve.engine_cache import (EngineCache, GraphCatalog,
                                      default_engine_cache,
                                      use_default_cache)

FAMILIES = (("erdos_renyi", dict(avg_degree=6)), ("star", {}), ("chain", {}),
            ("rmat", dict(edge_factor=4)))


def _graph(kind="erdos_renyi", n=200, seed=3, p=1, **kw):
    src, dst = generate(kind, n, seed=seed, **kw)
    return src, dst, shard_graph(src, dst, n, p)


# ---------------------------------------------------------------------------
# plan_key: canonical fingerprint
# ---------------------------------------------------------------------------

def test_plan_key_content_identity_and_distinctions():
    src, dst, g = _graph()
    opts = BFSOptions(mode="dense")
    base = plan(g, opts, num_sources=2).plan_key()

    # a separately built but block-identical graph keys the same
    g_twin = shard_graph(src, dst, 200, 1)
    assert plan(g_twin, opts, num_sources=2).plan_key() == base

    # every compile-relevant knob lands in the key
    assert plan(g, opts, num_sources=3).plan_key() != base
    assert plan(g, BFSOptions(mode="auto"), num_sources=2).plan_key() != base
    assert plan(g, BFSOptions(mode="dense", queue_cap=2048),
                num_sources=2).plan_key() != base
    assert plan(g, BFSOptions(mode="dense", max_levels=7),
                num_sources=2).plan_key() != base
    assert plan(g, opts, num_sources=2, partition="2d").plan_key() != base

    # different content -> different key
    _, _, g_other = _graph(seed=9)
    assert plan(g_other, opts, num_sources=2).plan_key() != base

    # "auto" strategies key as what they resolved to, so an explicit name
    # and the auto-pick that chose it share an engine
    resolved = plan(g, BFSOptions(mode="dense", dense_exchange="auto"),
                    num_sources=2)
    explicit = plan(g, BFSOptions(mode="dense",
                                  dense_exchange=resolved.dense_strategy.name),
                    num_sources=2)
    assert resolved.plan_key() == explicit.plan_key()


def test_plan_key_2d_same_from_either_entry_path():
    _, _, g = _graph(n=120)
    via_flag = plan(g, BFSOptions(mode="dense"), partition="2d")
    via_container = plan(to_2d(g, 1, 1), BFSOptions(mode="dense"))
    assert via_flag.plan_key() == via_container.plan_key()
    # and the conversion cache hands out one object per grid
    assert to_2d(g, 1, 1) is to_2d(g, 1, 1)


def test_estimated_device_bytes_tracks_static_shapes():
    _, _, g = _graph()
    p1 = plan(g, BFSOptions(mode="dense"), num_sources=1)
    p4 = plan(g, BFSOptions(mode="dense"), num_sources=4)
    assert p1.estimated_device_bytes() > 0
    # more source columns -> strictly more working-buffer bytes
    assert p4.estimated_device_bytes() > p1.estimated_device_bytes()
    # the engine reports its plan's estimate (what the cache charges)
    eng = p1.compile()
    assert eng.estimated_device_bytes() == p1.estimated_device_bytes()
    # a 2-D auto plan prices its lazily built bottom-up blocks
    p2d = plan(g, BFSOptions(mode="dense"), partition="2d")
    p2a = plan(g, BFSOptions(mode="auto"), partition="2d")
    assert p2a.estimated_device_bytes() > p2d.estimated_device_bytes()


def test_bottom_up_in_cap_is_exact_under_skew():
    """The budget must charge the bottom-up blocks at their *real* padded
    capacity: under degree skew (star hub) the in-edge blocks out-pad the
    forward blocks, so pricing them at e_cap would break the
    upper-bound contract ``EngineCache`` eviction relies on."""
    from repro.graphs import shard_graph_2d

    n = 6000
    src, dst = generate("star", n, seed=0)
    g2 = shard_graph_2d(src, dst, n, 2, 2)
    cap = g2.bottom_up_in_cap()            # computed without the blocks
    assert "_bottom_up_blocks" not in g2.__dict__
    assert cap > g2.e_cap                  # the skew case that undercounted
    assert cap == g2.in_e_cap              # matches the built blocks


# ---------------------------------------------------------------------------
# EngineCache: counters, LRU byte budget, pinning, thread safety
# ---------------------------------------------------------------------------

def test_cache_hit_miss_counters_and_dedup():
    _, _, g = _graph(n=100)
    cache = EngineCache()
    p_a = plan(g, BFSOptions(mode="dense", max_levels=3))
    e1 = cache.get_or_compile(p_a)
    e2 = cache.get_or_compile(plan(g, BFSOptions(mode="dense", max_levels=3)))
    assert e1 is e2
    st = cache.stats()
    assert (st["hits"], st["misses"], st["entries"]) == (1, 1, 1)
    assert st["compile_s_total"] > 0 and st["hit_rate"] == 0.5
    assert p_a in cache and e1 in cache       # plan- and engine-keyed lookup
    cache.get_or_compile(plan(g, BFSOptions(mode="dense", max_levels=4)))
    assert cache.stats()["misses"] == 2 and len(cache) == 2


def test_cache_byte_budget_evicts_lru_first():
    _, _, g = _graph(n=100)
    plans = [plan(g, BFSOptions(mode="dense", max_levels=3 + i))
             for i in range(3)]
    unit = plans[0].estimated_device_bytes()
    assert all(p.estimated_device_bytes() == unit for p in plans)
    cache = EngineCache(max_device_bytes=2 * unit)
    cache.get_or_compile(plans[0])
    cache.get_or_compile(plans[1])
    assert cache.stats()["evictions"] == 0
    cache.get_or_compile(plans[0])            # refresh: plans[1] is now LRU
    cache.get_or_compile(plans[2])            # over budget -> evict one
    st = cache.stats()
    assert st["evictions"] == 1 and st["entries"] == 2
    assert st["device_bytes"] <= 2 * unit
    assert plans[0] in cache and plans[2] in cache
    assert plans[1] not in cache              # LRU victim, not FIFO's [0]
    # an evicted plan recompiles on demand (miss, not error)
    cache.get_or_compile(plans[1])
    assert cache.stats()["misses"] == 4


def test_cache_pinned_engine_survives_eviction():
    _, _, g = _graph(n=100)
    plans = [plan(g, BFSOptions(mode="dense", max_levels=3 + i))
             for i in range(3)]
    unit = plans[0].estimated_device_bytes()
    cache = EngineCache(max_device_bytes=2 * unit)
    cache.get_or_compile(plans[0], pin=True)  # LRU but untouchable
    cache.get_or_compile(plans[1])
    cache.get_or_compile(plans[2])
    assert plans[0] in cache                  # pinned survived
    assert plans[1] not in cache              # the unpinned LRU went instead
    st = cache.stats()
    assert st["evictions"] == 1 and st["pinned"] == 1
    # pin() on a resident entry succeeds; on an evicted key it reports
    # failure instead of raising (the caller re-get_or_compiles)
    assert cache.pin(plans[2]) is True
    assert cache.pin(plans[1]) is False       # evicted above
    cache.unpin(plans[2])
    cache.unpin(plans[0])
    cache.get_or_compile(plan(g, BFSOptions(mode="dense", max_levels=9)))
    assert plans[0] not in cache              # unpinned -> evictable again


def test_cache_single_oversized_entry_is_kept():
    """An engine bigger than the whole budget still serves (the cache
    runs temporarily over rather than thrashing its own in-flight
    compile); the next insertion evicts it."""
    _, _, g = _graph(n=100)
    p_big = plan(g, BFSOptions(mode="dense", max_levels=3))
    cache = EngineCache(max_device_bytes=max(1,
                        p_big.estimated_device_bytes() // 2))
    eng = cache.get_or_compile(p_big)
    assert eng is not None and p_big in cache
    cache.get_or_compile(plan(g, BFSOptions(mode="dense", max_levels=4)))
    assert p_big not in cache


def test_device_blocks_dedup_across_engines_and_release_on_drop():
    """Engines of one graph share one upload per (mesh, axis, group); the
    graph-side map holds them weakly, so dropping every engine (e.g. a
    cache eviction) releases the device buffers instead of pinning them
    to the graph object forever."""
    import gc

    _, _, g = _graph(n=100)
    e1 = plan(g, BFSOptions(mode="dense", max_levels=3)).compile()
    e2 = plan(g, BFSOptions(mode="dense", max_levels=5)).compile()
    assert e1._gbufs[0] is e2._gbufs[0]       # shared edge-block upload
    assert e1._valid is e2._valid             # shared validity mask
    dev_map = g.__dict__["_device_blocks"]
    assert len(dev_map) == 2                  # edges + valid groups
    del e1, e2
    gc.collect()
    assert len(dev_map) == 0                  # weak map released the bufs


def test_cache_get_or_compile_coalesces_across_threads():
    _, _, g = _graph(n=150)
    cache = EngineCache()
    results, errors = [], []

    def worker():
        try:
            # each thread builds its own plan object; keys coincide
            results.append(cache.get_or_compile(
                plan(g, BFSOptions(mode="dense", max_levels=4))))
        except Exception as exc:  # pragma: no cover - surfaced by assert
            errors.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(results) == 6
    assert all(r is results[0] for r in results)  # one engine object
    st = cache.stats()
    assert st["misses"] == 1 and st["hits"] == 5  # one compile paid


def test_cache_counters_consistent_under_threaded_ladder_load():
    """N threads hammering ``get_or_compile`` across a batch-size bucket
    ladder: the hit/miss/compile-seconds counters must balance exactly —
    misses == distinct rungs, hits + misses == total calls — i.e. no
    lost updates under contention (the serving front-end reads these
    counters live while handler threads admit)."""
    from repro.core.engine import plan_ladder

    _, _, g = _graph(n=140)
    cache = EngineCache()
    ladder = (1, 2, 4)
    n_threads, per_thread = 8, 9
    engines, errors = {s: [] for s in ladder}, []

    def worker(tid):
        try:
            for k in range(per_thread):
                # deterministic rung walk offset per thread: every rung
                # sees first-touch races from several threads
                s = ladder[(tid + k) % len(ladder)]
                eng = cache.get_or_compile(
                    plan(g, BFSOptions(mode="dense"), num_sources=s))
                engines[s].append(eng)
        except Exception as exc:  # pragma: no cover - surfaced by assert
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    st = cache.stats()
    total = n_threads * per_thread
    assert st["misses"] == len(ladder)            # one compile per rung
    assert st["hits"] + st["misses"] == total     # nothing lost
    assert st["hits"] == total - len(ladder)
    assert st["entries"] == len(ladder)
    assert st["compile_s_total"] > 0
    assert st["hit_rate"] == pytest.approx(st["hits"] / total)
    for s in ladder:                              # one object per rung
        assert engines[s] and all(e is engines[s][0] for e in engines[s])
    # the ladder helper keys identically to the per-rung plans above
    for s, p in plan_ladder(g, BFSOptions(mode="dense"),
                            ladder=ladder).items():
        assert cache.get(p) is engines[s][0]


def test_default_cache_env_and_swap():
    cache = EngineCache(max_entries=2)
    with use_default_cache(cache):
        assert default_engine_cache() is cache
    assert default_engine_cache() is not cache


def test_cache_rejects_bad_bounds():
    with pytest.raises(ValueError, match="max_device_bytes"):
        EngineCache(max_device_bytes=0)
    with pytest.raises(ValueError, match="max_entries"):
        EngineCache(max_entries=-1)


# ---------------------------------------------------------------------------
# GraphCatalog
# ---------------------------------------------------------------------------

def test_graph_catalog_register_lookup_and_2d_reuse():
    _, _, g = _graph(n=90)
    cat = GraphCatalog()
    cat.register("er", g)
    assert "er" in cat and cat.get("er") is g
    assert cat.names() == ["er"] and len(cat) == 1
    # same-object re-registration is a no-op; replacement is an error
    cat.register("er", g)
    _, _, g2 = _graph(n=90, seed=11)
    with pytest.raises(ValueError, match="already registered"):
        cat.register("er", g2)
    with pytest.raises(KeyError, match="not registered"):
        cat.get("missing")
    with pytest.raises(ValueError, match="non-empty"):
        cat.register("", g2)
    # the catalog's 2-D view is the same cached object plan() converts to
    assert cat.get_2d("er", 1, 1) is to_2d(g, 1, 1)
    # a registered 2-D container serves only its own grid
    cat.register("er2d", to_2d(g, 1, 1))
    assert cat.get_2d("er2d", 1, 1) is to_2d(g, 1, 1)
    with pytest.raises(ValueError, match="grid"):
        cat.get_2d("er2d", 2, 2)
    cat.unregister("er")
    assert "er" not in cat


# ---------------------------------------------------------------------------
# multi-graph BFSService: routing, parity, compile-once, eviction
# ---------------------------------------------------------------------------

def _submit_all(svc, requests):
    for r in requests:
        svc.submit(r)
    return svc.run_until_drained()


def test_multi_graph_service_parity_mixed_partitions():
    """One service, four graph families, mixed 1-D and 2-D lanes: every
    result bitwise-equal to a dedicated per-graph engine and the numpy
    reference (the acceptance criterion's parity clause)."""
    n = 160
    cache = EngineCache()
    svc = BFSService(opts=BFSOptions(mode="dense"), batch_slots=2,
                     cache=cache)
    data = {}
    for i, (kind, kw) in enumerate(FAMILIES):
        src, dst, g = _graph(kind, n=n, seed=5 + i, **kw)
        data[kind] = (src, dst, g)
        # alternate partition schemes across lanes
        svc.add_graph(kind, g, partition="2d" if i % 2 else "1d",
                      mesh=None)
    assert svc.graph_names() == [k for k, _ in FAMILIES]

    sources = {kind: [0, (7 * (i + 2)) % n, n - 1 - i]
               for i, kind in enumerate(data)}
    reqs = [TraversalRequest(rid=i * 10 + j, source=s, graph=kind)
            for i, kind in enumerate(data)
            for j, s in enumerate(sources[kind])]
    done = _submit_all(svc, reqs)
    assert len(done) == len(reqs) and svc.drained()

    for kind, (src, dst, g) in data.items():
        want = bfs_reference(src, dst, n, sources[kind])
        # dedicated engine, compiled outside the cache, same scheme
        dedicated = plan(g, BFSOptions(mode="dense"),
                         num_sources=len(sources[kind]),
                         partition=svc.lane(kind).plan.partition
                         ).compile().run(sources[kind]).dist_host
        np.testing.assert_array_equal(dedicated, want)
        for j, r in enumerate([r for r in reqs if r.graph == kind]):
            assert r.done
            np.testing.assert_array_equal(r.dist, want[:, j])
            np.testing.assert_array_equal(r.dist, dedicated[:, j])


def test_multi_graph_service_compiles_each_plan_once_under_budget():
    """Acceptance: >= 3 graphs through one service, budget large enough
    to hold all engines -> exactly one compile per (graph, plan), pinned
    by cache counters AND engine trace counts, across repeated rounds."""
    n = 140
    graphs = {}
    for i, (kind, kw) in enumerate(FAMILIES[:3]):
        _, _, g = _graph(kind, n=n, seed=2 + i, **kw)
        graphs[kind] = g
    cache = EngineCache()      # unbounded: every engine stays resident
    svc = BFSService(graphs, opts=BFSOptions(mode="dense"), batch_slots=2,
                     cache=cache)
    for rnd in range(3):       # several rounds of traffic per tenant
        reqs = [TraversalRequest(rid=rnd * 100 + i, source=rnd * 3 + i,
                                 graph=kind)
                for i, kind in enumerate(graphs)]
        done = _submit_all(svc, reqs)
        assert len(done) == len(reqs)
    st = cache.stats()
    assert st["misses"] == len(graphs)         # one compile per plan
    assert st["evictions"] == 0
    assert st["hits"] >= 2 * len(graphs)       # warm rounds all hit
    for kind in graphs:
        eng = cache.get(svc.lane(kind).plan)
        assert eng is not None
        assert eng.trace_count == eng.compile_traces   # never retraced


def test_multi_graph_service_recovers_from_budget_eviction():
    """A budget that cannot hold every tenant forces LRU eviction; lanes
    whose engine was evicted recompile transparently on their next step
    and results stay exact."""
    n = 150
    cache = None
    data, svc = {}, None
    for i, (kind, kw) in enumerate(FAMILIES[:3]):
        src, dst, g = _graph(kind, n=n, seed=4 + i, **kw)
        data[kind] = (src, dst, g)
        if svc is None:
            unit = plan(g, BFSOptions(mode="dense"),
                        num_sources=2).estimated_device_bytes()
            # room for ~1.5 engines: round-robin over 3 lanes must evict
            cache = EngineCache(max_device_bytes=int(1.5 * unit))
            svc = BFSService(opts=BFSOptions(mode="dense"), batch_slots=2,
                             cache=cache)
        svc.add_graph(kind, g)
    for rnd in range(2):
        reqs = [TraversalRequest(rid=rnd * 10 + i, source=rnd + i,
                                 graph=kind)
                for i, kind in enumerate(data)]
        for r in _submit_all(svc, reqs):
            src, dst, _ = data[r.graph]
            want = bfs_reference(src, dst, n, [r.source])[:, 0]
            np.testing.assert_array_equal(r.dist, want)
    st = cache.stats()
    assert st["evictions"] >= 1                # the budget bound
    assert st["misses"] > len(data)            # evicted lanes recompiled
    assert st["device_bytes"] <= cache.max_device_bytes


def test_service_routes_by_name_and_validates():
    n = 120
    src, dst, g = _graph(n=n)
    src2, dst2, g2 = _graph("chain", n=60)
    svc = BFSService({"er": g, "chain": g2}, opts=BFSOptions(mode="dense"),
                     batch_slots=2, cache=EngineCache())
    # multi-lane service refuses unrouted requests...
    with pytest.raises(ValueError, match="name their graph"):
        svc.submit(TraversalRequest(rid=0, source=0))
    with pytest.raises(KeyError, match="no serving lane"):
        svc.submit(TraversalRequest(rid=0, source=0, graph="nope"))
    # ...and per-lane source validation uses that lane's vertex range
    with pytest.raises(ValueError, match="outside"):
        svc.submit(TraversalRequest(rid=0, source=100, graph="chain"))
    svc.submit(TraversalRequest(rid=0, source=100, graph="er"))  # in range
    done = svc.run_until_drained()
    assert [r.rid for r in done] == [0]
    np.testing.assert_array_equal(
        done[0].dist, bfs_reference(src, dst, n, [100])[:, 0])
    # single-lane conveniences stay off in multi-lane mode
    with pytest.raises(ValueError, match="lanes"):
        _ = svc.engine
    # duplicate lanes and queue mode are rejected at registration
    with pytest.raises(ValueError, match="already has a serving lane"):
        svc.add_graph("er", g)
    with pytest.raises(ValueError, match="single-source"):
        svc.add_graph("q", g2, opts=BFSOptions(mode="queue"))


def test_services_share_engines_through_one_cache():
    """Two services (and the lifecycle API) serving the same graph and
    options share one compiled engine via the cache."""
    _, _, g = _graph(n=130)
    cache = EngineCache()
    svc_a = BFSService(g, opts=BFSOptions(mode="dense"), batch_slots=2,
                       cache=cache)
    svc_b = BFSService(g, opts=BFSOptions(mode="dense"), batch_slots=2,
                       cache=cache)
    svc_a.submit(TraversalRequest(rid=0, source=0))
    svc_b.submit(TraversalRequest(rid=1, source=1))
    svc_a.run_until_drained()
    svc_b.run_until_drained()
    st = cache.stats()
    assert st["misses"] == 1 and st["entries"] == 1
    assert svc_a.engine is svc_b.engine


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs 4 devices (CI --devices 4 jobs)")
def test_multi_graph_service_parity_on_2x2_grid():
    """Mixed 1-D (p=4) and 2-D (2x2 grid) lanes in one service on real
    multi-device meshes, bitwise against dedicated engines."""
    from jax.sharding import Mesh
    from repro.launch.mesh import make_grid_mesh

    n, p = 160, 4
    mesh1 = Mesh(np.asarray(jax.devices()[:p]).reshape(p), ("p",))
    cache = EngineCache()
    svc = BFSService(opts=BFSOptions(mode="dense"), batch_slots=2,
                     mesh=mesh1, axis="p", cache=cache)
    data = {}
    for i, (kind, kw) in enumerate(FAMILIES):
        src, dst, g = _graph(kind, n=n, seed=6 + i, p=p, **kw)
        data[kind] = (src, dst, g)
        if i % 2:
            svc.add_graph(kind, g, mesh=make_grid_mesh(2, 2),
                          partition="2d")
        else:
            svc.add_graph(kind, g)
    reqs = [TraversalRequest(rid=i * 10 + j, source=(11 * j + i) % n,
                             graph=kind)
            for i, kind in enumerate(data) for j in range(3)]
    done = _submit_all(svc, reqs)
    assert len(done) == len(reqs)
    assert cache.stats()["misses"] == len(data)
    for r in done:
        src, dst, _ = data[r.graph]
        np.testing.assert_array_equal(
            r.dist, bfs_reference(src, dst, n, [r.source])[:, 0])
    for kind in data:
        eng = cache.get(svc.lane(kind).plan)
        assert eng.trace_count == eng.compile_traces
