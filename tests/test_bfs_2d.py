"""2-D edge partitioning behind the plan→compile→run lifecycle: partition
protocol, grid graph blocks, reference parity, engine dispatch, byte
models.  Multi-device grids run in-process only when the session has >= 4
devices (CI's --devices 4 jobs, incl. the 2x2 grid matrix entry); the
subprocess harness tests/helpers/grid_bfs.py covers them otherwise."""

import os

import numpy as np
import pytest

import jax

from repro.core import (BFSOptions, INF, Partition, Partition1D, Partition2D,
                        plan)
from repro.core import exchange as ex
from repro.core.ref import bfs_reference, bfs_reference_2d
from repro.graphs import generate, shard_graph, shard_graph_2d, to_2d
from repro.launch.mesh import default_grid

GRAPHS = (("erdos_renyi", dict(avg_degree=6)), ("star", {}), ("chain", {}))


# ---------------------------------------------------------------------------
# partition scheme abstraction
# ---------------------------------------------------------------------------

def test_partition_protocol_conformance():
    p1 = Partition1D(100, 4)
    p2 = Partition2D(100, 2, 2)
    assert isinstance(p1, Partition) and isinstance(p2, Partition)
    assert p1.kind == "1d" and p2.kind == "2d"
    # identical vertex chunks: the 2-D scheme re-blocks edges, not vertices
    assert (p2.shard_size, p2.n, p2.p) == (p1.shard_size, p1.n, p1.p)
    v = np.arange(p1.n)
    np.testing.assert_array_equal(p2.owner(v), p1.owner(v))
    np.testing.assert_array_equal(p2.flat.owner(v), p1.owner(v))


def test_partition2d_grid_maps_and_fold_index():
    part = Partition2D(23, 2, 3)           # b = 4, n = 24, last chunk pads
    b, c = part.shard_size, part.c
    for v in range(part.n):
        own = part.owner(v)
        assert 0 <= own < part.p
        gi, gj = part.grid_row(own), part.grid_col(own)
        assert own == gi * c + gj
        # fold layout: row rank of the owner, then local id
        assert part.fold_index(v) == gi * b + (v - own * b)
        # row block i covers exactly the chunks of grid row i
        assert part.row_start(gi) <= v < part.row_start(gi) + part.row_block_size
    assert part.fold_size == part.r * b


def test_partition2d_validation():
    with pytest.raises(ValueError, match="bad partition"):
        Partition2D(10, 0, 2)
    with pytest.raises(ValueError, match="bad partition"):
        Partition2D(-1, 2, 2)


# ---------------------------------------------------------------------------
# 2-D graph container
# ---------------------------------------------------------------------------

def test_shard_graph_2d_blocks_and_conversion():
    n, r, c = 50, 2, 3
    src, dst = generate("erdos_renyi", n, seed=4, avg_degree=4)
    g2 = shard_graph_2d(src, dst, n, r, c)
    part = g2.part
    assert g2.n_edges == src.shape[0]
    assert int((g2.dst_fold >= 0).sum()) == src.shape[0]
    # every edge sits in the cell of (source's grid row, target's grid col)
    b = part.shard_size
    for cell in range(part.p):
        gi, gj = cell // c, cell % c
        sel = g2.dst_fold[cell] >= 0
        u = g2.src_rowlocal[cell][sel] + gi * part.row_block_size
        vf = g2.dst_fold[cell][sel]
        assert ((u // b) // c == gi).all()          # sources in grid row i
        assert ((vf // b) * c + gj < part.p).all()  # targets in grid col j
    # conversion from the 1-D container reaches the same blocks, cached
    g1 = shard_graph(src, dst, n, r * c)
    conv = to_2d(g1, r, c)
    np.testing.assert_array_equal(
        np.sort(conv.dst_fold, axis=1), np.sort(g2.dst_fold, axis=1))
    assert to_2d(g1, r, c) is conv                  # cache hit
    with pytest.raises(ValueError, match="grid"):
        to_2d(g1, 2, 2)                             # 4 != p=6


# ---------------------------------------------------------------------------
# host reference parity (pure numpy, any grid shape)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", GRAPHS)
def test_reference_2d_matches_serial_reference(kind, kw):
    n = 257                                # prime: padding on every grid
    src, dst = generate(kind, n, seed=1, **kw)
    want = bfs_reference(src, dst, n, [0, 5])
    for r, c in ((1, 1), (2, 2), (2, 3), (4, 1), (1, 4)):
        got = bfs_reference_2d(src, dst, n, [0, 5], r, c)
        np.testing.assert_array_equal(got, want, err_msg=f"{kind} {r}x{c}")


# ---------------------------------------------------------------------------
# engine: same lifecycle, 2-D backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", GRAPHS)
def test_2d_engine_matches_references_single_device(kind, kw):
    n = 400
    src, dst = generate(kind, n, seed=3, **kw)
    g = shard_graph(src, dst, n, p=1)
    eng = plan(g, BFSOptions(mode="dense"), num_sources=2,
               partition="2d").compile()
    got = eng.run([0, 7]).dist_host
    want = bfs_reference(src, dst, n, [0, 7])
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        got, bfs_reference_2d(src, dst, n, [0, 7], 1, 1))
    # bitwise equal to the 1-D engine on the same graph
    eng1 = plan(g, BFSOptions(mode="dense"), num_sources=2).compile()
    np.testing.assert_array_equal(got, eng1.run([0, 7]).dist_host)


def test_2d_engine_reuse_zero_retraces_and_stats():
    n = 500
    src, dst = generate("erdos_renyi", n, seed=6, avg_degree=6)
    g = shard_graph(src, dst, n, p=1)
    eng = plan(g, BFSOptions(mode="dense"), num_sources=2,
               partition="2d").compile()
    traces = eng.trace_count
    assert traces == eng.compile_traces
    r1 = eng.run([0, 5])
    d1 = r1.dist_host.copy()
    r2 = eng.run([7, 123])                 # fresh sources: no retrace
    assert eng.trace_count == traces
    np.testing.assert_array_equal(r2.dist_host,
                                  bfs_reference(src, dst, n, [7, 123]))
    np.testing.assert_array_equal(r1.dist_host, d1)   # donation safety
    stats = r2.stats()
    assert stats.levels >= 1 and not stats.overflowed
    assert stats.mode_counts["dense"] == stats.levels  # 2-D is dense-only
    assert stats.visited == int((r2.dist_host < int(INF)).sum())


def test_2d_plan_validation_and_describe():
    n = 300
    src, dst = generate("erdos_renyi", n, seed=2, avg_degree=5)
    g = shard_graph(src, dst, n, p=1)
    # every mode plans in 2-D now; the queue frontier stays single-source
    assert plan(g, BFSOptions(mode="queue"), partition="2d").partition == "2d"
    assert plan(g, BFSOptions(mode="auto"), num_sources=3,
                partition="2d").partition == "2d"
    with pytest.raises(ValueError, match="single source"):
        plan(g, BFSOptions(mode="queue"), num_sources=2, partition="2d")
    with pytest.raises(ValueError, match="use_kernel"):
        plan(g, BFSOptions(mode="dense", use_kernel=True), partition="2d")
    with pytest.raises(ValueError, match="partition"):
        plan(g, BFSOptions(), partition="3d")
    # a 2-D graph cannot be planned as 1-D
    g2 = shard_graph_2d(src, dst, n, 1, 1)
    with pytest.raises(ValueError, match="2-D"):
        plan(g2, BFSOptions(), partition="1d")
    # ... nor against a mesh whose grid shape differs from its blocks,
    # even when the total device count matches
    if jax.device_count() >= 4:
        from repro.launch.mesh import make_grid_mesh
        src4, dst4 = generate("erdos_renyi", n, seed=2, avg_degree=5)
        g22 = shard_graph_2d(src4, dst4, n, 2, 2)
        with pytest.raises(ValueError, match="laid out"):
            plan(g22, BFSOptions(mode="dense"), mesh=make_grid_mesh(4, 1))
    meta = plan(g, BFSOptions(mode="dense"), num_sources=3,
                partition="2d").describe()
    assert meta["partition"] == "2d" and meta["grid"] == (1, 1)
    assert meta["expand_exchange"] == "allgather"
    assert meta["fold_exchange"] == "alltoall_reduce"
    assert meta["expand_sparse_exchange"] == "allgather"
    assert meta["fold_sparse_exchange"] == "alltoall_direct"
    assert meta["dense_level_bytes"] == 0  # single device: nothing on wire
    # per-phase mode/byte split: every level variant is priced
    assert set(meta["phase_bytes"]) == {"expand", "fold", "expand_sparse",
                                        "fold_sparse"}
    assert meta["queue_level_bytes"] == 0 and meta["bottom_up_level_bytes"] == 0
    # the 1-D describe carries the same per-mode byte keys
    meta1 = plan(g, BFSOptions(mode="dense")).describe()
    assert meta1["partition"] == "1d" and "dense_exchange" in meta1
    assert "queue_level_bytes" in meta1 and "bottom_up_level_bytes" in meta1


# ---------------------------------------------------------------------------
# direction-optimizing hybrid: queue / bottom-up / auto on the 2-D backend
# ---------------------------------------------------------------------------

HYBRID_GRAPHS = GRAPHS + (("rmat", dict(edge_factor=6)),)


@pytest.mark.parametrize("kind,kw", HYBRID_GRAPHS)
@pytest.mark.parametrize("mode", ["queue", "auto"])
def test_2d_hybrid_modes_match_references_single_device(kind, kw, mode):
    n = 400
    src, dst = generate(kind, n, seed=3, **kw)
    g = shard_graph(src, dst, n, p=1)
    opts = BFSOptions(mode=mode, queue_cap=128)
    eng = plan(g, opts, num_sources=1, partition="2d").compile()
    res = eng.run([3])
    want = bfs_reference(src, dst, n, [3])
    np.testing.assert_array_equal(res.dist_host, want)
    # bitwise equal to the 1-D engine in the same mode
    eng1 = plan(g, opts, num_sources=1).compile()
    np.testing.assert_array_equal(res.dist_host, eng1.run([3]).dist_host)
    # ... and to the numpy hybrid phase simulation, schedule included
    d2, sched = bfs_reference_2d(src, dst, n, [3], 1, 1, mode=mode,
                                 queue_cap=128, return_schedule=True)
    np.testing.assert_array_equal(res.dist_host, d2)
    st = res.stats()
    counts = {k: sum(1 for e in sched if e["kind"] == k)
              for k in ("dense", "queue", "bottom_up")}
    assert st.mode_counts == counts
    assert st.levels == len(sched)


def test_2d_auto_narrow_frontier_rides_sparse_levels():
    """Acceptance: mode_counts shows non-dense levels on a narrow frontier
    (every chain level holds <= 2 vertices -> all levels go sparse)."""
    n = 300
    src, dst = generate("chain", n, seed=0)
    g = shard_graph(src, dst, n, p=1)
    eng = plan(g, BFSOptions(mode="auto"), num_sources=1,
               partition="2d").compile()
    res = eng.run([0])
    np.testing.assert_array_equal(res.dist_host,
                                  bfs_reference(src, dst, n, [0]))
    st = res.stats()
    assert st.mode_counts["queue"] >= 1
    assert st.mode_counts["queue"] + st.mode_counts["bottom_up"] > 0
    assert not st.overflowed


def test_2d_queue_overflow_escalates_to_dense_exactly():
    """Satellite: a queue_cap overflow must fall back to the dense level
    (bitwise-identical result) and set the overflowed flag."""
    n = 400
    src, dst = generate("erdos_renyi", n, seed=6, avg_degree=8)
    g = shard_graph(src, dst, n, p=1)
    want = bfs_reference(src, dst, n, [0])
    # cap smaller than the mid-traversal frontier: pack/bucket overflow
    tiny = plan(g, BFSOptions(mode="queue", queue_cap=4, local_update=False),
                num_sources=1, partition="2d").compile().run([0])
    np.testing.assert_array_equal(tiny.dist_host, want)
    assert tiny.stats().overflowed
    # with local_update=True the p=1 grid absorbs every target locally,
    # so the overflow comes from the frontier-id pack instead
    tiny_lu = plan(g, BFSOptions(mode="queue", queue_cap=4),
                   num_sources=1, partition="2d").compile().run([0])
    np.testing.assert_array_equal(tiny_lu.dist_host, want)
    assert tiny_lu.stats().overflowed
    # a roomy cap never overflows
    big = plan(g, BFSOptions(mode="queue", queue_cap=n),
               num_sources=1, partition="2d").compile().run([0])
    np.testing.assert_array_equal(big.dist_host, want)
    assert not big.stats().overflowed


def test_2d_auto_multi_source_dense_bottom_up_only():
    """S > 1 disables sparse levels (id buckets are single-source) but
    keeps the dense/bottom-up switch; results stay exact."""
    n = 500
    src, dst = generate("erdos_renyi", n, seed=7, avg_degree=6)
    g = shard_graph(src, dst, n, p=1)
    eng = plan(g, BFSOptions(mode="auto"), num_sources=3,
               partition="2d").compile()
    res = eng.run([0, 9, 123])
    np.testing.assert_array_equal(res.dist_host,
                                  bfs_reference(src, dst, n, [0, 9, 123]))
    assert res.stats().mode_counts["queue"] == 0


def test_reference_2d_hybrid_schedule_and_validation():
    n = 257
    src, dst = generate("erdos_renyi", n, seed=1, avg_degree=6)
    want = bfs_reference(src, dst, n, [0])
    for r, c in ((1, 1), (2, 2), (2, 3)):
        d2, sched = bfs_reference_2d(src, dst, n, [0], r, c, mode="auto",
                                     queue_cap=64, return_schedule=True)
        np.testing.assert_array_equal(d2, want, err_msg=f"{r}x{c}")
        assert {e["kind"] for e in sched} <= {"dense", "queue", "bottom_up"}
    with pytest.raises(ValueError, match="single source"):
        bfs_reference_2d(src, dst, n, [0, 5], 1, 1, mode="queue")
    with pytest.raises(ValueError, match="mode"):
        bfs_reference_2d(src, dst, n, [0], 1, 1, mode="bogus")


def test_shard_graph_2d_in_edges_and_degrees():
    n, r, c = 50, 2, 3
    src, dst = generate("erdos_renyi", n, seed=4, avg_degree=4)
    g2 = shard_graph_2d(src, dst, n, r, c)
    part = g2.part
    b = part.shard_size
    assert int((g2.in_src_global >= 0).sum()) == src.shape[0]
    # every in-edge sits with the owner cell of its target
    for cell in range(part.p):
        sel = g2.in_src_global[cell] >= 0
        assert (g2.in_dst_local[cell][sel] >= 0).all()
        assert (g2.in_dst_local[cell][sel] < b).all()
        v = cell * b + g2.in_dst_local[cell][sel]
        assert (np.asarray(part.owner(v)) == cell).all()
        # padded slots mark both endpoints
        assert (g2.in_dst_local[cell][~sel] == -1).all()
    assert g2.out_degree.shape == (part.p, b)
    assert int(g2.out_degree.sum()) == src.shape[0]
    np.testing.assert_array_equal(
        g2.out_degree.reshape(-1)[:n],
        np.bincount(np.asarray(src), minlength=n))


# ---------------------------------------------------------------------------
# byte models: the r + c vs p argument
# ---------------------------------------------------------------------------

def test_2d_modeled_bytes_strictly_below_1d_at_p4():
    n, s = 100_000, 1
    part = Partition1D(n, 4)
    one_d = ex.dense_level_bytes("alltoall_direct", part.n, 4, s, 1)
    two_d = ex.grid_level_bytes("allgather", "alltoall_reduce",
                                part.n, 2, 2, s, 1)
    assert two_d < one_d                    # acceptance: strict at p=4
    # sparse phases (id buffers) sit strictly below the dense bitmap
    # phases at p=4 for any sane cap — the §5.1 narrow-level payoff
    sparse = ex.grid_sparse_level_bytes("allgather", "alltoall_direct",
                                        2, 2, 1024)
    assert sparse < two_d
    # and the gap widens with p for square grids
    for p in (16, 64, 256):
        r = int(p ** 0.5)
        pn = Partition1D(n, p).n
        assert ex.grid_level_bytes("allgather", "alltoall_reduce",
                                   pn, r, r, s, 1) < \
            ex.dense_level_bytes("alltoall_direct", pn, p, s, 1)


def test_default_grid_factorization():
    assert default_grid(1) == (1, 1)
    assert default_grid(4) == (2, 2)
    assert default_grid(12) == (3, 4)
    assert default_grid(7) == (1, 7)


def test_bfs_service_runs_over_2d_engine():
    """The serving layer is partition-agnostic: one flag swaps backends."""
    from repro.serve.bfs_service import BFSService, TraversalRequest

    n = 300
    src, dst = generate("erdos_renyi", n, seed=5, avg_degree=6)
    g = shard_graph(src, dst, n, p=1)
    svc = BFSService(g, BFSOptions(mode="dense"), batch_slots=2,
                     partition="2d")
    assert svc.engine.plan.partition == "2d"
    for i, s in enumerate([0, 17, 250]):
        svc.submit(TraversalRequest(rid=i, source=s))
    done = svc.run_until_drained()
    assert len(done) == 3 and svc.pool.drained()
    for r in done:
        want = bfs_reference(src, dst, n, [r.source])[:, 0]
        np.testing.assert_array_equal(r.dist, want)
    assert svc.engine.trace_count == svc.engine.compile_traces


# ---------------------------------------------------------------------------
# in-process multi-device grid (runs under CI --devices 4 / BFS_GRID=2x2)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >= 4 devices (--devices 4 / BFS_GRID=2x2)")
def test_2d_engine_on_device_grid_in_process():
    from jax.sharding import Mesh
    from repro.launch.mesh import make_grid_mesh

    # CI exports BFS_GRID as empty on non-grid matrix entries — treat
    # empty the same as unset
    grid = os.environ.get("BFS_GRID") or "2x2"
    r, c = (int(x) for x in grid.lower().split("x"))
    p = r * c
    mesh2 = make_grid_mesh(r, c)
    mesh1 = Mesh(np.asarray(jax.devices()[:p]).reshape(p), ("p",))
    n = 1200
    for kind, kw in GRAPHS:
        src, dst = generate(kind, n, seed=5, **kw)
        g = shard_graph(src, dst, n, p)
        eng2 = plan(g, BFSOptions(mode="dense"), mesh=mesh2, num_sources=2,
                    partition="2d").compile()
        got = eng2.run([0, 9]).dist_host
        np.testing.assert_array_equal(
            got, bfs_reference(src, dst, n, [0, 9]), err_msg=kind)
        np.testing.assert_array_equal(
            got, bfs_reference_2d(src, dst, n, [0, 9], r, c), err_msg=kind)
        eng1 = plan(g, BFSOptions(mode="dense"), mesh=mesh1, axis="p",
                    num_sources=2).compile()
        np.testing.assert_array_equal(got, eng1.run([0, 9]).dist_host,
                                      err_msg=kind)
        if r > 1 and c > 1:
            assert (eng2.run([0]).stats().comm_bytes
                    < eng1.run([0]).stats().comm_bytes)
