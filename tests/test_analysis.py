"""Static-analysis passes: HLO plan auditor, registry lint, lock pass.

Unit-level: the census parser / donation / host-transfer checks run on
synthetic HLO text; the lints and the lock pass run on known-bad source
fixtures that must fail with exactly the right rule ids, and on the real
tree, which must be clean.  A subprocess harness (helpers/audit_bad.py)
compiles a deliberately mis-registered exchange on 4 host devices and
checks the auditor catches the lie.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.analysis import hlo_audit
from repro.analysis.report import AuditReport, RULES
from repro.analysis.lint import lint_sources, lint_tree
from repro.analysis.locks import analyze_lock_source, analyze_serve
from repro.core import BFSOptions, plan
from repro.graphs import generate, shard_graph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# census parser on synthetic HLO
# ---------------------------------------------------------------------------

SYNTH_HLO = """\
HloModule synth, input_output_alias={ {0}: (2, {}, may-alias) }

%body (arg: (s32[], u8[4096])) -> (s32[], u8[4096]) {
  %ag = u8[4096]{0} all-gather(%f), replica_groups={{0,1,2,3}}, channel_id=1, metadata={op_name="jit(run)/while/body/all_gather" source_file="/x/exchange.py" source_line=42}
  %ctrl = s32[] all-reduce(%h), replica_groups={{0,1,2,3}}, to_apply=%sum, metadata={op_name="jit(run)/while/body/psum" source_file="/x/bfs.py" source_line=99}
  %a2a = (s32[64]{0}, s32[64]{0}) all-to-all(%q0, %q1), replica_groups=[2,2]<=[4], metadata={op_name="jit(run)/while/body/all_to_all" source_file="/x/exchange.py" source_line=50}
}

%cond (arg: (s32[], u8[4096])) -> pred[] {
  %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (p0: s32[4,8], p1: u8[4096], p2: s32[4096,1]) -> (s32[4096,1], s32[]) {
  %p2 = s32[4096,1]{1,0} parameter(2)
  %outside = u8[4096]{0} all-gather(%p1), replica_groups={{0,1,2,3}}, channel_id=9
  %w = (s32[], u8[4096]) while(%t), condition=%cond, body=%body
}
"""


def test_census_parses_kinds_groups_and_loop_membership():
    ops = hlo_audit.census(SYNTH_HLO)
    by_kind = {(op.kind, op.computation): op for op in ops}

    ag = by_kind[("all-gather", "body")]
    assert ag.in_loop and ag.group_size == 4 and ag.n_groups == 1
    assert ag.out_bytes == 4096
    assert ag.recv_bytes == pytest.approx(4096 * 3 / 4)
    assert ag.source == "exchange.py:42"

    # tuple-variadic all-to-all with iota replica_groups=[2,2]<=[4]
    a2a = by_kind[("all-to-all", "body")]
    assert a2a.group_size == 2 and a2a.n_groups == 2
    assert a2a.out_bytes == 2 * 64 * 4
    assert a2a.recv_bytes == pytest.approx(2 * 64 * 4 / 2)

    ctrl = by_kind[("all-reduce", "body")]
    assert ctrl.in_loop and ctrl.out_bytes == 4
    assert ctrl.recv_bytes == pytest.approx(4 * 2 * 3 / 4)

    outside = by_kind[("all-gather", "main")]
    assert not outside.in_loop


def test_recv_bytes_conversions():
    assert hlo_audit._recv_bytes("all-gather", 800, 4) == pytest.approx(600)
    assert hlo_audit._recv_bytes("all-to-all", 800, 4) == pytest.approx(600)
    assert hlo_audit._recv_bytes("reduce-scatter", 100, 4) == pytest.approx(300)
    assert hlo_audit._recv_bytes("all-reduce", 100, 4) == pytest.approx(150)
    assert hlo_audit._recv_bytes("all-gather", 800, 1) == 0.0


def test_match_census_control_unpriced_and_tie_steal():
    mk = lambda kind, out, g, comp="body": hlo_audit.CollectiveOp(
        kind=kind, out_bytes=out,
        recv_bytes=hlo_audit._recv_bytes(kind, out, g), group_size=g,
        n_groups=1, computation=comp, in_loop=True, source="s:1")

    # small all-reduce -> control plane, never priced
    rep = AuditReport("t")
    ops = [mk("all-reduce", 4, 4)]
    hlo_audit.match_census(ops, [], rep)
    assert ops[0].role == "control" and rep.ok()

    # data-sized op with no candidate role -> HA002
    rep = AuditReport("t")
    ops = [mk("all-to-all", 4096, 4)]
    hlo_audit.match_census(ops, [], rep)
    assert "HA002" in rep.rules() and not rep.ok()

    # exact-size tie: two identical gathers, two roles with equal models.
    # Greedy alone would stack both ops on one role and HA001 the other;
    # the steal pass must give each required role one op.
    rep = AuditReport("t")
    ops = [mk("all-gather", 512, 4), mk("all-gather", 512, 4)]
    roles = [
        hlo_audit.Role("sieve", ("all-gather",), 384.0, 4, True),
        hlo_audit.Role("bottom_up", ("all-gather",), 384.0, 4, True),
    ]
    assigned = hlo_audit.match_census(ops, roles, rep)
    assert rep.ok(), [str(v) for v in rep.violations]
    assert len(assigned["sieve"]) == 1 and len(assigned["bottom_up"]) == 1


def test_donation_check_ok_missing_and_wrong_dtype():
    rep = AuditReport("t")
    hlo_audit.donation_check(SYNTH_HLO, rep)
    assert rep.ok() and rep.info["donation"]["dist_param"] == 2

    # alias stripped -> the dist buffer is copied, not donated
    rep = AuditReport("t")
    stripped = SYNTH_HLO.replace(
        ", input_output_alias={ {0}: (2, {}, may-alias) }", "")
    hlo_audit.donation_check(stripped, rep)
    assert "HA004" in rep.rules() and not rep.ok()

    # alias points at a non-dist (u8) parameter -> wrong buffer donated
    rep = AuditReport("t")
    wrong = SYNTH_HLO.replace(
        "%p2 = s32[4096,1]{1,0} parameter(2)",
        "%p2 = u8[4096]{0} parameter(2)")
    hlo_audit.donation_check(wrong, rep)
    assert "HA004" in rep.rules()


def test_host_transfer_check_flags_loop_outfeed_only():
    rep = AuditReport("t")
    hlo_audit.host_transfer_check(SYNTH_HLO, rep)
    assert rep.ok()

    rep = AuditReport("t")
    bad = SYNTH_HLO.replace(
        "%ctrl = s32[] all-reduce(%h)",
        "%of = token[] outfeed(%h, %tok)\n  %ctrl = s32[] all-reduce(%h)")
    hlo_audit.host_transfer_check(bad, rep)
    assert "HA005" in rep.rules()


# ---------------------------------------------------------------------------
# the auditor end-to-end on a real (p=1) engine
# ---------------------------------------------------------------------------

def _engine(n=256, **opts):
    src, dst = generate("erdos_renyi", n, seed=0)
    g = shard_graph(src, dst, n, 1)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("p",))
    return plan(g, BFSOptions(**opts), mesh=mesh, axis="p").compile()


def test_audit_engine_clean_on_p1_and_catches_stripped_donation():
    engine = _engine(mode="auto", wire_format="auto")
    rep = hlo_audit.audit_engine(engine, run_check=True)
    assert rep.ok(), [str(v) for v in rep.violations]
    assert rep.info["trace_count"] == engine.compile_traces
    assert rep.name.startswith("hlo:1d:auto:")
    # the machine-readable report round-trips
    d = rep.to_dict()
    assert d["ok"] and d["name"] == rep.name
    assert all(r in RULES for r in
               {v["rule"] for v in d["violations"]} | set())

    # same engine's HLO with donation erased must fail HA004
    rep2 = AuditReport("t")
    text = engine.compiled_hlo()
    import re
    stripped = re.sub(r",?\s*input_output_alias=\{[^}]*\{[^}]*\}[^}]*\}",
                      "", text, count=1)
    hlo_audit.donation_check(stripped, rep2)
    assert "HA004" in rep2.rules()


def test_census_table_renders_loop_rows():
    engine = _engine(mode="dense")
    rep = hlo_audit.audit_engine(engine)
    table = hlo_audit.census_table(rep)
    assert table.splitlines()[0].startswith("role")


# ---------------------------------------------------------------------------
# registry / compiled-loop lint on known-bad fixtures and the real tree
# ---------------------------------------------------------------------------

BAD_REGISTRY = '''
import jax.numpy as jnp
from repro.core.exchange import register_exchange

def wrong_arity(n, p):
    return float(n * p)

@register_exchange("dense", "weird", wrong_arity)
def impl_a(x, axis):
    return x

def impure(p, cap, itemsize, density=1.0):
    return jnp.float32(cap)

@register_exchange("queue", "impure_model", impure)
def impl_b(x, axis):
    return x
'''

BAD_TRACED = '''
import time
import jax.numpy as jnp

def traversal(x):
    t0 = time.time()
    if jnp.any(x > 0):
        x = x + 1
    return x, t0
'''


def test_lint_flags_bad_registrations():
    rep = lint_sources({"core/custom.py": BAD_REGISTRY})
    rules = rep.rules()
    assert "RX001" in rules          # wrong_arity: 2 args, dense needs 5
    assert "RX002" in rules          # impure: jnp inside the byte model
    assert "RX003" in rules          # no packed/compressed twins
    assert not rep.ok()
    assert len(rep.info["registrations"]) == 2


def test_lint_flags_traced_if_and_host_clock():
    rep = lint_sources({"core/bfs.py": BAD_TRACED})
    assert {"RX004", "RX005"} <= rep.rules()
    # same source under a non-traced path: loop-hygiene rules don't apply
    rep2 = lint_sources({"serve/tools.py": BAD_TRACED})
    assert not ({"RX004", "RX005"} & rep2.rules())


def test_lint_suppression_and_bare_allow():
    suppressed = BAD_TRACED.replace(
        "t0 = time.time()",
        "t0 = time.time()  # audit: allow(RX005) -- wall-clock fixture")
    rep = lint_sources({"core/bfs.py": suppressed})
    assert "RX005" not in rep.rules()          # suppressed with a reason
    assert any(v.rule == "RX005" and v.suppressed for v in rep.violations)

    bare = BAD_TRACED.replace(
        "t0 = time.time()",
        "t0 = time.time()  # audit: allow(RX005)")
    rep2 = lint_sources({"core/bfs.py": bare})
    assert "SUP001" in rep2.rules()            # reason string is required


def test_lint_tree_real_repo_is_clean():
    rep = lint_tree()
    assert rep.ok(), [str(v) for v in rep.violations]
    assert len(rep.info["registrations"]) >= 20


# ---------------------------------------------------------------------------
# lock-discipline pass on known-bad fixtures and the real serve/ tree
# ---------------------------------------------------------------------------

BAD_LOCKS = '''
import threading

class Leaky:
    # guarded-by(_lock): _x
    def __init__(self):
        self._lock = threading.Lock()
        self._x = 0            # __init__ is exempt

    def bump(self):
        with self._lock:
            self._x += 1

    def peek(self):
        return self._x         # LK001: no lock held


class Deadlocky:
    # guarded-by(_a): _y
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self._y = 0

    def ab(self):
        with self._a:
            with self._b:
                self._y += 1

    def ba(self):
        with self._b:
            with self._a:
                self._y += 1


class Phantom:
    # guarded-by(_missing): _z
    def __init__(self):
        self._z = 0
'''


def test_locks_flag_unguarded_access_cycle_and_unknown_lock():
    rep = analyze_lock_source(BAD_LOCKS, "serve/bad.py")
    rules = rep.rules()
    assert "LK001" in rules          # Leaky.peek
    assert "LK002" in rules          # Deadlocky: _a->_b and _b->_a
    assert "LK003" in rules          # Phantom: annotation names no lock
    # __init__ writes never count
    assert not any(v.rule == "LK001" and "__init__" in v.message
                   for v in rep.violations)


def test_locks_def_level_suppression_covers_method():
    fixed = BAD_LOCKS.replace(
        "    def peek(self):",
        "    # audit: allow(LK001) -- read-only probe, callers tolerate"
        " races\n    def peek(self):")
    rep = analyze_lock_source(fixed, "serve/bad.py")
    assert "LK001" not in rep.rules()
    assert any(v.rule == "LK001" and v.suppressed for v in rep.violations)


def test_analyze_serve_real_tree_is_clean():
    rep = analyze_serve()
    assert rep.ok(), [str(v) for v in rep.violations]
    # the documented false positive stays visible, suppressed, reasoned
    sup = [v for v in rep.violations if v.suppressed]
    assert sup and all(v.suppress_reason for v in sup)


# ---------------------------------------------------------------------------
# serve regression: shutdown is prompt now that _running flips under _cv
# ---------------------------------------------------------------------------

def test_frontend_stats_loop_exits_promptly_on_shutdown():
    import time as _time
    from repro.serve.bfs_service import BFSService
    from repro.serve.engine_cache import EngineCache
    from repro.serve.frontend import BFSFrontend

    src, dst = generate("erdos_renyi", 96, seed=1)
    g = shard_graph(src, dst, 96, 1)
    svc = BFSService(opts=BFSOptions(mode="dense"), batch_buckets=(1,),
                     cache=EngineCache())
    svc.add_graph("er", g, partition="1d", mesh=None)
    lines = []
    fe = BFSFrontend(svc, stats_interval_s=0.05, log=lines.append)
    fe.wait(fe.submit("er", [0]), timeout_s=60.0)
    t0 = _time.monotonic()
    assert fe.shutdown(timeout_s=30.0)
    assert _time.monotonic() - t0 < 5.0
    if fe._stats_thread is not None:
        fe._stats_thread.join(timeout=1.0)
        assert not fe._stats_thread.is_alive()
    assert fe.metrics_payload()["draining"] is True


# ---------------------------------------------------------------------------
# 4-device subprocess: known-bad byte model fails with HA003
# ---------------------------------------------------------------------------

def test_audit_known_bad_fixture_multidev():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "helpers",
                                      "audit_bad.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-2500:]}"
    assert "GOOD" in r.stdout and "HA003" in r.stdout
