"""Core BFS engine: single-device (p=1) correctness + multi-device subprocess."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import BFSOptions, bfs
from repro.core.partition import Partition1D, repartition
from repro.core.ref import INF, bfs_reference
from repro.graphs import generate, shard_graph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("kind,kw", [
    ("star", {}),
    ("erdos_renyi", dict(avg_degree=6)),
    ("small_world", dict(k=4, beta=0.2)),
    ("rmat", dict(edge_factor=6)),
])
@pytest.mark.parametrize("mode", ["dense", "queue", "auto"])
def test_bfs_p1_matches_reference(kind, kw, mode):
    n = 700
    src, dst = generate(kind, n, seed=11, **kw)
    g = shard_graph(src, dst, n, p=1)
    want = bfs_reference(src, dst, n, [0])
    opts = BFSOptions(mode=mode, queue_cap=8192)
    got, stats = bfs(g, [0], opts=opts)
    np.testing.assert_array_equal(got, want)
    assert stats.levels >= 1
    assert stats.visited == int((want < INF).sum())


def test_bfs_batched_sources_p1():
    n = 500
    src, dst = generate("erdos_renyi", n, seed=2, avg_degree=5)
    g = shard_graph(src, dst, n, p=1)
    sources = [0, 13, 250, 499]
    want = bfs_reference(src, dst, n, sources)
    got, _ = bfs(g, sources, opts=BFSOptions(mode="dense"))
    np.testing.assert_array_equal(got, want)


def test_bfs_unreachable_is_inf():
    # two cliques, no bridge
    a = np.array([0, 1, 2, 0]), np.array([1, 2, 0, 2])
    b = np.array([5, 6, 7, 5]), np.array([6, 7, 5, 7])
    src = np.concatenate([a[0], b[0]])
    dst = np.concatenate([a[1], b[1]])
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    g = shard_graph(src, dst, 8, p=1)
    got, _ = bfs(g, [0], opts=BFSOptions(mode="dense"))
    assert (got[5:8] == INF).all() and (got[:3] < INF).all()


def test_partition_roundtrip_properties():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(n=st.integers(1, 10_000), p=st.integers(1, 64),
           data=st.data())
    def prop(n, p, data):
        part = Partition1D(n, p)
        assert part.n >= n and part.n % p == 0
        v = data.draw(st.integers(0, part.n - 1))
        o = int(part.owner(v))
        assert 0 <= o < p
        assert int(part.global_id(o, part.local_id(v))) == v
        # repartition preserves the logical vertex set
        part2 = repartition(part, max(1, p // 2))
        assert part2.n_logical == part.n_logical

    prop()


def test_owner_matches_numpy_and_jnp():
    import jax.numpy as jnp
    part = Partition1D(1000, 7)
    v_np = np.arange(1000)
    v_j = jnp.arange(1000)
    np.testing.assert_array_equal(np.asarray(part.owner(v_np)),
                                  np.asarray(part.owner(v_j)))


def test_expand_bottom_up_masks_both_endpoints():
    """Regression: a padded in-edge whose destination is the -1 sentinel
    but whose source field holds a valid id used to wrap (``.at[-1]``)
    and scatter into the shard's *last* row; an out-of-range local id
    must be dropped too, not land anywhere."""
    import jax.numpy as jnp
    from repro.core import frontier as fr

    shard, n, s = 4, 8, 1
    fglob = jnp.ones((n, s), jnp.uint8)          # every vertex in frontier
    # one real edge (src 5 -> local 2); one pad with dst=-1 but src "valid";
    # one pad with dst == shard (out of range) and src valid
    in_src = jnp.array([5, 0, 3], jnp.int32)
    in_dst = jnp.array([2, -1, shard], jnp.int32)
    cand = fr.expand_bottom_up(fglob, in_src, in_dst, shard)
    np.testing.assert_array_equal(
        np.asarray(cand)[:, 0], np.array([0, 0, 1, 0], np.uint8))
    # fully padded block: nothing scatters
    cand0 = fr.expand_bottom_up(fglob, jnp.full((3,), -1, jnp.int32),
                                jnp.full((3,), -1, jnp.int32), shard)
    assert int(np.asarray(cand0).sum()) == 0


def test_multidevice_bfs_subprocess():
    """Full 8-device matrix: strategies x modes x graph families."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "helpers", "multidev_bfs.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
