"""Subprocess wrappers for the 8-device harnesses (exchange byte model vs
HLO ground truth; owner-exchange GNN vs reference)."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "helpers", script)],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-2500:]}"
    return r.stdout


def test_exchange_byte_model_matches_hlo():
    out = _run("exchange_bytes.py")
    assert "dense/allgather_merge" in out and "queue/alltoall_direct" in out


def test_owner_exchange_graphcast_matches_reference():
    out = _run("owner_gnn.py")
    assert "OK" in out and "MISMATCH" not in out


def test_grid_bfs_2d_matches_references():
    out = _run("grid_bfs.py")
    assert "grid/2x2" in out and "grid/4x1" in out and "grid/1x4" in out
    assert "MISMATCH" not in out
