"""BFS engine with the Pallas bsr_spmm expansion (kernel-in-system path)."""

import numpy as np
import pytest

from repro.core import BFSOptions, bfs
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph


@pytest.mark.parametrize("kind,kw", [
    ("erdos_renyi", dict(avg_degree=6)),
    ("small_world", dict(k=4, beta=0.2)),
    ("star", {}),
])
def test_kernel_expansion_matches_oracle(kind, kw):
    n = 400
    src, dst = generate(kind, n, seed=5, **kw)
    g = shard_graph(src, dst, n, 1)
    want = bfs_reference(src, dst, n, [0, 13])
    got, stats = bfs(g, [0, 13],
                     opts=BFSOptions(mode="dense", use_kernel=True))
    np.testing.assert_array_equal(got, want)
    assert stats.levels >= 1


def test_kernel_expansion_directed_orientation():
    """Directed chain: kernel path must respect edge direction (catches a
    transposed adjacency)."""
    n = 300
    src, dst = np.arange(n - 1), np.arange(1, n)
    g = shard_graph(src, dst, n, 1)
    want = bfs_reference(src, dst, n, [0, n - 1])
    got, _ = bfs(g, [0, n - 1],
                 opts=BFSOptions(mode="dense", use_kernel=True))
    np.testing.assert_array_equal(got, want)


def test_kernel_path_rejects_multishard():
    src, dst = generate("erdos_renyi", 128, seed=0, avg_degree=4)
    g = shard_graph(src, dst, 128, 2)
    with pytest.raises(AssertionError):
        bfs(g, [0], opts=BFSOptions(mode="dense", use_kernel=True))
