"""BFS engine with the Pallas bsr_spmm expansion (kernel-in-system path).

The kernel runs per shard inside the 1-D loop (multi-device parity is
covered by tests/helpers/multidev_bfs.py); here the single-device session
checks oracle parity, orientation, the per-shard blocked-adjacency
builder, and the unsupported-combo rejections."""

import numpy as np
import pytest

from repro.core import BFSOptions, bfs, plan
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph


@pytest.mark.parametrize("kind,kw", [
    ("erdos_renyi", dict(avg_degree=6)),
    ("small_world", dict(k=4, beta=0.2)),
    ("star", {}),
])
def test_kernel_expansion_matches_oracle(kind, kw):
    n = 400
    src, dst = generate(kind, n, seed=5, **kw)
    g = shard_graph(src, dst, n, 1)
    want = bfs_reference(src, dst, n, [0, 13])
    got, stats = bfs(g, [0, 13],
                     opts=BFSOptions(mode="dense", use_kernel=True))
    np.testing.assert_array_equal(got, want)
    assert stats.levels >= 1


def test_kernel_expansion_directed_orientation():
    """Directed chain: kernel path must respect edge direction (catches a
    transposed adjacency)."""
    n = 300
    src, dst = np.arange(n - 1), np.arange(1, n)
    g = shard_graph(src, dst, n, 1)
    want = bfs_reference(src, dst, n, [0, n - 1])
    got, _ = bfs(g, [0, n - 1],
                 opts=BFSOptions(mode="dense", use_kernel=True))
    np.testing.assert_array_equal(got, want)


def test_kernel_path_rejects_non_dense_modes():
    """The old single-shard AssertionError became a planable multi-shard
    path; what still (clearly) rejects is a non-dense mode, which has no
    kernel analog."""
    src, dst = generate("erdos_renyi", 128, seed=0, avg_degree=4)
    g = shard_graph(src, dst, 128, 1)
    for mode in ("queue", "auto"):
        with pytest.raises(ValueError, match="mode='dense'"):
            plan(g, BFSOptions(mode=mode, use_kernel=True))


def test_bsr_shards_builder_pads_uniform_tiles():
    """Per-shard blocked adjacency: uniform K across shards, zero pad
    tiles whose block rows never jump backwards (the kernel's accumulator
    reset fires on row transitions)."""
    n, p = 700, 4
    src, dst = generate("erdos_renyi", n, seed=3, avg_degree=5)
    g = shard_graph(src, dst, n, p)
    blocks, brs, bcs, row_pad, col_pad = g.bsr_shards()
    shard = g.part.shard_size
    assert blocks.shape[0] == p and brs.shape == bcs.shape == blocks.shape[:2]
    assert row_pad % 128 == 0 and row_pad >= g.part.n
    assert col_pad % 128 == 0 and col_pad >= shard
    for j in range(p):
        assert (np.diff(brs[j]) >= 0).all(), j       # sorted incl. pads
        assert brs[j].max() < row_pad // 128
        assert bcs[j].max() < col_pad // 128
        # the shard's tiles reproduce exactly its edge set (transposed)
        dense = np.zeros((row_pad, col_pad), np.float32)
        for k in range(blocks.shape[1]):
            dense[brs[j, k] * 128:(brs[j, k] + 1) * 128,
                  bcs[j, k] * 128:(bcs[j, k] + 1) * 128] += blocks[j, k]
        valid = g.dst_global[j] >= 0
        want = np.zeros_like(dense)
        want[g.dst_global[j][valid], g.src_local[j][valid]] = 1.0
        np.testing.assert_array_equal(dense, want)
    # builder result is cached, and the cheap cap probe agrees with (and
    # after a build, reads from) it without re-tiling
    assert g.bsr_shards()[0] is blocks
    assert g.bsr_shard_caps() == (blocks.shape[1], 128)
    g2 = shard_graph(src, dst, n, p)          # fresh graph: caps-only path
    assert g2.bsr_shard_caps() == (blocks.shape[1], 128)
    assert "_bsr_shards" not in g2.__dict__   # no dense tiles materialized
