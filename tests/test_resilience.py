"""Deterministic chaos regression suite for the serving resilience layer.

Every scenario replays a fixed ``FaultPlan`` (seeded, hit-window
scheduled) against the real stack and asserts the exact trajectory:
breaker open -> fast 503 -> half-open probe -> close, deadline reaping
under a parked dispatcher (504 before any device work), retry-then-
degrade serving bitwise-correct results on worse plans, watchdog trips
failing only the wedged round, and ``run_until_drained`` rejecting
stranded requests with a typed error.  No sleeps drive state machines —
breakers take injected clocks and retries injected sleepers — so the
suite is exact, not statistical."""

import threading
import time

import numpy as np
import pytest

from repro.core import BFSOptions
from repro.core.engine import plan
from repro.core.ref import bfs_reference
from repro.graphs import generate, shard_graph
from repro.serve.bfs_service import BFSService
from repro.serve.engine_cache import EngineCache
from repro.serve.frontend.server import BFSFrontend
from repro.serve.resilience import faults
from repro.serve.resilience.breaker import CircuitBreaker
from repro.serve.resilience.deadline import Deadline
from repro.serve.resilience.degrade import degraded_traverse
from repro.serve.resilience.errors import (CircuitOpenError,
                                           DeadlineExceeded, InjectedError,
                                           StuckDispatchError,
                                           TransientError)
from repro.serve.resilience.faults import FaultPlan, FaultSpec, corrupt_bytes
from repro.serve.resilience.retry import RetryPolicy, call_with_retry
from repro.serve.resilience.watchdog import DispatchWatchdog


def _graph(n=120, seed=3):
    src, dst = generate("erdos_renyi", n, seed=seed)
    return src, dst, shard_graph(src, dst, n, 1)


def _service(g, ladder=(1, 4)):
    svc = BFSService(opts=BFSOptions(mode="dense"), batch_buckets=ladder,
                     cache=EngineCache())
    svc.add_graph("er", g, partition="1d", mesh=None)
    return svc


def _frontend(svc, **kw):
    kw.setdefault("start_dispatcher", False)
    kw.setdefault("max_queue_depth", 8)
    return BFSFrontend(svc, **kw)


# ---------------------------------------------------------------------------
# fault plan: deterministic scheduling + replay
# ---------------------------------------------------------------------------

def test_fault_plan_hit_windows_and_replay():
    spec = FaultSpec(site="s", kind="fail", after=2, times=2)
    for _ in range(2):                      # identical across replays
        p = FaultPlan([spec], seed=7)
        fired = [p.arm("s", "") is not None for _ in range(6)]
        assert fired == [False, False, True, True, False, False]
    assert FaultPlan([spec], seed=7).arm("other", "") is None


def test_fault_plan_tag_matching_targets_one_bucket():
    p = FaultPlan([FaultSpec(site="cache.compile", match="S=4")])
    assert p.arm("cache.compile", "S=1 mode=dense") is None
    assert p.arm("cache.compile", "S=4 mode=dense") is not None


def test_fire_is_noop_without_plan_and_raises_with():
    assert faults.fire("cache.compile", "anything") is None
    with faults.active(FaultPlan([FaultSpec(site="x", kind="fail")])):
        with pytest.raises(InjectedError, match="injected"):
            faults.fire("x")
    assert faults.fire("x") is None         # uninstalled on exit


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="s", kind="explode")
    with pytest.raises(ValueError, match="p must be"):
        FaultSpec(site="s", p=1.5)
    with pytest.raises(ValueError, match="times"):
        FaultSpec(site="s", times=0)


def test_corrupt_bytes_is_deterministic_and_mangles():
    body = b'{"graph": "er", "sources": [1, 2, 3]}'
    spec = FaultSpec(site="client.payload", kind="corrupt")
    for seed in range(6):
        a = corrupt_bytes(body, spec, seed=seed)
        assert a == corrupt_bytes(body, spec, seed=seed)
        assert a != body


# ---------------------------------------------------------------------------
# breaker: exact open / half-open / close trajectory on an injected clock
# ---------------------------------------------------------------------------

def test_breaker_opens_after_threshold_and_recovers():
    now = [0.0]
    b = CircuitBreaker(failure_threshold=3, reset_timeout_s=10.0,
                       name="er", clock=lambda: now[0])
    for _ in range(2):
        b.record_failure()
    assert b.state() == "closed"            # threshold not reached
    b.record_failure()
    assert b.state() == "open" and b.opened == 1
    assert not b.admits() and not b.allow()
    err = b.reject_error()
    assert isinstance(err, CircuitOpenError) and err.status == 503
    assert 0 < err.retry_after_s <= 10.0
    now[0] = 10.1                            # cooldown elapses
    assert b.state() == "half_open"
    assert b.allow()                         # the single probe
    assert not b.allow()                     # probe budget spent
    b.record_success()
    assert b.state() == "closed"
    assert [s for s, _ in b.transitions] == [
        "closed", "open", "half_open", "closed"]
    assert b.recovery_latencies_s() == [pytest.approx(10.1)]


def test_breaker_failed_probe_reopens_with_fresh_cooldown():
    now = [0.0]
    b = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                       clock=lambda: now[0])
    b.record_failure()
    now[0] = 5.0
    assert b.allow()                         # half-open probe
    b.record_failure()                       # probe fails
    assert b.state() == "open" and b.opened == 2
    now[0] = 9.9
    assert b.state() == "open"               # fresh cooldown, not stale
    now[0] = 10.0
    assert b.state() == "half_open"


# ---------------------------------------------------------------------------
# deadline + retry primitives
# ---------------------------------------------------------------------------

def test_deadline_checks_and_bounds():
    now = [100.0]
    d = Deadline.after_ms(250, clock=lambda: now[0])
    assert not d.expired() and d.remaining_s() == pytest.approx(0.25)
    assert d.bound(10.0) == pytest.approx(0.25)
    assert d.bound(0.1) == pytest.approx(0.1)
    d.check("queue")                         # no raise while live
    now[0] = 100.3
    assert d.expired() and d.bound(10.0) == 0.0
    with pytest.raises(DeadlineExceeded, match="queue") as ei:
        d.check("queue", "lane 'er'")
    assert ei.value.status == 504 and ei.value.stage == "queue"
    with pytest.raises(ValueError, match="> 0"):
        Deadline.after_ms(0)


def test_retry_backoff_deterministic_and_bounded():
    pol = RetryPolicy(max_attempts=4, base_s=0.1, max_s=0.3, seed=5)
    assert pol.backoffs() == pol.backoffs()  # seeded, replayable
    assert len(pol.backoffs()) == 3
    assert all(0.05 <= b <= 0.45 for b in pol.backoffs())

    calls, slept, retried = [], [], []
    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientError("transient")
        return "ok"
    out = call_with_retry(flaky, pol, sleep=slept.append,
                          on_retry=lambda a, e, b: retried.append(a))
    assert out == "ok" and len(calls) == 3 and retried == [1, 2]
    assert slept == pol.backoffs()[:2]

    # budget exhausted -> last transient propagates with exact attempts
    calls.clear()
    with pytest.raises(TransientError):
        call_with_retry(lambda: (_ for _ in ()).throw(TransientError("x")),
                        RetryPolicy(max_attempts=2, base_s=0.0),
                        sleep=lambda s: None)

    # non-transient errors never retry
    calls.clear()
    def hard():
        calls.append(1)
        raise ValueError("permanent")
    with pytest.raises(ValueError):
        call_with_retry(hard, pol, sleep=lambda s: None)
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# watchdog: trip, late completion accounting, on-time passthrough
# ---------------------------------------------------------------------------

def test_watchdog_passthrough_and_trip_accounting():
    wd = DispatchWatchdog(timeout_s=0.2)
    assert wd.guard(lambda: 42) == 42        # on-time value passes through
    with pytest.raises(ZeroDivisionError):   # callee errors propagate
        wd.guard(lambda: 1 // 0)
    assert wd.snapshot()["trips"] == 0

    release = threading.Event()
    with pytest.raises(StuckDispatchError, match="watchdog"):
        wd.guard(release.wait, label="wedged")
    assert wd.stuck() == 1 and wd.snapshot()["trips"] == 1
    release.set()                            # abandoned worker finishes
    assert wd.wait_idle(timeout_s=5.0)
    assert wd.snapshot()["completed_late"] == 1 and wd.stuck() == 0


# ---------------------------------------------------------------------------
# degradation arms: bitwise parity on worse plans
# ---------------------------------------------------------------------------

def test_degraded_traverse_split_arm_matches_reference():
    src, dst, g = _graph(n=110)
    svc = _service(g, ladder=(1, 4))
    # poison every S=4 compile: the preferred rung can never build, so
    # the walk lands on split:1 (4 sequential S=1 runs, stitched)
    with faults.active(FaultPlan([FaultSpec(site="cache.compile",
                                            match="S=4")])):
        res, bucket, arm = degraded_traverse(svc, "er", [5, 9, 40, 77])
    assert arm == "split:1" and bucket == 1
    res.block()
    want = bfs_reference(src, dst, 110, [5, 9, 40, 77])
    np.testing.assert_array_equal(res.dist_host, want)
    stats = res.run_stats.to_host()
    assert stats["levels"] >= 1 and "mode_counts" in stats


def test_degraded_traverse_wire_tier_arm():
    src, dst, g = _graph(n=100)
    svc = _service(g, ladder=(1,))
    base = svc.lane("er").plans[1]
    assert base.opts.wire_format != "bytes"
    # poison the preferred rung only (its resolved wire tier); with no
    # other rung, the bytes twin is the last arm standing
    tag = f"wire={base.opts.wire_format}"
    with faults.active(FaultPlan([FaultSpec(site="cache.compile",
                                            match=tag)])):
        res, bucket, arm = degraded_traverse(svc, "er", [3])
    assert arm == "wire:bytes" and bucket == 1
    np.testing.assert_array_equal(
        res.block().dist_host, bfs_reference(src, dst, 100, [3]))


def test_degraded_traverse_exhausted_reraises_transient():
    _, _, g = _graph(n=100)
    svc = _service(g, ladder=(1,))
    with faults.active(FaultPlan([FaultSpec(site="cache.compile")])):
        with pytest.raises(TransientError):
            degraded_traverse(svc, "er", [3])


# ---------------------------------------------------------------------------
# frontend integration: deadline reaping under a parked dispatcher
# ---------------------------------------------------------------------------

def test_deadline_reaped_before_device_work():
    _, _, g = _graph(n=100)
    svc = _service(g)
    fe = _frontend(svc)                      # dispatcher parked
    pending = fe.submit("er", [4], deadline_ms=30)
    with pytest.raises(DeadlineExceeded) as ei:
        fe.wait(pending, timeout_s=5.0)      # unblocks at the deadline,
    assert ei.value.stage == "wait"          # not after 5s
    # the dead entry is still queued; the next round must reap it
    # without dispatching (no compile, no device work)
    misses_before = svc.cache.stats()["misses"]
    assert fe._dispatch_round() == 0         # reaped, no live dispatch...
    assert svc.cache.stats()["misses"] == misses_before   # ...no compile
    assert pending.event.is_set()
    assert isinstance(pending.error, DeadlineExceeded)
    assert pending.error.stage == "queue"
    snap = fe.metrics.lane("er").snapshot()
    assert snap["deadline_expired"] == 2     # wait + reap
    assert fe.gates["er"].idle()             # admission released


def test_live_deadline_request_serves_normally():
    src, dst, g = _graph(n=100)
    svc = _service(g)
    fe = _frontend(svc)
    pending = fe.submit("er", [7], deadline_ms=60_000)
    assert fe._dispatch_round() == 1
    res = fe.wait(pending, timeout_s=5.0)
    np.testing.assert_array_equal(
        res.dist_host, bfs_reference(src, dst, 100, [7]))


# ---------------------------------------------------------------------------
# frontend integration: breaker trajectory through the dispatcher
# ---------------------------------------------------------------------------

def test_frontend_breaker_opens_sheds_and_recovers():
    src, dst, g = _graph(n=100)
    svc = _service(g, ladder=(1,))
    fe = _frontend(svc, breaker_threshold=2, breaker_reset_s=0.15,
                   degrade=False,
                   retry_policy=RetryPolicy(max_attempts=1))
    # two rounds of unretried, undegraded compile failures open it
    with faults.active(FaultPlan([FaultSpec(site="cache.compile",
                                            times=2)])):
        for _ in range(2):
            p = fe.submit("er", [1])
            fe._dispatch_round()
            with pytest.raises(InjectedError):
                fe.wait(p, timeout_s=1.0)
    assert fe.breakers["er"].state() == "open"
    # open circuit: submission door sheds with a typed 503 + retry hint
    with pytest.raises(CircuitOpenError) as ei:
        fe.submit("er", [1])
    assert ei.value.status == 503 and ei.value.retry_after_s > 0
    snap = fe.metrics.lane("er").snapshot()
    assert snap["breaker_rejected"] == 1
    ok, reasons = fe.ready()
    assert not ok and "breakers open" in reasons[0]
    # cooldown -> half-open probe -> healthy dispatch closes it
    time.sleep(0.2)
    p = fe.submit("er", [2])
    fe._dispatch_round()
    res = fe.wait(p, timeout_s=5.0)
    np.testing.assert_array_equal(
        res.dist_host, bfs_reference(src, dst, 100, [2]))
    assert fe.breakers["er"].state() == "closed"
    assert fe.ready()[0]


def test_frontend_retry_then_degrade_serves_bitwise():
    src, dst, g = _graph(n=100)
    svc = _service(g, ladder=(1, 4))
    fe = _frontend(svc, retry_policy=RetryPolicy(max_attempts=2,
                                                 base_s=0.0))
    # S=4 compiles always fail: both attempts burn, then the split arm
    # serves on the S=1 rung — caller sees a normal, correct response
    with faults.active(FaultPlan([FaultSpec(site="cache.compile",
                                            match="S=4")])):
        p = fe.submit("er", [8, 33, 60])
        fe._dispatch_round()
        res = fe.wait(p, timeout_s=10.0)
    np.testing.assert_array_equal(
        res.dist_host, bfs_reference(src, dst, 100, [8, 33, 60]))
    assert p.arm == "split:1" and p.bucket == 1
    snap = fe.metrics.lane("er").snapshot()
    assert snap["retries"] == 1
    assert snap["degraded"] == {"split:1": 1}
    assert snap["completed"] == 1 and snap["failed"] == 0
    assert fe.breakers["er"].state() == "closed"   # degraded = success


def test_frontend_watchdog_trips_only_the_wedged_round():
    src, dst, g = _graph(n=100)
    svc = _service(g, ladder=(1,))
    fe = _frontend(svc, watchdog_timeout_s=0.25)
    # one slow collective wedges one round past the watchdog bound
    with faults.active(FaultPlan([FaultSpec(site="frontend.block",
                                            kind="stall", delay_s=1.0,
                                            times=1)])):
        p1 = fe.submit("er", [5])
        fe._dispatch_round()
        with pytest.raises(StuckDispatchError) as ei:
            fe.wait(p1, timeout_s=5.0)
        assert ei.value.status == 500
    assert fe.breakers["er"].state() == "closed"   # 1 < threshold
    assert fe.metrics.lane("er").snapshot()["failed"] == 1
    # the abandoned round drains; the next request serves fine
    assert fe.watchdog.wait_idle(timeout_s=5.0)
    p2 = fe.submit("er", [6])
    fe._dispatch_round()
    np.testing.assert_array_equal(
        fe.wait(p2, timeout_s=5.0).dist_host,
        bfs_reference(src, dst, 100, [6]))
    wd = fe.watchdog.snapshot()
    assert wd["trips"] == 1 and wd["stuck"] == 0
    assert wd["completed_late"] == 1


def test_readyz_payload_and_metrics_surface_resilience():
    _, _, g = _graph(n=100)
    svc = _service(g)
    fe = _frontend(svc, watchdog_timeout_s=5.0)
    status, body = fe.readiness_payload()
    assert status == 200 and body["ready"]
    assert body["breakers"] == {"er": "closed"}
    assert body["watchdog_stuck"] == 0
    m = fe.metrics_payload()
    assert m["lanes"]["er"]["breaker"]["state"] == "closed"
    assert m["watchdog"]["trips"] == 0
    for key in ("deadline_expired", "breaker_rejected", "retries",
                "degraded"):
        assert key in m["lanes"]["er"]
    fe.drain(timeout_s=1.0)
    status, body = fe.readiness_payload()
    assert status == 503 and body["reasons"] == ["draining"]


# ---------------------------------------------------------------------------
# zero behavior change with faults disabled
# ---------------------------------------------------------------------------

def test_faults_disabled_bitwise_identical_and_plan_key_unchanged():
    src, dst, g = _graph(n=100)
    opts = BFSOptions(mode="dense")
    base = plan(g, opts, num_sources=2)
    # plan_key is untouched by the resilience layer (cache compatibility)
    assert base.plan_key() == plan(g, opts, num_sources=2).plan_key()
    engine = base.compile()
    direct = engine.run([4, 9]).dist_host
    svc = _service(g)
    fe = _frontend(svc)
    p = fe.submit("er", [4, 9])              # no deadline, no faults
    fe._dispatch_round()
    served = fe.wait(p, timeout_s=5.0).dist_host
    np.testing.assert_array_equal(served, direct)
    np.testing.assert_array_equal(direct,
                                  bfs_reference(src, dst, 100, [4, 9]))
    snap = fe.metrics.lane("er").snapshot()
    assert (snap["retries"], snap["breaker_rejected"],
            snap["deadline_expired"], snap["degraded"]) == (0, 0, 0, {})


def test_eviction_storm_recompiles_transparently():
    src, dst, g = _graph(n=100)
    svc = _service(g, ladder=(1,))
    fe = _frontend(svc)
    p = fe.submit("er", [3])
    fe._dispatch_round()
    fe.wait(p, timeout_s=5.0)
    # a storm between requests drops the compiled engine; the next
    # dispatch just recompiles — slower, never wrong
    with faults.active(FaultPlan([FaultSpec(site="cache.get",
                                            kind="storm", times=1)])):
        p2 = fe.submit("er", [8])
        fe._dispatch_round()
        res = fe.wait(p2, timeout_s=10.0)
    np.testing.assert_array_equal(
        res.dist_host, bfs_reference(src, dst, 100, [8]))
    assert svc.cache.stats()["evictions"] >= 1
    assert svc.cache.stats()["misses"] == 2
