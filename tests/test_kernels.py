"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import block_sparse_adjacency, erdos_renyi
from repro.kernels.bsr_spmm import ops as spmm_ops
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref, frontier_expand_ref
from repro.kernels.embedding_bag import ops as bag_ops
from repro.kernels.embedding_bag.ref import (embedding_bag_mean_ref,
                                             embedding_bag_sum_ref)
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- bsr_spmm
@pytest.mark.parametrize("n,avg_deg,d", [
    (256, 4, 128), (384, 8, 64), (512, 3, 256), (128, 16, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_spmm_matches_ref(n, avg_deg, d, dtype):
    src, dst = erdos_renyi(n, avg_degree=avg_deg, seed=n + d)
    blocks, br, bc, n_pad = block_sparse_adjacency(src, dst, n, block=128)
    x = jax.random.normal(jax.random.fold_in(KEY, n + d), (n_pad, d), dtype)
    got = spmm_ops.spmm(jnp.asarray(blocks), jnp.asarray(br), jnp.asarray(bc),
                        x, n_rows_pad=n_pad, interpret=True)
    want = bsr_spmm_ref(jnp.asarray(blocks), jnp.asarray(br), jnp.asarray(bc),
                        x, n_rows_pad=n_pad)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=rtol)


def test_bsr_spmm_matches_dense_matmul():
    n = 300
    src, dst = erdos_renyi(n, avg_degree=6, seed=1)
    blocks, br, bc, n_pad = block_sparse_adjacency(src, dst, n, block=128)
    x = jax.random.normal(KEY, (n_pad, 128), jnp.float32)
    got = spmm_ops.spmm(jnp.asarray(blocks), jnp.asarray(br), jnp.asarray(bc),
                        x, n_rows_pad=n_pad, interpret=True)
    a = np.zeros((n_pad, n_pad), np.float32)
    a[src, dst] = 1.0
    np.testing.assert_allclose(np.asarray(got), a @ np.asarray(x),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s", [1, 8, 128])
def test_frontier_expand_kernel_is_bfs_level(s):
    n = 256
    src, dst = erdos_renyi(n, avg_degree=5, seed=7)
    blocks, br, bc, n_pad = block_sparse_adjacency(src, dst, n, block=128)
    f = np.zeros((n_pad, s), np.uint8)
    rng = np.random.default_rng(0)
    for j in range(s):
        f[rng.integers(0, n), j] = 1
    got = spmm_ops.frontier_expand(jnp.asarray(blocks), jnp.asarray(br),
                                   jnp.asarray(bc), jnp.asarray(f),
                                   n_rows_pad=n_pad, interpret=True)
    want = frontier_expand_ref(jnp.asarray(blocks), jnp.asarray(br),
                               jnp.asarray(bc), jnp.asarray(f),
                               n_rows_pad=n_pad)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # cross-check against explicit neighbor expansion
    for j in range(min(s, 4)):
        seeds = np.where(f[:, j])[0]
        nbrs = set(dst[np.isin(src, seeds)].tolist())
        got_set = set(np.where(np.asarray(got)[:, j])[0].tolist())
        assert got_set == nbrs


# ------------------------------------------------------------ embedding_bag
@pytest.mark.parametrize("b,l,v,d", [
    (8, 4, 64, 128), (16, 1, 32, 256), (4, 13, 128, 128), (32, 3, 1000, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sum(b, l, v, d, dtype):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, b * l + v))
    table = jax.random.normal(k1, (v, d), dtype)
    idx = jax.random.randint(k2, (b, l), -1, v)  # includes -1 pads
    got = bag_ops.embedding_bag(idx, table, mode="sum", interpret=True)
    want = embedding_bag_sum_ref(idx, table)
    rtol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol,
                               atol=rtol)


def test_embedding_bag_mean_and_all_padded():
    table = jnp.ones((16, 8), jnp.float32) * jnp.arange(16)[:, None]
    idx = jnp.array([[0, 2, -1], [-1, -1, -1]], jnp.int32)
    got = bag_ops.embedding_bag(idx, table, mode="mean", interpret=True)
    want = embedding_bag_mean_ref(idx, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert np.asarray(got)[1].sum() == 0  # empty bag -> zeros


def test_embedding_bag_property_sum_of_rows():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(1, 8), l=st.integers(1, 6), v=st.integers(2, 40),
           seed=st.integers(0, 999))
    def prop(b, l, v, seed):
        rng = np.random.default_rng(seed)
        table = jnp.asarray(rng.standard_normal((v, 16)), jnp.float32)
        idx = jnp.asarray(rng.integers(-1, v, (b, l)), jnp.int32)
        got = np.asarray(bag_ops.embedding_bag(idx, table, interpret=True))
        tn, xn = np.asarray(table), np.asarray(idx)
        for i in range(b):
            rows = [tn[j] for j in xn[i] if j >= 0]
            want = np.sum(rows, axis=0) if rows else np.zeros(16, np.float32)
            np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-5)

    prop()


# ---------------------------------------------------------- flash_attention
@pytest.mark.parametrize("b,hq,hkv,sq,dh", [
    (1, 4, 4, 256, 64),    # MHA
    (2, 8, 2, 128, 64),    # GQA 4:1
    (1, 8, 1, 256, 128),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, hq, hkv, sq, dh, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, b + hq + sq), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, dh), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sq, dh), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sq, dh), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    rtol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 512, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 384, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 384, 64), jnp.float32)
    got = flash_attention(q, k, v, causal=False, interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_small_blocks_equivalence():
    """Block size must not change the result (online softmax exactness)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    b = flash_attention(q, k, v, block_q=128, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
