"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and emits a ``BENCH_*.json``
(``--out``, default ``BENCH_results.json``) recording, for every
engine-measured workload, *compile* wall time and *per-run* execute time
separately — the amortization ledger of the plan→compile→run lifecycle
(one compile per (graph, options, mesh), then device-only traversals).

Paper tables reproduced:
  * fig3/fig4  — star-graph strong scaling (p = 8/16/32)
  * fig5/fig6  — Erdős-Rényi strong scaling (100k vertices, p = 1..64)
  * fig7/fig8  — small-world strong scaling (100k vertices, p = 1..64)
  * §5.1       — exchange-strategy communication volume (the two paper
                 optimizations), cross-checked against compiled HLO by
                 tests/helpers/exchange_bytes.py
  * §5.2       — owner-local update / collective-merge payload reduction
  * §Roofline  — per-(arch x shape x mesh) terms from the dry-run JSON

Runtime here is a single CPU; per-level compute is *measured* on the real
engine and communication seconds are *modeled* from the HLO-validated
per-chip byte model at v5e link bandwidth — the same separation of
computation vs communication cost the paper uses to explain its scaling
curves (§4.2).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

import jax

from repro.core import BFSOptions, Partition1D, plan
from repro.core import exchange as ex
from repro.graphs import generate, shard_graph
from repro.launch.hlo_stats import ICI_BW
from repro.launch.mesh import default_grid

_ROWS = []
_ENGINE_TIMINGS = {}   # bench key -> {compile_s, per_run_s, ...}
_PARTITION_SWEEP = []  # 1-D vs 2-D scheme rows (modeled + measured bytes)
_SERVING = {}          # multi-graph serving ledger (cold/warm/hit rate)
_WIRE_FORMAT = []      # packed vs bytes wire rows (own BENCH_wire_format
                       # ledger; see --wire-out)
_SERVING_LATENCY = {}  # remote front-end ledger: bucket ladder latencies +
                       # overload 429s (own BENCH_serving_latency ledger;
                       # see --serving-out)
_SPARSE_WIRE = []      # compressed sparse-id wire + sieve rows (own
                       # BENCH_sparse_wire ledger; see --sparse-wire-out)
_LATENCY = {}          # fused-tail latency-hiding ledger: per-level step
                       # times fused vs unfused + trace-validated roofline
                       # (own BENCH_latency ledger; see --latency-out)


def row(name: str, us: float, derived: str = ""):
    _ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _measure_bfs(kind, n, opts, sources=(0,), seed=0, reps=3, **gkw):
    """Compile one engine, then time device-only traversals.

    Returns (per_run_s, stats, n_edges); compile wall time is recorded
    in the JSON ledger under ``bfs/<kind>/n=<n>/...``.
    """
    src, dst = generate(kind, n, seed=seed, **gkw)
    g = shard_graph(src, dst, n, p=1)
    t0 = time.time()
    engine = plan(g, opts, num_sources=len(sources)).compile()
    compile_s = time.time() - t0
    res = engine.run(list(sources))  # warmup (first dispatch)
    t0 = time.time()
    for _ in range(reps):
        res = engine.run(list(sources))
    dt = (time.time() - t0) / reps
    stats = res.stats()
    key = (f"bfs/{kind}/n={n}/mode={opts.mode}/S={len(sources)}"
           f"/ex={opts.dense_exchange}/lu={int(opts.local_update)}")
    _ENGINE_TIMINGS[key] = {
        "compile_s": compile_s, "per_run_s": dt, "levels": stats.levels,
    }
    return dt, stats, src.shape[0]


def _scaling_table(tag, kind, n, ps, strategy, gkw, mode="dense"):
    """Paper-style strong scaling: measured compute (perfect E/p split of
    the single-shard measurement) + modeled per-level exchange time."""
    opts = BFSOptions(mode=mode, dense_exchange=strategy, queue_cap=1 << 14)
    dt, stats, edges = _measure_bfs(kind, n, opts, **gkw)
    for p in ps:
        comp = dt / p
        if mode == "dense":
            per_level = ex.dense_level_bytes(strategy, n, p, 1, 1)
        else:
            per_level = ex.queue_level_bytes(strategy, p, 1 << 14)
        comm = stats.levels * per_level / ICI_BW
        total = comp + comm
        row(f"{tag}/p={p}", total * 1e6,
            f"levels={stats.levels};comp_us={comp*1e6:.1f};"
            f"comm_us={comm*1e6:.1f};strategy={strategy}")


def bench_fig3_star_scaling():
    """Paper fig. 3/4: star graph; measured at a reduced vertex count on
    the CPU runner (the 4M-vertex configuration is in BFS_WORKLOADS and is
    what examples/bfs_scaling.py sizes against)."""
    n = 200_000
    _scaling_table("fig3_star", "star", n, (8, 16, 32), "allgather_merge", {})
    _scaling_table("fig3_star_opt", "star", n, (8, 16, 32),
                   "alltoall_direct", {})


def bench_fig5_erdos_renyi_scaling():
    n = 100_000
    _scaling_table("fig5_erdos_renyi", "erdos_renyi", n,
                   (1, 2, 4, 8, 16, 32, 64), "allgather_merge",
                   {"avg_degree": 16.0})
    _scaling_table("fig5_erdos_renyi_opt", "erdos_renyi", n,
                   (1, 2, 4, 8, 16, 32, 64), "alltoall_direct",
                   {"avg_degree": 16.0})


def bench_fig7_small_world_scaling():
    n = 100_000
    _scaling_table("fig7_small_world", "small_world", n,
                   (1, 2, 4, 8, 16, 32, 64), "allgather_merge",
                   {"k": 16, "beta": 0.1})
    _scaling_table("fig7_small_world_opt", "small_world", n,
                   (1, 2, 4, 8, 16, 32, 64), "alltoall_direct",
                   {"k": 16, "beta": 0.1})


def bench_sec51_exchange_volume():
    """Paper §5.1: per-level exchange bytes, baseline vs both optimized
    paths (values cross-checked against compiled HLO by the test suite)."""
    n, cap = 1_000_000, 1 << 12
    for p in (8, 64, 256, 512):
        base = ex.dense_level_bytes("allgather_merge", n, p)
        direct = ex.dense_level_bytes("alltoall_direct", n, p)
        rs = ex.dense_level_bytes("reduce_scatter", n, p)
        row(f"sec51_dense_bytes/p={p}", 0.0,
            f"baseline={base:.0f};direct={direct:.0f};"
            f"reduce_scatter={rs:.0f};ratio={base/direct:.1f}")
        qb = ex.queue_level_bytes("allgather_merge", p, cap)
        qd = ex.queue_level_bytes("alltoall_direct", p, cap)
        row(f"sec51_queue_bytes/p={p}", 0.0,
            f"baseline={qb:.0f};direct={qd:.0f};ratio={qb/qd:.1f}")


def bench_sec52_local_update():
    """Paper §5.1-(1)/§5.2: owner-local update + dedupe shrink the queue
    payload; engine-measured wall time and modeled comm bytes."""
    n = 50_000
    for lu in (False, True):
        opts = BFSOptions(mode="queue", local_update=lu, dedupe=lu,
                          queue_cap=1 << 15)
        dt, stats, edges = _measure_bfs("erdos_renyi", n, opts,
                                        avg_degree=16.0)
        row(f"sec52_queue_local_update={int(lu)}", dt * 1e6,
            f"levels={stats.levels};comm_bytes={stats.comm_bytes:.0f}")


def bench_direction_optimizing():
    """Beyond-paper: auto (queue/dense/bottom-up) vs fixed modes."""
    n = 100_000
    for mode in ("dense", "queue", "auto"):
        opts = BFSOptions(mode=mode, queue_cap=1 << 15)
        dt, stats, edges = _measure_bfs("rmat", n, opts, edge_factor=16)
        row(f"direction_opt/{mode}", dt * 1e6,
            f"levels={stats.levels};modes={stats.mode_counts};"
            f"comm_bytes={stats.comm_bytes:.0f}")


def bench_engine_amortization():
    """The API-lifecycle result on the paper's erdos_renyi_100k workload:
    one-shot plan+compile+run per traversal (what the old ``bfs()``
    entrypoint cost) vs compile-once ``engine.run`` over fresh sources.
    The per-traversal time excluding compile is the serving-path number."""
    n = 100_000
    src, dst = generate("erdos_renyi", n, seed=0, avg_degree=16.0)
    g = shard_graph(src, dst, n, p=1)
    opts = BFSOptions(mode="dense")

    t0 = time.time()
    engine = plan(g, opts, num_sources=1).compile()
    compile_s = time.time() - t0
    t0 = time.time()
    engine.run([0])
    first_run_s = time.time() - t0

    reps = 5
    t0 = time.time()
    for s in range(1, reps + 1):       # fresh source per run: no retrace
        engine.run([s * 7])
    per_run_s = (time.time() - t0) / reps
    assert engine.trace_count == engine.compile_traces

    t0 = time.time()
    plan(g, opts, num_sources=1).compile().run([0])  # seed-style one-shot
    one_shot_s = time.time() - t0

    row("engine_amortized/erdos_renyi_100k", per_run_s * 1e6,
        f"compile_us={compile_s*1e6:.0f};first_run_us={first_run_s*1e6:.0f};"
        f"one_shot_us={one_shot_s*1e6:.0f};"
        f"speedup_vs_one_shot={one_shot_s/per_run_s:.1f}x")
    _ENGINE_TIMINGS["amortization/erdos_renyi_100k"] = {
        "compile_s": compile_s, "first_run_s": first_run_s,
        "per_run_s": per_run_s, "one_shot_s": one_shot_s,
        "speedup_vs_one_shot": one_shot_s / per_run_s,
    }


def bench_partition_1d_vs_2d():
    """1-D vertex blocks vs 2-D edge blocks on erdos_renyi_100k.

    For each shard count: per-level *modeled* exchange bytes of both
    schemes (1-D dense alltoall over p shards vs 2-D row-allgather +
    column-fold over an r x c grid — the r+c vs p communication argument),
    plus *measured* engine traversals for every grid the local device set
    can host (per-run wall time and the run's accumulated comm bytes).
    Everything lands in the BENCH_*.json ``partition_sweep`` ledger keyed
    by partition kind so 1-D and 2-D trajectories never collapse.
    """
    n, s = 100_000, 1
    graph_name = "erdos_renyi_100k"
    cap = 1024                        # sparse-level id-buffer capacity

    for p in (1, 4, 16, 64):
        r, c = default_grid(p)
        n_pad = Partition1D(n, p).n
        one_d = ex.dense_level_bytes("alltoall_direct", n_pad, p, s, 1)
        two_d = ex.grid_level_bytes("allgather", "alltoall_reduce",
                                    n_pad, r, c, s, 1)
        two_d_sparse = ex.grid_sparse_level_bytes(
            "allgather", "alltoall_direct", r, c, cap)
        _PARTITION_SWEEP.append({
            "graph": graph_name, "partition": "1d", "mode": "dense",
            "p": p, "r": 1, "c": p,
            "modeled_level_bytes": one_d,
            "phase_bytes": {"alltoall": one_d},
        })
        _PARTITION_SWEEP.append({
            "graph": graph_name, "partition": "2d", "mode": "dense",
            "p": p, "r": r, "c": c,
            "modeled_level_bytes": two_d,
            "phase_bytes": {
                "expand": ex.get_exchange(
                    "expand_row", "allgather").bytes_model(n_pad, r, c, s, 1),
                "fold": ex.get_exchange(
                    "fold_col", "alltoall_reduce").bytes_model(
                        n_pad, r, c, s, 1)},
        })
        # sparse (queue) 2-D levels: per-phase id buffers — the narrow
        # first/last levels of a traversal ride these instead of bitmaps
        _PARTITION_SWEEP.append({
            "graph": graph_name, "partition": "2d", "mode": "sparse",
            "p": p, "r": r, "c": c, "queue_cap": cap,
            "modeled_level_bytes": two_d_sparse,
            "phase_bytes": {
                "expand_sparse": ex.get_exchange(
                    "expand_row_sparse", "allgather").bytes_model(
                        r, c, cap, 4),
                "fold_sparse": ex.get_exchange(
                    "fold_col_sparse", "alltoall_direct").bytes_model(
                        r, c, cap, 4)},
        })
        ratio = one_d / two_d if two_d else float("inf")
        row(f"partition_bytes/p={p}", 0.0,
            f"1d={one_d:.0f};2d={two_d:.0f};2d_sparse={two_d_sparse:.0f};"
            f"grid={r}x{c};ratio={ratio:.2f}")

    # measured: every grid the local device set can host (p=1 always; the
    # CI 4-device runners also measure the real 2x2 collectives)
    src, dst = generate("erdos_renyi", n, seed=0, avg_degree=16.0)
    p_avail = jax.device_count()
    for p in {1, 4} & set(range(1, p_avail + 1)):
        import numpy as _np
        from jax.sharding import Mesh
        g = shard_graph(src, dst, n, p)
        r, c = default_grid(p)
        meshes = {
            "1d": (Mesh(_np.asarray(jax.devices()[:p]).reshape(p), ("p",)),
                   "p"),
            "2d": (Mesh(_np.asarray(jax.devices()[:p]).reshape(r, c),
                        ("rows", "cols")), None),
        }
        for kind, (mesh, axis) in meshes.items():
            t0 = time.time()
            eng = plan(g, BFSOptions(mode="dense"), mesh=mesh, axis=axis,
                       num_sources=s, partition=kind).compile()
            compile_s = time.time() - t0
            res = eng.run([0])             # warmup
            t0 = time.time()
            for i in range(3):
                res = eng.run([7 * i + 1])
            per_run = (time.time() - t0) / 3
            stats = res.stats()
            kr, kc = (r, c) if kind == "2d" else (1, p)
            _PARTITION_SWEEP.append({
                "graph": graph_name, "partition": kind, "p": p, "r": kr,
                "c": kc, "measured": True, "compile_s": compile_s,
                "per_run_s": per_run, "levels": stats.levels,
                "run_comm_bytes": stats.comm_bytes,
                "modeled_level_bytes": (stats.comm_bytes / stats.levels
                                        if stats.levels else 0.0),
            })
            row(f"partition_measured/{kind}/p={p}", per_run * 1e6,
                f"levels={stats.levels};comm_bytes={stats.comm_bytes:.0f};"
                f"compile_us={compile_s*1e6:.0f}")

    # direction-optimizing 2-D: measured per-level mode split on a
    # narrow-frontier graph (most levels ride the sparse phases) and on
    # the er workload (hybrid dense/bottom-up middle)
    for kind_name, gen_kw, n_small in (("chain", {}, 2_000),
                                       ("erdos_renyi",
                                        {"avg_degree": 16.0}, n)):
        gsrc, gdst = generate(kind_name, n_small, seed=0, **gen_kw)
        g = shard_graph(gsrc, gdst, n_small, 1)
        eng = plan(g, BFSOptions(mode="auto", queue_cap=1024),
                   num_sources=1, partition="2d").compile()
        res = eng.run([0])
        st = res.stats()
        _PARTITION_SWEEP.append({
            "graph": f"{kind_name}_{n_small}", "partition": "2d",
            "mode": "auto", "p": 1, "r": 1, "c": 1, "measured": True,
            "levels": st.levels, "mode_counts": st.mode_counts,
            "run_comm_bytes": st.comm_bytes,
        })
        row(f"partition_modes/2d_auto/{kind_name}", 0.0,
            f"levels={st.levels};modes={st.mode_counts};"
            f"comm_bytes={st.comm_bytes:.0f}")


def bench_wire_format_sweep():
    """Packed-bitset vs byte-mask dense wire format (the §5-adjacent
    "Compression and Sieve" optimization).

    Modeled rows price the per-level dense exchange of both formats for
    both partition schemes at growing shard counts (packed words model
    8× below the uint8 mask).  Measured rows compile real engines per
    (wire_format, partition) on every shard count the local device set
    hosts and record (a) the run's accumulated per-level exchange bytes
    and (b) the collective bytes XLA actually emitted in the compiled
    loop body (``hlo_stats.collective_bytes`` over the engine
    executable) — compiler ground truth for the on-wire reduction.  A
    final row per p records what ``wire_format="auto"`` resolved to.
    Everything lands in the ``BENCH_wire_format.json`` ledger
    (``--wire-out``), rendered by ``render_roofline.py``.
    """
    import numpy as _np
    from jax.sharding import Mesh
    from repro.launch.hlo_stats import collective_bytes
    from repro.launch.mesh import make_grid_mesh

    n_model, s = 100_000, 1
    pairs_1d = (("bytes", "alltoall_direct"),
                ("packed", "alltoall_direct_packed"))
    pairs_2d = (("bytes", ("allgather", "alltoall_reduce")),
                ("packed", ("allgather_packed", "alltoall_reduce_packed")))

    for p in (4, 16, 64):
        r, c = default_grid(p)
        n_pad = Partition1D(n_model, p).n
        modeled = {}
        for fmt, strat in pairs_1d:
            b = ex.dense_level_bytes(strat, n_pad, p, s, 1)
            modeled[("1d", fmt)] = b
            _WIRE_FORMAT.append({
                "graph": f"erdos_renyi_{n_model // 1000}k",
                "partition": "1d", "wire_format": fmt, "p": p, "r": 1,
                "c": p, "strategy": strat, "modeled_level_bytes": b,
            })
        for fmt, (es, fs) in pairs_2d:
            b = ex.grid_level_bytes(es, fs, n_pad, r, c, s, 1)
            modeled[("2d", fmt)] = b
            _WIRE_FORMAT.append({
                "graph": f"erdos_renyi_{n_model // 1000}k",
                "partition": "2d", "wire_format": fmt, "p": p, "r": r,
                "c": c, "strategy": f"{es}+{fs}", "modeled_level_bytes": b,
            })
        row(f"wire_modeled/p={p}", 0.0,
            f"1d_bytes={modeled['1d', 'bytes']:.0f};"
            f"1d_packed={modeled['1d', 'packed']:.0f};"
            f"2d_bytes={modeled['2d', 'bytes']:.0f};"
            f"2d_packed={modeled['2d', 'packed']:.0f};"
            f"ratio_1d={modeled['1d', 'bytes'] / modeled['1d', 'packed']:.1f}")

    # measured: real engines on the local device set (CI's 4-device job
    # measures the p=4 collectives; smaller n keeps the CPU loop fast)
    n_meas = 20_000
    src, dst = generate("erdos_renyi", n_meas, seed=0, avg_degree=16.0)
    p_avail = jax.device_count()
    for p in sorted({1, 4} & set(range(1, p_avail + 1))):
        g = shard_graph(src, dst, n_meas, p)
        r, c = default_grid(p)
        meshes = {
            "1d": (Mesh(_np.asarray(jax.devices()[:p]).reshape(p), ("p",)),
                   "p"),
            "2d": (make_grid_mesh(r, c), None),
        }
        for kind, (mesh, axis) in meshes.items():
            meas, hlo_meas = {}, {}
            for fmt in ("bytes", "packed"):
                pl = plan(g, BFSOptions(mode="dense", wire_format=fmt),
                          mesh=mesh, axis=axis, num_sources=s,
                          partition=kind)
                t0 = time.time()
                eng = pl.compile()
                compile_s = time.time() - t0
                res = eng.run([0])                 # warmup
                t0 = time.time()
                for i in range(3):
                    res = eng.run([7 * i + 1])
                per_run = (time.time() - t0) / 3
                stats = res.stats()
                hlo = collective_bytes(eng.compiled_hlo())
                level_bytes = (stats.comm_bytes / stats.levels
                               if stats.levels else 0.0)
                meas[fmt] = level_bytes
                hlo_meas[fmt] = hlo["total"]
                meta = pl.describe()
                _WIRE_FORMAT.append({
                    "graph": f"erdos_renyi_{n_meas // 1000}k",
                    "partition": kind, "wire_format": fmt, "p": p,
                    "r": r if kind == "2d" else 1,
                    "c": c if kind == "2d" else p, "measured": True,
                    "levels": stats.levels, "per_run_s": per_run,
                    "compile_s": compile_s,
                    "run_comm_bytes": stats.comm_bytes,
                    "measured_level_bytes": level_bytes,
                    "hlo_collective_bytes": hlo["total"],
                    "wire_formats": meta["wire_formats"],
                })
                row(f"wire_measured/{kind}/p={p}/{fmt}", per_run * 1e6,
                    f"levels={stats.levels};level_bytes={level_bytes:.0f};"
                    f"hlo_collective_bytes={hlo['total']:.0f}")
            if p > 1:
                # the tentpole claim, checked on compiler ground truth:
                # the collective buffer bytes XLA emitted for the packed
                # loop must be >= 4x below the bytes loop's (the run-stat
                # ratio is the analytic model and would hold trivially)
                assert (hlo_meas["bytes"] / max(hlo_meas["packed"], 1)
                        >= 4), hlo_meas
            # what "auto" resolves to at this topology (packed for dense
            # phases whenever p > 1 — the byte model decides)
            auto_meta = plan(g, BFSOptions(mode="dense", wire_format="auto"),
                             mesh=mesh, axis=axis, num_sources=s,
                             partition=kind).describe()
            _WIRE_FORMAT.append({
                "graph": f"erdos_renyi_{n_meas // 1000}k",
                "partition": kind, "wire_format": "auto", "p": p,
                "r": r if kind == "2d" else 1,
                "c": c if kind == "2d" else p,
                "resolved": auto_meta["wire_formats"],
            })
            row(f"wire_auto/{kind}/p={p}", 0.0,
                f"resolved={auto_meta['wire_formats']}")


def bench_sparse_wire_sweep():
    """Compressed sparse-id wire + visited sieve ("Compression and
    Sieve", the sparse-phase half of the adaptive wire stack).

    Modeled rows price the per-level sparse exchanges — 1-D queue and
    2-D expand/fold id buffers — raw int32 ids vs the delta+varint
    compressed payload at paper-like frontier densities (the codec's
    bitmap-adaptive branch shows up as the capacity clamp at high
    density).  Measured rows compile each sparse exchange *standalone*
    under shard_map on the local device set and parse the collective
    bytes XLA emitted (the engine loop's HLO carries identical
    dense-escalation-branch collectives under both wires, so the sparse
    phase must be isolated — the same compile_and_parse pattern as
    tests/helpers/exchange_bytes.py), asserting the >= 2x on-wire cut
    at p = 4.  Engine rows run queue-mode traversals raw vs compressed
    with the sieve on/off (bitwise-identical distances required) and a
    final row per topology records what ``wire_format="auto"`` /
    ``sieve="auto"`` resolved.  Everything lands in the
    ``BENCH_sparse_wire.json`` ledger (``--sparse-wire-out``).
    """
    import functools
    import numpy as _np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import frontier as frmod
    from repro.core.compat import shard_map
    from repro.launch.hlo_stats import collective_bytes
    from repro.launch.mesh import make_grid_mesh

    cap = 256

    # --- modeled: raw vs compressed sparse-level bytes across densities
    for p in (4, 16, 64):
        r, c = default_grid(p)
        for density in (0.03125, 0.5):
            q_raw = ex.queue_level_bytes("alltoall_direct", p, cap, 4,
                                         density=density)
            q_comp = ex.queue_level_bytes("alltoall_direct_compressed", p,
                                          cap, 4, density=density)
            g_raw = ex.grid_sparse_level_bytes(
                "allgather", "alltoall_direct", r, c, cap, 4,
                density=density)
            g_comp = ex.grid_sparse_level_bytes(
                "allgather_compressed", "alltoall_direct_compressed",
                r, c, cap, 4, density=density)
            _SPARSE_WIRE.append({
                "kind": "modeled", "p": p, "r": r, "c": c, "cap": cap,
                "density": density,
                "queue_raw_bytes": q_raw, "queue_compressed_bytes": q_comp,
                "grid_sparse_raw_bytes": g_raw,
                "grid_sparse_compressed_bytes": g_comp,
            })
            row(f"sparse_wire_modeled/p={p}/density={density}", 0.0,
                f"queue_raw={q_raw:.0f};queue_comp={q_comp:.0f};"
                f"ratio_q={q_raw / q_comp:.1f};grid_raw={g_raw:.0f};"
                f"grid_comp={g_comp:.0f};ratio_g={g_raw / g_comp:.1f}")

    # --- measured: standalone sparse exchanges vs compiled-HLO bytes
    def hlo_total(fn, in_specs, out_specs, shapes, mesh):
        mapped = shard_map(fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
        lowered = jax.jit(mapped).lower(*shapes)
        return collective_bytes(lowered.compile().as_text())["total"]

    import jax.numpy as jnp
    if jax.device_count() >= 4:
        p, density = 4, 0.5
        bc = frmod.compressed_capacity(cap, int(cap / density))
        mesh1 = Mesh(_np.asarray(jax.devices()[:p]).reshape(p), ("p",))
        q_raw_hlo = hlo_total(
            functools.partial(ex.exchange_queue, axis="p",
                              strategy="alltoall_direct"),
            P(None, None), P(None, None),
            (jax.ShapeDtypeStruct((p, cap), jnp.int32),), mesh1)
        q_comp_hlo = hlo_total(
            functools.partial(ex.exchange_queue, axis="p",
                              strategy="alltoall_direct_compressed"),
            P(None, None), P(None, None),
            (jax.ShapeDtypeStruct((p, bc), jnp.uint8),), mesh1)

        r, c = 2, 2
        mesh2 = make_grid_mesh(r, c)
        exp_raw = ex.get_exchange("expand_row_sparse", "allgather")
        exp_comp = ex.get_exchange("expand_row_sparse",
                                   "allgather_compressed")
        fold_raw = ex.get_exchange("fold_col_sparse", "alltoall_direct")
        fold_comp = ex.get_exchange("fold_col_sparse",
                                    "alltoall_direct_compressed")
        g_raw_hlo = hlo_total(
            lambda x: exp_raw.impl(x, "cols"), P(None), P(None),
            (jax.ShapeDtypeStruct((cap,), jnp.int32),), mesh2
        ) + hlo_total(
            lambda x: fold_raw.impl(x, "rows"), P(None, None), P(None, None),
            (jax.ShapeDtypeStruct((r, cap), jnp.int32),), mesh2)
        g_comp_hlo = hlo_total(
            lambda x: exp_comp.impl(x, "cols"), P(None), P(None),
            (jax.ShapeDtypeStruct((bc,), jnp.uint8),), mesh2
        ) + hlo_total(
            lambda x: fold_comp.impl(x, "rows"), P(None, None),
            P(None, None),
            (jax.ShapeDtypeStruct((r, bc), jnp.uint8),), mesh2)

        # the tentpole claim on compiler ground truth: >= 2x fewer
        # sparse-phase collective bytes at p = 4 under the compressed wire
        assert q_raw_hlo / max(q_comp_hlo, 1) >= 2.0, (q_raw_hlo,
                                                       q_comp_hlo)
        assert g_raw_hlo / max(g_comp_hlo, 1) >= 2.0, (g_raw_hlo,
                                                       g_comp_hlo)
        _SPARSE_WIRE.append({
            "kind": "measured_hlo", "p": p, "r": r, "c": c, "cap": cap,
            "density": density, "payload_bytes": bc,
            "queue_raw_hlo_bytes": q_raw_hlo,
            "queue_compressed_hlo_bytes": q_comp_hlo,
            "grid_sparse_raw_hlo_bytes": g_raw_hlo,
            "grid_sparse_compressed_hlo_bytes": g_comp_hlo,
        })
        row(f"sparse_wire_hlo/p={p}", 0.0,
            f"queue_raw={q_raw_hlo:.0f};queue_comp={q_comp_hlo:.0f};"
            f"ratio_q={q_raw_hlo / max(q_comp_hlo, 1):.1f};"
            f"grid_raw={g_raw_hlo:.0f};grid_comp={g_comp_hlo:.0f};"
            f"ratio_g={g_raw_hlo / max(g_comp_hlo, 1):.1f}")
    else:
        row("sparse_wire_hlo/skipped", 0.0,
            f"device_count={jax.device_count()}<4 (the 4-device CI job "
            "measures the real collectives)")

    # --- engine rows: queue-mode traversals, raw vs compressed + sieve
    n_meas = 20_000
    src, dst = generate("erdos_renyi", n_meas, seed=0, avg_degree=8.0)
    p_avail = jax.device_count()
    for p in sorted({1, 4} & set(range(1, p_avail + 1))):
        g = shard_graph(src, dst, n_meas, p)
        mesh = Mesh(_np.asarray(jax.devices()[:p]).reshape(p), ("p",))
        dists = {}
        for fmt in ("bytes", "compressed"):
            for sieve in (False, True):
                pl = plan(g, BFSOptions(mode="queue", wire_format=fmt,
                                        sieve=sieve, queue_cap=1 << 14),
                          mesh=mesh, axis="p", num_sources=1)
                t0 = time.time()
                eng = pl.compile()
                compile_s = time.time() - t0
                res = eng.run([0])
                h = res.run_stats.to_host()
                dists[(fmt, sieve)] = res.dist_host
                meta = pl.describe()
                _SPARSE_WIRE.append({
                    "kind": "engine", "p": p, "wire_format": fmt,
                    "sieve": sieve, "queue_cap": 1 << 14,
                    "graph": f"erdos_renyi_{n_meas // 1000}k",
                    "compile_s": compile_s, "levels": h["levels"],
                    "run_comm_bytes": h["comm_bytes"],
                    "sieve_hits": h["sieve_hits"],
                    "queue_level_bytes": meta["queue_level_bytes"],
                    "resolved_queue": meta["queue_exchange"],
                })
                row(f"sparse_wire_engine/p={p}/{fmt}/sieve={int(sieve)}",
                    0.0, f"levels={h['levels']};"
                    f"comm_bytes={h['comm_bytes']:.0f};"
                    f"sieve_hits={h['sieve_hits']}")
        # every wire x sieve combination must land bitwise-identical
        base = dists["bytes", False]
        assert all(_np.array_equal(d, base) for d in dists.values())

        # what auto resolves at this topology (records the adaptive stack)
        for part_kind in ("1d",) if p == 1 else ("1d", "2d"):
            r, c = default_grid(p) if part_kind == "2d" else (1, p)
            kmesh = make_grid_mesh(r, c) if part_kind == "2d" else mesh
            meta = plan(g, BFSOptions(mode="auto", wire_format="auto",
                                      sieve="auto", queue_cap=1024),
                        mesh=kmesh, axis="p" if part_kind == "1d" else None,
                        num_sources=1, partition=part_kind).describe()
            _SPARSE_WIRE.append({
                "kind": "auto_resolution", "p": p, "partition": part_kind,
                "resolved": meta["wire_formats"], "sieve": meta["sieve"],
            })
            row(f"sparse_wire_auto/{part_kind}/p={p}", 0.0,
                f"resolved={meta['wire_formats']};sieve={meta['sieve']}")


def bench_multi_graph_serving():
    """Multi-tenant serving: cross-graph compile amortization.

    Phase 1 (unbounded cache): register N graphs in one ``BFSService``
    and measure, per graph, the *cold* path (plan + compile through the
    shared ``EngineCache``) vs the *warm* path (cache hit + device-only
    run) — the amortization the cache buys every tenant after its first
    request.

    Phase 2 (byte budget sized to hold only part of the engine set):
    deal requests round-robin across all graphs so the LRU working set
    exceeds the budget — engines evict and recompile, and the ledger
    records the achieved hit rate and eviction count.  This is the cost
    envelope of over-subscribed multi-tenant serving.
    """
    from repro.serve.bfs_service import BFSService, TraversalRequest
    from repro.serve.engine_cache import EngineCache, GraphCatalog

    n = 20_000
    families = [
        ("er", "erdos_renyi", n, {"avg_degree": 8.0}),
        ("star", "star", n, {}),
        # chain traverses one level per vertex — keep it small so the
        # deep-traversal tenant doesn't dominate the serving rounds
        ("chain", "chain", 1_000, {}),
        ("rmat", "rmat", n, {"edge_factor": 8}),
    ]
    slots = 2
    opts = BFSOptions(mode="dense")
    graphs = {}
    for name, kind, gn, kw in families:
        src, dst = generate(kind, gn, seed=0, **kw)
        graphs[name] = shard_graph(src, dst, gn, p=1)

    # phase 1: cold compile vs warm run, unbounded budget
    cache = EngineCache()
    svc = BFSService(opts=opts, batch_slots=slots, cache=cache,
                     catalog=GraphCatalog())
    per_graph = {}
    for name, g in graphs.items():
        svc.add_graph(name, g)
    for rid, name in enumerate(graphs):
        t0 = time.time()
        svc.submit(TraversalRequest(rid=rid, source=0, graph=name))
        svc.run_until_drained()
        cold_s = time.time() - t0              # includes the lane's compile
        t0 = time.time()
        svc.submit(TraversalRequest(rid=100 + rid, source=1, graph=name))
        svc.run_until_drained()
        warm_s = time.time() - t0              # cache hit + device-only run
        per_graph[name] = {"cold_ms": cold_s * 1e3, "warm_ms": warm_s * 1e3,
                           "amortization": cold_s / max(warm_s, 1e-9)}
        row(f"serving_cold_vs_warm/{name}", warm_s * 1e6,
            f"cold_ms={cold_s*1e3:.1f};warm_ms={warm_s*1e3:.1f};"
            f"amortization={cold_s/max(warm_s, 1e-9):.1f}x")
    st = cache.stats()
    assert st["misses"] == len(graphs), st     # each plan compiled once
    total_engine_bytes = st["device_bytes"]    # whole fleet, all 4 engines

    # phase 2: budget admits ~half the engines -> forced LRU eviction
    budget = max(1, total_engine_bytes // 2)
    cache2 = EngineCache(max_device_bytes=budget)
    svc2 = BFSService(opts=opts, batch_slots=slots, cache=cache2,
                      catalog=GraphCatalog())
    for name, g in graphs.items():
        svc2.add_graph(name, g)
    t0 = time.time()
    rounds = 3
    for k in range(rounds):
        for rid, name in enumerate(graphs):
            svc2.submit(TraversalRequest(rid=k * 100 + rid, source=k,
                                         graph=name))
        svc2.run_until_drained()
    wall_s = time.time() - t0
    st2 = cache2.stats()
    assert st2["evictions"] >= 1, st2          # the budget must bind
    row("serving_under_budget", wall_s / (rounds * len(graphs)) * 1e6,
        f"budget_bytes={budget};evictions={st2['evictions']};"
        f"hit_rate={st2['hit_rate']:.2f};"
        f"recompiles={st2['misses'] - len(graphs)}")
    _SERVING.update({
        "graphs": per_graph,
        "unbounded": st,
        "eviction_pass": {"budget_bytes": budget, "rounds": rounds,
                          "wall_s": wall_s, **st2},
    })


def bench_serving_latency():
    """Remote front-end: bucket-ladder latency + bounded-queue overload.

    Drives the transport-agnostic ``BFSFrontend`` in process (the same
    submit/dispatch/complete path ``POST /v1/traverse`` rides, minus
    HTTP framing) over one lane compiled at the 1/8/64 bucket ladder.

    Phase 1 — per batch size: the *cold* request (first touch of its
    bucket pays the compile through the shared cache) vs *warm* repeats,
    with the dispatcher's own queue-wait/device split from the response
    timing.  Batch 3 lands between rungs and must be served by bucket 8
    — its warm per-source cost is the price of ladder padding.

    Phase 2 — overload: queue bound 1 with the dispatcher parked, then
    a synchronized 8-client burst.  Exactly one request is admitted and
    the rest get 429s with retry-after hints; the dispatcher then starts
    and drains the survivor.  Deterministic *and* concurrent.
    """
    import threading as _threading

    from repro.serve.bfs_service import BFSService
    from repro.serve.engine_cache import EngineCache, GraphCatalog
    from repro.serve.frontend import AdmissionError, BFSFrontend

    n, ladder = 20_000, (1, 8, 64)
    src, dst = generate("erdos_renyi", n, seed=0, avg_degree=8.0)
    g = shard_graph(src, dst, n, p=1)
    svc = BFSService(opts=BFSOptions(mode="dense"), batch_buckets=ladder,
                     cache=EngineCache(), catalog=GraphCatalog())
    svc.add_graph("er", g)

    fe = BFSFrontend(svc, max_queue_depth=64)
    per_batch = {}
    reps = 3
    for batch in (1, 8, 3, 64):        # 3 after 8: its bucket is pre-warmed
        t0 = time.time()
        out = fe.traverse("er", list(range(batch)))
        cold_s = time.time() - t0
        t0 = time.time()
        for i in range(reps):
            out = fe.traverse(
                "er", [(batch * 7 + i * 131 + v) % n for v in range(batch)])
        warm_s = (time.time() - t0) / reps
        per_batch[batch] = {
            "bucket": out["bucket"], "cold_ms": cold_s * 1e3,
            "warm_ms": warm_s * 1e3,
            "warm_us_per_source": warm_s * 1e6 / batch,
            "timing_ms": out["timing_ms"],
        }
        row(f"serving_latency/batch={batch}", warm_s * 1e6 / batch,
            f"bucket={out['bucket']};cold_ms={cold_s*1e3:.1f};"
            f"warm_ms={warm_s*1e3:.1f};"
            f"queue_wait_ms={out['timing_ms']['queue_wait']:.1f};"
            f"device_ms={out['timing_ms']['device']:.1f}")
    assert per_batch[3]["bucket"] == 8, per_batch   # between-rung routing
    lane_snap = fe.metrics_payload()
    fe.shutdown()

    clients = 8
    fe2 = BFSFrontend(svc, max_queue_depth=1, start_dispatcher=False)
    admitted, rejected = [], []
    lock = _threading.Lock()
    barrier = _threading.Barrier(clients)

    def fire(i):
        barrier.wait()
        try:
            p = fe2.submit("er", [i])
            with lock:
                admitted.append(p)
        except AdmissionError as exc:
            with lock:
                rejected.append(exc)

    threads = [_threading.Thread(target=fire, args=(i,))
               for i in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(admitted) == 1 and len(rejected) == clients - 1, (
        len(admitted), len(rejected))
    fe2.start()                        # un-park: drain the one survivor
    for p in admitted:
        fe2.wait(p, timeout_s=60.0)
    fe2.shutdown()
    row("serving_overload", 0.0,
        f"clients={clients};queue_depth=1;admitted={len(admitted)};"
        f"rejected_429={len(rejected)};"
        f"retry_after_s={rejected[0].retry_after_s:.3f}")

    _SERVING_LATENCY.update({
        "ladder": list(ladder),
        "graph": {"kind": "erdos_renyi", "n": n, "avg_degree": 8.0},
        "batches": {str(k): v for k, v in sorted(per_batch.items())},
        "overload": {
            "clients": clients, "queue_depth": 1,
            "admitted": len(admitted), "rejected_429": len(rejected),
            "retry_after_s": sorted(round(e.retry_after_s, 3)
                                    for e in rejected),
        },
        "lane_metrics": lane_snap["lanes"]["er"],
        "engine_cache": lane_snap["engine_cache"],
    })


def bench_latency():
    """Fused fold/owner-update tail + collective-compute overlap (the
    profile-driven latency-hiding stack), fused vs the unfused baseline
    on the same packed wire at p = 4 over the 2x2 grid.

    Step-time rows: dense and auto modes on a sparse Erdős-Rényi
    workload (avg_degree 2 — where the tail's eliminated byte passes
    are the largest share of the level).  The asserted >= 1.15x
    improvement is the *modeled* per-level step time from the
    describe() roofline (v5e bandwidths), weighted by the run's
    measured per-mode level counts — the same compiler-/model-ground-
    truth convention the wire benches use, because on the CPU host
    backend wall time is per-op dispatch + barrier wait, not bandwidth
    (the measured wall ratio is recorded honestly next to it).  The
    auto rows disable queue escalation (``queue_threshold=0``) so every
    level rides the dense/bottom-up phases the fused tail optimizes;
    the sparse path has its own ledger (BENCH_sparse_wire).

    Roofline validation (the model must be *measured*, not assumed):
    one small dense traversal per variant — sized so the profiler's
    event buffer does not truncate — is captured with ``jax.profiler``
    and parsed by ``analysis.trace_model``.  The calibration scale
    (host seconds per modeled v5e second) is fit on the *unfused*
    engine's compute phases only, then the *fused* engine's measured
    compute must land within 3x of the calibrated prediction — a
    cross-engine check the fit cannot satisfy by construction.  The
    collective term is validated in the byte domain instead (modeled
    wire bytes vs the collective bytes in the compiled HLO, within
    3x): measured collective *durations* on the host backend are
    barrier wait, which no wire model should be tuned to reproduce.
    """
    if jax.device_count() < 4:
        row("latency/skipped", 0.0,
            f"device_count={jax.device_count()}<4 (the 4-device CI job "
            "measures the 2x2 grid)")
        return

    import shutil
    import tempfile

    from repro.analysis import trace_model
    from repro.launch.hlo_stats import collective_bytes
    from repro.launch.mesh import make_grid_mesh

    mesh = make_grid_mesh(2, 2)
    compute_phases = ("expand", "fold", "owner_update")

    def weighted_model_step(meta, mode_counts):
        rf = meta["roofline"]
        total = sum(mode_counts.values()) or 1
        return sum(rf[k]["t_level_s"] * v
                   for k, v in mode_counts.items()) / total

    # --- step-time rows: fused vs unfused, dense + auto ----------------
    n, deg, reps = 30_000, 2.0, 3
    src, dst = generate("erdos_renyi", n, seed=0, avg_degree=deg)
    g = shard_graph(src, dst, n, 4)
    mode_rows = {}
    for mode, extra in (("dense", {}), ("auto", {"queue_threshold": 0.0})):
        variants = {}
        for label, fused in (("unfused", False), ("fused", True)):
            opts = BFSOptions(mode=mode, wire_format="packed",
                              use_fused_tail=fused, queue_cap=1 << 12,
                              **extra)
            pl = plan(g, opts, mesh=mesh, num_sources=1, partition="2d")
            t0 = time.time()
            eng = pl.compile()
            compile_s = time.time() - t0
            res = eng.run([0])                 # warmup
            best = float("inf")
            for i in range(reps):
                t0 = time.time()
                res = eng.run([7 * i + 1])
                best = min(best, time.time() - t0)
            stats = res.stats()
            meta = pl.describe()
            variants[label] = {
                "use_fused_tail": meta["use_fused_tail"],
                "levels": stats.levels,
                "mode_counts": stats.mode_counts,
                "compile_s": compile_s,
                "wall_per_level_s": best / max(1, stats.levels),
                "model_per_level_s": weighted_model_step(
                    meta, stats.mode_counts),
                "roofline": meta["roofline"],
            }
        un, fu = variants["unfused"], variants["fused"]
        # both variants must have traversed the same level/mode profile
        # for the per-level comparison to be meaningful
        assert un["mode_counts"] == fu["mode_counts"], (un, fu)
        improvement = un["model_per_level_s"] / fu["model_per_level_s"]
        wall_ratio = un["wall_per_level_s"] / fu["wall_per_level_s"]
        mode_rows[mode] = {**{"variants": variants},
                           "model_step_improvement": improvement,
                           "wall_step_ratio": wall_ratio}
        row(f"latency/{mode}", fu["wall_per_level_s"] * 1e6,
            f"levels={fu['levels']};modes={fu['mode_counts']};"
            f"model_improvement={improvement:.2f}x;"
            f"wall_ratio={wall_ratio:.2f}x")
        # the tentpole claim: >= 1.15x modeled per-level step-time win
        # for the fused+overlap plan in both modes
        assert improvement >= 1.15, (mode, improvement)

    # --- roofline validation: traced compute + HLO collective bytes ----
    nv, degv = 2048, 8.0
    vsrc, vdst = generate("erdos_renyi", nv, seed=0, avg_degree=degv)
    gv = shard_graph(vsrc, vdst, nv, 4)
    traced = {}
    for label, fused in (("unfused", False), ("fused", True)):
        opts = BFSOptions(mode="dense", wire_format="packed",
                          use_fused_tail=fused)
        pl = plan(gv, opts, mesh=mesh, num_sources=1, partition="2d")
        eng = pl.compile()
        res = eng.run([0])                     # warmup outside the trace
        logdir = tempfile.mkdtemp(prefix=f"bench_latency_{label}_")
        try:
            with trace_model.capture(logdir):
                res = eng.run([1])
            stats = res.stats()
            t = trace_model.parse_trace(logdir, n_levels=stats.levels)
        finally:
            shutil.rmtree(logdir, ignore_errors=True)
        # a truncated trace silently undercounts phases — refuse it
        assert t.n_ops < 900_000, f"profiler event buffer hit: {t.n_ops}"
        rf = pl.describe()["roofline"]["dense"]
        traced[label] = {
            "levels": stats.levels,
            "n_ops": t.n_ops,
            "level_segments": len(t.levels),
            "measured_compute_per_level_s":
                sum(t.total_s[p] for p in compute_phases)
                / max(1, stats.levels),
            "measured_collective_per_level_s":
                t.total_s["collective"] / max(1, stats.levels),
            "model_compute_per_level_s": rf["t_compute_s"],
            "model_wire_bytes_per_level": rf["wire_bytes"],
            "hlo_collective_bytes_per_level":
                collective_bytes(eng.compiled_hlo())["total"],
        }
    un, fu = traced["unfused"], traced["fused"]
    scale = (un["measured_compute_per_level_s"]
             / un["model_compute_per_level_s"])
    predicted = scale * fu["model_compute_per_level_s"]
    compute_ratio = fu["measured_compute_per_level_s"] / predicted
    wire_ratios = {
        label: tr["hlo_collective_bytes_per_level"]
               / max(1.0, tr["model_wire_bytes_per_level"])
        for label, tr in traced.items()}
    row("latency/roofline_validation", 0.0,
        f"scale={scale:.3e};compute_pred_ratio={compute_ratio:.2f};"
        f"wire_hlo_ratio_unfused={wire_ratios['unfused']:.2f};"
        f"wire_hlo_ratio_fused={wire_ratios['fused']:.2f}")
    assert 1 / 3 <= compute_ratio <= 3, compute_ratio
    for label, wr in wire_ratios.items():
        assert 1 / 3 <= wr <= 3, (label, wr)

    _LATENCY.update({
        "graph": {"kind": "erdos_renyi", "n": n, "avg_degree": deg},
        "grid": "2x2", "p": 4, "wire_format": "packed",
        "modes": mode_rows,
        "per_level_step_time_improvement": {
            m: r["model_step_improvement"] for m, r in mode_rows.items()},
        "trace_validation": {
            "graph": {"kind": "erdos_renyi", "n": nv, "avg_degree": degv},
            "engines": traced,
            "calibration_scale": scale,
            "fused_compute_pred_vs_measured": compute_ratio,
            "wire_model_vs_hlo": wire_ratios,
            "note": ("calibration fit on the unfused engine's compute "
                     "phases; collective term validated in the byte "
                     "domain (host-backend collective durations are "
                     "barrier wait)"),
        },
    })


def bench_multi_source_throughput():
    """Batched multi-source BFS (the MXU formulation): us per source."""
    n = 30_000
    for s in (1, 8, 64):
        opts = BFSOptions(mode="dense")
        dt, stats, _ = _measure_bfs("erdos_renyi", n, opts,
                                    sources=tuple(range(s)),
                                    avg_degree=8.0)
        row(f"multi_source/S={s}", dt * 1e6 / s,
            f"total_us={dt*1e6:.0f};levels={stats.levels}")


def bench_kernels():
    import jax.numpy as jnp
    from repro.graphs import block_sparse_adjacency, erdos_renyi
    from repro.kernels.bsr_spmm import ops as spmm_ops
    from repro.kernels.embedding_bag import ops as bag_ops

    n = 1024
    src, dst = erdos_renyi(n, avg_degree=16, seed=0)
    blocks, br, bc, n_pad = block_sparse_adjacency(src, dst, n)
    x = jnp.ones((n_pad, 128), jnp.float32)
    args = (jnp.asarray(blocks), jnp.asarray(br), jnp.asarray(bc), x)
    f = jax.jit(lambda *a: spmm_ops.spmm(*a, n_rows_pad=n_pad,
                                         interpret=True))
    f(*args).block_until_ready()
    t0 = time.time()
    f(*args).block_until_ready()
    row("kernel_bsr_spmm_interp", (time.time() - t0) * 1e6,
        f"blocks={blocks.shape[0]};d=128")

    table = jnp.ones((10_000, 128), jnp.float32)
    idx = jnp.zeros((256, 8), jnp.int32)
    g = jax.jit(lambda i, t: bag_ops.embedding_bag(i, t, interpret=True))
    g(idx, table).block_until_ready()
    t0 = time.time()
    g(idx, table).block_until_ready()
    row("kernel_embedding_bag_interp", (time.time() - t0) * 1e6,
        "B=256;L=8;D=128")


def bench_roofline_table():
    """§Roofline: per-cell terms from the dry-run sweep (if present)."""
    path = os.path.join(os.path.dirname(__file__), "dryrun_results.json")
    if not os.path.exists(path):
        row("roofline_table", 0.0, "missing dryrun_results.json (run "
            "python -m repro.launch.dryrun --all --mesh both --out ...)")
        return
    with open(path) as f:
        data = json.load(f)
    for r in data["rows"]:
        tt = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        row(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", tt * 1e6,
            f"bottleneck={r['bottleneck']};"
            f"compute_us={r['t_compute_s']*1e6:.1f};"
            f"memory_us={r['t_memory_s']*1e6:.1f};"
            f"collective_us={r['t_collective_s']*1e6:.1f};"
            f"mem_gib={r['bytes_per_device']/2**30:.2f}")


BENCHES = [
    bench_fig3_star_scaling,
    bench_fig5_erdos_renyi_scaling,
    bench_fig7_small_world_scaling,
    bench_sec51_exchange_volume,
    bench_sec52_local_update,
    bench_direction_optimizing,
    bench_engine_amortization,
    bench_partition_1d_vs_2d,
    bench_wire_format_sweep,
    bench_sparse_wire_sweep,
    bench_multi_graph_serving,
    bench_serving_latency,
    bench_latency,
    bench_multi_source_throughput,
    bench_kernels,
    bench_roofline_table,
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_results.json",
                    help="JSON ledger path (compile vs per-run split)")
    ap.add_argument("--wire-out", default="BENCH_wire_format.json",
                    help="wire-format sweep ledger path (written when the "
                         "wire_format bench runs)")
    ap.add_argument("--serving-out", default="BENCH_serving_latency.json",
                    help="serving front-end ledger path (written when the "
                         "serving_latency bench runs)")
    ap.add_argument("--sparse-wire-out", default="BENCH_sparse_wire.json",
                    help="compressed sparse-wire + sieve ledger path "
                         "(written when the sparse_wire bench runs)")
    ap.add_argument("--latency-out", default="BENCH_latency.json",
                    help="fused-tail latency ledger path (written when "
                         "the latency bench runs)")
    ap.add_argument("--only", default=None,
                    help="substring filter on bench function names")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the selected "
                         "benches into DIR and print the parsed per-phase "
                         "device-time summary after the run")
    args = ap.parse_args(argv)

    if args.only and args.out == ap.get_default("out"):
        # don't let a filtered run clobber the full default ledger
        args.out = f"BENCH_results.{args.only}.json"

    profile_cm = contextlib.nullcontext()
    if args.profile:
        from repro.analysis import trace_model
        profile_cm = trace_model.capture(args.profile)

    print("name,us_per_call,derived")
    with profile_cm:
        for b in BENCHES:
            if args.only and args.only not in b.__name__:
                continue
            b()
    if args.profile:
        from repro.analysis import trace_model
        print(trace_model.format_summary(
            trace_model.parse_trace(args.profile)))

    ledger = {
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in _ROWS],
        "engine_timings": _ENGINE_TIMINGS,
        "partition_sweep": _PARTITION_SWEEP,
        "serving": _SERVING,
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    with open(args.out, "w") as f:
        json.dump(ledger, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out} ({len(_ROWS)} rows, "
          f"{len(_ENGINE_TIMINGS)} engine timings)", flush=True)

    if _WIRE_FORMAT:
        wire_ledger = {
            "wire_format": _WIRE_FORMAT,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "device_count": jax.device_count(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.wire_out, "w") as f:
            json.dump(wire_ledger, f, indent=2, sort_keys=True)
        print(f"# wrote {args.wire_out} ({len(_WIRE_FORMAT)} wire rows)",
              flush=True)

    if _SPARSE_WIRE:
        sparse_ledger = {
            "sparse_wire": _SPARSE_WIRE,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "device_count": jax.device_count(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.sparse_wire_out, "w") as f:
            json.dump(sparse_ledger, f, indent=2, sort_keys=True)
        print(f"# wrote {args.sparse_wire_out} "
              f"({len(_SPARSE_WIRE)} sparse-wire rows)", flush=True)

    if _LATENCY:
        latency_ledger = {
            "latency": _LATENCY,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "device_count": jax.device_count(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.latency_out, "w") as f:
            json.dump(latency_ledger, f, indent=2, sort_keys=True)
        print(f"# wrote {args.latency_out} "
              f"({len(_LATENCY['modes'])} mode rows)", flush=True)

    if _SERVING_LATENCY:
        serving_ledger = {
            "serving_latency": _SERVING_LATENCY,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "device_count": jax.device_count(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.serving_out, "w") as f:
            json.dump(serving_ledger, f, indent=2, sort_keys=True)
        print(f"# wrote {args.serving_out} "
              f"({len(_SERVING_LATENCY['batches'])} batch rows)", flush=True)


if __name__ == "__main__":
    main()
