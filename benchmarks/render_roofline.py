"""Render the §Roofline markdown table from dryrun_results.json."""

import json
import os
import sys


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x:.1e}"
    return f"{x:.4f}" if x < 1 else f"{x:.2f}"


def main(path):
    with open(path) as f:
        data = json.load(f)
    print("| arch | shape | mesh | t_compute (s) | t_memory (s) | "
          "t_collective (s) | bottleneck | GiB/dev | useful-flops ratio |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in data["rows"]:
        ur = r.get("useful_flops_ratio")
        ur = "-" if ur is None or ur != ur else f"{1/ur:.2f}x" if ur else "-"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
              f"| {fmt_s(r['t_collective_s'])} | {r['bottleneck']} "
              f"| {r['bytes_per_device']/2**30:.2f} | {ur} |")
    if data.get("failures"):
        print("\nFAILURES:", data["failures"])


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "dryrun_results.json"))
