"""Render benchmark JSON ledgers as markdown tables.

Four inputs render here: the §Roofline table from
``dryrun_results.json``; from a ``BENCH_*.json`` the 1-D vs 2-D
partition sweep (``partition_sweep`` key) and the multi-graph serving
amortization ledger (``serving`` key: per-graph cold compile vs warm run,
plus the budget-bound eviction pass); and the standalone
``BENCH_wire_format.json`` ledger (``wire_format`` key: packed vs bytes
dense exchanges, modeled + measured + HLO-parsed collective bytes) and
the standalone ``BENCH_serving_latency.json`` ledger
(``serving_latency`` key: remote-front-end bucket-ladder latencies and
the bounded-queue overload pass).  Every sweep series label carries
the partition kind (``erdos_renyi_100k[1d]`` vs ``erdos_renyi_100k[2d]``)
so the two schemes plot as distinct curves instead of collapsing into
one.  A ledger matching none of the known schemas (or a ``--only``
filtered BENCH json whose sections are empty) renders as an explanatory
note instead of a KeyError — non-roofline ledgers are skipped
gracefully.
"""

import json
import os
import sys


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x:.1e}"
    return f"{x:.4f}" if x < 1 else f"{x:.2f}"


def series_label(r: dict) -> str:
    """Label a sweep row by graph AND partition kind — the partition is
    part of the series identity, never an aggregated-away attribute."""
    return f"{r.get('graph', r.get('arch', '?'))}[{r.get('partition', '1d')}]"


def render_partition_sweep(data):
    series = {}
    for r in data["partition_sweep"]:
        series.setdefault(series_label(r), []).append(r)
    print("| series | p | grid | modeled bytes/level | measured | "
          "per-run (s) | levels |")
    print("|---|---|---|---|---|---|---|")
    for label in sorted(series):
        for r in sorted(series[label], key=lambda x: (x["p"],
                                                      bool(x.get("measured")))):
            meas = "yes" if r.get("measured") else "modeled"
            per_run = fmt_s(r["per_run_s"]) if "per_run_s" in r else "-"
            levels = r.get("levels", "-")
            print(f"| {label} | {r['p']} | {r['r']}x{r['c']} "
                  f"| {r['modeled_level_bytes']:.0f} | {meas} "
                  f"| {per_run} | {levels} |")


def render_serving(data):
    serving = data["serving"]
    print("| graph | cold compile (ms) | warm run (ms) | amortization |")
    print("|---|---|---|---|")
    for name, g in sorted(serving.get("graphs", {}).items()):
        print(f"| {name} | {g['cold_ms']:.1f} | {g['warm_ms']:.1f} "
              f"| {g['amortization']:.1f}x |")
    ev = serving.get("eviction_pass")
    if ev:
        print(f"\neviction pass: budget={ev['budget_bytes']} B, "
              f"hit_rate={ev['hit_rate']:.2f}, "
              f"evictions={ev['evictions']}, "
              f"compile_s={ev['compile_s_total']:.2f} "
              f"over {ev['rounds']} round-robin rounds")


def render_wire_format(data):
    """BENCH_wire_format.json: packed vs bytes rows grouped per series.

    ``auto`` rows (what the plan resolved per phase) print after the
    table so the table columns stay uniform.
    """
    rows = [r for r in data["wire_format"] if "resolved" not in r]
    autos = [r for r in data["wire_format"] if "resolved" in r]
    print("| series | p | grid | wire | modeled B/level | measured B/level "
          "| HLO collective B | per-run (s) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda x: (series_label(x), x["p"],
                                         bool(x.get("measured")),
                                         x["wire_format"])):
        modeled = (f"{r['modeled_level_bytes']:.0f}"
                   if "modeled_level_bytes" in r else "-")
        meas = (f"{r['measured_level_bytes']:.0f}"
                if "measured_level_bytes" in r else "-")
        hlo = (f"{r['hlo_collective_bytes']:.0f}"
               if "hlo_collective_bytes" in r else "-")
        per_run = fmt_s(r["per_run_s"]) if "per_run_s" in r else "-"
        print(f"| {series_label(r)} | {r['p']} | {r['r']}x{r['c']} "
              f"| {r['wire_format']} | {modeled} | {meas} | {hlo} "
              f"| {per_run} |")
    for r in autos:
        print(f"\nauto @ {series_label(r)} p={r['p']}: "
              f"resolved {r['resolved']}")


def render_serving_latency(data):
    """BENCH_serving_latency.json: bucket-ladder latency + overload."""
    sl = data["serving_latency"]
    print(f"bucket ladder {sl['ladder']} on "
          f"{sl['graph']['kind']} n={sl['graph']['n']}\n")
    print("| batch | bucket | cold (ms) | warm (ms) | warm us/source | "
          "queue wait (ms) | device (ms) |")
    print("|---|---|---|---|---|---|---|")
    for batch, b in sorted(sl["batches"].items(), key=lambda kv: int(kv[0])):
        t = b["timing_ms"]
        print(f"| {batch} | {b['bucket']} | {b['cold_ms']:.1f} "
              f"| {b['warm_ms']:.1f} | {b['warm_us_per_source']:.0f} "
              f"| {t['queue_wait']:.1f} | {t['device']:.1f} |")
    ov = sl["overload"]
    print(f"\noverload: {ov['clients']} clients vs queue_depth="
          f"{ov['queue_depth']} -> {ov['admitted']} admitted, "
          f"{ov['rejected_429']} x 429 "
          f"(retry-after hints {ov['retry_after_s']} s)")
    e2e = sl["lane_metrics"]["e2e"]
    cache = sl.get("engine_cache", {})
    print(f"lane e2e: count={e2e['count']} p50={e2e['p50_ms']}ms "
          f"p95={e2e['p95_ms']}ms; cache hit_rate="
          f"{cache.get('hit_rate', 0):.2f} over {cache.get('entries', 0)} "
          "engines")


def render_audit(data):
    """BENCH_audit.json: per-variant audit verdicts + violation digest."""
    a = data["audit"]
    g = a["graph"]
    print(f"audit of {g['kind']} n={g['n']} on p={a['p']} "
          f"(grid {a['grid'][0]}x{a['grid'][1]}, byte tolerance "
          f"{a['tolerance']}): {'PASS' if a['ok'] else 'FAIL'}\n")
    print("| report | verdict | loop data colls | control | violations "
          "| suppressed |")
    print("|---|---|---|---|---|---|")
    for rep in a["reports"]:
        coll = rep.get("info", {}).get("collectives", {})
        vs = rep.get("violations", [])
        n_sup = sum(1 for v in vs if v.get("suppressed"))
        n_live = len(vs) - n_sup
        print(f"| {rep['name']} | {'ok' if rep.get('ok') else 'FAIL'} "
              f"| {coll.get('loop_data', '-')} "
              f"| {coll.get('loop_control', '-')} "
              f"| {n_live} | {n_sup} |")
    lines = [f"{v['rule']}: {v['message']}"
             for rep in a["reports"]
             for v in rep.get("violations", []) if not v.get("suppressed")]
    if lines:
        print("\nunsuppressed violations:")
        for ln in lines:
            print(f"  {ln}")


def render_dryrun(data):
    print("| arch | shape | mesh | t_compute (s) | t_memory (s) | "
          "t_collective (s) | bottleneck | GiB/dev | useful-flops ratio |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in data["rows"]:
        ur = r.get("useful_flops_ratio")
        ur = "-" if ur is None or ur != ur else f"{1/ur:.2f}x" if ur else "-"
        # series label keeps the partition kind when the dry-run sweep
        # carries one (1-D rows and 2-D rows must stay separate curves)
        arch = (f"{r['arch']}[{r['partition']}]" if "partition" in r
                else r["arch"])
        print(f"| {arch} | {r['shape']} | {r['mesh']} "
              f"| {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
              f"| {fmt_s(r['t_collective_s'])} | {r['bottleneck']} "
              f"| {r['bytes_per_device']/2**30:.2f} | {ur} |")
    if data.get("failures"):
        print("\nFAILURES:", data["failures"])


def main(path):
    with open(path) as f:
        data = json.load(f)
    # BENCH ledgers always carry the partition_sweep key (possibly empty
    # under --only filters); dispatch on presence, not truthiness, so a
    # filtered BENCH json never falls through to the dryrun schema.
    if "serving_latency" in data and "partition_sweep" not in data:
        # the standalone BENCH_serving_latency.json ledger
        if data.get("serving_latency"):
            render_serving_latency(data)
        else:
            print("(empty serving_latency ledger — run benchmarks/run.py "
                  "--only serving_latency)")
        return
    if "wire_format" in data and "partition_sweep" not in data:
        # the standalone BENCH_wire_format.json ledger
        if data.get("wire_format"):
            render_wire_format(data)
        else:
            print("(empty wire_format ledger — run benchmarks/run.py "
                  "--only wire_format)")
        return
    if "partition_sweep" in data or "serving" in data:
        rendered = False
        if data.get("partition_sweep"):
            render_partition_sweep(data)
            rendered = True
        if data.get("serving"):
            if rendered:
                print()
            render_serving(data)
            rendered = True
        if not rendered:
            print("(no partition_sweep or serving rows in this ledger — "
                  "run benchmarks/run.py without --only, or with "
                  "--only partition / --only serving)")
        return
    if "audit" in data:
        # the standalone BENCH_audit.json ledger (launch/bfs_audit --out)
        render_audit(data)
        return
    if "rows" in data:
        render_dryrun(data)
        return
    # not a roofline/BENCH ledger at all: say so instead of KeyError-ing
    print(f"(unrecognized ledger schema in {path}: keys "
          f"{sorted(data)[:8]} — expected a dry-run roofline json or a "
          "BENCH_*.json; nothing to render)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "dryrun_results.json"))
