"""MODEL_FLOPS: analytic useful-work estimates per cell (roofline §g).

LM follows the 6·N·D / 2·N·D convention (N = *active* params including the
tied embedding matmul, D = tokens), with explicit attention-matmul terms
added where they are first-order (long-context decode).  GNN/recsys use
per-layer matmul counts.  These are 'useful work' floors — the ratio
HLO_FLOPs/MODEL_FLOPS exposes remat/dispatch/padding overhead.
"""

from __future__ import annotations

from repro.configs.base import (GNNConfig, GNNShape, LMShape, RecsysConfig,
                                RecsysShape, TransformerConfig)


def lm_model_flops(cfg: TransformerConfig, shape: LMShape) -> float:
    n_act = cfg.active_param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        attn = 0.0
        for i in range(cfg.n_layers):
            spec = cfg.pattern[i % len(cfg.pattern)]
            ctx = min(shape.seq_len, spec.window or shape.seq_len)
            # qk + pv, fwd+bwd(2x): 3 * 2 * 2 * tokens * ctx/2 * heads*dh
            attn += 3 * 2 * tokens * ctx * cfg.n_heads * cfg.head_dim
        return 6.0 * n_act * tokens + attn
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        attn = 0.0
        for i in range(cfg.n_layers):
            spec = cfg.pattern[i % len(cfg.pattern)]
            ctx = min(shape.seq_len, spec.window or shape.seq_len)
            attn += 2 * tokens * ctx * cfg.n_heads * cfg.head_dim
        return 2.0 * n_act * tokens + attn
    # decode: one token per sequence, attention reads the whole cache
    b = shape.global_batch
    attn = 0.0
    for i in range(cfg.n_layers):
        spec = cfg.pattern[i % len(cfg.pattern)]
        ctx = min(shape.seq_len, spec.window or shape.seq_len)
        attn += 4 * b * ctx * cfg.n_heads * cfg.head_dim
    return 2.0 * n_act * b + attn


def gnn_model_flops(cfg: GNNConfig, shape: GNNShape, n: int, e: int) -> float:
    d = cfg.d_hidden
    f = shape.d_feat
    if cfg.kind == "gcn":
        fwd = 2 * n * f * d + 2 * e * d  # first layer dominates on cora
        for _ in range(cfg.n_layers - 1):
            fwd += 2 * n * d * d + 2 * e * d
    elif cfg.kind == "gatedgcn":
        fwd = 2 * n * f * d
        fwd += cfg.n_layers * (5 * 2 * max(n, e) * d * d + 4 * e * d)
    elif cfg.kind == "schnet":
        fwd = 2 * n * f * d
        fwd += cfg.n_layers * (2 * e * cfg.rbf * d + 2 * e * d * d
                               + 2 * 2 * n * d * d + 2 * e * d)
    else:  # graphcast: edge MLP (3d->d->d) + node MLP (2d->d->d)
        fwd = 2 * n * f * d + 2 * e * 4 * d
        fwd += cfg.n_layers * (2 * e * (3 * d * d + d * d)
                               + 2 * n * (2 * d * d + d * d) + 2 * e * d)
        fwd += 2 * n * d * cfg.n_vars
    return 3.0 * fwd  # fwd + bwd ~ 3x


def recsys_model_flops(cfg: RecsysConfig, shape: RecsysShape) -> float:
    d = cfg.embed_dim
    mlp_in = cfg.n_sparse * d + cfg.n_dense
    dims = (mlp_in, *cfg.mlp_dims, 1)
    mlp = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    fm = 4 * cfg.n_sparse * d
    if shape.step == "retrieval":
        return 2.0 * shape.n_candidates * d
    per_ex = mlp + fm
    mult = 3.0 if shape.step == "train" else 1.0
    return mult * shape.batch * per_ex


def model_flops(bundle) -> float:
    from repro.data.synthetic import _gnn_dims
    if bundle.family == "lm":
        return lm_model_flops(bundle.cfg, bundle.shape)
    if bundle.family == "gnn":
        n, e = _gnn_dims(bundle.cfg, bundle.shape)
        return gnn_model_flops(bundle.cfg, bundle.shape, n, e)
    return recsys_model_flops(bundle.cfg, bundle.shape)
