"""BFS traversal-service launcher: batched source requests on one engine.

    PYTHONPATH=src python -m repro.launch.bfs_serve --n 50000 --requests 32
    PYTHONPATH=src python -m repro.launch.bfs_serve --workload erdos_renyi_100k \
        --slots 8 --devices 4

Compiles one multi-source ``BFSEngine`` sized to ``--slots`` and drains a
queue of single-source traversal requests through it (serve/bfs_service.py)
— the serving-path proof that per-request cost is one device dispatch per
batch, not one compile per request.
"""

from repro.launch import host_devices_from_argv

host_devices_from_argv()  # must precede the jax import below

import argparse  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs.base import BFS_WORKLOADS  # noqa: E402
from repro.core import BFSOptions  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402
from repro.serve.bfs_service import BFSService, TraversalRequest  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=None,
                    choices=[w.name for w in BFS_WORKLOADS])
    ap.add_argument("--graph", default="erdos_renyi")
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--mode", default="dense", choices=["dense", "auto"])
    ap.add_argument("--exchange", default="alltoall_direct")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--devices", type=int, default=0)  # parsed above
    args = ap.parse_args()

    if args.workload:
        wl = next(w for w in BFS_WORKLOADS if w.name == args.workload)
        kind, n, kw = wl.graph, wl.n_vertices, dict(wl.gen_kwargs)
    else:
        kind, n, kw = args.graph, args.n, {"avg_degree": 8.0} \
            if args.graph == "erdos_renyi" else {}

    devs = jax.devices()
    p = len(devs)
    mesh = Mesh(np.asarray(devs).reshape(p), ("p",))
    src, dst = generate(kind, n, seed=0, **kw)
    g = shard_graph(src, dst, n, p)
    print(f"graph={kind} n={n} edges={src.shape[0]} shards={p} "
          f"slots={args.slots}")

    t0 = time.time()
    svc = BFSService(g, BFSOptions(mode=args.mode,
                                   dense_exchange=args.exchange,
                                   queue_cap=1 << 15),
                     mesh=mesh, axis="p", batch_slots=args.slots)
    print(f"service up (plan+compile) in {time.time()-t0:.2f}s")

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        svc.submit(TraversalRequest(rid=i, source=int(rng.integers(0, n))))
    t0 = time.time()
    done = svc.run_until_drained()
    dt = time.time() - t0
    print(f"{len(done)} traversals in {dt:.2f}s "
          f"({len(done)/max(dt, 1e-9):.1f} req/s, "
          f"{dt/max(len(done), 1)*1e3:.1f} ms/req)")
    for r in done[:4]:
        print(f"  rid={r.rid} source={r.source} levels={r.levels} "
              f"visited={r.visited}")


if __name__ == "__main__":
    main()
