"""Multi-tenant BFS serving launcher: many graphs, one engine cache.

    PYTHONPATH=src python -m repro.launch.bfs_serve --n 50000 --requests 32
    PYTHONPATH=src python -m repro.launch.bfs_serve --devices 4 \
        --graph er=erdos_renyi:40000 --graph hub=star:20000 \
        --graph ring=chain:5000:2x2 --requests 24 --cache-budget-mb 64

Registers every ``--graph`` spec in a ``GraphCatalog`` and serves them
through one multi-graph ``BFSService``: each graph gets a serving lane
(its own slot pool, sized to ``--slots``), requests are routed by graph
name, and every compiled engine lives in a shared byte-budgeted
``EngineCache`` — the serving-path proof that per-request cost is one
device dispatch per batch and per-plan compile cost is paid once across
the whole tenant set (and bounded: under ``--cache-budget-mb`` pressure
LRU engines evict and recompile on their lane's next turn).

Graph specs are ``[name=]kind[:n][:RxC]``; a trailing grid selects the
2-D edge partition for that lane, so one service mixes schemes.  With no
``--graph`` the launcher serves the single-graph workload flags exactly
like before.  ``--verify`` checks every finished traversal against the
numpy reference; ``--expect-eviction`` exits nonzero unless the budget
actually forced at least one eviction (CI smoke).

``--http HOST:PORT`` binds the remote front-end instead of running the
self-driven request loop::

    PYTHONPATH=src python -m repro.launch.bfs_serve --devices 4 \
        --graph er=erdos_renyi:40000 --graph ring=chain:5000:2x2 \
        --http 127.0.0.1:8642 --buckets 1,8,64 --queue-depth 32 \
        --cache-budget-mb 64 --stats-interval 10

Each lane then compiles a ladder of batch-size buckets (``--buckets``)
through the shared engine cache; remote requests (``launch/bfs_client``)
are padded to the smallest fitting bucket, admission is bounded by
``--queue-depth`` / ``--max-inflight-mb`` (429 + Retry-After when full),
and ``/metrics`` serves per-lane latency histograms next to the cache
counters.  ``HOST:0`` binds an ephemeral port; ``--port-file`` writes
the bound port for scripted callers.  The server runs until
``POST /admin/shutdown`` (graceful drain), SIGINT, or ``--serve-secs``.

Resilience knobs (HTTP mode): ``--breaker-threshold`` /
``--breaker-reset-secs`` size the per-lane circuit breakers,
``--watchdog-secs`` bounds each device round, ``--no-degrade`` turns
off the degradation arms, and ``--default-deadline-ms`` stamps a
deadline on requests that carry none; ``/readyz`` reports readiness
separately from ``/healthz`` liveness.
"""

from repro.launch import host_devices_from_argv, parse_graph_spec

host_devices_from_argv()  # must precede the jax import below

import argparse  # noqa: E402
import contextlib  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.analysis import trace_model  # noqa: E402
from repro.configs.base import BFS_WORKLOADS  # noqa: E402
from repro.core import BFSOptions  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402
from repro.launch.mesh import make_grid_mesh  # noqa: E402
from repro.serve.bfs_service import BFSService, TraversalRequest  # noqa: E402
from repro.serve.engine_cache import (EngineCache,  # noqa: E402
                                      GraphCatalog)

_GEN_DEFAULTS = {
    "erdos_renyi": {"avg_degree": 8.0},
    "small_world": {"k": 8, "beta": 0.1},
    "rmat": {"edge_factor": 8},
}


def _print_profile(logdir: str) -> None:
    """Parse + print the phase summary of a captured serving trace.

    Serving windows interleave traversals of several lanes, so levels of
    different runs do not cluster cleanly — the summary reports phase
    totals only (the median-gap segmentation heuristic still splits what
    it can)."""
    try:
        print(trace_model.format_summary(trace_model.parse_trace(logdir)))
    except FileNotFoundError as exc:
        print(f"profile: {exc}", file=sys.stderr)


def _serve_http(args, svc, graph_specs):
    """Bind the remote front-end and run the accept loop to completion."""
    from repro.serve.frontend.server import serve_http

    try:
        host, _, port_s = args.http.rpartition(":")
        host = host or "127.0.0.1"
        port = int(port_s)
    except ValueError:
        raise SystemExit(f"--http expects HOST:PORT, got {args.http!r}")

    httpd, frontend = serve_http(
        svc, host, port, max_queue_depth=args.queue_depth,
        max_inflight_mb=args.max_inflight_mb,
        stats_interval_s=args.stats_interval, graph_specs=graph_specs,
        breaker_threshold=args.breaker_threshold,
        breaker_reset_s=args.breaker_reset_s,
        watchdog_timeout_s=(args.watchdog_secs
                            if args.watchdog_secs > 0 else None),
        degrade=not args.no_degrade,
        default_deadline_ms=(args.default_deadline_ms
                             if args.default_deadline_ms > 0 else None))
    bound = httpd.server_address[1]
    print(f"serving on http://{host}:{bound} "
          f"(queue_depth={args.queue_depth}, "
          f"max_inflight_mb={args.max_inflight_mb:g}); "
          "POST /admin/shutdown to drain and stop", flush=True)
    if args.port_file:
        with open(args.port_file, "w") as f:
            f.write(str(bound))

    if args.serve_secs > 0:
        import threading

        def _timer():
            time.sleep(args.serve_secs)
            httpd.drain_and_stop()

        threading.Thread(target=_timer, daemon=True).start()
    profile_cm = (trace_model.capture(args.profile) if args.profile
                  else contextlib.nullcontext())
    try:
        with profile_cm:
            httpd.serve_forever()
    except KeyboardInterrupt:
        print("interrupt: draining", flush=True)
        frontend.shutdown()
    finally:
        httpd.server_close()
    if args.profile:
        _print_profile(args.profile)

    st = svc.cache_stats()
    done = sum(m.completed for m in frontend.metrics.lanes.values())
    rejected = sum(m.rejected for m in frontend.metrics.lanes.values())
    print(f"served {done} traversals ({rejected} rejected 429); "
          f"cache: hits={st['hits']} misses={st['misses']} "
          f"evictions={st['evictions']} hit_rate={st['hit_rate']:.2f} "
          f"compile_s={st['compile_s_total']:.2f}")
    if args.expect_eviction and st["evictions"] == 0:
        print("EXPECTED at least one cache eviction under "
              f"--cache-budget-mb {args.cache_budget_mb}; none happened")
        sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=None,
                    choices=[w.name for w in BFS_WORKLOADS])
    ap.add_argument("--graph", action="append", default=None,
                    metavar="[NAME=]KIND[:N][:RxC]",
                    help="graph spec; repeatable — each spec opens one "
                         "serving lane (a trailing RxC grid selects the "
                         "2-D edge partition for that lane)")
    ap.add_argument("--n", type=int, default=50_000,
                    help="default vertex count for specs without :N")
    ap.add_argument("--mode", default="dense", choices=["dense", "auto"])
    ap.add_argument("--exchange", default="alltoall_direct")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16,
                    help="total requests, dealt round-robin across graphs")
    ap.add_argument("--cache-budget-mb", type=float, default=0.0,
                    help="engine-cache device-byte budget (0 = unbounded)")
    ap.add_argument("--verify", action="store_true",
                    help="check every traversal against the numpy reference")
    ap.add_argument("--expect-eviction", action="store_true",
                    help="exit nonzero unless the cache evicted >= 1 engine")
    ap.add_argument("--http", default=None, metavar="HOST:PORT",
                    help="bind the remote front-end instead of running the "
                         "self-driven request loop (PORT 0 = ephemeral)")
    ap.add_argument("--buckets", default=None, metavar="S1,S2,...",
                    help="batch-size bucket ladder per lane, e.g. 1,8,64 "
                         "(default: one bucket of --slots)")
    ap.add_argument("--queue-depth", type=int, default=64,
                    help="per-lane admission queue bound (HTTP mode)")
    ap.add_argument("--max-inflight-mb", type=float, default=256.0,
                    help="per-lane in-flight response-byte bound (HTTP)")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="seconds between serving stats log lines (0=off)")
    ap.add_argument("--port-file", default=None,
                    help="write the bound HTTP port to this file")
    ap.add_argument("--breaker-threshold", type=int, default=5,
                    help="consecutive lane failures that open its circuit "
                         "breaker (HTTP mode)")
    ap.add_argument("--breaker-reset-secs", type=float, default=5.0,
                    dest="breaker_reset_s",
                    help="open-circuit cooldown before half-open probes")
    ap.add_argument("--watchdog-secs", type=float, default=0.0,
                    help="fail a device round exceeding this bound with a "
                         "typed 500; other lanes keep serving (0 = off)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="disable degradation arms (other buckets, split "
                         "runs, the uncompressed wire tier) on persistent "
                         "transient failures")
    ap.add_argument("--default-deadline-ms", type=float, default=0.0,
                    help="server-side deadline for requests that carry no "
                         "deadline_ms of their own (0 = none)")
    ap.add_argument("--serve-secs", type=float, default=0.0,
                    help="auto-shutdown the HTTP server after this many "
                         "seconds (0 = run until /admin/shutdown or ^C)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the serving "
                         "window (self-driven loop, or HTTP accept loop "
                         "until drain) into DIR and print the per-phase "
                         "device-time summary parsed from it")
    ap.add_argument("--devices", type=int, default=0)  # parsed above
    args = ap.parse_args()

    buckets = None
    if args.buckets:
        try:
            buckets = tuple(int(tok) for tok in args.buckets.split(","))
        except ValueError:
            ap.error(f"--buckets expects comma-separated ints, got "
                     f"{args.buckets!r}")

    # spec rows: (name, kind, n, grid, generator kwargs) — a named
    # workload keeps its configured gen_kwargs; ad-hoc specs use the
    # per-kind defaults
    if args.graph and args.workload:
        # bfs_run resolves this pair the other way; refuse the ambiguity
        # instead of silently serving different graphs per launcher
        ap.error("--graph and --workload are mutually exclusive; pass the "
                 "workload's graph as a --graph spec instead")
    if args.graph:
        specs = []
        for s in args.graph:
            name, kind, n, grid = parse_graph_spec(s, args.n)
            specs.append((name, kind, n, grid,
                          dict(_GEN_DEFAULTS.get(kind, {}))))
        names = [s[0] for s in specs]
        dupes = sorted({x for x in names if names.count(x) > 1})
        if dupes:
            ap.error(f"duplicate graph name(s) {dupes}: lane names must "
                     "be unique — disambiguate with a name= prefix, e.g. "
                     f"--graph small={dupes[0]}:10000")
    elif args.workload:
        wl = next(w for w in BFS_WORKLOADS if w.name == args.workload)
        specs = [(wl.name, wl.graph, wl.n_vertices, None,
                  dict(wl.gen_kwargs))]
    else:
        specs = [("default", "erdos_renyi", args.n, None,
                  dict(_GEN_DEFAULTS["erdos_renyi"]))]

    devs = jax.devices()
    p = len(devs)
    mesh_1d = Mesh(np.asarray(devs).reshape(p), ("p",))

    cache = EngineCache(
        max_device_bytes=(int(args.cache_budget_mb * 2**20)
                          if args.cache_budget_mb > 0 else None))
    catalog = GraphCatalog()
    svc = BFSService(opts=BFSOptions(mode=args.mode,
                                     dense_exchange=args.exchange,
                                     queue_cap=1 << 15),
                     mesh=mesh_1d, axis="p", batch_slots=args.slots,
                     batch_buckets=buckets, cache=cache, catalog=catalog)

    edge_lists = {}
    graph_specs = {}
    t0 = time.time()
    for name, kind, n, grid, kw in specs:
        src, dst = generate(kind, n, seed=0, **kw)
        edge_lists[name] = (src, dst, n)
        # advertised via /v1/graphs so a remote --verify client can
        # regenerate the identical graph and check depths bitwise
        graph_specs[name] = {"kind": kind, "n": n, "seed": 0,
                             "gen_kwargs": kw}
        g = shard_graph(src, dst, n, p)
        if grid:
            svc.add_graph(name, g, mesh=make_grid_mesh(*grid), axis=None,
                          partition="2d")
        else:
            svc.add_graph(name, g)
        part_lbl = f"2d:{grid[0]}x{grid[1]}" if grid else "1d"
        print(f"lane {name}: kind={kind} n={n} edges={src.shape[0]} "
              f"partition={part_lbl}")
    print(f"{len(specs)} lane(s) registered in {time.time()-t0:.2f}s "
          f"(shards={p}, buckets={list(buckets) if buckets else [args.slots]},"
          f" budget={args.cache_budget_mb or 'unbounded'} MB)", flush=True)

    if args.http is not None:
        return _serve_http(args, svc, graph_specs)

    rng = np.random.default_rng(0)
    names = svc.graph_names()
    for i in range(args.requests):
        name = names[i % len(names)]
        n = edge_lists[name][2]
        svc.submit(TraversalRequest(rid=i, source=int(rng.integers(0, n)),
                                    graph=name))
    profile_cm = (trace_model.capture(args.profile) if args.profile
                  else contextlib.nullcontext())
    t0 = time.time()
    with profile_cm:
        done = svc.run_until_drained()
    dt = time.time() - t0
    if args.profile:
        _print_profile(args.profile)
    print(f"{len(done)} traversals over {len(names)} graph(s) in {dt:.2f}s "
          f"({len(done)/max(dt, 1e-9):.1f} req/s, "
          f"{dt/max(len(done), 1)*1e3:.1f} ms/req)")
    for r in done[:4]:
        print(f"  rid={r.rid} graph={r.graph} source={r.source} "
              f"levels={r.levels} visited={r.visited}")

    st = svc.cache_stats()
    print(f"cache: hits={st['hits']} misses={st['misses']} "
          f"evictions={st['evictions']} entries={st['entries']} "
          f"bytes={st['device_bytes']}/{st['max_device_bytes'] or 'inf'} "
          f"hit_rate={st['hit_rate']:.2f} "
          f"compile_s={st['compile_s_total']:.2f}")

    if args.verify:
        from repro.core.ref import bfs_reference
        for r in done:
            src, dst, n = edge_lists[r.graph]
            want = bfs_reference(src, dst, n, [r.source])[:, 0]
            if not np.array_equal(r.dist, want):
                print(f"VERIFY FAILED: rid={r.rid} graph={r.graph} "
                      f"source={r.source}")
                sys.exit(1)
        print(f"verify: {len(done)} traversals match the numpy reference")

    if args.expect_eviction and st["evictions"] == 0:
        print("EXPECTED at least one cache eviction under "
              f"--cache-budget-mb {args.cache_budget_mb}; none happened")
        sys.exit(1)


if __name__ == "__main__":
    main()
