"""PartitionSpec assignment for every family's params, state and batches.

Strategy (baseline; §Perf iterates on it per-cell):
  * LM: Megatron-style tensor parallel over ``model`` (attention heads when
    head count divides the axis, otherwise the contracting dim), MoE expert
    parallel over ``model``, batch over ``data`` (+``pod``), vocab-sharded
    embedding.  Optimizer moments additionally sharded over ``data``
    (ZeRO-1) on the first divisible dimension.
  * GNN/BFS: 1-D vertex partitioning over ALL mesh axes flattened — the
    paper's partitioning, applied to node/edge arrays; model params are
    small and replicated.
  * RecSys: embedding-table rows 1-D partitioned over ``model`` (the
    owner-exchange technique), batch over data axes.

Decode caches shard batch over ``data`` when divisible and always shard the
sequence dim over ``model`` (sequence-parallel KV) — for long_500k (B=1)
the sequence dim takes every axis.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import TransformerConfig
from repro.launch.mesh import Axes, mesh_axes


def _size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _div(n: int, mesh, axes) -> bool:
    return n % _size(mesh, axes) == 0


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_param_specs(cfg: TransformerConfig, mesh, mode: str = "tp") -> dict:
    """mode='tp': Megatron tensor parallel over the model axis (+FSDP
    storage added by fsdp_specs).  mode='fsdp': no tensor parallelism —
    weights replicated for compute, storage sharded over ALL axes, batch
    over all axes (pure ZeRO-3)."""
    ax = mesh_axes(mesh)
    m = ax.model
    if mode == "fsdp":
        def rep(tree):
            return jax.tree.map(lambda _: None, tree)
        blocks = []
        for spec in cfg.pattern:
            b = {"attn": {k: P() for k in
                          (["wq", "wk", "wv", "wo"]
                           + (["bq", "bk", "bv"] if cfg.qkv_bias else []))},
                 "ln1": P(), "ln2": P()}
            if spec.moe and cfg.moe is not None:
                moe = {"router": P(), "w_gate": P(m, None, None),
                       "w_up": P(m, None, None), "w_down": P(m, None, None)}
                if cfg.moe.shared_experts:
                    moe["shared"] = {"w_gate": P(), "w_up": P(),
                                     "w_down": P()}
                b["moe"] = moe
            else:
                b["mlp"] = {"w_gate": P(), "w_up": P(), "w_down": P()}
            blocks.append(b)
        out = {"embed": P(), "blocks": blocks, "final_norm": P()}
        if not cfg.tie_embeddings:
            out["unembed"] = P()
        return out
    hq_ok = _div(cfg.n_heads, mesh, m)
    hkv_ok = _div(cfg.n_kv_heads, mesh, m)

    def attn_specs(has_bias):
        s = {
            # heads over model when divisible, else contract D (row-parallel)
            "wq": P(None, None, m, None) if hq_ok else P(None, m, None, None),
            "wk": P(None, None, m, None) if hkv_ok else P(None, m, None, None),
            "wv": P(None, None, m, None) if hkv_ok else P(None, m, None, None),
            "wo": P(None, m, None, None) if hq_ok else P(None, None, None, m),
        }
        if has_bias:
            s["bq"] = P(None, m, None) if hq_ok else P(None, None, None)
            s["bk"] = P(None, m, None) if hkv_ok else P(None, None, None)
            s["bv"] = P(None, m, None) if hkv_ok else P(None, None, None)
        return s

    blocks = []
    for spec in cfg.pattern:
        b = {"attn": attn_specs(cfg.qkv_bias),
             "ln1": P(None, None), "ln2": P(None, None)}
        if spec.moe and cfg.moe is not None:
            moe = {
                "router": P(None, None, None),  # tiny; shard_map wants it whole
                "w_gate": P(None, m, None, None),
                "w_up": P(None, m, None, None),
                "w_down": P(None, m, None, None),
            }
            if cfg.moe.shared_experts:
                moe["shared"] = {"w_gate": P(None, None, m),
                                 "w_up": P(None, None, m),
                                 "w_down": P(None, m, None)}
            b["moe"] = moe
        else:
            b["mlp"] = {"w_gate": P(None, None, m), "w_up": P(None, None, m),
                        "w_down": P(None, m, None)}
        blocks.append(b)

    out = {
        # input table: D-sharded so the token gather never all-gathers V
        "embed": P(None, m) if _div(cfg.d_model, mesh, m) else P(None, None),
        "blocks": blocks,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        # output head: V-sharded so CE/logits stay vocab-partitioned
        out["unembed"] = (P(m, None) if _div(cfg.vocab, mesh, m)
                          else P(None, None))
    else:
        out["embed"] = P(m, None) if _div(cfg.vocab, mesh, m) else P(None, None)
    return out


def lm_batch_specs(cfg: TransformerConfig, shape, mesh) -> dict:
    ax = mesh_axes(mesh)
    dp = ax.dp
    if shape.step in ("train", "prefill"):
        bspec = dp if _div(shape.global_batch, mesh, dp) else None
        return {"tokens": P(bspec, None)}
    # decode: cache (G, B, Hkv, Smax, Dh)
    b_ok = _div(shape.global_batch, mesh, dp)
    seq_axes = (ax.model,) if b_ok else tuple([*dp, ax.model])
    cache_spec = P(None, dp if b_ok else None, None, seq_axes, None)
    return {
        "cache": [{"k": cache_spec, "v": cache_spec} for _ in cfg.pattern],
        "pos": P(),
        "last_token": P(dp if b_ok else None),
    }


# ---------------------------------------------------------------------------
# GNN — 1-D vertex partition over all axes (the paper's partitioning)
# ---------------------------------------------------------------------------

def gnn_param_specs(params_shape, mesh) -> dict:
    return jax.tree.map(lambda _: P(), params_shape)


def gnn_batch_specs(batch_specs: dict, mesh) -> dict:
    ax = mesh_axes(mesh)
    flat = ax.flat
    out = {}
    for k, v in batch_specs.items():
        if k == "graph_targets":
            out[k] = P(None, None)
        elif v.ndim == 1:
            out[k] = P(flat if v.shape[0] % _size(mesh, flat) == 0 else None)
        else:
            rest = (None,) * (v.ndim - 1)
            out[k] = P(flat if v.shape[0] % _size(mesh, flat) == 0 else None,
                       *rest)
    return out


# ---------------------------------------------------------------------------
# RecSys — row-partitioned tables (owner-exchange), data-parallel batch
# ---------------------------------------------------------------------------

def recsys_param_specs(cfg, mesh) -> dict:
    ax = mesh_axes(mesh)
    m = ax.model
    row = m if _div(cfg.total_rows, mesh, m) else None
    return {
        "table": P(row, None),
        "lin_table": P(row, None),
        "lin_dense": P(None),
        "bias": P(),
        "mlp": [{"w": P(None, None), "b": P(None)}
                for _ in range(len(cfg.mlp_dims) + 1)],
    }


def recsys_batch_specs(cfg, shape, mesh) -> dict:
    ax = mesh_axes(mesh)
    dp = ax.dp
    if shape.step == "retrieval":
        c_ok = _div(shape.n_candidates, mesh, dp)
        return {"sparse": P(None, None), "cand_ids": P(dp if c_ok else None)}
    b = dp if _div(shape.batch, mesh, dp) else None
    out = {"sparse": P(b, None), "dense": P(b, None)}
    if shape.step == "train":
        out["label"] = P(b)
    return out


# ---------------------------------------------------------------------------
# optimizer state: ZeRO-1 (moments extra-sharded over data)
# ---------------------------------------------------------------------------

def zero1_spec(param_spec: P, shape: tuple, mesh, dp) -> P:
    """Extend a param spec by sharding the first free divisible dim over
    the data axes (classic optimizer-state sharding).  No-op if the spec
    already uses a data axis (e.g. FSDP-sharded storage)."""
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    if any(a in used for a in dp):
        return param_spec
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % _size(mesh, dp) == 0 and dim > 0:
            entries[i] = dp
            return P(*entries)
    return param_spec


def fsdp_specs(param_specs, params_shape, mesh, min_size: int = 2 ** 20,
               dp_axes=None):
    """FSDP: shard weight *storage* over the data axes on the first free
    divisible dim (small leaves stay as-is).  GSPMD all-gathers weights at
    use and transposes the gather to a reduce-scatter for gradients — the
    standard ZeRO-3 dataflow, expressed purely via placement.  Pass
    ``dp_axes`` to shard storage over a wider axis set (pure-FSDP mode)."""
    ax = mesh_axes(mesh)
    dp = tuple(dp_axes) if dp_axes else ax.dp

    def one(sp, sh):
        import numpy as np
        if int(np.prod(sh.shape)) * 2 < min_size:
            return sp
        return zero1_spec(sp, sh.shape, mesh, dp)

    return jax.tree.map(one, param_specs, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def state_specs(param_specs, params_shape, mesh, *, zero1: bool = True,
                fsdp: bool = False):
    """Specs for {'params', 'opt': {'m','v','step'}} train state."""
    ax = mesh_axes(mesh)
    if fsdp:
        param_specs = fsdp_specs(param_specs, params_shape, mesh)
    if not zero1:
        mv = param_specs
    else:
        mv = jax.tree.map(
            lambda sp, sh: zero1_spec(sp, sh.shape, mesh, ax.dp),
            param_specs, params_shape,
            is_leaf=lambda x: isinstance(x, P))
    return {"params": param_specs,
            "opt": {"m": mv, "v": mv, "step": P()}}


def to_named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
