"""Stdlib HTTP client for the BFS serving front-end (+ CI smoke driver).

    PYTHONPATH=src python -m repro.launch.bfs_client \
        --url http://127.0.0.1:8642 --graph er --requests 8 --batch 4 \
        --concurrency 2 --verify

Library use::

    from repro.launch.bfs_client import BFSClient
    c = BFSClient("http://127.0.0.1:8642")
    out = c.traverse("er", [0, 17, 99])      # dict: depths/bucket/stats
    c.graphs(); c.metrics(); c.health()

The CLI fires ``--requests`` traversals of ``--batch`` random distinct
sources each, spread over ``--concurrency`` threads released together
(a synchronized burst — what the admission-control smoke needs), then
prints a latency summary and the server's cache hit rate.  ``--verify``
regenerates each lane's graph from the ``spec`` the server advertises in
``/v1/graphs`` and checks every depth row bitwise against the numpy
reference (and parent rows for validity when ``--include-parents``).
``--expect-429`` flips the contract: the run fails unless at least one
request was rejected with 429 (and 429s stop counting as errors).
``--max-retries N`` makes the client honor the server's ``Retry-After``
hint on 429/503 (capped, jittered backoff); the default 0 fails fast.

Import-light on purpose: urllib only, numpy/JAX imported lazily inside
``--verify`` so a plain round-trip works without touching the device
stack.
"""

from __future__ import annotations

import argparse
import json
import random
import sys
import threading
import time
import urllib.error
import urllib.request

#: ceiling on one Retry-After-driven backoff sleep (a misbehaving or
#: draining server must not park a client thread for minutes)
MAX_BACKOFF_S = 10.0

#: statuses worth retrying when the caller opts in: admission shed (429)
#: and not-ready/breaker-open/draining (503) — both explicitly
#: retry-later states the server stamps a Retry-After on
RETRYABLE_STATUSES = (429, 503)


class HTTPStatusError(RuntimeError):
    """Non-2xx response; carries the status and decoded error payload.

    ``retry_after_s`` is the server's ``Retry-After`` header in seconds
    (None when absent) — what ``max_retries > 0`` clients sleep on.
    """

    def __init__(self, status: int, payload: dict, url: str,
                 retry_after_s=None):
        super().__init__(f"HTTP {status} from {url}: "
                         f"{payload.get('error', payload)}")
        self.status = status
        self.payload = payload
        self.retry_after_s = retry_after_s


class BFSClient:
    """Stdlib client; ``max_retries > 0`` honors ``Retry-After`` on
    429/503 with capped jittered sleeps (default 0 = fail fast, the
    pre-retry behavior exactly)."""

    def __init__(self, base_url: str, timeout_s: float = 120.0, *,
                 max_retries: int = 0, seed: int = 0):
        self.base_url = base_url.rstrip("/")
        self.timeout_s = timeout_s
        self.max_retries = int(max_retries)
        self.retries_used = 0            # cumulative, for smoke summaries
        self._rng = random.Random(seed)

    def _request_once(self, path: str, body: dict = None) -> dict:
        url = self.base_url + path
        data = json.dumps(body).encode() if body is not None else None
        req = urllib.request.Request(
            url, data=data, method="POST" if body is not None else "GET",
            headers={"Content-Type": "application/json"} if body else {})
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as rsp:
                return json.loads(rsp.read().decode())
        except urllib.error.HTTPError as exc:
            try:
                payload = json.loads(exc.read().decode())
            except Exception:
                payload = {"error": str(exc)}
            retry_after = exc.headers.get("Retry-After")
            try:
                retry_after = (float(retry_after)
                               if retry_after is not None else None)
            except ValueError:
                retry_after = None
            raise HTTPStatusError(exc.code, payload, url,
                                  retry_after_s=retry_after) from None

    def _request(self, path: str, body: dict = None) -> dict:
        """One request, retried up to ``max_retries`` times on 429/503.

        Sleeps the server's Retry-After hint (default 1s when absent),
        capped at ``MAX_BACKOFF_S`` and jittered +-25% so synchronized
        clients don't re-burst on the same tick."""
        attempt = 0
        while True:
            try:
                return self._request_once(path, body)
            except HTTPStatusError as exc:
                if (exc.status not in RETRYABLE_STATUSES
                        or attempt >= self.max_retries):
                    raise
                attempt += 1
                self.retries_used += 1
                hint = exc.retry_after_s if exc.retry_after_s else 1.0
                delay = min(MAX_BACKOFF_S, hint)
                time.sleep(delay * (1.0 + 0.25 * (2 * self._rng.random()
                                                  - 1)))

    # ------------------------------------------------------------ endpoints
    def traverse(self, graph, sources, include_parents: bool = False,
                 deadline_ms=None) -> dict:
        body = {"sources": list(sources), "include_parents": include_parents}
        if graph is not None:
            body["graph"] = graph
        if deadline_ms is not None:
            body["deadline_ms"] = deadline_ms
        return self._request("/v1/traverse", body)

    def graphs(self) -> dict:
        return self._request("/v1/graphs")

    def metrics(self) -> dict:
        return self._request("/metrics")

    def health(self) -> dict:
        return self._request("/healthz")

    def ready(self) -> dict:
        return self._request("/readyz")

    def shutdown(self) -> dict:
        return self._request("/admin/shutdown", body={})


# ---------------------------------------------------------------------------
# CLI smoke driver
# ---------------------------------------------------------------------------

def _verify_depths(lane_info: dict, results: list,
                   check_parents: bool) -> int:
    """Bitwise check of every depth row against the numpy reference on a
    regenerated copy of the server's graph; returns the failure count."""
    import numpy as np

    from repro.core.ref import bfs_reference
    from repro.graphs import generate

    spec = lane_info.get("spec")
    if not spec:
        print(f"verify: lane {lane_info['name']!r} advertises no spec; "
              "cannot regenerate the graph client-side", file=sys.stderr)
        return 1
    src, dst = generate(spec["kind"], spec["n"], seed=spec.get("seed", 0),
                        **spec.get("gen_kwargs", {}))
    failures = 0
    for out in results:
        want = bfs_reference(src, dst, spec["n"], out["sources"])
        got = np.asarray(out["depths"], dtype=np.int64).T   # (n, S)
        if not np.array_equal(got, want):
            print(f"VERIFY FAILED: graph={out['graph']} "
                  f"sources={out['sources']}", file=sys.stderr)
            failures += 1
            continue
        if check_parents:
            parents = np.asarray(out["parents"], dtype=np.int64).T
            for j, s in enumerate(out["sources"]):
                d, par = want[:, j], parents[:, j]
                reached = d < out["unreached"]
                ok = (par[s] == s
                      and np.all(par[reached] >= 0)
                      and np.all(par[~reached] == -1)
                      and np.all(d[par[reached & (d > 0)]]
                                 == d[reached & (d > 0)] - 1))
                if not ok:
                    print(f"VERIFY FAILED (parents): graph={out['graph']} "
                          f"source={s}", file=sys.stderr)
                    failures += 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--url", required=True,
                    help="server base url, e.g. http://127.0.0.1:8642")
    ap.add_argument("--graph", default=None,
                    help="lane name (optional on single-lane servers)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=1,
                    help="distinct random sources per request")
    ap.add_argument("--concurrency", type=int, default=1,
                    help="worker threads, released simultaneously")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--include-parents", action="store_true")
    ap.add_argument("--verify", action="store_true",
                    help="bitwise depth check vs the numpy reference on "
                         "the regenerated graph (needs the server spec)")
    ap.add_argument("--expect-429", action="store_true",
                    help="fail unless >= 1 request was rejected with 429")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="retry 429/503 responses up to N times, sleeping "
                         "the server's Retry-After hint (capped, jittered); "
                         "0 = fail fast (default)")
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--shutdown", action="store_true",
                    help="POST /admin/shutdown after the run")
    args = ap.parse_args(argv)

    client = BFSClient(args.url, timeout_s=args.timeout,
                       max_retries=args.max_retries, seed=args.seed)
    catalog = client.graphs()["graphs"]
    lanes = {g["name"]: g for g in catalog}
    if args.graph is None and len(lanes) == 1:
        args.graph = next(iter(lanes))
    if args.graph not in lanes:
        print(f"no lane {args.graph!r} on {args.url}; lanes: "
              f"{sorted(lanes)}", file=sys.stderr)
        return 2
    lane = lanes[args.graph]
    n = lane["n"]
    if args.batch > max(lane["buckets"]):
        print(f"--batch {args.batch} exceeds the lane's largest bucket "
              f"{max(lane['buckets'])}", file=sys.stderr)
        return 2

    rng = random.Random(args.seed)
    source_sets = [rng.sample(range(n), args.batch)
                   for _ in range(args.requests)]

    results, rejected, errors, latencies = [], [], [], []
    lock = threading.Lock()
    barrier = threading.Barrier(args.concurrency)

    def worker(worker_id: int):
        barrier.wait()                 # synchronized burst
        for i in range(worker_id, args.requests, args.concurrency):
            t0 = time.monotonic()
            try:
                out = client.traverse(args.graph, source_sets[i],
                                      include_parents=args.include_parents)
                with lock:
                    results.append(out)
                    latencies.append(time.monotonic() - t0)
            except HTTPStatusError as exc:
                with lock:
                    (rejected if exc.status == 429 else errors).append(exc)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(args.concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    lat_ms = sorted(x * 1e3 for x in latencies)
    p = (lambda q: lat_ms[min(len(lat_ms) - 1,
                              int(q * len(lat_ms)))] if lat_ms else 0.0)
    print(f"{len(results)}/{args.requests} ok on lane {args.graph!r} "
          f"(batch={args.batch}, served buckets="
          f"{sorted({r['bucket'] for r in results})}), "
          f"{len(rejected)} x 429, {len(errors)} errors, "
          f"{client.retries_used} retries; "
          f"p50={p(0.5):.1f}ms p95={p(0.95):.1f}ms")
    try:
        cache = client.metrics().get("engine_cache", {})
        print(f"server cache: hit_rate={cache.get('hit_rate', 0):.2f} "
              f"evictions={cache.get('evictions', 0)} "
              f"entries={cache.get('entries', 0)}")
    except (HTTPStatusError, OSError):
        pass                           # metrics are best-effort here

    rc = 0
    for exc in errors[:3]:
        print(f"error: {exc}", file=sys.stderr)
    if errors:
        rc = 1
    if args.expect_429 and not rejected:
        print("EXPECTED at least one 429 rejection; none happened",
              file=sys.stderr)
        rc = 1
    if not args.expect_429 and rejected:
        print(f"unexpected 429s: {rejected[0]}", file=sys.stderr)
        rc = 1
    if args.verify and results:
        # what the server lane actually resolved on the wire — so a CI
        # log shows which formats the bitwise check just covered
        wires = lane.get("wire_formats")
        if wires is not None:
            fmt = " ".join(f"{k}={v}" for k, v in sorted(wires.items()))
            print(f"verify: lane {args.graph!r} wire formats: {fmt} "
                  f"sieve={lane.get('sieve')}")
        try:
            # per-level device step-time percentiles the server measured
            # for the runs just verified (the distribution the fused
            # fold/owner-update tail shortens)
            lane_m = client.metrics()["lanes"].get(args.graph, {})
            pl = lane_m.get("per_level_device") or {}
            if pl.get("count"):
                print(f"verify: lane {args.graph!r} per-level device time: "
                      f"p50={pl['p50_ms']}ms p95={pl['p95_ms']}ms "
                      f"p99={pl['p99_ms']}ms over {pl['count']} levels")
        except (HTTPStatusError, OSError):
            pass                       # metrics are best-effort here
        if _verify_depths(lane, results, args.include_parents):
            rc = 1
        else:
            print(f"verify: {len(results)} traversals match the numpy "
                  "reference bitwise")
    if args.shutdown:
        try:
            client.shutdown()
        except (HTTPStatusError, OSError):
            pass                       # server may exit before replying
    return rc


if __name__ == "__main__":
    sys.exit(main())
