"""Serving launcher: continuous batching over a chosen LM arch.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_34b --reduced \
        --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import transformer as tf
from repro.serve.batcher import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.reduced if args.reduced else spec.config
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, batch_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        srv.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
            max_new_tokens=args.max_new_tokens))
    t0 = time.time()
    done = srv.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {toks} tokens, {dt:.2f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
