"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gcn_cora \
        --shape full_graph_sm --steps 50 --reduced

On a TPU cluster this binary is started once per host (JAX distributed
initialization via JAX_COORDINATOR/etc.), builds the production mesh over
the global device set, and drives the same Trainer; on this CPU container
``--reduced`` runs the smoke-scale configs end-to-end.  ``--compression``
enables the cross-pod gradient compressor.
"""

from __future__ import annotations

import argparse

from repro.configs.base import get_arch
from repro.launch.steps import build_bundle
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "topk"])
    args = ap.parse_args()

    spec = get_arch(args.arch)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    bundle = build_bundle(spec, args.shape, reduced=args.reduced,
                          opt_cfg=opt_cfg, microbatches=args.microbatches)
    assert bundle.step_kind == "train", \
        f"{args.shape} is a {bundle.step_kind} cell; use launch.serve"

    tcfg = TrainerConfig(num_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         grad_compression=args.compression)
    trainer = Trainer(bundle, tcfg, opt_cfg=opt_cfg)
    trainer.run()
    for m in trainer.metrics_log:
        print(m)
    if trainer.straggler_events:
        print(f"straggler events: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
