"""Chaos soak: the serving stack under a randomized fault schedule.

    PYTHONPATH=src python -m repro.launch.bfs_chaos --seed 0 --secs 30 \
        --devices 4 --out BENCH_chaos.json

Builds the full remote serving stack (multi-lane ``BFSService`` ->
``BFSFrontend`` -> HTTP) with every resilience feature armed — per-lane
circuit breakers, bounded retries, degradation arms, request deadlines,
the dispatcher watchdog — installs a seeded ``FaultPlan`` drawn from the
whole fault menu (compile failures, device-dispatch exceptions,
dispatcher stalls, slow collectives, cache-eviction storms, malformed
wire payloads), and hammers it with concurrent clients for ``--secs``.

The verdict (exit 0 iff all hold):

  * **typed outcomes** — every request resolves to a known status:
    200, 400/413 (the corrupt payloads we sent), 429 admission,
    503 breaker/draining, 504 deadline, 500 watchdog; anything else is
    a verdict failure.
  * **bitwise-correct survivors** — every 200's depth rows equal the
    numpy reference on the regenerated graph, bit for bit, no matter
    which bucket/split/wire degradation arm served it.
  * **no hung futures** — every client thread joins within its bound;
    the server drains clean.
  * **no leaks / no deadlock** — after the storm, admission gates are
    idle, no watchdog-abandoned round is still stuck, and ``/readyz``
    recovers to 200 once the schedule stops firing.

``--out`` writes the machine-readable ledger (``BENCH_chaos.json`` in
CI): the fault plan's firing counts next to the outcome histogram,
breaker trajectories and recovery latencies, and the watchdog snapshot.
"""

from repro.launch import host_devices_from_argv

host_devices_from_argv()  # must precede the jax import below

import argparse  # noqa: E402
import json  # noqa: E402
import random  # noqa: E402
import sys  # noqa: E402
import threading  # noqa: E402
import time  # noqa: E402
import urllib.error  # noqa: E402
import urllib.request  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import BFSOptions  # noqa: E402
from repro.core.ref import bfs_reference  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402
from repro.launch.bfs_client import BFSClient, HTTPStatusError  # noqa: E402
from repro.serve.bfs_service import BFSService  # noqa: E402
from repro.serve.engine_cache import EngineCache  # noqa: E402
from repro.serve.frontend.server import serve_http  # noqa: E402
from repro.serve.resilience import faults  # noqa: E402
from repro.serve.resilience.faults import (FaultPlan,  # noqa: E402
                                           FaultSpec, corrupt_bytes)
from repro.serve.resilience.retry import RetryPolicy  # noqa: E402

#: statuses the stack is *allowed* to answer under chaos; anything else
#: (or a transport-level hang) fails the soak
EXPECTED_STATUSES = {200, 400, 404, 413, 429, 500, 503, 504}

WATCHDOG_S = 1.0
BREAKER_RESET_S = 1.0


def build_fault_plan(seed: int, secs: float) -> FaultPlan:
    """A randomized (but seeded) schedule across the whole fault menu.

    Spec counts scale with the soak length so a 30s CI run sees every
    kind fire repeatedly; ``after``/``times`` windows are drawn so
    faults start, burn out, and let the breakers recover in between.
    """
    rng = random.Random(seed)
    rounds = max(2, int(secs / 5))
    specs = []
    for _ in range(rounds):
        # compile failures: enough consecutive hits to open a breaker,
        # bounded so half-open probes eventually close it again
        specs.append(FaultSpec(site="cache.compile", kind="fail",
                               after=rng.randrange(0, 20),
                               times=rng.randrange(3, 9)))
        # device-dispatch exceptions (transient: retry fodder)
        specs.append(FaultSpec(site="engine.dispatch", kind="fail",
                               after=rng.randrange(0, 30),
                               times=rng.randrange(1, 4)))
        # dispatcher stalls + slow collectives; some block-stalls exceed
        # the watchdog bound (typed 500 + tracked abandoned round)
        specs.append(FaultSpec(site="frontend.loop", kind="stall",
                               delay_s=0.05 + 0.1 * rng.random(),
                               after=rng.randrange(0, 40),
                               times=rng.randrange(1, 4)))
        specs.append(FaultSpec(site="frontend.block", kind="stall",
                               delay_s=(WATCHDOG_S * 1.5 if rng.random()
                                        < 0.3 else 0.1),
                               after=rng.randrange(0, 40),
                               times=rng.randrange(1, 3)))
        # eviction storms: the cache drops everything unpinned
        specs.append(FaultSpec(site="cache.get", kind="storm",
                               after=rng.randrange(0, 50),
                               times=rng.randrange(1, 3)))
        # malformed wire payloads (applied by the sending client)
        specs.append(FaultSpec(site="client.payload", kind="corrupt",
                               after=rng.randrange(0, 30),
                               times=rng.randrange(1, 4)))
    return FaultPlan(specs, seed=seed)


def _post_corrupt(base_url: str, body: dict, spec, seed: int) -> int:
    """Send a deliberately mangled body; returns the HTTP status (must
    land in the 400 family — the server's door, not its dispatcher,
    absorbs malformed wire input)."""
    raw = corrupt_bytes(json.dumps(body).encode(), spec, seed=seed)
    req = urllib.request.Request(
        base_url + "/v1/traverse", data=raw, method="POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=30.0) as rsp:
            return rsp.status
    except urllib.error.HTTPError as exc:
        return exc.code


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="randomized-fault soak of the resilient serving "
                    "stack; exits 0 iff the verdict holds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--secs", type=float, default=10.0,
                    help="fault-storm duration (recovery checks run "
                         "after)")
    ap.add_argument("--n", type=int, default=1200,
                    help="vertices per lane graph")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent client threads")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the chaos ledger json (BENCH_chaos)")
    ap.add_argument("--devices", type=int, default=0)  # parsed above
    args = ap.parse_args(argv)

    devs = jax.devices()
    p = len(devs)
    mesh = Mesh(np.asarray(devs).reshape(p), ("p",))
    print(f"chaos: seed={args.seed} secs={args.secs:g} p={p} "
          f"clients={args.clients} n={args.n}")

    # two lanes so breaker/degradation failures on one are observably
    # isolated from the other; small bucket ladder so the split arm and
    # bucket arm both exist
    lanes = {}
    svc = BFSService(opts=BFSOptions(mode="dense", queue_cap=1 << 14),
                     mesh=mesh, axis="p", batch_buckets=(1, 4),
                     cache=EngineCache(max_entries=32))
    for name, kind in (("er", "erdos_renyi"), ("ring", "small_world")):
        src, dst = generate(kind, args.n, seed=args.seed)
        lanes[name] = (src, dst)
        svc.add_graph(name, shard_graph(src, dst, args.n, p))

    httpd, frontend = serve_http(
        svc, "127.0.0.1", 0, max_queue_depth=16,
        breaker_threshold=3, breaker_reset_s=BREAKER_RESET_S,
        retry_policy=RetryPolicy(max_attempts=3, base_s=0.02, max_s=0.2,
                                 seed=args.seed),
        watchdog_timeout_s=WATCHDOG_S, degrade=True)
    base_url = f"http://127.0.0.1:{httpd.server_address[1]}"
    accept = threading.Thread(target=httpd.serve_forever, daemon=True)
    accept.start()

    # warm both lanes' preferred rungs before the storm so the soak
    # exercises serving-time faults, not just first-compile latency
    for name in lanes:
        BFSClient(base_url).traverse(name, [0])

    plan = build_fault_plan(args.seed, args.secs)
    outcomes = {}                     # status -> count
    lock = threading.Lock()
    failures = []                     # verdict-breaking observations
    deadline = time.monotonic() + args.secs

    def record(status: int) -> None:
        with lock:
            outcomes[status] = outcomes.get(status, 0) + 1

    def worker(wid: int) -> None:
        rng = random.Random((args.seed << 8) ^ wid)
        client = BFSClient(base_url, timeout_s=60.0,
                           max_retries=rng.randrange(0, 3), seed=wid)
        while time.monotonic() < deadline:
            name = rng.choice(sorted(lanes))
            k = rng.choice((1, 2, 4))
            sources = rng.sample(range(args.n), k)
            body = {"graph": name, "sources": sources}
            spec = faults.fire("client.payload", name)
            if spec is not None and spec.kind == "corrupt":
                status = _post_corrupt(base_url, body, spec,
                                       seed=rng.randrange(1 << 30))
                record(status)
                if status not in (400, 413):
                    with lock:
                        failures.append(
                            f"corrupt payload answered {status}, "
                            "expected 400/413")
                continue
            dl_ms = (rng.choice((25, 100, 400))
                     if rng.random() < 0.25 else None)
            try:
                out = client.traverse(name, sources, deadline_ms=dl_ms)
            except HTTPStatusError as exc:
                record(exc.status)
                if exc.status not in EXPECTED_STATUSES:
                    with lock:
                        failures.append(f"unexpected status {exc.status}: "
                                        f"{exc}")
                continue
            except Exception as exc:   # transport hang/crash = verdict
                with lock:
                    failures.append(f"transport failure: {exc!r}")
                continue
            record(200)
            src, dst = lanes[name]
            want = bfs_reference(src, dst, args.n, sources)
            got = np.asarray(out["depths"], dtype=np.int64).T
            if not np.array_equal(got, want):
                with lock:
                    failures.append(f"BITWISE MISMATCH lane={name} "
                                    f"sources={sources}")

    with faults.active(plan):
        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            # generous join bound: a thread that outlives it is a hung
            # future, which is exactly what the verdict must catch
            t.join(timeout=args.secs + 120.0)
        hung = [t for t in threads if t.is_alive()]
        if hung:
            failures.append(f"{len(hung)} client thread(s) hung")

    # ----------------------------------------------------- recovery phase
    # schedule uninstalled; the stack must return to fully healthy
    recovered = False
    t0 = time.monotonic()
    while time.monotonic() - t0 < 3 * BREAKER_RESET_S + 10.0:
        try:
            BFSClient(base_url).traverse("er", [1])
            if BFSClient(base_url).ready().get("ready"):
                recovered = True
                break
        except (HTTPStatusError, OSError):
            pass
        time.sleep(0.2)
    if not recovered:
        failures.append("stack did not recover to ready after the storm")
    wd = frontend.watchdog
    if wd is not None and not wd.wait_idle(timeout_s=30.0):
        failures.append(f"{wd.stuck()} watchdog round(s) still stuck "
                        "(leaked device work)")
    drained = frontend.drain(timeout_s=30.0)
    if not drained:
        failures.append("gates not idle after drain (leaked admissions)")
    httpd.shutdown()
    httpd.server_close()

    ledger = {
        "config": {"seed": args.seed, "secs": args.secs, "p": p,
                   "n": args.n, "clients": args.clients,
                   "watchdog_s": WATCHDOG_S,
                   "breaker_reset_s": BREAKER_RESET_S},
        "faults": plan.summary(),
        "outcomes": {str(k): v for k, v in sorted(outcomes.items())},
        "breakers": {name: {
            "snapshot": b.snapshot(),
            "recovery_latencies_s": [round(x, 3)
                                     for x in b.recovery_latencies_s()],
        } for name, b in frontend.breakers.items()},
        "watchdog": wd.snapshot() if wd is not None else None,
        "metrics": frontend.metrics_payload(),
        "failures": failures,
        "ok": not failures,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(ledger, f, indent=2, sort_keys=True)
        print(f"ledger -> {args.out}")

    fired = plan.summary()
    print(f"faults fired: {fired['fired_total']} {fired['by_kind']}")
    print(f"outcomes: { {k: v for k, v in sorted(outcomes.items())} }")
    for name, b in frontend.breakers.items():
        snap = b.snapshot()
        print(f"breaker[{name}]: state={snap['state']} "
              f"opened={snap['opened']} shed={snap['rejected_fast']}")
    if wd is not None:
        print(f"watchdog: trips={wd.snapshot()['trips']} "
              f"stuck={wd.stuck()}")
    if failures:
        for f_ in failures[:10]:
            print(f"CHAOS FAILURE: {f_}", file=sys.stderr)
        print(f"verdict: FAIL ({len(failures)} failure(s))",
              file=sys.stderr)
        return 1
    ok = outcomes.get(200, 0)
    print(f"verdict: OK — {ok} bitwise-correct responses, every fault "
          "retried/degraded/rejected with a typed status, no hung "
          "futures, no leaks")
    return 0


if __name__ == "__main__":
    sys.exit(main())
