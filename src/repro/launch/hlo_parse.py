"""Loop-aware HLO accounting.

``compiled.cost_analysis()`` and naive text scans count while-loop bodies
ONCE — an 80-layer scanned transformer under-reports flops and loop-local
collectives by ~80x.  This parser walks the HLO module text, extracts the
call graph (while bodies/conditions, fusions, calls), infers each while's
trip count from its condition's compare-against-constant, and accumulates

  * collective bytes (output shape bytes of all-gather / all-reduce /
    all-to-all / reduce-scatter / collective-permute),
  * dot FLOPs (2 * prod(output dims) * prod(contraction dims)),

each weighted by the product of enclosing trip counts.  Trip counts that
cannot be inferred default to 1 (conservative).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "all-to-all", "reduce-scatter",
                "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) (?:\([^)]*\) -> .*?)?\{",
                      re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(txt: str):
    """Return {comp_name: [lines]} for every computation in the module.

    A header is any line ending in "{" that contains ") -> " (computation
    signature) — regexing the param list is hopeless because tuple-typed
    params nest parentheses.
    """
    comps = {}
    cur, buf = None, []
    for line in txt.splitlines():
        s = line.strip()
        if s.endswith("{") and (") -> " in s or s.startswith("ENTRY")) \
                and not s.startswith("ROOT"):
            if cur is not None:
                comps[cur] = buf
            is_entry = s.startswith("ENTRY")
            head = s[6:] if is_entry else s
            cur = head.split("(", 1)[0].strip().lstrip("%").strip()
            buf = []
            if is_entry:
                comps.setdefault("__entry_name__", cur)
        elif s == "}" or s.startswith("} "):
            if cur is not None:
                comps[cur] = buf
                cur, buf = None, []
        elif cur is not None:
            buf.append(s)
    if cur is not None:
        comps[cur] = buf
    return comps


def _trip_count(cond_lines, comps=None) -> int:
    """Infer trip count from the condition: counter-vs-constant compare.

    The compare may be wrapped in a fusion (CPU backend), so when no inline
    compare is found, fall back to the condition's s32 scalar constant
    (loop counters start at 0 and compare LT bound), checking the called
    fusion for an LE direction.
    """
    consts = {}
    for l in cond_lines or []:
        m = re.match(r"%?([\w\.\-]+) = s32\[\] constant\((\d+)\)", l)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for l in cond_lines or []:
        if "compare(" in l and ("direction=LT" in l or "direction=LE" in l):
            for name, v in consts.items():
                if name in l:
                    return v + (1 if "direction=LE" in l else 0)
    if consts:
        bound = max(consts.values())
        le = False
        if comps is not None:
            for l in cond_lines or []:
                mc = re.search(r"calls=%?([\w\.\-]+)", l)
                if mc:
                    sub = "\n".join(comps.get(mc.group(1)) or [])
                    if "direction=LE" in sub:
                        le = True
        return bound + (1 if le else 0)
    return 1


_DEF_RE = re.compile(r"^%?([\w\.\-]+) = [a-z0-9]+\[([0-9,]*)\]")
_DOT_RE = re.compile(
    r"^%?[\w\.\-]+ = [a-z0-9]+\[([0-9,]*)\][^=]*? dot\(%?([\w\.\-]+)")


def _comp_dot_flops(lines) -> float:
    """2 * prod(out dims) * prod(lhs contracting dims), with operand shapes
    resolved from the computation's own definition lines."""
    shapes = {}
    for l in lines:
        m = _DEF_RE.match(l)
        if m:
            shapes[m.group(1)] = [int(d) for d in m.group(2).split(",") if d]
    flops = 0.0
    for l in lines:
        m = _DOT_RE.match(l)
        if not m:
            continue
        out = 1
        for d in m.group(1).split(","):
            if d:
                out *= int(d)
        contract = 1
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", l)
        lhs_dims = shapes.get(m.group(2))
        if mc and lhs_dims:
            for i in [int(x) for x in mc.group(1).split(",") if x]:
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
        flops += 2.0 * out * contract
    return flops


def loop_aware_stats(txt: str) -> dict:
    comps = _split_computations(txt)
    comps.pop("__entry__", None)
    entry = comps.pop("__entry_name__", None)

    # map: caller computation -> [(callee, multiplier)]
    # while: body runs trip_count times; fusion/call/cond: once
    calls = defaultdict(list)
    local = {}
    for name, lines in comps.items():
        if lines is None:
            continue
        coll = dict.fromkeys(_COLLECTIVES, 0.0)
        flops = 0.0
        for l in lines:
            mw = re.search(r"while\(.*\)", l)
            if mw and "body=" in l:
                mb = re.search(r"body=%?([\w\.\-]+)", l)
                mcnd = re.search(r"condition=%?([\w\.\-]+)", l)
                tc = _trip_count(comps.get(mcnd.group(1)), comps) if mcnd else 1
                calls[name].append((mb.group(1), float(max(tc, 1))))
                if mcnd:
                    calls[name].append((mcnd.group(1), float(max(tc, 1))))
                continue
            for key in ("calls=", "body=", "condition=", "to_apply=",
                        "branch_computations="):
                if key in l:
                    for cal in re.findall(r"%?([\w\.\-]+)",
                                          l.split(key, 1)[1].split(",")[0]):
                        if cal in comps:
                            calls[name].append((cal, 1.0))
                        break
            m = re.match(r"%?[\w\.\-]+ = (\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)"
                         r"\s+(all-reduce-start|all-reduce|all-gather-start|"
                         r"all-gather|all-to-all|reduce-scatter|"
                         r"collective-permute-start|collective-permute)\(", l)
            if m:
                coll[m.group(2).replace("-start", "")] += _shape_bytes(m.group(1))
        flops = _comp_dot_flops(lines)
        local[name] = (coll, flops)

    # accumulate with memoized weighted traversal
    import functools

    @functools.lru_cache(maxsize=None)
    def total(name) -> tuple:
        coll, flops = local.get(name, (dict.fromkeys(_COLLECTIVES, 0.0), 0.0))
        coll = dict(coll)
        for callee, mult in calls.get(name, ()):  # may recurse once per call
            sub_coll, sub_flops = total(callee)
            for i, k in enumerate(_COLLECTIVES):
                coll[k] += mult * sub_coll[i]
            flops += mult * sub_flops
        return tuple(coll[k] for k in _COLLECTIVES), flops

    root = entry or max(local, key=lambda n: local[n][1], default=None)
    if root is None:
        return {"collectives": dict.fromkeys(_COLLECTIVES, 0.0),
                "coll_total": 0.0, "dot_flops": 0.0}
    coll_t, flops = total(root)
    coll = dict(zip(_COLLECTIVES, coll_t))
    return {"collectives": coll, "coll_total": sum(coll.values()),
            "dot_flops": flops}
