"""Launchers and host-topology helpers.

This package ``__init__`` must stay import-light (stdlib only): the
``host_devices`` helper has to run *before* JAX is first imported, and the
launcher modules themselves import JAX at top level.
"""

from __future__ import annotations

import os
import sys

_DEV_FLAG = "--xla_force_host_platform_device_count"


def host_devices(n) -> None:
    """Force ``n`` host (CPU) devices for a local multi-shard run.

    Rewrites ``XLA_FLAGS`` (replacing any previous device-count flag, and
    preserving unrelated flags).  XLA reads the variable at backend
    initialization, so this must be called before JAX is first imported —
    launchers parse ``--devices`` from ``sys.argv`` ahead of their JAX
    imports, and the 8-device test harnesses call it at the top of the
    subprocess.  Raises if JAX is already loaded and the request differs
    from the current environment (a silent no-op there would *look* like
    a multi-shard run while executing on one device).
    """
    n = int(n)
    if n <= 0:
        return
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(_DEV_FLAG)]
    flags.append(f"{_DEV_FLAG}={n}")
    new = " ".join(flags)
    if new == os.environ.get("XLA_FLAGS", ""):
        return
    if "jax" in sys.modules:
        raise RuntimeError(
            f"host_devices({n}) called after jax was imported; XLA has "
            "already fixed its device count. Call it before any jax "
            "import (or set XLA_FLAGS in the environment).")
    os.environ["XLA_FLAGS"] = new


def parse_graph_spec(spec: str, default_n: int):
    """Parse a launcher ``--graph`` spec: ``[name=]kind[:n][:RxC]``.

    Returns ``(name, kind, n, grid-or-None)``.  One grammar for every
    launcher (``bfs_serve`` serves the grid token as a 2-D lane;
    ``bfs_run`` rejects it in favor of its global ``--partition/--grid``
    flags) — a spec copied between their command lines either works or
    fails with a clear message, never a raw ``int()`` traceback.
    Stdlib-only on purpose: this module must stay importable before JAX.
    """
    name, _, rest = spec.partition("=") if "=" in spec else ("", "", spec)
    parts = rest.split(":")
    kind = parts[0]
    n, grid = default_n, None
    for tok in parts[1:]:
        if "x" in tok.lower():
            try:
                r, c = (int(x) for x in tok.lower().split("x"))
            except ValueError:
                raise SystemExit(f"bad grid token {tok!r} in --graph "
                                 f"{spec!r}; expected RxC, e.g. 2x2")
            grid = (r, c)
        else:
            try:
                n = int(tok)
            except ValueError:
                raise SystemExit(f"bad vertex count {tok!r} in --graph "
                                 f"{spec!r}; expected [name=]kind[:n][:RxC]")
    return (name or kind), kind, n, grid


def host_devices_from_argv(argv=None) -> None:
    """Apply ``--devices N`` (or ``--devices=N``) from a launcher command
    line, pre-JAX-import."""
    argv = sys.argv if argv is None else argv
    for i, arg in enumerate(argv):
        if arg == "--devices":
            if i + 1 >= len(argv):
                raise SystemExit("--devices requires a value")
            host_devices(argv[i + 1])
            return
        if arg.startswith("--devices="):
            host_devices(arg.split("=", 1)[1])
            return
