"""BFS launcher: run any BFS workload on the local device set.

    PYTHONPATH=src python -m repro.launch.bfs_run --workload erdos_renyi_100k
    PYTHONPATH=src python -m repro.launch.bfs_run --graph star --n 4000000

Uses every visible device as one 1-D shard row (on a TPU pod slice this is
the full production run; on CPU it is p=1).  ``--devices N`` forces N host
devices for a local multi-shard run (set before jax init).
"""

import os
import sys

if "--devices" in sys.argv:
    i = sys.argv.index("--devices")
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={sys.argv[i + 1]}")

import argparse  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs.base import BFS_WORKLOADS  # noqa: E402
from repro.core import BFSOptions, bfs  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=None,
                    choices=[w.name for w in BFS_WORKLOADS])
    ap.add_argument("--graph", default="erdos_renyi")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--mode", default="auto",
                    choices=["dense", "queue", "auto"])
    ap.add_argument("--exchange", default="alltoall_direct")
    ap.add_argument("--sources", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0)  # parsed above
    args = ap.parse_args()

    if args.workload:
        wl = next(w for w in BFS_WORKLOADS if w.name == args.workload)
        kind, n, kw = wl.graph, wl.n_vertices, dict(wl.gen_kwargs)
    else:
        kind, n, kw = args.graph, args.n, {}

    devs = jax.devices()
    p = len(devs)
    mesh = Mesh(np.asarray(devs).reshape(p), ("p",))
    print(f"graph={kind} n={n} shards={p}")
    t0 = time.time()
    src, dst = generate(kind, n, seed=0, **kw)
    g = shard_graph(src, dst, n, p)
    print(f"generated {src.shape[0]} edges in {time.time()-t0:.1f}s")
    opts = BFSOptions(mode=args.mode, dense_exchange=args.exchange,
                      queue_cap=1 << 15)
    sources = list(range(args.sources))
    t0 = time.time()
    dist, stats = bfs(g, sources, mesh=mesh, axis="p", opts=opts)
    print(f"BFS: levels={stats.levels} visited={stats.visited} "
          f"modes={stats.mode_counts} comm_bytes/chip={stats.comm_bytes:.2e} "
          f"wall={time.time()-t0:.2f}s")


if __name__ == "__main__":
    main()
