"""BFS launcher: run any BFS workload on the local device set.

    PYTHONPATH=src python -m repro.launch.bfs_run --workload erdos_renyi_100k
    PYTHONPATH=src python -m repro.launch.bfs_run --graph star --n 4000000
    PYTHONPATH=src python -m repro.launch.bfs_run \
        --graph erdos_renyi:100000 --graph star:50000 --repeats 2

Uses every visible device as one 1-D shard row (on a TPU pod slice this is
the full production run; on CPU it is p=1), or — with ``--partition 2d``
— as an ``r x c`` grid (``--grid 2x2``; defaults to the most-square
factorization) running the two-phase edge-partitioned engine.
``--devices N`` forces N host devices for a local multi-shard run
(applied before jax initializes via ``repro.launch.host_devices``).

The launcher drives the compile-once lifecycle: one ``plan().compile()``
per (graph, options, mesh), then ``--repeats`` traversals from rotating
source sets against the same engine — compile wall time and per-traversal
wall time are reported separately, which is the paper's amortization story
at the CLI.  ``--graph`` is repeatable (``KIND[:N]``): every engine
resolves through the process-wide shared ``EngineCache``, and the final
stats line shows the cross-graph compile amortization (hits / misses /
evictions / compile seconds).
"""

from repro.launch import host_devices_from_argv, parse_graph_spec

host_devices_from_argv()  # must precede the jax import below

import argparse  # noqa: E402
import contextlib  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.analysis import trace_model  # noqa: E402
from repro.configs.base import BFS_WORKLOADS  # noqa: E402
from repro.core import BFSOptions, plan  # noqa: E402
from repro.graphs import generate, shard_graph, shard_graph_2d  # noqa: E402
from repro.launch.mesh import default_grid, make_grid_mesh  # noqa: E402
from repro.serve.engine_cache import default_engine_cache  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=None,
                    choices=[w.name for w in BFS_WORKLOADS])
    ap.add_argument("--graph", action="append", default=None,
                    metavar="KIND[:N]",
                    help="graph to traverse; repeatable — each runs "
                         "against its own cached engine (default: one "
                         "erdos_renyi of --n vertices)")
    ap.add_argument("--n", type=int, default=100_000,
                    help="default vertex count for --graph without :N")
    ap.add_argument("--mode", default="auto",
                    choices=["dense", "queue", "auto"])
    ap.add_argument("--exchange", default="alltoall_direct")
    ap.add_argument("--wire-format", default="auto",
                    choices=["packed", "bytes", "compressed", "auto"],
                    help="wire layout: packed uint32 bitset words (dense, "
                         "8x smaller), uint8 mask bytes / raw int32 ids, "
                         "delta+varint compressed ids (sparse phases), or "
                         "byte-model auto-selection per phase")
    ap.add_argument("--sieve", default="auto",
                    choices=["auto", "on", "off"],
                    help="visited-sieve: filter candidate ids against a "
                         "replicated coarse visited-summary bitmap before "
                         "the sparse exchange (auto: on when p>1 and the "
                         "plan has a sparse phase)")
    ap.add_argument("--describe", action="store_true",
                    help="print the compiled plan's full describe() "
                         "metadata — per-phase strategies, the wire "
                         "format 'auto' chose for each, and per-level "
                         "byte pricing")
    ap.add_argument("--audit", action="store_true",
                    help="run the HLO plan auditor on each compiled "
                         "engine (collective census vs resolved "
                         "strategies and modeled bytes, donation, "
                         "host-transfer checks) and print the census "
                         "next to the modeled bytes; exits 1 if any "
                         "engine fails the audit")
    ap.add_argument("--sources", type=int, default=1)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the timed "
                         "traversals into DIR and print the per-phase "
                         "device-time summary (expand / collective / "
                         "fold / owner_update) parsed from it")
    ap.add_argument("--repeats", type=int, default=3,
                    help="traversals to run against each compiled engine")
    ap.add_argument("--devices", type=int, default=0)  # parsed above
    ap.add_argument("--partition", default="1d", choices=["1d", "2d"],
                    help="vertex blocks over all p shards (1d) or edge "
                         "blocks over an r x c grid (2d)")
    ap.add_argument("--grid", default=None, metavar="RxC",
                    help="2-D grid shape, e.g. 2x2 (default: most-square "
                         "factorization of the device count)")
    args = ap.parse_args()

    if args.workload and args.graph:
        ap.error("--graph and --workload are mutually exclusive; pass the "
                 "workload's graph as a --graph spec instead")
    if args.workload:
        wl = next(w for w in BFS_WORKLOADS if w.name == args.workload)
        graphs = [(wl.graph, wl.n_vertices, dict(wl.gen_kwargs))]
    elif args.graph:
        graphs = []
        for spec in args.graph:
            _, kind, n, grid = parse_graph_spec(spec, args.n)
            if grid is not None:
                ap.error(f"--graph {spec}: per-spec grids are a bfs_serve "
                         "feature; here use --partition 2d --grid "
                         f"{grid[0]}x{grid[1]} (applies to every graph)")
            graphs.append((kind, n, {}))
    else:
        graphs = [("erdos_renyi", args.n, {})]

    devs = jax.devices()
    p = len(devs)
    sieve = {"auto": "auto", "on": True, "off": False}[args.sieve]
    if args.partition == "2d":
        if args.grid:
            r, c = (int(x) for x in args.grid.lower().split("x"))
        else:
            r, c = default_grid(p)
        mesh = make_grid_mesh(r, c)
        axis = None                          # plan uses the mesh's two axes
        # --exchange names a *dense* (1-D) strategy; the 2-D phases use
        # expand/fold strategies.  Honor it when it is also a registered
        # fold strategy, otherwise say so instead of silently dropping it.
        from repro.core import FOLD_COL_STRATEGIES
        fold = "alltoall_reduce"
        if args.exchange in FOLD_COL_STRATEGIES:
            fold = args.exchange
        elif args.exchange != ap.get_default("exchange"):
            print(f"partition=2d ignores --exchange={args.exchange} "
                  f"(uses expand/fold strategies; fold options: "
                  f"{tuple(FOLD_COL_STRATEGIES)})")
        # every mode works over grids: queue levels bucket fold-layout ids
        # down grid columns, auto switches per level (sparse needs S=1)
        opts = BFSOptions(mode=args.mode, fold_exchange=fold,
                          wire_format=args.wire_format, sieve=sieve,
                          queue_cap=1 << 15)
        print(f"grid={r}x{c} (p={r*c}) mode={args.mode} "
              f"wire={args.wire_format} sieve={args.sieve}")
    else:
        mesh = Mesh(np.asarray(devs).reshape(p), ("p",))
        axis = "p"
        opts = BFSOptions(mode=args.mode, dense_exchange=args.exchange,
                          wire_format=args.wire_format, sieve=sieve,
                          queue_cap=1 << 15)
        print(f"shards={p} mode={args.mode} wire={args.wire_format} "
              f"sieve={args.sieve}")

    cache = default_engine_cache()
    audit_failed = False
    for kind, n, kw in graphs:
        t0 = time.time()
        src, dst = generate(kind, n, seed=0, **kw)
        if args.partition == "2d":
            # bucket straight into the r x c edge blocks; the bottom-up
            # in-edge blocks build lazily iff mode=auto compiles them
            g = shard_graph_2d(src, dst, n, r, c)
        else:
            g = shard_graph(src, dst, n,
                            int(np.prod(list(mesh.shape.values()))))
        print(f"graph={kind} n={n}: generated {src.shape[0]} edges "
              f"in {time.time()-t0:.1f}s")

        t0 = time.time()
        engine = cache.get_or_compile(
            plan(g, opts, mesh=mesh, axis=axis, num_sources=args.sources,
                 partition=args.partition))
        compile_s = time.time() - t0
        meta = engine.plan.describe()
        exchanges = (f"{meta['expand_exchange']}+{meta['fold_exchange']}"
                     if args.partition == "2d" else meta["dense_exchange"])
        wires = meta["wire_formats"]
        print(f"plan+get_or_compile: {compile_s:.2f}s (S={args.sources}, "
              f"{exchanges}, "
              f"level_bytes/chip={meta['dense_level_bytes']:.2e})")
        # per-level-variant pricing with the wire format each phase
        # resolved to (what "auto" actually chose for this topology); a
        # 2-D dense level has two phases which may resolve differently
        # (a degenerate grid's peerless phase keeps bytes), so both show
        dense_wire = (wires["dense"] if args.partition != "2d"
                      else f"{wires['expand']}+{wires['fold']}")
        queue_wire = wires["queue" if args.partition != "2d"
                           else "fold_sparse"]
        print("  level variants: "
              f"dense={meta['dense_level_bytes']:.2e}B[{dense_wire}]  "
              f"queue={meta['queue_level_bytes']:.2e}B[{queue_wire}]  "
              f"bottom_up={meta['bottom_up_level_bytes']:.2e}B"
              f"[{wires['bottom_up']}]")
        if args.describe:
            for k in sorted(meta):
                print(f"  describe.{k} = {meta[k]}")
        if args.audit:
            from repro.analysis import hlo_audit
            rep = hlo_audit.audit_engine(engine, run_check=False)
            print(f"  {rep.summary()}")
            print(hlo_audit.census_table(rep))
            for v in rep.violations:
                print(f"  {v}")
            audit_failed |= not rep.ok()

        rng = np.random.default_rng(0)
        profile_cm = (trace_model.capture(args.profile) if args.profile
                      else contextlib.nullcontext())
        total_levels = 0
        with profile_cm:
            for rep in range(max(1, args.repeats)):
                sources = (list(range(args.sources)) if rep == 0 else
                           sorted(rng.choice(n, size=args.sources,
                                             replace=False).tolist()))
                t0 = time.time()
                res = engine.run(sources)
                run_s = time.time() - t0
                stats = res.stats()
                total_levels += stats.levels
                hits = int(stats.sieve_hits)
                # hit-rate: share of would-be enqueued candidates the
                # sieve dropped before they reached the wire (visited ids
                # that the coarse replicated summary could already prove
                # discovered)
                rate = hits / max(1, hits + stats.visited)
                sieve_str = (f" sieve_hits={hits} ({rate:.0%})"
                             if meta["sieve"] else "")
                print(f"run[{rep}] sources={sources[:4]}"
                      f"{'...' if len(sources) > 4 else ''}: "
                      f"levels={stats.levels} visited={stats.visited} "
                      f"modes={stats.mode_counts} "
                      f"comm_bytes/chip={stats.comm_bytes:.2e} "
                      f"wall={run_s:.3f}s{sieve_str}")
        if args.profile:
            timings = trace_model.parse_trace(args.profile,
                                              n_levels=total_levels)
            print(trace_model.format_summary(timings))
        assert engine.trace_count == engine.compile_traces, \
            "engine retraced after compile — amortization broken"

    st = cache.stats()
    print(f"engine cache: hits={st['hits']} misses={st['misses']} "
          f"evictions={st['evictions']} entries={st['entries']} "
          f"bytes={st['device_bytes']} "
          f"compile_s={st['compile_s_total']:.2f}")
    if audit_failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
