"""BFS launcher: run any BFS workload on the local device set.

    PYTHONPATH=src python -m repro.launch.bfs_run --workload erdos_renyi_100k
    PYTHONPATH=src python -m repro.launch.bfs_run --graph star --n 4000000

Uses every visible device as one 1-D shard row (on a TPU pod slice this is
the full production run; on CPU it is p=1).  ``--devices N`` forces N host
devices for a local multi-shard run (applied before jax initializes via
``repro.launch.host_devices``).

The launcher drives the compile-once lifecycle: one ``plan().compile()``
per (graph, options, mesh), then ``--repeats`` traversals from rotating
source sets against the same engine — compile wall time and per-traversal
wall time are reported separately, which is the paper's amortization story
at the CLI.
"""

from repro.launch import host_devices_from_argv

host_devices_from_argv()  # must precede the jax import below

import argparse  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs.base import BFS_WORKLOADS  # noqa: E402
from repro.core import BFSOptions, plan  # noqa: E402
from repro.graphs import generate, shard_graph  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=None,
                    choices=[w.name for w in BFS_WORKLOADS])
    ap.add_argument("--graph", default="erdos_renyi")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--mode", default="auto",
                    choices=["dense", "queue", "auto"])
    ap.add_argument("--exchange", default="alltoall_direct")
    ap.add_argument("--sources", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3,
                    help="traversals to run against the compiled engine")
    ap.add_argument("--devices", type=int, default=0)  # parsed above
    args = ap.parse_args()

    if args.workload:
        wl = next(w for w in BFS_WORKLOADS if w.name == args.workload)
        kind, n, kw = wl.graph, wl.n_vertices, dict(wl.gen_kwargs)
    else:
        kind, n, kw = args.graph, args.n, {}

    devs = jax.devices()
    p = len(devs)
    mesh = Mesh(np.asarray(devs).reshape(p), ("p",))
    print(f"graph={kind} n={n} shards={p}")
    t0 = time.time()
    src, dst = generate(kind, n, seed=0, **kw)
    g = shard_graph(src, dst, n, p)
    print(f"generated {src.shape[0]} edges in {time.time()-t0:.1f}s")
    opts = BFSOptions(mode=args.mode, dense_exchange=args.exchange,
                      queue_cap=1 << 15)

    t0 = time.time()
    engine = plan(g, opts, mesh=mesh, axis="p",
                  num_sources=args.sources).compile()
    compile_s = time.time() - t0
    print(f"plan+compile: {compile_s:.2f}s "
          f"(S={args.sources}, {engine.plan.describe()['dense_exchange']})")

    rng = np.random.default_rng(0)
    for rep in range(max(1, args.repeats)):
        sources = (list(range(args.sources)) if rep == 0 else
                   sorted(rng.choice(n, size=args.sources, replace=False)
                          .tolist()))
        t0 = time.time()
        res = engine.run(sources)
        run_s = time.time() - t0
        stats = res.stats()
        print(f"run[{rep}] sources={sources[:4]}"
              f"{'...' if len(sources) > 4 else ''}: "
              f"levels={stats.levels} visited={stats.visited} "
              f"modes={stats.mode_counts} "
              f"comm_bytes/chip={stats.comm_bytes:.2e} wall={run_s:.3f}s")
    assert engine.trace_count == engine.compile_traces, \
        "engine retraced after compile — amortization broken"


if __name__ == "__main__":
    main()
