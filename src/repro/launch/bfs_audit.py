"""Audit gate: statically verify compiled plans + repo conventions.

    PYTHONPATH=src python -m repro.launch.bfs_audit \
        --graph er:4096 --all-variants --devices 4

For each partition x wire-format x mode x fused-tail variant, compile
the plan (via the shared EngineCache, so twins that resolve to the same
plan key cost one compile) and run the HLO plan auditor
(analysis/hlo_audit): the
collective census must match the resolved strategies, modeled bytes
must agree with HLO received bytes within the documented tolerance, the
dist buffer must be donated, no host transfer may hide in the loop, and
two distinct-source runs must not retrace.  The registry/loop lint
(analysis/lint) and the serve/ lock-discipline pass (analysis/locks)
run once alongside.

Exit code 0 iff every report is clean (suppressed violations carry
their reasons in the report but do not gate).  ``--out`` writes the
full machine-readable ledger (``BENCH_audit.json`` in CI).
"""

from repro.launch import host_devices_from_argv, parse_graph_spec

host_devices_from_argv()  # must precede the jax import below

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.analysis import hlo_audit  # noqa: E402
from repro.analysis.lint import lint_tree  # noqa: E402
from repro.analysis.locks import analyze_serve  # noqa: E402
from repro.core import BFSOptions, plan  # noqa: E402
from repro.graphs import generate, shard_graph, shard_graph_2d  # noqa: E402
from repro.launch.mesh import default_grid, make_grid_mesh  # noqa: E402
from repro.serve.engine_cache import default_engine_cache  # noqa: E402

MODES = ("dense", "queue", "auto")
WIRES = ("bytes", "packed", "compressed", "auto")
# the fused-tail axis doubles the gate: every wire x mode compiles its
# unfused twin and its "auto"-resolved twin (which turns the fused tail
# on exactly where it can exist — packed dense/fold wire + a dense-path
# mode; elsewhere both resolve to the same plan_key and the EngineCache
# dedups the compile, so the doubling is nominal)
FUSED = (False, "auto")


def _variants(p: int, all_variants: bool, args):
    if not all_variants:
        yield (args.partition, args.mode, args.wire_format,
               {"on": True, "off": False, "auto": "auto"}[args.fused_tail])
        return
    partitions = ("1d", "2d") if p > 1 else ("1d",)
    for part in partitions:
        for wire in WIRES:
            for mode in MODES:
                for fused in FUSED:
                    yield part, mode, wire, fused


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="static audit of compiled BFS plans (HLO census, "
                    "donation, retrace) + registry lint + lock pass")
    ap.add_argument("--graph", default="er:4096", metavar="KIND[:N]",
                    help="graph spec to audit plans against")
    ap.add_argument("--all-variants", action="store_true",
                    help="audit every partition x wire-format x mode "
                         "variant (the CI gate); default audits the "
                         "single variant named by --partition/--mode/"
                         "--wire-format")
    ap.add_argument("--partition", default="1d", choices=["1d", "2d"])
    ap.add_argument("--mode", default="auto", choices=list(MODES))
    ap.add_argument("--wire-format", default="auto", choices=list(WIRES))
    ap.add_argument("--fused-tail", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused fold/owner-update tail for the single-"
                         "variant audit (--all-variants always audits "
                         "both twins)")
    ap.add_argument("--grid", default=None, metavar="RxC",
                    help="2-D grid (default: most-square factorization)")
    ap.add_argument("--sources", type=int, default=1,
                    help="compiled source-batch capacity S")
    ap.add_argument("--devices", type=int, default=0)  # parsed above
    ap.add_argument("--tolerance", default=None, metavar="LO,HI",
                    help="HLO-vs-model byte ratio band "
                         f"(default {hlo_audit.DEFAULT_TOLERANCE})")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the full audit ledger json (BENCH_audit)")
    ap.add_argument("--census", action="store_true",
                    help="print the per-variant census table")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-locks", action="store_true")
    ap.add_argument("--skip-run-check", action="store_true",
                    help="skip the two-run retrace check (HA006)")
    args = ap.parse_args(argv)

    tol = hlo_audit.DEFAULT_TOLERANCE
    if args.tolerance:
        lo, hi = (float(x) for x in args.tolerance.split(","))
        tol = (lo, hi)

    _, kind, n, spec_grid = parse_graph_spec(args.graph, 4096)
    devs = jax.devices()
    p = len(devs)
    grid = spec_grid
    if grid is None:
        grid = (int(x) for x in args.grid.lower().split("x")) \
            if args.grid else default_grid(p)
    r, c = grid
    print(f"audit: graph={kind}:{n} p={p} grid={r}x{c} "
          f"tolerance={list(tol)}")

    src, dst = generate(kind, n, seed=0)
    mesh_1d = Mesh(np.asarray(devs).reshape(p), ("p",))
    g1 = shard_graph(src, dst, n, p)
    g2 = shard_graph_2d(src, dst, n, r, c) if p > 1 else None
    mesh_2d = make_grid_mesh(r, c) if p > 1 else None

    cache = default_engine_cache()
    reports = []
    failed = False
    for part, mode, wire, fused in _variants(p, args.all_variants, args):
        opts = BFSOptions(mode=mode, wire_format=wire,
                          use_fused_tail=fused)
        t0 = time.time()
        if part == "2d":
            pl = plan(g2, opts, mesh=mesh_2d, num_sources=args.sources,
                      partition="2d")
        else:
            pl = plan(g1, opts, mesh=mesh_1d, axis="p",
                      num_sources=args.sources)
        if (args.all_variants and fused == "auto"
                and not pl.use_fused_tail):
            # "auto" resolved the fused tail off — this plan_key is the
            # fused=False twin already audited; skip the duplicate report
            continue
        engine = cache.get_or_compile(pl)
        fused_tag = ":fused" if pl.use_fused_tail else ""
        rep = hlo_audit.audit_engine(
            engine, tolerance=tol, run_check=not args.skip_run_check,
            name=f"hlo:{part}:{mode}:{wire}:S{args.sources}{fused_tag}")
        coll = rep.info["collectives"]
        print(f"{rep.summary()}  "
              f"[{coll['loop_data']} data + {coll['loop_control']} control "
              f"collectives, {time.time() - t0:.1f}s]")
        if args.census:
            print(hlo_audit.census_table(rep))
        for v in rep.violations:
            print(f"  {v}")
        failed |= not rep.ok()
        reports.append(rep)

    if not args.skip_lint:
        rep = lint_tree()
        print(rep.summary() + f"  [{len(rep.info['registrations'])} "
              "registrations checked]")
        for v in rep.violations:
            print(f"  {v}")
        failed |= not rep.ok()
        reports.append(rep)
    if not args.skip_locks:
        rep = analyze_serve()
        print(rep.summary() + f"  [{len(rep.info['lock_edges'])} lock "
              "edges]")
        for v in rep.violations:
            print(f"  {v}")
        failed |= not rep.ok()
        reports.append(rep)

    st = cache.stats()
    print(f"engine cache: hits={st['hits']} misses={st['misses']} "
          f"compile_s={st['compile_s_total']:.1f}")
    if args.out:
        ledger = {
            "audit": {
                "graph": {"kind": kind, "n": n}, "p": p,
                "grid": [r, c], "tolerance": list(tol),
                "ok": not failed,
                "reports": [rep.to_dict() for rep in reports],
            },
        }
        with open(args.out, "w") as f:
            json.dump(ledger, f, indent=2, sort_keys=True, default=str)
        print(f"wrote {args.out}")
    print("audit: " + ("FAIL" if failed else "PASS"))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
