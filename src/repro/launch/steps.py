"""Unified step builder: (architecture x shape) -> jittable step + specs.

Everything downstream — smoke tests, the trainer, the multi-pod dry-run,
the roofline benches — gets its step function and abstract input specs from
``build_bundle``, so there is exactly one definition of what each of the 40
assigned cells computes.

Step kinds per family:
  lm      train (fwd+bwd+AdamW) | prefill | decode
  gnn     train (all four shape modes)
  recsys  train | serve | retrieval
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchSpec, GNNShape, LMShape, RecsysShape,
                                TransformerConfig, get_shape)
from repro.data import synthetic as syn
from repro.models import transformer as tf
from repro.models.gnn import models as gnn
from repro.models.recsys import deepfm
from repro.models import sharding_hints as hints
from repro.optim.adamw import AdamWConfig, apply_updates, init_state


@dataclasses.dataclass
class StepBundle:
    arch_id: str
    family: str
    step_kind: str           # train | prefill | decode | serve | retrieval
    cfg: Any
    shape: Any
    init_params: Callable    # key -> params
    make_state: Callable     # params -> state (train) or params (serve)
    fn: Callable             # (state, batch) -> outputs
    input_specs: Callable    # () -> batch pytree of ShapeDtypeStruct
    make_batch: Callable     # (seed) -> concrete batch (smoke/examples)


def reduce_shape(shape, family: str):
    """Tiny same-structure shape for CPU smoke tests."""
    if family == "lm":
        return LMShape(shape.name, shape.step, seq_len=32,
                       global_batch=2)
    if family == "gnn":
        kw = dict(name=shape.name, mode=shape.mode)
        if shape.mode == "sampled":
            return GNNShape(**kw, n_nodes=64, n_edges=256, d_feat=12,
                            batch_nodes=8, fanout=(3, 2))
        if shape.mode == "batched":
            return GNNShape(**kw, n_nodes=10, n_edges=24, d_feat=12,
                            batch_graphs=4)
        return GNNShape(**kw, n_nodes=200, n_edges=800, d_feat=12)
    if family == "recsys":
        return RecsysShape(shape.name, shape.step, batch=64,
                           n_candidates=256 if shape.step == "retrieval" else 0)
    raise ValueError(family)


def _train_wrap(loss_fn, opt_cfg: AdamWConfig, microbatches: int = 1):
    """fwd+bwd+AdamW step; with microbatches > 1 the batch is split on its
    leading axis and gradients accumulate in fp32 across a scan — activation
    memory scales with B/microbatches while keeping the same global batch
    (the standard grad-accumulation lever; see EXPERIMENTS.md §Perf)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state, batch):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(state["params"], batch)
            grads = hints.constrain_grads(grads)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def micro(carry, b):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state["params"], b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / microbatches,
                    g_acc, g)
                return (g_acc, l_acc + l / microbatches), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), mb)
            grads = hints.constrain_grads(grads)
        new_p, new_opt, m = apply_updates(opt_cfg, state["params"], grads,
                                          state["opt"])
        return {"params": new_p, "opt": new_opt}, {"loss": loss, **m}
    return step


def _make_state(params):
    return {"params": params, "opt": init_state(params)}


# ---------------------------------------------------------------------------

def _lm_bundle(spec: ArchSpec, shape: LMShape, cfg: TransformerConfig,
               opt_cfg: AdamWConfig, microbatches: int = 1) -> StepBundle:
    if shape.step == "train":
        fn = _train_wrap(
            lambda p, b: tf.lm_loss(cfg, p, b["tokens"]), opt_cfg,
            microbatches=microbatches)
        return StepBundle(
            spec.arch_id, "lm", "train", cfg, shape,
            init_params=lambda key: tf.init_params(cfg, key),
            make_state=_make_state, fn=fn,
            input_specs=lambda: syn.lm_train_specs(cfg, shape),
            make_batch=lambda seed=0: syn.lm_train_batch(
                cfg, shape.global_batch, shape.seq_len, seed))

    if shape.step == "prefill":
        def fn(params, batch):
            logits, cache, _ = tf.prefill(cfg, params, batch["tokens"],
                                          max_len=shape.seq_len)
            return logits, cache
        return StepBundle(
            spec.arch_id, "lm", "prefill", cfg, shape,
            init_params=lambda key: tf.init_params(cfg, key),
            make_state=lambda p: p, fn=fn,
            input_specs=lambda: syn.lm_prefill_specs(cfg, shape),
            make_batch=lambda seed=0: {
                "tokens": syn.lm_train_batch(
                    cfg, shape.global_batch, shape.seq_len - 1,
                    seed)["tokens"]})

    # decode: one new token against a seq_len-deep KV cache
    def fn(params, batch):
        return tf.decode_step(cfg, params, batch["cache"], batch["pos"],
                              batch["last_token"])

    def make_batch(seed=0):
        import numpy as np
        rng = np.random.default_rng(seed)
        cache = tf.init_cache(cfg, shape.global_batch, shape.seq_len)
        return {"cache": cache,
                "pos": jnp.int32(shape.seq_len - 1),
                "last_token": rng.integers(
                    0, cfg.vocab, (shape.global_batch,)).astype("int32")}

    return StepBundle(
        spec.arch_id, "lm", "decode", cfg, shape,
        init_params=lambda key: tf.init_params(cfg, key),
        make_state=lambda p: p, fn=fn,
        input_specs=lambda: syn.lm_decode_specs(cfg, shape),
        make_batch=make_batch)


def _gnn_bundle(spec: ArchSpec, shape: GNNShape, cfg,
                opt_cfg: AdamWConfig, pad: int) -> StepBundle:
    fn = _train_wrap(lambda p, b: gnn.loss_fn(cfg, p, b), opt_cfg)
    return StepBundle(
        spec.arch_id, "gnn", "train", cfg, shape,
        init_params=lambda key: gnn.init_params(cfg, shape.d_feat, key),
        make_state=_make_state, fn=fn,
        input_specs=lambda: syn.gnn_specs(cfg, shape, pad=pad),
        make_batch=lambda seed=0: syn.gnn_batch(cfg, shape, seed=seed,
                                                pad=min(pad, 128)))


def _recsys_bundle(spec: ArchSpec, shape: RecsysShape, cfg,
                   opt_cfg: AdamWConfig) -> StepBundle:
    if shape.step == "train":
        fn = _train_wrap(lambda p, b: deepfm.loss_fn(cfg, p, b), opt_cfg)
        make_state = _make_state
        kind = "train"
    elif shape.step == "serve":
        fn = lambda params, batch: deepfm.serve_step(cfg, params, batch)
        make_state = lambda p: p
        kind = "serve"
    else:
        fn = lambda params, batch: deepfm.retrieval_step(cfg, params, batch)
        make_state = lambda p: p
        kind = "retrieval"
    return StepBundle(
        spec.arch_id, "recsys", kind, cfg, shape,
        init_params=lambda key: deepfm.init_params(cfg, key),
        make_state=make_state, fn=fn,
        input_specs=lambda: syn.recsys_specs(cfg, shape),
        make_batch=lambda seed=0: syn.recsys_batch(
            cfg, shape.batch, step=shape.step,
            n_candidates=shape.n_candidates, seed=seed))


def build_bundle(spec: ArchSpec, shape_or_name, *, reduced: bool = False,
                 opt_cfg: AdamWConfig = AdamWConfig(), pad: int = 512,
                 microbatches: int = 1) -> StepBundle:
    shape = (get_shape(spec, shape_or_name)
             if isinstance(shape_or_name, str) else shape_or_name)
    cfg = spec.reduced if reduced else spec.config
    if reduced:
        shape = reduce_shape(shape, spec.family)
        pad = min(pad, 64)
        microbatches = min(microbatches, 2)
    if spec.family == "lm":
        return _lm_bundle(spec, shape, cfg, opt_cfg, microbatches)
    if spec.family == "gnn":
        return _gnn_bundle(spec, shape, cfg, opt_cfg, pad)
    if spec.family == "recsys":
        return _recsys_bundle(spec, shape, cfg, opt_cfg)
    raise ValueError(spec.family)
