"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the pod axis is
pure data parallelism across the slower inter-pod links (DCN), so the only
cross-pod collective in steady state is the gradient all-reduce.

Functions, not module constants: importing this module never touches jax
device state (the dry-run pins the device count before any jax init).
"""

from __future__ import annotations

import dataclasses

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical axis-name bundles for a mesh (flat tuples for 1-D jobs)."""
    dp: tuple          # data-parallel axes (includes pod when present)
    model: str         # tensor/expert-parallel axis
    flat: tuple        # every axis (BFS/GNN vertex partitioning)

    @property
    def dp_size(self):
        return None  # resolved against a mesh via sizes()

    def sizes(self, mesh):
        import numpy as np
        dp = int(np.prod([mesh.shape[a] for a in self.dp]))
        return {"dp": dp, "model": mesh.shape[self.model],
                "flat": int(np.prod([mesh.shape[a] for a in self.flat]))}


def mesh_axes(mesh) -> Axes:
    names = tuple(mesh.axis_names)
    if "pod" in names:
        return Axes(dp=("pod", "data"), model="model", flat=names)
    return Axes(dp=("data",), model="model", flat=names)


def make_host_mesh(p: int = 1, name: str = "data"):
    """Small mesh over real local devices (tests, examples)."""
    import numpy as np
    devs = np.asarray(jax.devices()[:p]).reshape(p)
    return jax.sharding.Mesh(devs, (name,))


def default_grid(p: int) -> tuple:
    """Most-square ``(r, c)`` factorization of ``p`` (r <= c).

    The 2-D exchange cost scales with r + c, which a square grid
    minimizes; prime ``p`` degenerates to ``(1, p)`` (= 1-D expand-free).
    """
    r = int(p ** 0.5)
    while p % r:
        r -= 1
    return r, p // r


def make_grid_mesh(r: int = 2, c: int = 2, names: tuple = ("rows", "cols")):
    """``r x c`` device grid for the 2-D BFS edge partition.

    Device ``(i, j)`` owns vertex chunk ``i*c + j``; the expand phase
    allgathers frontiers over ``names[1]`` (within a grid row) and the
    fold phase merges candidates over ``names[0]`` (within a grid
    column).  Needs ``r*c`` local devices (``host_devices(n)`` /
    ``--devices n`` before the first jax import for CPU runs).
    """
    import numpy as np
    devs = jax.devices()
    if len(devs) < r * c:
        raise ValueError(f"grid {r}x{c} needs {r*c} devices; "
                         f"have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[: r * c]).reshape(r, c), names)
