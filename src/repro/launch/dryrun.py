from repro.launch import host_devices
host_devices(512)

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract roofline terms.  MUST be run as its own process (the two
lines above pin the device count before any jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3_12b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json

No arrays are ever materialized: params come from eval_shape, inputs are
ShapeDtypeStructs, and .lower().compile() proves the sharding + memory plan.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import ARCH_IDS, get_arch  # noqa: E402
from repro.launch import shardings as sh  # noqa: E402
from repro.launch.flops_est import model_flops  # noqa: E402
from repro.launch.hlo_stats import analyze, collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_axes  # noqa: E402
from repro.launch.steps import build_bundle  # noqa: E402
from repro.models import sharding_hints  # noqa: E402


def lower_owner_gnn(arch_id: str, shape_name: str, *, multi_pod: bool,
                    donate: bool = True):
    """Owner-exchange GraphCast cell (paper-technique path; §Perf)."""
    import jax.numpy as jnp  # noqa: F401
    from repro.core.partition import Partition1D
    from repro.launch.steps import _make_state, _train_wrap
    from repro.models.gnn import dist_graphcast as dg
    from repro.optim.adamw import AdamWConfig

    spec = get_arch(arch_id)
    from repro.configs.base import get_shape
    shape = get_shape(spec, shape_name)
    cfg = spec.config
    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = mesh_axes(mesh)
    p = mesh.size

    def pad(x, m=64):
        return -(-int(x) // m) * m

    e_cap = pad(shape.n_edges / p * 1.25)
    r_cap = pad(min(e_cap, e_cap / p * 1.5 + 64))
    loss_fn = dg.make_loss_fn(cfg, mesh, ax.flat)
    fn = _train_wrap(loss_fn, AdamWConfig())

    params_shape = jax.eval_shape(
        lambda k: dg.init_params(cfg, shape.d_feat, k), jax.random.PRNGKey(0))
    state_shape = jax.eval_shape(_make_state, params_shape)
    from jax.sharding import PartitionSpec as P
    pspecs = jax.tree.map(lambda _: P(), params_shape)
    sspecs = {"params": pspecs,
              "opt": {"m": pspecs, "v": pspecs, "step": P()}}
    batch_shape = dg.routing_specs(shape.n_nodes, p, shape.d_feat, cfg,
                                   r_cap, e_cap)
    bspecs = dg.routing_batch_specs(ax.flat)

    jitted = jax.jit(fn, in_shardings=(sh.to_named(sspecs, mesh),
                                       sh.to_named(bspecs, mesh)),
                     donate_argnums=(0,) if donate else ())
    with mesh:
        t0 = time.time()
        compiled = jitted.lower(state_shape, batch_shape).compile()
        dt = time.time() - t0
    mem = compiled.memory_analysis()
    roof = analyze(compiled, p, model_flops_override=0.0)
    meta = {
        "arch": f"{arch_id}+owner", "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": p,
        "compile_s": round(dt, 1),
        "bytes_per_device": int(mem.temp_size_in_bytes
                                + mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
        "r_cap": r_cap, "e_cap": e_cap,
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in roof.row().items()},
    }
    return compiled, meta


def _batch_specs(bundle, mesh):
    if bundle.family == "lm":
        return sh.lm_batch_specs(bundle.cfg, bundle.shape, mesh)
    if bundle.family == "gnn":
        return sh.gnn_batch_specs(bundle.input_specs(), mesh)
    return sh.recsys_batch_specs(bundle.cfg, bundle.shape, mesh)


def _param_specs(bundle, params_shape, mesh, lm_mode="tp"):
    if bundle.family == "lm":
        return sh.lm_param_specs(bundle.cfg, mesh, mode=lm_mode)
    if bundle.family == "gnn":
        return sh.gnn_param_specs(params_shape, mesh)
    return sh.recsys_param_specs(bundle.cfg, mesh)


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               donate: bool = True, zero1: bool = True, fsdp: bool = True,
               pad: int = 512, microbatches: int = 1, seq_shard: bool = True,
               lm_mode: str = "tp"):
    """Lower + compile one cell; returns (compiled, meta dict)."""
    spec = get_arch(arch_id)
    bundle = build_bundle(spec, shape_name, pad=pad,
                          microbatches=microbatches)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    params_shape = jax.eval_shape(bundle.init_params, jax.random.PRNGKey(0))
    pspecs = _param_specs(bundle, params_shape, mesh, lm_mode=lm_mode)
    bspecs = _batch_specs(bundle, mesh)
    batch_shape = bundle.input_specs()
    ax = mesh_axes(mesh)
    pure_fsdp = lm_mode == "fsdp" and bundle.family == "lm"
    if pure_fsdp and bundle.step_kind == "train":
        from jax.sharding import PartitionSpec as P
        if bundle.shape.global_batch % mesh.size == 0:
            bspecs = {"tokens": P(ax.flat, None)}

    pspecs_final = pspecs
    if bundle.step_kind == "train":
        state_shape = jax.eval_shape(
            lambda ps: bundle.make_state(ps), params_shape)
        use_fsdp = fsdp and bundle.family == "lm"
        if use_fsdp:
            pspecs_final = sh.fsdp_specs(
                pspecs, params_shape, mesh,
                dp_axes=ax.flat if pure_fsdp else None)
        sspecs = sh.state_specs(pspecs_final, params_shape, mesh,
                                zero1=zero1, fsdp=False)
        in_shardings = (sh.to_named(sspecs, mesh), sh.to_named(bspecs, mesh))
        args = (state_shape, batch_shape)
        donate_args = (0,) if donate else ()
    else:
        in_shardings = (sh.to_named(pspecs, mesh), sh.to_named(bspecs, mesh))
        args = (params_shape, batch_shape)
        donate_args = (1,) if (donate and bundle.step_kind == "decode") else ()

    jitted = jax.jit(bundle.fn, in_shardings=in_shardings,
                     donate_argnums=donate_args)
    with mesh, sharding_hints.hints(
            mesh, ax.flat if pure_fsdp else ax.dp, ax.model, ax.flat,
            seq_shard=seq_shard and not pure_fsdp,
            param_specs=pspecs_final if bundle.step_kind == "train" else None):
        t0 = time.time()
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        dt = time.time() - t0

    mem = compiled.memory_analysis()
    roof = analyze(compiled, chips, model_flops(bundle))
    coll = collective_bytes(compiled.as_text())
    meta = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "compile_s": round(dt, 1),
        "bytes_per_device": int(mem.temp_size_in_bytes
                                + mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "arg_bytes": int(mem.argument_size_in_bytes),
        "collectives": {k: v for k, v in coll.items() if v},
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in roof.row().items()},
    }
    return compiled, meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--lm-mode", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--gnn-exchange", default="gspmd",
                    choices=["gspmd", "owner"])
    ap.add_argument("--no-donate", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch_id in ARCH_IDS:
            spec = get_arch(arch_id)
            for shp in spec.shapes:
                cells.append((arch_id, shp.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    rows, failures = [], []
    for arch_id, shape_name in cells:
        for mp in meshes:
            tag = f"{arch_id}/{shape_name}/{'multi' if mp else 'single'}"
            try:
                if args.gnn_exchange == "owner":
                    compiled, meta = lower_owner_gnn(
                        arch_id, shape_name, multi_pod=mp,
                        donate=not args.no_donate)
                else:
                    compiled, meta = lower_cell(
                        arch_id, shape_name, multi_pod=mp,
                        donate=not args.no_donate, zero1=not args.no_zero1,
                        fsdp=not args.no_fsdp,
                        microbatches=args.microbatches,
                        seq_shard=not args.no_seq_shard,
                        lm_mode=args.lm_mode)
                rows.append(meta)
                print(f"OK   {tag:60s} compile={meta['compile_s']:7.1f}s "
                      f"mem/dev={meta['bytes_per_device']/2**30:6.2f}GiB "
                      f"bottleneck={meta['bottleneck']:10s} "
                      f"t=({meta['t_compute_s']:.2e},{meta['t_memory_s']:.2e},"
                      f"{meta['t_collective_s']:.2e})s", flush=True)
                del compiled
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append({"cell": tag, "error": f"{type(e).__name__}: {e}"})
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": failures}, f, indent=1)
        print(f"wrote {args.out}: {len(rows)} ok, {len(failures)} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
