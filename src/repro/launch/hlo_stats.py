"""Roofline-term extraction from compiled executables.

``cost_analysis`` gives HLO FLOPs and bytes accessed; collective traffic is
not in there, so we parse the post-SPMD optimized HLO text and sum the
output-shape bytes of every collective op.  Hardware model: TPU v5e.
"""

from __future__ import annotations

import dataclasses
import re

# --- TPU v5e per-chip constants (targets; runtime here is CPU) ---
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "all-to-all", "reduce-scatter",
                "collective-permute")

# result type(s) then op name, e.g.:
#   %ar = bf16[128,4096]{1,0} all-reduce(...)
#   %tup = (f32[4]{0}, f32[8]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|all-to-all|"
    r"reduce-scatter|collective-permute-start|collective-permute)\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum of collective output bytes, by op kind (whole-program, i.e. the
    per-device SPMD program: sizes are already per-shard)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        out[op] += _shape_bytes(type_str)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float              # per-device HLO FLOPs
    hbm_bytes: float          # per-device bytes accessed
    coll_bytes: float         # per-device collective bytes
    chips: int
    model_flops: float = 0.0  # 6*N*D-style useful-work estimate (global)

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self):
        if self.model_flops and self.flops:
            return self.model_flops / (self.flops * self.chips)
        return float("nan")

    def row(self):
        return {
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_chip": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "useful_flops_ratio": self.useful_ratio,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0,
            model_flops_override=None) -> Roofline:
    """Roofline terms.  FLOPs and collective bytes are LOOP-AWARE (HLO
    while bodies weighted by trip count — hlo_parse); cost_analysis counts
    loop bodies once and is kept only as a floor.  HBM bytes are scaled by
    the flops correction ratio (same loop undercount applies)."""
    from repro.launch.hlo_parse import loop_aware_stats
    if model_flops_override is not None:
        model_flops = model_flops_override
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca_flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    st = loop_aware_stats(compiled.as_text())
    flops = max(ca_flops, st["dot_flops"])
    if ca_flops > 0 and flops > ca_flops:
        hbm *= flops / ca_flops  # loop-corrected estimate
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=st["coll_total"],
                    chips=chips, model_flops=model_flops)
