"""AdamW + global-norm clipping + cosine schedule, pure pytree ops.

Moments are kept in fp32 regardless of param dtype (bf16 training).  The
launcher's ZeRO-1 sharding (launch/shardings.py) shards these moments over
the data axis — they are pure elementwise state, so any partitioning that
matches the gradient layout is valid.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay (fp32 scalar, traced ok)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
