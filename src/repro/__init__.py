"""repro: distributed-BFS-centric multi-pod JAX training/inference framework.

Reproduces and extends "Optimizations to the Parallel Breadth First Search
on Distributed Memory" (Sharma & Zaidi, CS.DC 2020): 1-D vertex
partitioning with owner-computes updates and direct all-to-all exchange,
generalized into the owner-exchange primitive that also drives GNN halo
exchange, MoE token dispatch and sharded embedding lookup.
"""

__version__ = "0.1.0"
