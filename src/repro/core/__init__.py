"""The paper's primary contribution: 1-D partitioned distributed BFS with
optimized owner-exchange communication (Sharma & Zaidi, CS.DC 2020).

Public lifecycle: ``plan(graph, opts, mesh) -> BFSPlan -> .compile() ->
BFSEngine -> .run(sources) / .run_async(sources) -> BFSResult``.  The
one-shot ``bfs()`` remains as a deprecated wrapper over that lifecycle.
``plan(..., partition="2d")`` selects the 2-D edge-partitioned backend
(row-expand + column-fold over an r x c grid) behind the same API.
"""

from repro.core.bfs import (BFSOptions, BFSStats, INF, bfs,
                            validate_sources)
from repro.core.engine import (BFSEngine, BFSPlan, BFSResult, BFSRunStats,
                               normalize_ladder, pick_bucket, plan,
                               plan_ladder)
from repro.core.exchange import (DENSE_STRATEGIES, EXPAND_ROW_STRATEGIES,
                                 EXPAND_ROW_SPARSE_STRATEGIES,
                                 FOLD_COL_STRATEGIES,
                                 FOLD_COL_SPARSE_STRATEGIES, QUEUE_STRATEGIES,
                                 ExchangeStrategy, exchange_dense,
                                 exchange_queue, expand_row, fold_col,
                                 get_exchange, register_exchange,
                                 select_exchange, unregister_exchange)
from repro.core.partition import (Partition, Partition1D, Partition2D,
                                  repartition)

__all__ = [
    "BFSOptions", "BFSStats", "INF", "bfs", "validate_sources",
    "BFSEngine", "BFSPlan", "BFSResult", "BFSRunStats", "plan",
    "plan_ladder", "pick_bucket", "normalize_ladder",
    "Partition", "Partition1D", "Partition2D", "repartition",
    "exchange_dense", "exchange_queue", "expand_row", "fold_col",
    "ExchangeStrategy", "register_exchange", "unregister_exchange",
    "get_exchange", "select_exchange",
    "DENSE_STRATEGIES", "QUEUE_STRATEGIES", "EXPAND_ROW_STRATEGIES",
    "FOLD_COL_STRATEGIES", "EXPAND_ROW_SPARSE_STRATEGIES",
    "FOLD_COL_SPARSE_STRATEGIES",
]
