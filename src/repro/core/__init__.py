"""The paper's primary contribution: 1-D partitioned distributed BFS with
optimized owner-exchange communication (Sharma & Zaidi, CS.DC 2020)."""

from repro.core.bfs import BFSOptions, BFSStats, INF, bfs
from repro.core.exchange import (DENSE_STRATEGIES, QUEUE_STRATEGIES,
                                 exchange_dense, exchange_queue)
from repro.core.partition import Partition1D, repartition

__all__ = [
    "BFSOptions", "BFSStats", "INF", "bfs", "Partition1D", "repartition",
    "exchange_dense", "exchange_queue", "DENSE_STRATEGIES", "QUEUE_STRATEGIES",
]
