"""The paper's primary contribution: 1-D partitioned distributed BFS with
optimized owner-exchange communication (Sharma & Zaidi, CS.DC 2020).

Public lifecycle: ``plan(graph, opts, mesh) -> BFSPlan -> .compile() ->
BFSEngine -> .run(sources) / .run_async(sources) -> BFSResult``.  The
one-shot ``bfs()`` remains as a deprecated wrapper over that lifecycle.
"""

from repro.core.bfs import (BFSOptions, BFSStats, INF, bfs,
                            validate_sources)
from repro.core.engine import (BFSEngine, BFSPlan, BFSResult, BFSRunStats,
                               plan)
from repro.core.exchange import (DENSE_STRATEGIES, QUEUE_STRATEGIES,
                                 ExchangeStrategy, exchange_dense,
                                 exchange_queue, get_exchange,
                                 register_exchange, unregister_exchange)
from repro.core.partition import Partition1D, repartition

__all__ = [
    "BFSOptions", "BFSStats", "INF", "bfs", "validate_sources",
    "BFSEngine", "BFSPlan", "BFSResult", "BFSRunStats", "plan",
    "Partition1D", "repartition",
    "exchange_dense", "exchange_queue", "ExchangeStrategy",
    "register_exchange", "unregister_exchange", "get_exchange",
    "DENSE_STRATEGIES", "QUEUE_STRATEGIES",
]
