"""Owner-exchange collectives — the paper's §5 contribution as a module.

The paper's two optimizations over Buluç-Madduri [2]:

  (1) *local update* (§5.1-1): candidates owned by the computing processor
      never enter a send buffer — the owner updates its distance vector in
      the same step.  Lives in ``frontier.build_queue_buckets``.

  (2) *direct exchange* (§5.1-2): per-destination buffers are sent straight
      to their owners ("we were able to send local buffers to other
      processors directly") instead of being aggregated into one buffer and
      re-scattered.  On TPU this is the difference between an
      ``all-gather`` of everyone's full candidate set (bytes ∝ p·n per
      chip — "communication overhead which increases linearly with the
      number of processors") and an ``all-to-all``/``reduce-scatter`` where
      each chip receives only what it owns (bytes ∝ n, independent of p).

Beyond the paper, every dense-phase collective also has a *packed-bitset*
twin (``<name>_packed``): the ``uint8`` candidate/frontier mask packs into
``uint32`` words (``frontier.pack_bits``, 32 vertices per word) before the
collective and merges with bitwise OR — 8× fewer bytes per chip per dense
level, the "Compression and Sieve" / Buluç-Madduri word-packed-frontier
optimization.  ``BFSOptions.wire_format`` selects the layout per plan
("packed" | "bytes" | "auto", the last pricing both per phase).

Strategies are *pluggable*: each one is a function registered with
``@register_exchange(kind, name, bytes_model, wire=...)`` which pairs the
collective implementation with its analytic per-chip byte model.  ``BFSPlan``
(core/engine.py) resolves strategy names through this registry at plan
time, so new exchange algorithms slot in without touching the BFS engine.
``DENSE_STRATEGIES`` / ``QUEUE_STRATEGIES`` remain as live, tuple-like
views of the registered names for backward compatibility.

Every byte model is cross-checked against bytes parsed from compiled HLO
(tests/helpers/exchange_bytes.py), which pins the paper-reproduction
numbers (benchmarks/run.py tables) to compiler ground truth.  The same
module drives BFS frontier exchange, GNN halo exchange, MoE token dispatch
and recsys embedding lookup (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import jax.numpy as jnp
from jax import lax

from repro.core import frontier as _fr

AxisName = Union[str, tuple]

#: on-wire payload layouts: raw ids / uint8 masks, packed uint32 bitset
#: words (dense phases), delta+varint compressed id streams (sparse phases)
WIRE_FORMATS = ("bytes", "packed", "compressed")


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExchangeStrategy:
    """A named exchange algorithm plus its analytic per-chip byte model.

    ``impl(x, axis)`` runs under shard_map; ``bytes_model`` signature is
    kind-specific: dense ``(n, p, s, itemsize, axes_sizes)``, queue
    ``(p, cap, itemsize)``.  Both return bytes *received* per chip per
    level — the quantity the paper's §4 scalability analysis is built on.

    ``wire`` declares the on-wire layout the impl operates on: ``"bytes"``
    (one uint8 per vertex, merge by max) or ``"packed"`` (``uint32``
    bitset words from ``frontier.pack_bits``, merge by bitwise OR — 8×
    smaller payloads).  The loop bodies pack/unpack at the exchange
    boundary based on this field, so a strategy's wire format is part of
    its registered identity and the ``"auto"`` selection can price the
    two layouts against each other.
    """

    name: str
    kind: str                 # see KINDS below
    impl: Callable
    bytes_model: Callable
    wire: str = "bytes"       # see WIRE_FORMATS


_REGISTRY: dict = {}          # (kind, name) -> ExchangeStrategy

# Exchange kinds, one per communication pattern in the two partition schemes:
#   dense      — 1-D full-length candidate-mask merge over all p shards
#   queue      — 1-D per-destination sparse id buffers
#   expand_row — 2-D expand phase: frontier allgather across a grid row
#                (c participants); byte model (n, r, c, s, itemsize)
#   fold_col   — 2-D fold phase: candidate merge across a grid column
#                (r participants); byte model (n, r, c, s, itemsize)
#   expand_row_sparse — sparse expand phase: active frontier *ids* across
#                a grid row instead of the bitmap; byte model
#                (r, c, cap, itemsize, density=1.0)
#   fold_col_sparse   — sparse fold phase: per-row-rank candidate id
#                buckets down a grid column; byte model (r, c, cap,
#                itemsize, density=1.0)
#
# Sparse byte models take a trailing ``density`` — the id capacity as a
# fraction of the id range each buffer draws from (cap / id_range).  Raw
# id strategies ignore it; the ``_compressed`` twins derive the varint
# buffer size from it, which is how ``wire_format="auto"`` prices raw
# ids against compressed streams per phase at plan time.
KINDS = ("dense", "queue", "expand_row", "fold_col",
         "expand_row_sparse", "fold_col_sparse")


def _check_kind(kind: str) -> None:
    if kind not in KINDS:
        raise ValueError(f"unknown exchange kind {kind!r}; "
                         f"expected one of: {', '.join(KINDS)}")


def register_exchange(kind: str, name: str, bytes_model: Callable,
                      wire: str = "bytes"):
    """Decorator: register an exchange impl under ``(kind, name)``.

    ``kind`` is one of ``KINDS`` (see above); ``wire`` is one of
    ``WIRE_FORMATS`` and declares the payload layout the impl consumes.
    Re-registering a name overwrites it, which keeps iterative strategy
    development REPL-friendly.
    """
    _check_kind(kind)
    if wire not in WIRE_FORMATS:
        raise ValueError(f"unknown wire format {wire!r}; "
                         f"expected one of: {', '.join(WIRE_FORMATS)}")

    def deco(fn):
        _REGISTRY[(kind, name)] = ExchangeStrategy(
            name=name, kind=kind, impl=fn, bytes_model=bytes_model,
            wire=wire)
        return fn

    return deco


def unregister_exchange(kind: str, name: str) -> None:
    """Remove a registered strategy; idempotent (missing names are a no-op)."""
    _REGISTRY.pop((kind, name), None)


def get_exchange(kind: str, name: str) -> ExchangeStrategy:
    _check_kind(kind)
    try:
        return _REGISTRY[(kind, name)]
    except KeyError:
        avail = ", ".join(sorted(n for k, n in _REGISTRY if k == kind))
        raise ValueError(
            f"unknown {kind} exchange strategy {name!r}; "
            f"registered: {avail}") from None


def select_exchange(kind: str, *model_args,
                    wire: Optional[str] = None) -> ExchangeStrategy:
    """Auto-select the registered strategy with the smallest modeled bytes.

    ``model_args`` must match the kind's byte-model signature.  Plans
    resolve the ``"auto"`` strategy name through this, so auto-selection
    spans every registered strategy of both partition schemes; ties break
    by name for determinism (which also prefers a ``"bytes"`` impl over
    its ``_packed`` twin when both model to zero, e.g. at p = 1 — no
    pointless pack/unpack on a single device).  ``wire`` restricts the
    candidate set to one wire format (``None`` spans both, which is how
    ``BFSOptions.wire_format="auto"`` resolves packed-vs-bytes per phase
    at plan time).
    """
    _check_kind(kind)
    cands = [st for (k, _), st in _REGISTRY.items()
             if k == kind and (wire is None or st.wire == wire)]
    if not cands:
        raise ValueError(f"no exchange strategies registered for {kind!r}"
                         + (f" with wire format {wire!r}" if wire else ""))
    return min(cands, key=lambda st: (st.bytes_model(*model_args), st.name))


class _StrategyNames:
    """Live tuple-like view of registered names (back-compat for the old
    frozen ``DENSE_STRATEGIES`` / ``QUEUE_STRATEGIES`` tuples)."""

    def __init__(self, kind: str):
        self._kind = kind

    def _names(self) -> tuple:
        return tuple(n for k, n in _REGISTRY if k == self._kind)

    def __iter__(self):
        return iter(self._names())

    def __contains__(self, name) -> bool:
        return (self._kind, name) in _REGISTRY

    def __len__(self) -> int:
        return len(self._names())

    def __getitem__(self, i):
        return self._names()[i]

    def __repr__(self) -> str:
        return repr(self._names())


DENSE_STRATEGIES = _StrategyNames("dense")
QUEUE_STRATEGIES = _StrategyNames("queue")
EXPAND_ROW_STRATEGIES = _StrategyNames("expand_row")
FOLD_COL_STRATEGIES = _StrategyNames("fold_col")
EXPAND_ROW_SPARSE_STRATEGIES = _StrategyNames("expand_row_sparse")
FOLD_COL_SPARSE_STRATEGIES = _StrategyNames("fold_col_sparse")


def axis_size(axis: AxisName) -> int:
    return lax.psum(1, axis)


def axis_index(axis: AxisName) -> jnp.ndarray:
    return lax.axis_index(axis)


def _axes_tuple(axis: AxisName) -> tuple:
    return axis if isinstance(axis, tuple) else (axis,)


# ---------------------------------------------------------------------------
# Dense candidate exchange: full-length (n, S) candidate mask -> owned slice
# ---------------------------------------------------------------------------

def _bytes_allgather_merge(n, p, s, itemsize, axes_sizes):
    return (p - 1) * n * s * itemsize


def _bytes_alltoall_direct(n, p, s, itemsize, axes_sizes):
    return (p - 1) / p * n * s * itemsize


def _bytes_reduce_scatter(n, p, s, itemsize, axes_sizes):
    return (p - 1) / p * n * s * 2  # bf16 widening


def _bytes_hierarchical(n, p, s, itemsize, axes_sizes):
    sizes = list(axes_sizes) or [p]
    return sum((sz - 1) / sz * n * s * itemsize for sz in sizes)


@register_exchange("dense", "allgather_merge", _bytes_allgather_merge)
def _dense_allgather_merge(cand: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # Faithful to [2]'s aggregate-then-scatter: every shard materializes
    # the union of all buffers (as the master would), then keeps its own
    # slice.  Received bytes per chip: (p-1) * n * S.
    p = axis_size(axis)
    shard = cand.shape[0] // p
    allc = lax.all_gather(cand, axis)            # (p, n, S)
    merged = allc.max(axis=0)
    me = axis_index(axis)
    return lax.dynamic_slice_in_dim(merged, me * shard, shard, axis=0)


@register_exchange("dense", "alltoall_direct", _bytes_alltoall_direct)
def _dense_alltoall_direct(cand: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # Paper §5.1-2: send each destination's slice straight to its owner.
    # Received bytes per chip: (p-1)/p * n * S.
    p = axis_size(axis)
    shard = cand.shape[0] // p
    recv = lax.all_to_all(cand, axis, split_axis=0, concat_axis=0,
                          tiled=True)            # (n, S): p blocks of shard
    return recv.reshape(p, shard, *cand.shape[1:]).max(axis=0)


@register_exchange("dense", "reduce_scatter", _bytes_reduce_scatter)
def _dense_reduce_scatter(cand: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # Beyond-paper alternative: let the network do the merge (sum == OR
    # for 0/1 masks since contributions are non-negative).  Needs a
    # summable dtype wide enough that nonzero cannot vanish; bf16 is
    # safe for any p (sums of non-negative ints never round to zero).
    x = cand.astype(jnp.bfloat16)
    own = lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
    return (own > 0).astype(cand.dtype)


@register_exchange("dense", "hierarchical", _bytes_hierarchical)
def _dense_hierarchical(cand: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # Beyond-paper: two-phase exchange matched to the mesh topology
    # (e.g. first across the fast intra-pod axis, then across pods).
    # 2x bytes on the wire but Θ(p_a + p_b) messages instead of Θ(p).
    axes = _axes_tuple(axis)
    if len(axes) == 1:
        return _dense_alltoall_direct(cand, axes[0])
    # Process axes major-first (matches PartitionSpec((a, b)) owner
    # linearization: owner = a * |b| + b).  After exchanging over an
    # axis, all received blocks target this shard's coordinate on that
    # axis, so they merge immediately and the working set shrinks.
    out = cand
    for ax in axes:
        sz = lax.psum(1, ax)
        recv = lax.all_to_all(out, ax, split_axis=0, concat_axis=0,
                              tiled=True)
        out = recv.reshape(sz, out.shape[0] // sz, *out.shape[1:]).max(axis=0)
    return out


# --- packed dense strategies: uint32 bitset words on the wire ------------
# The same four collectives over frontier.pack_bits output — one word per
# 32 vertices, bitwise-OR merges.  8× fewer bytes per chip per level than
# the uint8 mask (4-byte words for 32 one-byte slots).  Packing is blocked
# per shard, so the per-shard word count is ceil((n/p)/32) and every
# split/slice below stays static.  Byte models share the dense signature
# (n, p, s, itemsize, axes_sizes); the mask itemsize is irrelevant — the
# wire carries 4-byte words.

def _or_reduce(x: jnp.ndarray, axis_num: int = 0) -> jnp.ndarray:
    """Bitwise-OR reduction over one positional axis (packed-word merge)."""
    return lax.reduce(x, x.dtype.type(0), lax.bitwise_or, (axis_num,))


def _words_per_shard(n, p):
    return _fr.packed_words(n // p)


def _bytes_allgather_merge_packed(n, p, s, itemsize, axes_sizes):
    return (p - 1) * p * _words_per_shard(n, p) * 4 * s


@register_exchange("dense", "allgather_merge_packed",
                   _bytes_allgather_merge_packed, wire="packed")
def _dense_allgather_merge_packed(words: jnp.ndarray,
                                  axis: AxisName) -> jnp.ndarray:
    # [2]-style aggregate-then-scatter on packed words: every shard
    # receives all p packed candidate sets and ORs them.
    p = axis_size(axis)
    w = words.shape[0] // p
    allw = lax.all_gather(words, axis)           # (p, p*W, S)
    merged = _or_reduce(allw, 0)
    me = axis_index(axis)
    return lax.dynamic_slice_in_dim(merged, me * w, w, axis=0)


def _bytes_alltoall_direct_packed(n, p, s, itemsize, axes_sizes):
    return (p - 1) * _words_per_shard(n, p) * 4 * s


@register_exchange("dense", "alltoall_direct_packed",
                   _bytes_alltoall_direct_packed, wire="packed")
def _dense_alltoall_direct_packed(words: jnp.ndarray,
                                  axis: AxisName) -> jnp.ndarray:
    # Paper §5.1-2 on packed words: each owner's W-word block goes straight
    # to it; the p received partial bitsets OR locally.
    p = axis_size(axis)
    w = words.shape[0] // p
    recv = lax.all_to_all(words, axis, split_axis=0, concat_axis=0,
                          tiled=True)            # (p*W, S): p blocks of W
    return _or_reduce(recv.reshape(p, w, *words.shape[1:]), 0)


@register_exchange("dense", "reduce_scatter_packed",
                   _bytes_alltoall_direct_packed, wire="packed")
def _dense_reduce_scatter_packed(words: jnp.ndarray,
                                 axis: AxisName) -> jnp.ndarray:
    # The network cannot OR packed words (psum carries across bit lanes),
    # so the packed twin routes word blocks directly and ORs locally —
    # all_to_all bytes, kept under this name so wire_format="packed"
    # composes with every strategy name a caller may have pinned.
    return _dense_alltoall_direct_packed(words, axis)


def _bytes_hierarchical_packed(n, p, s, itemsize, axes_sizes):
    sizes = list(axes_sizes) or [p]
    w = _words_per_shard(n, p)
    return sum((sz - 1) / sz * p * w * 4 * s for sz in sizes)


@register_exchange("dense", "hierarchical_packed",
                   _bytes_hierarchical_packed, wire="packed")
def _dense_hierarchical_packed(words: jnp.ndarray,
                               axis: AxisName) -> jnp.ndarray:
    # Topology-matched two-phase exchange over packed words; same
    # major-first axis order as the bytes impl, OR-merge after each hop.
    axes = _axes_tuple(axis)
    if len(axes) == 1:
        return _dense_alltoall_direct_packed(words, axes[0])
    out = words
    for ax in axes:
        sz = lax.psum(1, ax)
        recv = lax.all_to_all(out, ax, split_axis=0, concat_axis=0,
                              tiled=True)
        out = _or_reduce(recv.reshape(sz, out.shape[0] // sz, *out.shape[1:]),
                         0)
    return out


def exchange_dense(cand: jnp.ndarray, axis: AxisName, strategy: str) -> jnp.ndarray:
    """Merge per-shard candidate masks; return this shard's owned slice.

    cand: (n, S) uint8/int32 0-1 mask over ALL global vertices, produced by
    this shard's edge expansion.  Result: (n/p, S) of the same dtype with
    OR/merge semantics across shards.  Packed strategies are transparent
    here — the mask is packed per shard before the collective and the
    owned words unpacked after — so callers (and the HLO byte-model
    harness) can name any registered strategy; the engine loop bodies
    instead keep candidates packed across the exchange boundary.
    """
    p = axis_size(axis)
    n = cand.shape[0]
    assert n % p == 0, f"dense exchange needs n ({n}) divisible by p ({p})"
    st = get_exchange("dense", strategy)
    if st.wire == "packed":
        own_words = st.impl(_fr.pack_bits(cand, n_blocks=p), axis)
        return _fr.unpack_bits(own_words, n // p).astype(cand.dtype)
    return st.impl(cand, axis)


# ---------------------------------------------------------------------------
# 2-D grid exchange: expand across a grid row, fold across a grid column
# ---------------------------------------------------------------------------
# The 2-D edge partition (core/partition.Partition2D) replaces the single
# all-shards collective of the 1-D scheme with two small ones per level:
# an ``expand_row`` allgather of the frontier among the c devices of a grid
# row, and a ``fold_col`` merge of transposed candidates among the r devices
# of a grid column.  Per-chip received bytes drop from Θ((p-1)/p · n) to
# Θ((r-1 + c-1) · n/p) — collective participants shrink from p to r + c.
# Byte-model signature for both kinds: (n, r, c, s, itemsize) with n the
# padded global vertex count.

def _bytes_expand_allgather(n, r, c, s, itemsize):
    return (c - 1) * (n // (r * c)) * s * itemsize


@register_exchange("expand_row", "allgather", _bytes_expand_allgather)
def _expand_row_allgather(frontier: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # (b, S) local frontier chunk -> (c*b, S) row-block frontier.  The c
    # chunks of a grid row are globally contiguous, so the tiled gather is
    # already in global-id order for the local edge expansion.
    return lax.all_gather(frontier, axis, tiled=True)


def _bytes_fold_alltoall(n, r, c, s, itemsize):
    return (r - 1) * (n // (r * c)) * s * itemsize


@register_exchange("fold_col", "alltoall_reduce", _bytes_fold_alltoall)
def _fold_col_alltoall(cand: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # (r*b, S) fold-ordered candidates -> (b, S) owned merge: block rr goes
    # to the grid-column device at row rank rr, then the r received partial
    # masks are OR-merged (max) locally.
    r = axis_size(axis)
    recv = lax.all_to_all(cand, axis, split_axis=0, concat_axis=0, tiled=True)
    return recv.reshape(r, cand.shape[0] // r, *cand.shape[1:]).max(axis=0)


def _bytes_fold_reduce_scatter(n, r, c, s, itemsize):
    return (r - 1) * (n // (r * c)) * s * 2  # bf16 widening


@register_exchange("fold_col", "reduce_scatter", _bytes_fold_reduce_scatter)
def _fold_col_reduce_scatter(cand: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # Let the network merge: sum == OR for non-negative 0/1 contributions
    # (same argument as the dense reduce_scatter strategy).
    x = cand.astype(jnp.bfloat16)
    own = lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
    return (own > 0).astype(cand.dtype)


# --- packed 2-D phases: the grid collectives over uint32 bitset words.
# Chunk size b = n/(r*c) packs to Wb = ceil(b/32) words; the expand
# allgather ships (c-1)·Wb·4 bytes instead of (c-1)·b, the fold
# all-to-all (r-1)·Wb·4 instead of (r-1)·b — the same 8× dense-phase
# saving as the 1-D packed strategies, applied per phase.

def _grid_words(n, r, c):
    return _fr.packed_words(n // (r * c))


def _bytes_expand_allgather_packed(n, r, c, s, itemsize):
    return (c - 1) * _grid_words(n, r, c) * 4 * s


@register_exchange("expand_row", "allgather_packed",
                   _bytes_expand_allgather_packed, wire="packed")
def _expand_row_allgather_packed(fwords: jnp.ndarray,
                                 axis: AxisName) -> jnp.ndarray:
    # (Wb, S) packed frontier chunk -> (c*Wb, S) packed row frontier;
    # segment j = grid column j's words (blocked packing keeps the
    # per-chunk word offsets static for the unpack).
    return lax.all_gather(fwords, axis, tiled=True)


def _bytes_fold_alltoall_packed(n, r, c, s, itemsize):
    return (r - 1) * _grid_words(n, r, c) * 4 * s


@register_exchange("fold_col", "alltoall_reduce_packed",
                   _bytes_fold_alltoall_packed, wire="packed")
def _fold_col_alltoall_packed(cwords: jnp.ndarray,
                              axis: AxisName) -> jnp.ndarray:
    # (r*Wb, S) fold-ordered packed candidates -> (Wb, S) owned OR-merge.
    r = axis_size(axis)
    w = cwords.shape[0] // r
    recv = lax.all_to_all(cwords, axis, split_axis=0, concat_axis=0,
                          tiled=True)
    return _or_reduce(recv.reshape(r, w, *cwords.shape[1:]), 0)


@register_exchange("fold_col", "reduce_scatter_packed",
                   _bytes_fold_alltoall_packed, wire="packed")
def _fold_col_reduce_scatter_packed(cwords: jnp.ndarray,
                                    axis: AxisName) -> jnp.ndarray:
    # psum carries across bit lanes, so the packed twin routes word
    # blocks directly and ORs locally (same rationale as the dense
    # reduce_scatter_packed strategy).
    return _fold_col_alltoall_packed(cwords, axis)


# --- sparse 2-D phases: ship ids instead of bitmaps (paper §5.1 on the
# grid).  Payload scales with the frontier (cap ids), not with n/p, so the
# narrow first/last levels cost (c-1)·cap + (r-1)·cap id-bytes instead of
# (c-1 + r-1)·n/p mask-bytes.  Byte-model signature:
# (r, c, cap, itemsize, density=1.0).

def _compressed_payload(cap, density):
    """Static byte size of one compressed id buffer: the model-side twin
    of ``frontier.compressed_capacity``, reconstructing the id range
    from the capacity density (``id_range = cap / density``) so the
    analytic models and the compiled loop price the same buffer."""
    if density and density > 0:
        id_range = max(1, int(round(cap / density)))
    else:
        id_range = max(1, cap)
    return _fr.compressed_capacity(cap, id_range)


def _bytes_expand_sparse_allgather(r, c, cap, itemsize, density=1.0):
    return (c - 1) * cap * itemsize


@register_exchange("expand_row_sparse", "allgather",
                   _bytes_expand_sparse_allgather)
def _expand_row_sparse_allgather(ids: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # (cap,) local active-frontier ids -> (c*cap,) row concatenation;
    # segment j holds grid column j's ids (unpack_row_frontier rebuilds
    # the row bitmap from the static segment offsets).
    return lax.all_gather(ids, axis, tiled=True)


def _bytes_fold_sparse_alltoall(r, c, cap, itemsize, density=1.0):
    return (r - 1) * cap * itemsize


@register_exchange("fold_col_sparse", "alltoall_direct",
                   _bytes_fold_sparse_alltoall)
def _fold_col_sparse_alltoall(buckets: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # Paper §5.1-2 down a grid column: bucket rr goes straight to the
    # device at row rank rr.  (r, cap) -> (r, cap): row rr = what the
    # column peer at row rank rr sent me.
    return lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0,
                          tiled=True)


def _bytes_fold_sparse_allgather(r, c, cap, itemsize, density=1.0):
    return (r - 1) * r * cap * itemsize


@register_exchange("fold_col_sparse", "allgather_merge",
                   _bytes_fold_sparse_allgather)
def _fold_col_sparse_allgather(buckets: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # [2]-style aggregate-everywhere baseline on the column: every device
    # receives every bucket and keeps the rows addressed to it.
    allb = lax.all_gather(buckets, axis)         # (r, r, cap)
    me = axis_index(axis)
    return lax.dynamic_slice_in_dim(allb, me, 1, axis=1)[:, 0]


# --- compressed sparse 2-D phases: the same collectives over delta+varint
# payloads (frontier.encode_delta_varint output, uint8).  The byte models
# reconstruct the buffer size from the capacity density, so auto-selection
# trades raw ids (4 bytes each, density-blind) against the compressed
# stream (~1 byte per id at typical gaps, bitset-capped at high density).

def _bytes_expand_sparse_allgather_compressed(r, c, cap, itemsize,
                                              density=1.0):
    return (c - 1) * _compressed_payload(cap, density)


@register_exchange("expand_row_sparse", "allgather_compressed",
                   _bytes_expand_sparse_allgather_compressed,
                   wire="compressed")
def _expand_row_sparse_allgather_compressed(payload: jnp.ndarray,
                                            axis: AxisName) -> jnp.ndarray:
    # (byte_cap,) compressed local frontier -> (c*byte_cap,) row
    # concatenation; segment j decodes to grid column j's ids.
    return lax.all_gather(payload, axis, tiled=True)


def _bytes_fold_sparse_alltoall_compressed(r, c, cap, itemsize, density=1.0):
    return (r - 1) * _compressed_payload(cap, density)


@register_exchange("fold_col_sparse", "alltoall_direct_compressed",
                   _bytes_fold_sparse_alltoall_compressed, wire="compressed")
def _fold_col_sparse_alltoall_compressed(payload: jnp.ndarray,
                                         axis: AxisName) -> jnp.ndarray:
    # (r, byte_cap) compressed per-row-rank buckets routed straight to
    # their owners (§5.1-2 down the grid column, byte payloads).
    return lax.all_to_all(payload, axis, split_axis=0, concat_axis=0,
                          tiled=True)


def _bytes_fold_sparse_allgather_compressed(r, c, cap, itemsize,
                                            density=1.0):
    return (r - 1) * r * _compressed_payload(cap, density)


@register_exchange("fold_col_sparse", "allgather_merge_compressed",
                   _bytes_fold_sparse_allgather_compressed, wire="compressed")
def _fold_col_sparse_allgather_compressed(payload: jnp.ndarray,
                                          axis: AxisName) -> jnp.ndarray:
    # aggregate-everywhere baseline over compressed buckets.
    allb = lax.all_gather(payload, axis)         # (r, r, byte_cap)
    me = axis_index(axis)
    return lax.dynamic_slice_in_dim(allb, me, 1, axis=1)[:, 0]


def expand_row(frontier: jnp.ndarray, axis: AxisName, strategy: str) -> jnp.ndarray:
    """2-D expand phase: (b, S) chunk -> (c*b, S) grid-row frontier.

    Packed strategies are transparent (pack before, unpack after); the
    engine loop keeps the words packed across the wire instead.
    """
    st = get_exchange("expand_row", strategy)
    if st.wire == "packed":
        c = axis_size(axis)
        words = st.impl(_fr.pack_bits(frontier), axis)
        return _fr.unpack_bits(words, frontier.shape[0],
                               n_blocks=c).astype(frontier.dtype)
    return st.impl(frontier, axis)


def fold_col(cand: jnp.ndarray, axis: AxisName, strategy: str) -> jnp.ndarray:
    """2-D fold phase: (r*b, S) fold-ordered candidates -> (b, S) owned.

    Packed strategies are transparent here (see ``expand_row``).
    """
    r = axis_size(axis)
    assert cand.shape[0] % r == 0, \
        f"fold needs len ({cand.shape[0]}) divisible by r ({r})"
    st = get_exchange("fold_col", strategy)
    if st.wire == "packed":
        words = st.impl(_fr.pack_bits(cand, n_blocks=r), axis)
        return _fr.unpack_bits(words, cand.shape[0] // r).astype(cand.dtype)
    return st.impl(cand, axis)


# ---------------------------------------------------------------------------
# Sparse queue exchange: (p, cap) per-destination vertex-id buffers
# ---------------------------------------------------------------------------

def _qbytes_alltoall_direct(p, cap, itemsize, density=1.0):
    return (p - 1) * cap * itemsize


def _qbytes_allgather_merge(p, cap, itemsize, density=1.0):
    return (p - 1) * p * cap * itemsize


@register_exchange("queue", "allgather_merge", _qbytes_allgather_merge)
def _queue_allgather_merge(buckets: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # [2]-style aggregate-everywhere: every shard receives every buffer
    # (p^2·cap ids on the wire) and picks out the rows addressed to it.
    allb = lax.all_gather(buckets, axis)         # (p, p, cap)
    me = axis_index(axis)
    return lax.dynamic_slice_in_dim(allb, me, 1, axis=1)[:, 0]


@register_exchange("queue", "alltoall_direct", _qbytes_alltoall_direct)
def _queue_alltoall_direct(buckets: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    # Paper §5.1-2 applied to queues: MPI_Alltoallv equivalent.
    return lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0,
                          tiled=True)


# --- compressed queue twins: per-destination delta+varint byte buffers.
# Bucket row j carries shard j's candidates *base-relative* (id - j*shard,
# so every row's deltas start near zero); the loop encodes before and
# decodes after the collective, with encode overflow joining the same
# dense-escalation predicate as bucket overflow.

def _qbytes_alltoall_direct_compressed(p, cap, itemsize, density=1.0):
    return (p - 1) * _compressed_payload(cap, density)


@register_exchange("queue", "alltoall_direct_compressed",
                   _qbytes_alltoall_direct_compressed, wire="compressed")
def _queue_alltoall_direct_compressed(payload: jnp.ndarray,
                                      axis: AxisName) -> jnp.ndarray:
    # (p, byte_cap) uint8 routed straight to owners, like the id twin.
    return lax.all_to_all(payload, axis, split_axis=0, concat_axis=0,
                          tiled=True)


def _qbytes_allgather_merge_compressed(p, cap, itemsize, density=1.0):
    return (p - 1) * p * _compressed_payload(cap, density)


@register_exchange("queue", "allgather_merge_compressed",
                   _qbytes_allgather_merge_compressed, wire="compressed")
def _queue_allgather_merge_compressed(payload: jnp.ndarray,
                                      axis: AxisName) -> jnp.ndarray:
    # aggregate-everywhere baseline over compressed buffers.
    allb = lax.all_gather(payload, axis)         # (p, p, byte_cap)
    me = axis_index(axis)
    return lax.dynamic_slice_in_dim(allb, me, 1, axis=1)[:, 0]


def exchange_queue(buckets: jnp.ndarray, axis: AxisName, strategy: str) -> jnp.ndarray:
    """Route per-destination id buffers to their owners.

    buckets: (p, cap) int32; row j holds candidate global ids owned by
    shard j (-1 padded).  Returns (p, cap): row j = what shard j sent me.
    """
    p = axis_size(axis)
    assert buckets.shape[0] == p
    return get_exchange("queue", strategy).impl(buckets, axis)


def allgather_frontier(frontier: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    """(shard, S) -> (n, S): replicate the frontier bitmap (bottom-up pass).

    Cheap by construction: the *frontier* (n bits) is exchanged instead of
    the *candidate* set (up to E entries) — the direction-optimizing
    rationale restated in communication terms.
    """
    return lax.all_gather(frontier, axis, tiled=True)


# ---------------------------------------------------------------------------
# Analytic per-chip byte models (used by benchmarks + roofline cross-check)
# ---------------------------------------------------------------------------

def dense_level_bytes(strategy: str, n: int, p: int, s: int = 1,
                      itemsize: int = 1, axes_sizes: Sequence[int] = ()) -> float:
    """Bytes *received* per chip for one dense exchange."""
    return get_exchange("dense", strategy).bytes_model(
        n, p, s, itemsize, axes_sizes)


def queue_level_bytes(strategy: str, p: int, cap: int, itemsize: int = 4,
                      density: float = 1.0) -> float:
    return get_exchange("queue", strategy).bytes_model(
        p, cap, itemsize, density)


def bottomup_level_bytes(n: int, p: int, s: int = 1, itemsize: int = 1,
                         wire: str = "bytes") -> float:
    """Bytes received per chip for one bottom-up frontier allgather.

    ``wire="packed"`` prices the packed-bitset gather: each peer ships
    its ``ceil((n/p)/32)`` uint32 frontier words instead of ``n/p`` mask
    bytes (the bottom-up expansion then reads bits straight out of the
    gathered words — see ``frontier.expand_bottom_up_packed``).
    """
    if wire == "packed":
        return (p - 1) * _words_per_shard(n, p) * 4 * s
    return (p - 1) / p * n * s * itemsize


def grid_level_bytes(expand_strategy: str, fold_strategy: str, n: int,
                     r: int, c: int, s: int = 1, itemsize: int = 1) -> float:
    """Bytes received per chip for one 2-D level (expand + fold phases)."""
    return (get_exchange("expand_row", expand_strategy).bytes_model(
                n, r, c, s, itemsize) +
            get_exchange("fold_col", fold_strategy).bytes_model(
                n, r, c, s, itemsize))


def grid_sparse_level_bytes(expand_strategy: str, fold_strategy: str,
                            r: int, c: int, cap: int, itemsize: int = 4,
                            density: float = 1.0) -> float:
    """Bytes received per chip for one sparse 2-D level (id buffers on
    both phases; payload independent of n)."""
    return (get_exchange("expand_row_sparse", expand_strategy).bytes_model(
                r, c, cap, itemsize, density) +
            get_exchange("fold_col_sparse", fold_strategy).bytes_model(
                r, c, cap, itemsize, density))
