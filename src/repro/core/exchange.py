"""Owner-exchange collectives — the paper's §5 contribution as a module.

The paper's two optimizations over Buluç-Madduri [2]:

  (1) *local update* (§5.1-1): candidates owned by the computing processor
      never enter a send buffer — the owner updates its distance vector in
      the same step.  Lives in ``frontier.build_queue_buckets``.

  (2) *direct exchange* (§5.1-2): per-destination buffers are sent straight
      to their owners ("we were able to send local buffers to other
      processors directly") instead of being aggregated into one buffer and
      re-scattered.  On TPU this is the difference between an
      ``all-gather`` of everyone's full candidate set (bytes ∝ p·n per
      chip — "communication overhead which increases linearly with the
      number of processors") and an ``all-to-all``/``reduce-scatter`` where
      each chip receives only what it owns (bytes ∝ n, independent of p).

Both the dense-bitmap and sparse-queue frontier representations support a
faithful baseline strategy and the paper-optimized direct strategy, plus
two beyond-paper strategies (hierarchical two-phase all-to-all matched to
the pod/ICI topology, and a widening reduce-scatter).  The same module
drives BFS frontier exchange, GNN halo exchange, MoE token dispatch and
recsys embedding lookup (DESIGN.md §Arch-applicability).

Every strategy has an analytic per-chip byte model (``dense_level_bytes`` /
``queue_level_bytes``) which benchmarks cross-check against bytes parsed
from compiled HLO (tests/test_exchange_bytes.py).
"""

from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

AxisName = Union[str, tuple]

DENSE_STRATEGIES = ("allgather_merge", "alltoall_direct", "reduce_scatter",
                    "hierarchical")
QUEUE_STRATEGIES = ("allgather_merge", "alltoall_direct")


def axis_size(axis: AxisName) -> int:
    return lax.psum(1, axis)


def axis_index(axis: AxisName) -> jnp.ndarray:
    return lax.axis_index(axis)


def _axes_tuple(axis: AxisName) -> tuple:
    return axis if isinstance(axis, tuple) else (axis,)


# ---------------------------------------------------------------------------
# Dense candidate exchange: full-length (n, S) candidate mask -> owned slice
# ---------------------------------------------------------------------------

def exchange_dense(cand: jnp.ndarray, axis: AxisName, strategy: str) -> jnp.ndarray:
    """Merge per-shard candidate masks; return this shard's owned slice.

    cand: (n, S) uint8/int32 0-1 mask over ALL global vertices, produced by
    this shard's edge expansion.  Result: (n/p, S) of the same dtype with
    OR/merge semantics across shards.
    """
    p = axis_size(axis)
    n = cand.shape[0]
    assert n % p == 0, f"dense exchange needs n ({n}) divisible by p ({p})"
    shard = n // p

    if strategy == "allgather_merge":
        # Faithful to [2]'s aggregate-then-scatter: every shard materializes
        # the union of all buffers (as the master would), then keeps its own
        # slice.  Received bytes per chip: (p-1) * n * S.
        allc = lax.all_gather(cand, axis)            # (p, n, S)
        merged = allc.max(axis=0)
        me = axis_index(axis)
        return lax.dynamic_slice_in_dim(merged, me * shard, shard, axis=0)

    if strategy == "alltoall_direct":
        # Paper §5.1-2: send each destination's slice straight to its owner.
        # Received bytes per chip: (p-1)/p * n * S.
        recv = lax.all_to_all(cand, axis, split_axis=0, concat_axis=0,
                              tiled=True)            # (n, S): p blocks of shard
        return recv.reshape(p, shard, *cand.shape[1:]).max(axis=0)

    if strategy == "reduce_scatter":
        # Beyond-paper alternative: let the network do the merge (sum == OR
        # for 0/1 masks since contributions are non-negative).  Needs a
        # summable dtype wide enough that nonzero cannot vanish; bf16 is
        # safe for any p (sums of non-negative ints never round to zero).
        x = cand.astype(jnp.bfloat16)
        own = lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
        return (own > 0).astype(cand.dtype)

    if strategy == "hierarchical":
        # Beyond-paper: two-phase exchange matched to the mesh topology
        # (e.g. first across the fast intra-pod axis, then across pods).
        # 2x bytes on the wire but Θ(p_a + p_b) messages instead of Θ(p).
        axes = _axes_tuple(axis)
        if len(axes) == 1:
            return exchange_dense(cand, axes[0], "alltoall_direct")
        # Process axes major-first (matches PartitionSpec((a, b)) owner
        # linearization: owner = a * |b| + b).  After exchanging over an
        # axis, all received blocks target this shard's coordinate on that
        # axis, so they merge immediately and the working set shrinks.
        out = cand
        for ax in axes:
            sz = lax.psum(1, ax)
            recv = lax.all_to_all(out, ax, split_axis=0, concat_axis=0,
                                  tiled=True)
            out = recv.reshape(sz, out.shape[0] // sz, *out.shape[1:]).max(axis=0)
        return out

    raise ValueError(f"unknown dense strategy {strategy!r}")


# ---------------------------------------------------------------------------
# Sparse queue exchange: (p, cap) per-destination vertex-id buffers
# ---------------------------------------------------------------------------

def exchange_queue(buckets: jnp.ndarray, axis: AxisName, strategy: str) -> jnp.ndarray:
    """Route per-destination id buffers to their owners.

    buckets: (p, cap) int32; row j holds candidate global ids owned by
    shard j (-1 padded).  Returns (p, cap): row j = what shard j sent me.
    """
    p = axis_size(axis)
    assert buckets.shape[0] == p

    if strategy == "alltoall_direct":
        # Paper §5.1-2 applied to queues: MPI_Alltoallv equivalent.
        return lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0,
                              tiled=True)

    if strategy == "allgather_merge":
        # [2]-style aggregate-everywhere: every shard receives every buffer
        # (p^2·cap ids on the wire) and picks out the rows addressed to it.
        allb = lax.all_gather(buckets, axis)         # (p, p, cap)
        me = axis_index(axis)
        return lax.dynamic_slice_in_dim(allb, me, 1, axis=1)[:, 0]

    raise ValueError(f"unknown queue strategy {strategy!r}")


def allgather_frontier(frontier: jnp.ndarray, axis: AxisName) -> jnp.ndarray:
    """(shard, S) -> (n, S): replicate the frontier bitmap (bottom-up pass).

    Cheap by construction: the *frontier* (n bits) is exchanged instead of
    the *candidate* set (up to E entries) — the direction-optimizing
    rationale restated in communication terms.
    """
    return lax.all_gather(frontier, axis, tiled=True)


# ---------------------------------------------------------------------------
# Analytic per-chip byte models (used by benchmarks + roofline cross-check)
# ---------------------------------------------------------------------------

def dense_level_bytes(strategy: str, n: int, p: int, s: int = 1,
                      itemsize: int = 1, axes_sizes: Sequence[int] = ()) -> float:
    """Bytes *received* per chip for one dense exchange."""
    if strategy == "allgather_merge":
        return (p - 1) * n * s * itemsize
    if strategy == "alltoall_direct":
        return (p - 1) / p * n * s * itemsize
    if strategy == "reduce_scatter":
        return (p - 1) / p * n * s * 2  # bf16 widening
    if strategy == "hierarchical":
        sizes = list(axes_sizes) or [p]
        return sum((sz - 1) / sz * n * s * itemsize for sz in sizes)
    raise ValueError(strategy)


def queue_level_bytes(strategy: str, p: int, cap: int, itemsize: int = 4) -> float:
    if strategy == "alltoall_direct":
        return (p - 1) * cap * itemsize
    if strategy == "allgather_merge":
        return (p - 1) * p * cap * itemsize
    raise ValueError(strategy)


def bottomup_level_bytes(n: int, p: int, s: int = 1, itemsize: int = 1) -> float:
    return (p - 1) / p * n * s * itemsize
