"""Partition schemes: 1-D vertex blocks (paper §2.1) and 2-D edge blocks.

The paper distributes vertices of ``G(V, E)`` across ``p`` processors with a
1-D partitioning: every vertex has exactly one *owner* processor, and only
the owner may decide visitation and assign a BFS level (owner-computes rule,
paper §2.3).  We use a contiguous *block* distribution — vertex ``v`` is
owned by ``v // ceil(n/p)`` — which makes ``find_owner`` a single integer
divide and keeps each shard's vertex ids contiguous so a shard's slice of
any vertex-indexed dense array (distance vector, frontier bitmap, feature
matrix) is a plain static slice.

Beyond the paper, ``Partition2D`` block-distributes the *adjacency matrix*
over an ``r x c`` processor grid (Buluç & Madduri, arXiv:1104.4518): edge
``(u, v)`` lives on grid cell ``(grid_row(owner(u)), grid_col(owner(v)))``.
The vertex distribution is unchanged — chunk ``k`` (same ``ceil(n/p)``
blocks, ``p = r*c``) belongs to device ``(k // c, k % c)`` — so distance and
frontier arrays lay out identically under both schemes and the two engines
share their buffers' shapes.  What changes is the communication pattern:
each BFS level's exchange is an allgather across a grid *row* (``c``
participants, the expand phase) plus an all-to-all+reduce across a grid
*column* (``r`` participants, the fold phase), instead of one collective
over all ``p`` shards.

Both schemes satisfy the structural ``Partition`` protocol below (owner
lookup, shard slicing, padded sizes) and are reused for every partitioned
structure in the framework: BFS distance vectors, GNN node features, and
recsys embedding table rows (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, Union, runtime_checkable

import jax.numpy as jnp
import numpy as np

Array = Union[np.ndarray, jnp.ndarray]


@runtime_checkable
class Partition(Protocol):
    """Structural protocol every partition scheme satisfies.

    ``isinstance(x, Partition)`` works at runtime (data members are checked
    for presence only).  All id maps must accept python ints, numpy arrays
    and jnp arrays, and must map every padded id in ``[0, n)`` — including
    the padding ids ``[n_logical, n)`` at the last shard boundary — to a
    valid shard without raising.
    """

    n_logical: int

    @property
    def kind(self) -> str: ...              # "1d" | "2d"

    @property
    def p(self) -> int: ...                 # number of shards

    @property
    def shard_size(self) -> int: ...        # padded ids per shard

    @property
    def n(self) -> int: ...                 # padded global size

    def owner(self, v): ...

    def local_id(self, v): ...

    def global_id(self, shard, local): ...

    def shard_slice(self, shard) -> slice: ...

    def pad_vertex_array(self, x, fill=0): ...


class _BlockVertexMixin:
    """Shared owner/local-id algebra for contiguous block distributions.

    Relies on ``self.p``, ``self.shard_size``, ``self.n_logical`` and
    ``self.n``.  Arithmetic only (no np/jnp calls), so every map works
    unchanged on python ints, numpy arrays and traced jnp arrays.
    """

    # --- owner / local id maps (work on python ints, numpy and jnp arrays) ---
    def owner(self, v: Array) -> Array:
        """``find_owner`` from the paper's algorithm (fig. 2, line 15).

        Valid for every padded id in ``[0, n)``: the tail padding ids
        ``[n_logical, n)`` land on the last shard(s) by construction
        (``n = p * shard_size``), never out of range — pinned by the
        regression tests in tests/test_partition_and_registry.py.
        """
        return v // self.shard_size

    find_owner = owner  # the paper's name for the same map

    def local_id(self, v: Array) -> Array:
        return v - (v // self.shard_size) * self.shard_size

    def global_id(self, shard: Array, local: Array) -> Array:
        return shard * self.shard_size + local

    def shard_start(self, shard: int) -> int:
        return shard * self.shard_size

    def shard_slice(self, shard: int) -> slice:
        """Padded-coordinate slice ``[shard*size, (shard+1)*size)``."""
        if not 0 <= shard < self.p:
            raise ValueError(f"shard {shard} outside [0, {self.p})")
        return slice(shard * self.shard_size, (shard + 1) * self.shard_size)

    def shard_logical_slice(self, shard: int) -> slice:
        """``shard_slice`` clipped to the logical vertex range.

        Safe for slicing length-``n_logical`` host arrays: a last shard
        that is partially (or entirely) padding yields a short (or empty)
        slice instead of overrunning.
        """
        s = self.shard_slice(shard)
        return slice(min(s.start, self.n_logical), min(s.stop, self.n_logical))

    # --- numpy helpers used by the host-side graph builder ---
    def counts_per_owner(self, v: np.ndarray) -> np.ndarray:
        return np.bincount(np.asarray(self.owner(v)), minlength=self.p)

    def pad_vertex_array(self, x: np.ndarray, fill=0) -> np.ndarray:
        """Pad a length-``n_logical`` vertex-indexed array to length ``n``."""
        if x.shape[0] == self.n:
            return x
        pad = [(0, self.n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
        return np.pad(x, pad, constant_values=fill)

    def valid_mask_local(self) -> np.ndarray:
        """(p, shard_size) bool — True where the local slot is a real vertex."""
        gids = np.arange(self.n).reshape(self.p, self.shard_size)
        return gids < self.n_logical


@dataclasses.dataclass(frozen=True)
class Partition1D(_BlockVertexMixin):
    """Block 1-D partition of ``n_logical`` ids over ``p`` shards.

    ``n`` is padded up so every shard owns exactly ``shard_size`` ids;
    padding ids (``>= n_logical``) are valid to store but are never real
    vertices.
    """

    n_logical: int
    p: int

    def __post_init__(self):
        if self.n_logical <= 0 or self.p <= 0:
            raise ValueError(f"bad partition ({self.n_logical=}, {self.p=})")

    @property
    def kind(self) -> str:
        return "1d"

    @property
    def shard_size(self) -> int:
        return -(-self.n_logical // self.p)  # ceil div

    @property
    def n(self) -> int:
        """Padded global size (``p * shard_size``)."""
        return self.shard_size * self.p


@dataclasses.dataclass(frozen=True)
class Partition2D(_BlockVertexMixin):
    """2-D block partition of the adjacency matrix over an ``r x c`` grid.

    Vertices keep the same contiguous chunks as ``Partition1D(n, r*c)``
    (chunk ``k`` on grid device ``(k // c, k % c)``), so vertex-indexed
    arrays shard identically under both schemes.  Edges are assigned by
    *both* endpoints: edge ``(u, v)`` lives on the device at grid row
    ``grid_row(owner(u))`` and grid column ``grid_col(owner(v))``.

    The derived blocks of each level's two-phase exchange:

      * row block ``i`` (expand phase) — the ``c`` contiguous vertex chunks
        owned by grid row ``i``: global ids ``[i*c*b, (i+1)*c*b)``.  The
        frontier segment a device needs for local expansion is exactly its
        grid row's allgather (``c`` participants).
      * fold layout (column phase) — candidates a device produces target
        the ``r`` chunks owned by its grid *column* ``j`` (chunks
        ``{j, c+j, ..., (r-1)c+j}``, strided).  They are packed transposed
        as ``fold_index(v) = row_rank(owner(v)) * b + local_id(v)`` so the
        column all-to-all (``r`` participants) delivers chunk-contiguous
        slices straight to their owners.
    """

    n_logical: int
    r: int
    c: int

    def __post_init__(self):
        if self.n_logical <= 0 or self.r <= 0 or self.c <= 0:
            raise ValueError(
                f"bad partition ({self.n_logical=}, {self.r=}, {self.c=})")

    @property
    def kind(self) -> str:
        return "2d"

    @property
    def p(self) -> int:
        return self.r * self.c

    @property
    def shard_size(self) -> int:
        return -(-self.n_logical // self.p)  # ceil div

    @property
    def n(self) -> int:
        return self.shard_size * self.p

    # --- grid coordinate maps ---
    def grid_row(self, shard: Array) -> Array:
        return shard // self.c

    def grid_col(self, shard: Array) -> Array:
        return shard - (shard // self.c) * self.c

    @property
    def row_block_size(self) -> int:
        """Vertices per grid row (the expand-phase frontier segment)."""
        return self.c * self.shard_size

    @property
    def fold_size(self) -> int:
        """Length of the transposed fold-phase candidate layout (r * b)."""
        return self.r * self.shard_size

    def row_start(self, grid_row: int) -> int:
        return grid_row * self.row_block_size

    def fold_index(self, v: Array) -> Array:
        """Transposed candidate index: ``row_rank(owner(v)) * b + local``."""
        own = self.owner(v)
        return self.grid_row(own) * self.shard_size + self.local_id(v)

    @property
    def flat(self) -> Partition1D:
        """The equivalent 1-D vertex partition (identical owner map)."""
        return Partition1D(self.n_logical, self.p)


def repartition(part: Partition1D, new_p: int) -> Partition1D:
    """Elastic rescale: same logical vertex set, new shard count.

    Used by the elastic runtime when the number of healthy hosts changes
    (train/elastic.py); all owner maps are pure functions of (n_logical, p)
    so no state beyond the distance/feature arrays needs to move.
    """
    return Partition1D(part.n_logical, new_p)
