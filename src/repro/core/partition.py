"""1-D block partitioning of the vertex set (paper §2.1).

The paper distributes vertices of ``G(V, E)`` across ``p`` processors with a
1-D partitioning: every vertex has exactly one *owner* processor, and only
the owner may decide visitation and assign a BFS level (owner-computes rule,
paper §2.3).  We use a contiguous *block* distribution — vertex ``v`` is
owned by ``v // ceil(n/p)`` — which makes ``find_owner`` a single integer
divide and keeps each shard's vertex ids contiguous so a shard's slice of
any vertex-indexed dense array (distance vector, frontier bitmap, feature
matrix) is a plain static slice.

The same object is reused for every 1-D-partitioned structure in the
framework: BFS distance vectors, GNN node features, and recsys embedding
table rows (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax.numpy as jnp
import numpy as np

Array = Union[np.ndarray, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Partition1D:
    """Block 1-D partition of ``n_logical`` ids over ``p`` shards.

    ``n`` is padded up so every shard owns exactly ``shard_size`` ids;
    padding ids (``>= n_logical``) are valid to store but are never real
    vertices.
    """

    n_logical: int
    p: int

    def __post_init__(self):
        if self.n_logical <= 0 or self.p <= 0:
            raise ValueError(f"bad partition ({self.n_logical=}, {self.p=})")

    @property
    def shard_size(self) -> int:
        return -(-self.n_logical // self.p)  # ceil div

    @property
    def n(self) -> int:
        """Padded global size (``p * shard_size``)."""
        return self.shard_size * self.p

    # --- owner / local id maps (work on python ints, numpy and jnp arrays) ---
    def owner(self, v: Array) -> Array:
        """``find_owner`` from the paper's algorithm (fig. 2, line 15)."""
        return v // self.shard_size

    def local_id(self, v: Array) -> Array:
        return v - (v // self.shard_size) * self.shard_size

    def global_id(self, shard: Array, local: Array) -> Array:
        return shard * self.shard_size + local

    def shard_start(self, shard: int) -> int:
        return shard * self.shard_size

    # --- numpy helpers used by the host-side graph builder ---
    def counts_per_owner(self, v: np.ndarray) -> np.ndarray:
        return np.bincount(np.asarray(self.owner(v)), minlength=self.p)

    def pad_vertex_array(self, x: np.ndarray, fill=0) -> np.ndarray:
        """Pad a length-``n_logical`` vertex-indexed array to length ``n``."""
        if x.shape[0] == self.n:
            return x
        pad = [(0, self.n - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
        return np.pad(x, pad, constant_values=fill)

    def valid_mask_local(self) -> np.ndarray:
        """(p, shard_size) bool — True where the local slot is a real vertex."""
        gids = np.arange(self.n).reshape(self.p, self.shard_size)
        return gids < self.n_logical


def repartition(part: Partition1D, new_p: int) -> Partition1D:
    """Elastic rescale: same logical vertex set, new shard count.

    Used by the elastic runtime when the number of healthy hosts changes
    (train/elastic.py); all owner maps are pure functions of (n_logical, p)
    so no state beyond the distance/feature arrays needs to move.
    """
    return Partition1D(part.n_logical, new_p)
