"""Compile-once BFS lifecycle: ``plan() -> BFSPlan -> compile() -> BFSEngine``.

The paper's headline result is cutting *per-traversal* communication cost,
so the API must not give the win back at the call boundary.  The lifecycle
separates the three cost tiers explicitly:

  * ``plan(graph, opts, mesh)``   — host-side validation and static-shape
    derivation: checks options, resolves exchange strategies from the
    registry (core/exchange.py), normalizes the mesh/axis, fixes the
    source-batch capacity S.  Cheap; pure metadata (``BFSPlan``).
  * ``BFSPlan.compile()``         — builds the ``shard_map``-wrapped
    while-loop once and AOT-lowers it via ``jax.jit(...).lower().compile()``
    with the ``dist`` buffer donated; uploads the graph's edge blocks to
    device.  Paid once per (graph, opts, mesh, S).
  * ``BFSEngine.run(sources)``    — per traversal.  Source injection is a
    device-side scatter from an ``(S,)`` int32 array
    (frontier.init_dist_frontier), so fresh source sets never retrace and
    never materialize host ``(n, S)`` arrays.  ``run_async`` returns
    un-blocked device arrays for pipelined dispatch; stats stay on device
    (``BFSRunStats`` pytree) until ``.block()``/``.stats()``.

Every later scaling feature (2-D partitioning, multi-graph caching, the
serve-layer traversal endpoint) plugs into this seam.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import exchange as ex
from repro.core import frontier as fr
from repro.core.bfs import (BFSOptions, BFSStats, INF, _make_shard_fn,
                            validate_sources)
from repro.core.compat import shard_map

if TYPE_CHECKING:
    from repro.graphs.formats import ShardedGraph


# ---------------------------------------------------------------------------
# Per-run stats: a device pytree — no host sync until .block()/.to_host()
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BFSRunStats:
    """Per-traversal statistics as device scalars (a JAX pytree).

    Static plan facts (p, S, strategies, byte model, ...) live in
    ``BFSPlan.describe()``; only values produced by the traversal itself
    are here, so pipelined ``run_async`` dispatch never blocks on stats.
    """

    levels: jax.Array          # () int32
    comm_bytes: jax.Array      # () float32, analytic per-chip
    overflowed: jax.Array      # () bool
    mode_counts: jax.Array     # (3,) int32: dense, queue, bottom_up levels

    def block(self) -> "BFSRunStats":
        jax.block_until_ready((self.levels, self.comm_bytes,
                               self.overflowed, self.mode_counts))
        return self

    def to_host(self) -> dict:
        return {
            "levels": int(self.levels),
            "comm_bytes": float(self.comm_bytes),
            "overflowed": bool(self.overflowed),
            "mode_counts": {"dense": int(self.mode_counts[0]),
                            "queue": int(self.mode_counts[1]),
                            "bottom_up": int(self.mode_counts[2])},
        }


jax.tree_util.register_dataclass(
    BFSRunStats,
    data_fields=["levels", "comm_bytes", "overflowed", "mode_counts"],
    meta_fields=[])


@dataclasses.dataclass
class BFSResult:
    """One traversal's outputs; device-resident until explicitly synced.

    ``dist`` is the padded global (n, S) int32 distance matrix (sharded
    over the mesh); ``dist_host`` slices it to the logical vertex range
    and the actually-requested source columns.
    """

    dist: jax.Array
    run_stats: BFSRunStats
    n_logical: int
    n_sources: int             # actual requested sources (<= compiled S)

    def block(self) -> "BFSResult":
        jax.block_until_ready(self.dist)
        self.run_stats.block()
        return self

    @property
    def dist_host(self) -> np.ndarray:
        """Host view of the distances; the D2H copy is made once and
        cached (stats() and callers both read it)."""
        if not hasattr(self, "_dist_host"):
            self._dist_host = np.asarray(
                self.dist)[: self.n_logical, : self.n_sources]
        return self._dist_host

    def stats(self) -> BFSStats:
        """Materialize legacy host-side stats (syncs device -> host)."""
        h = self.run_stats.to_host()
        visited = int((self.dist_host < int(INF)).sum())
        return BFSStats(levels=h["levels"], visited=visited,
                        comm_bytes=h["comm_bytes"],
                        overflowed=h["overflowed"],
                        mode_counts=h["mode_counts"])


# ---------------------------------------------------------------------------
# Plan: validated static metadata for one (graph, opts, mesh, S) traversal
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BFSPlan:
    graph: "ShardedGraph"
    opts: BFSOptions
    mesh: Mesh
    axis: object               # str or tuple of mesh axis names
    axes_sizes: tuple
    num_sources: int           # compiled source-batch capacity S
    max_levels: int
    dense_strategy: ex.ExchangeStrategy
    queue_strategy: ex.ExchangeStrategy

    def describe(self) -> dict:
        """Static plan metadata (the non-per-run half of the old BFSStats)."""
        part = self.graph.part
        return {
            "mode": self.opts.mode,
            "dense_exchange": self.dense_strategy.name,
            "queue_exchange": self.queue_strategy.name,
            "p": part.p,
            "n": part.n,
            "n_logical": part.n_logical,
            "shard_size": part.shard_size,
            "e_cap": self.graph.e_cap,
            "in_e_cap": self.graph.in_e_cap,
            "num_sources": self.num_sources,
            "max_levels": self.max_levels,
            "axes": self.axis if isinstance(self.axis, tuple) else (self.axis,),
            "axes_sizes": self.axes_sizes,
            "dense_level_bytes": self.dense_strategy.bytes_model(
                part.n, part.p, self.num_sources, 1, self.axes_sizes),
        }

    def compile(self) -> "BFSEngine":
        return BFSEngine(self)


def plan(graph: "ShardedGraph", opts: BFSOptions = BFSOptions(), *,
         mesh: Optional[Mesh] = None, axis=None,
         num_sources: int = 1) -> BFSPlan:
    """Validate options/topology and derive the static traversal shapes.

    ``num_sources`` fixes the compiled source-batch capacity S; a compiled
    engine accepts any 1..S sources per run without retracing.
    """
    opts.validate()
    part = graph.part
    if num_sources < 1:
        raise ValueError(f"num_sources must be >= 1 ({num_sources})")
    if opts.mode == "queue" and num_sources != 1:
        raise ValueError("queue frontier supports a single source "
                         f"(num_sources={num_sources})")
    if opts.use_kernel:
        # Pallas path precondition; AssertionError kept for back-compat.
        assert part.p == 1 and opts.mode == "dense", \
            "use_kernel requires p == 1 and mode == 'dense'"

    if mesh is None:
        dev = jax.devices()[:1]
        mesh = Mesh(np.asarray(dev).reshape(1), ("bfs_p",))
        axis = "bfs_p"
        if part.p != 1:
            raise ValueError("pass a mesh whose total size equals part.p")
    axis = axis if axis is not None else tuple(mesh.axis_names)
    axis = tuple(axis) if isinstance(axis, (list, tuple)) else axis
    axes = axis if isinstance(axis, tuple) else (axis,)
    axes_sizes = tuple(mesh.shape[a] for a in axes)
    if int(np.prod(axes_sizes)) != part.p:
        raise ValueError(f"mesh axes {axes} of sizes {axes_sizes} do not "
                         f"multiply to the graph's p={part.p}")

    return BFSPlan(
        graph=graph, opts=opts, mesh=mesh, axis=axis,
        axes_sizes=axes_sizes, num_sources=int(num_sources),
        max_levels=opts.max_levels or part.n_logical,
        dense_strategy=ex.get_exchange("dense", opts.dense_exchange),
        queue_strategy=ex.get_exchange("queue", opts.queue_exchange),
    )


# ---------------------------------------------------------------------------
# Engine: AOT-compiled executables + device-resident graph buffers
# ---------------------------------------------------------------------------

class BFSEngine:
    """A compiled traversal: run unlimited source sets with device-only work.

    Two AOT executables are built at construction:

      * ``_init_c(sources)``   — scatters the (S,) source vector into fresh
        (n, S) dist/frontier buffers on device.
      * ``_run_c(edges..., dist0, frontier0, valid)`` — the while-loop
        kernel.  ``dist0`` is donated: its (n, S) buffer is reused for the
        output distance matrix, so steady-state traversals allocate no new
        large buffers.  (``frontier0`` is not donated — the kernel has no
        same-shaped uint8 output to alias it to.)

    ``trace_count`` exposes how many times the kernel body has been traced;
    it must not grow across ``run()`` calls (asserted by the test suite).
    """

    def __init__(self, plan_: BFSPlan):
        self.plan = plan_
        self._trace_count = 0
        graph, opts, mesh = plan_.graph, plan_.opts, plan_.mesh
        part = graph.part
        p, n = part.p, part.n
        s = plan_.num_sources
        axis = plan_.axis

        expand_fn = self._build_kernel_expand() if opts.use_kernel else None

        shard_fn = _make_shard_fn(
            part, graph.n_edges, s, axis, plan_.axes_sizes, opts,
            plan_.max_levels, plan_.dense_strategy, plan_.queue_strategy,
            expand_fn=expand_fn, on_trace=self._bump_trace)

        spec_edge = P(axis)
        spec_vert = P(axis, None)
        sh_edge = NamedSharding(mesh, spec_edge)
        sh_vert = NamedSharding(mesh, spec_vert)
        sh_repl = NamedSharding(mesh, P())
        self._sh_repl = sh_repl

        mapped = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(spec_edge, spec_edge, spec_edge, spec_edge,
                      spec_vert, spec_vert, spec_edge),
            out_specs=(spec_vert, P(), P(), P(), P()),
            check_vma=False,
        )

        # Graph blocks + validity mask live on device for the engine's
        # lifetime; every run reuses them with zero H2D traffic.  They are
        # shared across engines on the same (mesh, axis) — compiling
        # several option/S variants of one graph must not duplicate its
        # largest buffers.
        dev_cache = graph.__dict__.setdefault("_device_blocks", {})
        bufs = dev_cache.get((mesh, axis))
        if bufs is None:
            src_local, dst_global, in_src_global, in_dst_local = graph.flat()
            valid = np.arange(n) < part.n_logical
            bufs = (tuple(
                jax.device_put(np.asarray(a, dtype=np.int32), sh_edge)
                for a in (src_local, dst_global, in_src_global,
                          in_dst_local)),
                jax.device_put(valid, sh_edge))
            dev_cache[(mesh, axis)] = bufs
        self._gbufs, self._valid = bufs

        dist_sds = jax.ShapeDtypeStruct((n, s), jnp.int32, sharding=sh_vert)
        front_sds = jax.ShapeDtypeStruct((n, s), jnp.uint8, sharding=sh_vert)
        src_sds = jax.ShapeDtypeStruct((s,), jnp.int32, sharding=sh_repl)

        self._run_c = jax.jit(mapped, donate_argnums=(4,)).lower(
            *self._gbufs, dist_sds, front_sds, self._valid).compile()

        def init_fn(sources):
            self._bump_trace()
            return fr.init_dist_frontier(sources, n, part.n_logical)

        self._init_c = jax.jit(
            init_fn, out_shardings=(sh_vert, sh_vert)).lower(src_sds).compile()

        # Traces spent building the two executables; run() must never add
        # to this (the engine-reuse tests pin trace_count to it).
        self.compile_traces = self._trace_count

    # ------------------------------------------------------------------ misc
    def _bump_trace(self):
        self._trace_count += 1

    @property
    def trace_count(self) -> int:
        return self._trace_count

    def _build_kernel_expand(self):
        # Pallas bsr_spmm frontier expansion: block-CSR adjacency on the
        # MXU (boolean semiring via sum + >0).  Single-shard dense mode —
        # the multi-shard path keeps the segment-scatter expansion.
        from repro.graphs.formats import block_sparse_adjacency
        from repro.kernels.bsr_spmm import ops as spmm_ops

        graph = self.plan.graph
        n = graph.part.n
        src_local, dst_global, _, _ = graph.flat()
        valid_e = dst_global >= 0
        src_g = np.asarray(src_local)[valid_e]
        dst_g = np.asarray(dst_global)[valid_e]
        blocks, brr, bcc, n_pad_b = block_sparse_adjacency(
            dst_g, src_g, n)  # transposed: candidates = A^T @ f
        blocks_j = jnp.asarray(blocks)
        br_j = jnp.asarray(brr)
        bc_j = jnp.asarray(bcc)

        def expand_fn(frontier):  # (n, S) uint8 -> (n, S) uint8
            f = frontier
            if n_pad_b > n:
                f = jnp.pad(f, ((0, n_pad_b - n), (0, 0)))
            cand = spmm_ops.frontier_expand(
                blocks_j, br_j, bc_j, f, n_rows_pad=n_pad_b)
            return cand[:n]

        return expand_fn

    # ------------------------------------------------------------------- run
    def run_async(self, sources) -> BFSResult:
        """Dispatch one traversal; returns un-blocked device arrays.

        ``sources`` may hold 1..S vertex ids; unused engine columns stay
        empty (their dist columns are all-INF and are sliced off by
        ``dist_host``).
        """
        s = self.plan.num_sources
        src_arr = validate_sources(sources, self.plan.graph.part.n_logical,
                                   max_sources=s)
        n_req = int(src_arr.shape[0])
        # ids are bounded by n_logical, which must fit the int32 dist/
        # source buffers — guard rather than let numpy wrap silently
        if src_arr.max() > np.iinfo(np.int32).max:
            raise ValueError("source ids exceed int32 range; the engine's "
                             "distance/source buffers are int32")
        padded = np.full((s,), -1, dtype=np.int32)
        padded[:n_req] = src_arr
        src_dev = jax.device_put(padded, self._sh_repl)

        dist0, frontier0 = self._init_c(src_dev)
        dist, levels, comm_bytes, overflowed, modes = self._run_c(
            *self._gbufs, dist0, frontier0, self._valid)
        return BFSResult(
            dist=dist,
            run_stats=BFSRunStats(levels=levels, comm_bytes=comm_bytes,
                                  overflowed=overflowed, mode_counts=modes),
            n_logical=self.plan.graph.part.n_logical,
            n_sources=n_req,
        )

    def run(self, sources) -> BFSResult:
        """Run one traversal to completion (blocks until device work ends)."""
        return self.run_async(sources).block()
