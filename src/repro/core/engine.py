"""Compile-once BFS lifecycle: ``plan() -> BFSPlan -> compile() -> BFSEngine``.

The paper's headline result is cutting *per-traversal* communication cost,
so the API must not give the win back at the call boundary.  The lifecycle
separates the three cost tiers explicitly:

  * ``plan(graph, opts, mesh)``   — host-side validation and static-shape
    derivation: checks options, resolves exchange strategies from the
    registry (core/exchange.py), normalizes the mesh/axis, fixes the
    source-batch capacity S.  Cheap; pure metadata (``BFSPlan``).
  * ``BFSPlan.compile()``         — builds the ``shard_map``-wrapped
    while-loop once and AOT-lowers it via ``jax.jit(...).lower().compile()``
    with the ``dist`` buffer donated; uploads the graph's edge blocks to
    device.  Paid once per (graph, opts, mesh, S).
  * ``BFSEngine.run(sources)``    — per traversal.  Source injection is a
    device-side scatter from an ``(S,)`` int32 array
    (frontier.init_dist_frontier), so fresh source sets never retrace and
    never materialize host ``(n, S)`` arrays.  ``run_async`` returns
    un-blocked device arrays for pipelined dispatch; stats stay on device
    (``BFSRunStats`` pytree) until ``.block()``/``.stats()``.

Every later scaling feature plugs into this seam; the first alternative
backend is already here: ``plan(graph, opts, mesh, partition="2d")``
compiles the 2-D edge-partitioned two-phase traversal (row-allgather
expand + column fold, r + c collective participants instead of p) behind
the exact same lifecycle — callers change nothing but the flag.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import exchange as ex
from repro.core import frontier as fr
from repro.core.bfs import (BFSOptions, BFSStats, INF, _make_shard_fn,
                            _make_shard_fn_2d, validate_sources)
from repro.core.compat import shard_map
# chaos layer: a no-op global read unless a FaultPlan is installed
# (stdlib-only module; degrade.py defers its engine import, no cycle)
from repro.serve.resilience import faults as _faults

if TYPE_CHECKING:
    from repro.graphs.formats import ShardedGraph, ShardedGraph2D


# ---------------------------------------------------------------------------
# Per-run stats: a device pytree — no host sync until .block()/.to_host()
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BFSRunStats:
    """Per-traversal statistics as device scalars (a JAX pytree).

    Static plan facts (p, S, strategies, byte model, ...) live in
    ``BFSPlan.describe()``; only values produced by the traversal itself
    are here, so pipelined ``run_async`` dispatch never blocks on stats.
    """

    levels: jax.Array          # () int32
    comm_bytes: jax.Array      # () float32, analytic per-chip
    overflowed: jax.Array      # () bool
    mode_counts: jax.Array     # (3,) int32: dense, queue, bottom_up levels
    sieve_hits: jax.Array      # () int32: candidates dropped pre-collective

    def block(self) -> "BFSRunStats":
        jax.block_until_ready((self.levels, self.comm_bytes,
                               self.overflowed, self.mode_counts,
                               self.sieve_hits))
        return self

    def to_host(self) -> dict:
        return {
            "levels": int(self.levels),
            "comm_bytes": float(self.comm_bytes),
            "overflowed": bool(self.overflowed),
            "mode_counts": {"dense": int(self.mode_counts[0]),
                            "queue": int(self.mode_counts[1]),
                            "bottom_up": int(self.mode_counts[2])},
            "sieve_hits": int(self.sieve_hits),
        }


jax.tree_util.register_dataclass(
    BFSRunStats,
    data_fields=["levels", "comm_bytes", "overflowed", "mode_counts",
                 "sieve_hits"],
    meta_fields=[])


@dataclasses.dataclass
class BFSResult:
    """One traversal's outputs; device-resident until explicitly synced.

    ``dist`` is the padded global (n, S) int32 distance matrix (sharded
    over the mesh); ``dist_host`` slices it to the logical vertex range
    and the actually-requested source columns.
    """

    dist: jax.Array
    run_stats: BFSRunStats
    n_logical: int
    n_sources: int             # actual requested sources (<= compiled S)

    def block(self) -> "BFSResult":
        jax.block_until_ready(self.dist)
        self.run_stats.block()
        return self

    @property
    def dist_host(self) -> np.ndarray:
        """Host view of the distances; the D2H copy is made once and
        cached (stats() and callers both read it)."""
        if not hasattr(self, "_dist_host"):
            self._dist_host = np.asarray(
                self.dist)[: self.n_logical, : self.n_sources]
        return self._dist_host

    def stats(self) -> BFSStats:
        """Materialize legacy host-side stats (syncs device -> host)."""
        h = self.run_stats.to_host()
        visited = int((self.dist_host < int(INF)).sum())
        return BFSStats(levels=h["levels"], visited=visited,
                        comm_bytes=h["comm_bytes"],
                        overflowed=h["overflowed"],
                        mode_counts=h["mode_counts"],
                        sieve_hits=h["sieve_hits"])


# ---------------------------------------------------------------------------
# Plan: validated static metadata for one (graph, opts, mesh, S) traversal
# ---------------------------------------------------------------------------

def _roofline_row(wire_bytes, hbm_bytes, flops, overlap: bool) -> dict:
    """Price one level variant on the TPU-v5e roofline.

    Three analytic terms per level: collective bytes over ICI bandwidth,
    memory traffic over HBM bandwidth, and elementwise work over peak
    FLOPs (bit tests and compares counted one op each).  Fused plans
    double-buffer the frontier generation, so the expand collective of
    level L+1 can overlap the tail compute of level L — modeled as
    ``max(collective, compute)``; unfused plans serialize the two
    (``sum``).  Absolute numbers use the v5e constants from
    launch/hlo_stats (the runtime here is CPU); the benchmark harness
    validates *relative* phase shape against parsed profiler traces
    after fitting one global calibration scale.
    """
    # deferred import: launch/hlo_stats is stdlib-only (import-light by
    # its package contract), so core -> launch here cannot cycle
    from repro.launch.hlo_stats import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

    t_coll = wire_bytes / ICI_BW
    t_comp = hbm_bytes / HBM_BW + flops / PEAK_FLOPS_BF16
    t_level = max(t_coll, t_comp) if overlap else t_coll + t_comp
    return {
        "wire_bytes": float(wire_bytes),
        "hbm_bytes": float(hbm_bytes),
        "flops": float(flops),
        "t_collective_s": t_coll,
        "t_compute_s": t_comp,
        "t_level_s": t_level,
        "bottleneck": "collective" if t_coll >= t_comp else "compute",
        "model": "overlap(max)" if overlap else "serial(sum)",
    }


@dataclasses.dataclass(frozen=True)
class BFSPlan:
    graph: "ShardedGraph"
    opts: BFSOptions
    mesh: Mesh
    axis: object               # str or tuple of mesh axis names
    axes_sizes: tuple
    num_sources: int           # compiled source-batch capacity S
    max_levels: int
    dense_strategy: Optional[ex.ExchangeStrategy] = None
    queue_strategy: Optional[ex.ExchangeStrategy] = None
    # 2-D (partition="2d") plans: the r x c edge blocks plus the two phase
    # strategies that replace the single dense exchange.
    partition: str = "1d"
    graph2d: Optional["ShardedGraph2D"] = None
    expand_strategy: Optional[ex.ExchangeStrategy] = None
    fold_strategy: Optional[ex.ExchangeStrategy] = None
    expand_sparse_strategy: Optional[ex.ExchangeStrategy] = None
    fold_sparse_strategy: Optional[ex.ExchangeStrategy] = None
    # resolved wire layout of the bottom-up frontier gather (the one dense
    # exchange that is not a registry strategy); "auto" resolves here at
    # plan time just like the per-phase strategies resolve above
    bottom_up_wire: str = "bytes"
    # resolved visited-sieve decision (BFSOptions.sieve="auto" resolves at
    # plan time: on when the plan has a reachable queue path and p > 1)
    sieve: bool = False
    # resolved fused fold/owner-update tail (BFSOptions.use_fused_tail;
    # "auto" resolves at plan time: on when the dense/fold phase ships
    # packed words — the fused kernel consumes them directly — and the
    # mode has a dense path to fuse)
    use_fused_tail: bool = False

    def describe(self) -> dict:
        """Static plan metadata (the non-per-run half of the old BFSStats)."""
        part = self.graph.part
        meta = {
            "mode": self.opts.mode,
            "partition": self.partition,
            "p": part.p,
            "n": part.n,
            "n_logical": part.n_logical,
            "shard_size": part.shard_size,
            "num_sources": self.num_sources,
            "max_levels": self.max_levels,
            "axes": self.axis if isinstance(self.axis, tuple) else (self.axis,),
            "axes_sizes": self.axes_sizes,
        }
        # sparse phases report their resolved payload layout: "ids" (raw
        # int32) or "compressed" (delta+varint uint8)
        def sparse_wire(strategy):
            return "ids" if strategy.wire == "bytes" else strategy.wire

        if self.partition == "2d":
            part2 = self.graph2d.part
            r, c, s = part2.r, part2.c, self.num_sources
            cap = self.opts.queue_cap
            b = part2.shard_size
            density = cap / b
            sieve_bytes = ((part2.p - 1) * fr.sieve_layout(b)[2] * 4
                           if self.sieve else 0)
            phase_bytes = {
                # per-phase byte split of every level variant: row phase
                # then column phase, dense bitmaps vs sparse id buffers
                "expand": self.expand_strategy.bytes_model(
                    part2.n, r, c, s, 1),
                "fold": self.fold_strategy.bytes_model(part2.n, r, c, s, 1),
                "expand_sparse": self.expand_sparse_strategy.bytes_model(
                    r, c, cap, 4, density),
                "fold_sparse": self.fold_sparse_strategy.bytes_model(
                    r, c, cap, 4, density),
            }
            meta.update({
                "grid": (r, c),
                "expand_exchange": self.expand_strategy.name,
                "fold_exchange": self.fold_strategy.name,
                "expand_sparse_exchange": self.expand_sparse_strategy.name,
                "fold_sparse_exchange": self.fold_sparse_strategy.name,
                # per-phase wire layout the plan resolved (what "auto"
                # actually picked)
                "wire_formats": {
                    "expand": self.expand_strategy.wire,
                    "fold": self.fold_strategy.wire,
                    "expand_sparse": sparse_wire(self.expand_sparse_strategy),
                    "fold_sparse": sparse_wire(self.fold_sparse_strategy),
                    "bottom_up": self.bottom_up_wire,
                },
                "sieve": self.sieve,
                # (no in_e_cap here: the bottom-up blocks build lazily at
                # compile time for auto plans; describe() must stay cheap)
                "e_cap": self.graph2d.e_cap,
                "phase_bytes": phase_bytes,
                # per-level exchange bytes of each mode a traversal can
                # take (mode_counts in BFSRunStats says how many of each
                # actually ran); queue levels add the sieve summary gather
                # when the plan resolved the sieve on
                "dense_level_bytes": (phase_bytes["expand"]
                                      + phase_bytes["fold"]),
                "queue_level_bytes": (phase_bytes["expand_sparse"]
                                      + phase_bytes["fold_sparse"]
                                      + sieve_bytes),
                "bottom_up_level_bytes": ex.bottomup_level_bytes(
                    part2.n, part2.p, s, 1, wire=self.bottom_up_wire),
            })
            # roofline latency terms per level variant (see _roofline_row):
            # HBM traffic = edge index reads (8B/edge) + frontier gather/
            # candidate scatter (1B/edge/source) + fold-width candidate
            # array passes + the dist read/write + mask tails
            e_p = self.graph2d.e_cap
            meta["use_fused_tail"] = self.use_fused_tail
            # byte passes only the *unfused* tail pays: the frontier pack
            # feeding the expand allgather, the c-segment row unpack the
            # expansion reads, the fold-word unpack, and the separate
            # new-frontier mask pass — all skipped by the carried packed
            # generation + fused fold/owner-update kernel
            elim_hbm = (c + 5) * b * s if self.use_fused_tail else 0
            elim_flops = (c + 2) * b * s if self.use_fused_tail else 0
            meta["roofline"] = {
                "dense": _roofline_row(
                    meta["dense_level_bytes"],
                    hbm_bytes=(8 * e_p + 2 * e_p * s
                               + (3 * r * b + 10 * b) * s - elim_hbm),
                    flops=(e_p + r * b + 4 * b) * s - elim_flops,
                    overlap=self.use_fused_tail),
                "queue": _roofline_row(
                    meta["queue_level_bytes"],
                    hbm_bytes=(8 * e_p + e_p * s + 16 * (r + c) * cap
                               + 8 * b * s),
                    flops=(e_p + (r + c) * cap) * s,
                    overlap=False),
                "bottom_up": _roofline_row(
                    meta["bottom_up_level_bytes"],
                    # in-edge blocks build lazily; the forward e_cap is the
                    # cheap same-order proxy describe() is allowed to use
                    hbm_bytes=8 * e_p + e_p * s + 8 * b * s,
                    flops=e_p * s,
                    overlap=self.use_fused_tail),
            }
        else:
            density = self.opts.queue_cap / part.shard_size
            sieve_bytes = ((part.p - 1) * fr.sieve_layout(part.shard_size)[2]
                           * 4 if self.sieve else 0)
            meta.update({
                "dense_exchange": self.dense_strategy.name,
                "queue_exchange": self.queue_strategy.name,
                "wire_formats": {
                    "dense": self.dense_strategy.wire,
                    "queue": sparse_wire(self.queue_strategy),
                    "bottom_up": self.bottom_up_wire,
                },
                "sieve": self.sieve,
                "e_cap": self.graph.e_cap,
                "in_e_cap": self.graph.in_e_cap,
                "dense_level_bytes": self.dense_strategy.bytes_model(
                    part.n, part.p, self.num_sources, 1, self.axes_sizes),
                "queue_level_bytes": self.queue_strategy.bytes_model(
                    part.p, self.opts.queue_cap, 4, density) + sieve_bytes,
                "bottom_up_level_bytes": ex.bottomup_level_bytes(
                    part.n, part.p, self.num_sources, 1,
                    wire=self.bottom_up_wire),
            })
            e_p, in_e = self.graph.e_cap, self.graph.in_e_cap
            shard, s = part.shard_size, self.num_sources
            cap = self.opts.queue_cap
            meta["use_fused_tail"] = self.use_fused_tail
            # unfused-only byte passes (1-D shape of the same list as the
            # 2-D branch: expand-side frontier pack, merged-word unpack,
            # separate new-frontier mask pass)
            elim_hbm = 5 * shard * s if self.use_fused_tail else 0
            elim_flops = 2 * shard * s if self.use_fused_tail else 0
            meta["roofline"] = {
                "dense": _roofline_row(
                    meta["dense_level_bytes"],
                    hbm_bytes=(8 * e_p + 2 * e_p * s
                               + (3 * part.n + 10 * shard) * s - elim_hbm),
                    flops=(e_p + part.n + 4 * shard) * s - elim_flops,
                    overlap=self.use_fused_tail),
                "queue": _roofline_row(
                    meta["queue_level_bytes"],
                    hbm_bytes=(8 * e_p + e_p * s + 16 * part.p * cap
                               + 8 * shard * s),
                    flops=(e_p + part.p * cap) * s,
                    overlap=False),
                "bottom_up": _roofline_row(
                    meta["bottom_up_level_bytes"],
                    hbm_bytes=8 * in_e + in_e * s + 8 * shard * s,
                    flops=in_e * s,
                    overlap=self.use_fused_tail),
            }
        return meta

    def plan_key(self) -> tuple:
        """Canonical hashable fingerprint of everything a compile depends
        on: graph content, options, mesh topology, partition scheme,
        source capacity and the *resolved* exchange strategies.

        Two plans with equal keys compile byte-identical executables, so
        the cross-graph ``EngineCache`` (serve/engine_cache.py) can hand
        out one engine for both.  Exchange strategies enter by resolved
        name — ``"auto"`` and the strategy it resolved to key the same.
        Graph identity is a content hash (``ShardedGraph.fingerprint``),
        cached on the container, so two independently built but
        block-identical graphs share engines too.
        """
        mesh_key = (tuple(self.mesh.axis_names),
                    tuple(int(self.mesh.shape[a])
                          for a in self.mesh.axis_names),
                    tuple(int(d.id) for d in self.mesh.devices.flat))
        o = self.opts
        opt_key = (o.mode, o.local_update, o.dedupe, o.queue_cap,
                   o.queue_threshold, o.bottom_up_threshold, o.use_kernel,
                   # wire formats key by what they *resolved* to: the
                   # packed-vs-bytes choice of each phase is in the
                   # resolved strategy names below; the bottom-up gather
                   # and the sieve have no registry strategy so their
                   # resolutions key here, as does the resolved fused tail
                   self.bottom_up_wire, self.sieve, self.use_fused_tail)
        strat_key = tuple(
            s.name if s is not None else None
            for s in (self.dense_strategy, self.queue_strategy,
                      self.expand_strategy, self.fold_strategy,
                      self.expand_sparse_strategy, self.fold_sparse_strategy))
        graph_fp = (self.graph2d.fingerprint() if self.partition == "2d"
                    else self.graph.fingerprint())
        axis_key = (tuple(self.axis) if isinstance(self.axis, tuple)
                    else self.axis)
        return ("bfs_plan", graph_fp, self.partition, mesh_key, axis_key,
                opt_key, strat_key, self.num_sources, self.max_levels)

    def estimated_device_bytes(self) -> int:
        """Upper-bound estimate of the device memory a compiled engine of
        this plan holds live: edge blocks + validity mask (engine-lifetime
        residents) plus two generations of (n, S) dist/frontier working
        buffers (one in flight, one being initialized — the dist buffer is
        donated so steady state never holds more).

        Derived from the same static shapes the byte models price, so the
        ``EngineCache`` budget can be enforced before compiling.  It
        deliberately ignores the cross-engine sharing of device blocks
        (engine.py dedups them per (mesh, axis, group)): counting each
        engine's blocks in full makes the estimate an upper bound, which
        is the safe direction for an eviction budget.  For a 2-D ``auto``
        plan the lazily built bottom-up blocks are priced at their exact
        padded capacity (``bottom_up_in_cap()``, a cached bincount —
        under degree skew it exceeds ``e_cap``, so pricing them at the
        forward blocks' size would undercount and break the bound).
        """
        if self.partition == "2d":
            g = self.graph2d
            n = g.part.n
            b = g.part.shard_size
            edge = 2 * g.p * g.e_cap * 4           # src_rowlocal + dst_fold
            if self.opts.mode == "auto":
                # in_src_global + in_dst_local and the (p, b) out-degrees
                edge += 2 * g.p * g.bottom_up_in_cap() * 4 + n * 4
            # packed phases keep a loop-live word array per device: the
            # gathered row words (c*Wb) and/or the fold words (r*Wb)
            wire = 0
            if self.expand_strategy.wire == "packed":
                wire += g.part.c * fr.packed_words(b) * 4
            if self.fold_strategy.wire == "packed":
                wire += g.part.r * fr.packed_words(b) * 4
            # compressed sparse phases keep encode + gathered decode
            # payloads live across the level; the sieve keeps the
            # replicated summary words
            if (self.expand_sparse_strategy.wire == "compressed"
                    or self.fold_sparse_strategy.wire == "compressed"):
                wire += 2 * g.part.p * fr.compressed_capacity(
                    self.opts.queue_cap, b)
            if self.sieve:
                wire += g.part.p * fr.sieve_layout(b)[2] * 4
            if self.use_fused_tail:
                # double-buffered frontier generation: the carried packed
                # words plus the kernel's emitted next-generation words
                # are both live across the level boundary (that overlap
                # window is the point), and the fused kernel keeps one
                # (32-row, S) dist tile of scratch in flight
                wire += 2 * fr.packed_words(b) * 4 + 32 * 4
        else:
            g = self.graph
            n = g.part.n
            edge = 2 * g.p * (g.e_cap + g.in_e_cap) * 4
            # the packed candidate word array ((p*W, S) uint32) is live
            # across the dense exchange
            wire = (g.p * fr.packed_words(g.part.shard_size) * 4
                    if self.dense_strategy.wire == "packed" else 0)
            if self.queue_strategy.wire == "compressed":
                wire += 2 * g.p * fr.compressed_capacity(
                    self.opts.queue_cap, g.part.shard_size)
            if self.sieve:
                wire += g.p * fr.sieve_layout(g.part.shard_size)[2] * 4
            if self.use_fused_tail:
                # same double-buffered generation + kernel scratch as 2-D
                wire += 2 * fr.packed_words(g.part.shard_size) * 4 + 32 * 4
            if self.opts.use_kernel:
                # per-shard blocked adjacency resident on device for the
                # engine's lifetime (tile values + block row/col indices),
                # priced from the tile *count* alone — materializing the
                # dense tiles belongs to compile(), not cache admission
                kmax, blk = g.bsr_shard_caps()
                edge += g.p * kmax * (blk * blk * 4 + 2 * 4)
        s = self.num_sources
        work = 2 * (n * s * 4 + n * s * 1)         # dist (i32) + frontier (u8)
        return int(edge + n + work + wire * s)     # + 1-byte validity mask

    def compile(self) -> "BFSEngine":
        return BFSEngine(self)


_SPARSE_KINDS = ("queue", "expand_row_sparse", "fold_col_sparse")


def _resolve_strategy(kind: str, name: str, model_args: tuple,
                      wire_format: str = "bytes"):
    """Registry lookup, or byte-model auto-selection for name="auto".

    ``wire_format`` (``BFSOptions.wire_format``) resolves each phase's
    payload layout at plan time.  Dense kinds choose between raw uint8
    masks and the strategy's ``<name>_packed`` bitset twin; sparse kinds
    (queue / expand_row_sparse / fold_col_sparse) choose between raw
    int32 ids and the ``<name>_compressed`` delta+varint twin.  The
    option's tier maps onto what each kind implements:

      * ``"bytes"``      — the named strategy as registered.
      * ``"packed"``     — dense: the packed twin (error if none);
        sparse: raw ids (the bitset tier has no sparse analog — the
        compressed codec carries its own adaptive bitmap fallback).
      * ``"compressed"`` — sparse: the compressed twin (error if none);
        dense: the packed twin (the densest layout that kind has).
      * ``"auto"``       — whichever twin models fewer bytes for this
        plan's shapes; ties keep the base (no pack/codec work when
        nothing crosses the wire, e.g. p = 1).

    A name that already carries a twin suffix is an explicit choice and
    short-circuits the resolution; ``name="auto"`` spans every
    registered strategy of the wire formats the option admits.
    """
    sparse = kind in _SPARSE_KINDS
    suffix = "_compressed" if sparse else "_packed"
    if sparse:
        effective = {"bytes": "bytes", "packed": "bytes",
                     "compressed": "compressed",
                     "auto": "auto"}[wire_format]
    else:
        effective = {"bytes": "bytes", "packed": "packed",
                     "compressed": "packed", "auto": "auto"}[wire_format]
    if name == "auto":
        wire = None if effective == "auto" else effective
        return ex.select_exchange(kind, *model_args, wire=wire)
    if effective == "bytes" or name.endswith(suffix):
        return ex.get_exchange(kind, name)
    try:
        twin = ex.get_exchange(kind, name + suffix)
    except ValueError:
        if effective != "auto":
            raise ValueError(
                f"{kind} strategy {name!r} has no {suffix[1:]} variant; "
                f"use wire_format='bytes' or 'auto'") from None
        return ex.get_exchange(kind, name)
    if effective != "auto":
        return twin
    base = ex.get_exchange(kind, name)
    return (twin if twin.bytes_model(*model_args)
            < base.bytes_model(*model_args) else base)


def _resolve_sieve(sieve, mode: str, p: int, s: int) -> bool:
    """Resolve ``BFSOptions.sieve`` to the plan-time bool.

    The sieve filters queue-phase candidate ids against a replicated
    coarse visited summary *before* the collective, so it only applies
    where a queue path can run: not in pure dense mode, and only with a
    single source column (the summary is per vertex, not per source —
    multi-source plans keep it off even when asked).  ``"auto"`` turns
    it on exactly when the filter can save wire bytes: p > 1.
    """
    if mode == "dense" or s != 1:
        return False
    if sieve == "auto":
        return p > 1
    return bool(sieve)


def _resolve_bottom_up_wire(wire_format: str, n: int, p: int, s: int) -> str:
    """Packed-vs-bytes for the bottom-up frontier gather (not a registry
    strategy; same resolution rules as ``_resolve_strategy``)."""
    if wire_format == "packed":
        return "packed"
    if wire_format == "auto" and (
            ex.bottomup_level_bytes(n, p, s, wire="packed")
            < ex.bottomup_level_bytes(n, p, s)):
        return "packed"
    return "bytes"


def _resolve_fused_tail(use_fused_tail, mode: str, dense_wire: str) -> bool:
    """Resolve ``BFSOptions.use_fused_tail`` to the plan-time bool.

    The fused kernel consumes the *packed* merged candidate words of the
    dense (1-D) / fold (2-D) collective, so it only exists where that
    phase resolved to a packed wire — ``True`` on a bytes wire is a
    contradiction and fails loudly.  ``"auto"`` additionally requires a
    mode with a dense path on the steady critical path: pure queue mode
    re-packs per sparse level and only ever reaches the fused tail after
    a bottom-up escalation, so auto keeps it off there.
    """
    if use_fused_tail is False:
        return False
    packed = dense_wire == "packed"
    if use_fused_tail is True:
        if not packed:
            raise ValueError(
                "use_fused_tail=True needs the dense/fold phase on a "
                f"packed wire (resolved wire is {dense_wire!r}); set "
                "wire_format='packed' or 'auto', or drop the flag")
        return True
    return packed and mode in ("dense", "auto")


def normalize_ladder(ladder) -> tuple:
    """Canonicalize a batch-size bucket ladder: ints, deduped, ascending.

    The serving front-end compiles one engine per rung and routes every
    request to the smallest rung that fits, so the ladder is the whole
    set of compiled plans a lane can ever occupy — a malformed ladder
    must fail at configuration time, not on the first mid-sized request.
    """
    rungs = tuple(sorted({int(s) for s in ladder}))
    if not rungs:
        raise ValueError("bucket ladder must name at least one batch size")
    if rungs[0] < 1:
        raise ValueError(f"bucket ladder sizes must be >= 1 ({list(ladder)})")
    return rungs


def pick_bucket(n_sources: int, ladder) -> int:
    """Smallest ladder rung that fits ``n_sources`` (bucket routing).

    The engine already pads unused source columns on device (``run_async``
    accepts 1..S sources), so routing to the next-larger rung costs only
    the padded columns' device work — never a recompile.
    """
    n = int(n_sources)
    if n < 1:
        raise ValueError(f"n_sources must be >= 1 ({n_sources})")
    for s in normalize_ladder(ladder):
        if n <= s:
            return s
    raise ValueError(
        f"{n} sources exceed the largest bucket {max(ladder)} of ladder "
        f"{sorted(set(int(s) for s in ladder))}; add a larger rung or "
        "split the request")


def plan_ladder(graph, opts: BFSOptions = BFSOptions(), *,
                mesh: Optional[Mesh] = None, axis=None,
                ladder=(1, 8, 64), partition: Optional[str] = None) -> dict:
    """Plan one engine per batch-size bucket: ``{S: BFSPlan}`` ascending.

    The inference-serving idiom (sorted batch sizes, pad to bucket)
    applied to traversal: compiling a small ladder of source capacities
    once bounds the set of compiled executables while arbitrary request
    fan-outs route to the smallest fitting rung.  All rungs share the
    graph's device edge blocks (the per-(mesh, axis, group) upload dedup),
    so an extra rung costs roughly its (n, S) working buffers, not a
    second copy of the graph.
    """
    return {s: plan(graph, opts, mesh=mesh, axis=axis, num_sources=s,
                    partition=partition)
            for s in normalize_ladder(ladder)}


def plan(graph, opts: BFSOptions = BFSOptions(), *,
         mesh: Optional[Mesh] = None, axis=None,
         num_sources: int = 1, partition: Optional[str] = None) -> BFSPlan:
    """Validate options/topology and derive the static traversal shapes.

    ``num_sources`` fixes the compiled source-batch capacity S; a compiled
    engine accepts any 1..S sources per run without retracing.

    ``partition`` selects the scheme: ``"1d"`` (the paper's vertex blocks,
    default) or ``"2d"`` (edge blocks over an r x c grid — pass a mesh with
    two axes ``(rows, cols)``; each level's exchange is then a row
    allgather + column fold over r + c participants instead of one
    collective over all p shards).  ``None`` infers the scheme from the
    graph container, so callers holding a ``ShardedGraph2D`` need no flag;
    a 1-D graph is converted (and the conversion cached) on first use.
    """
    from repro.graphs.formats import ShardedGraph2D, to_2d

    opts.validate()
    part = graph.part
    s = int(num_sources)
    if num_sources < 1:
        raise ValueError(f"num_sources must be >= 1 ({num_sources})")
    if partition is None:
        partition = "2d" if isinstance(graph, ShardedGraph2D) else "1d"
    if partition not in ("1d", "2d"):
        raise ValueError(f"unknown partition scheme {partition!r}; "
                         "expected '1d' | '2d'")

    if opts.mode == "queue" and num_sources != 1:
        raise ValueError("queue frontier supports a single source "
                         f"(num_sources={num_sources})")
    if opts.use_kernel and opts.mode != "dense":
        # unsupported combos fail loudly instead of silently ignoring the
        # flag: the queue/auto level loops take the segment-scatter
        # expansion paths the kernel does not implement
        raise ValueError(
            f"use_kernel requires mode='dense' (got mode={opts.mode!r}); "
            "the Pallas bsr_spmm expansion has no queue/bottom-up analog")

    if partition == "2d":
        if opts.use_kernel:
            raise ValueError("use_kernel is a 1-D dense path (the blocked "
                             "adjacency is encoded per vertex shard); not "
                             "available with partition='2d'")
        if mesh is None:
            if part.p != 1:
                raise ValueError("pass a 2-axis mesh whose r*c equals the "
                                 f"graph's p={part.p}")
            mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                        ("rows", "cols"))
            axis = ("rows", "cols")
        axes = tuple(axis) if axis is not None else tuple(mesh.axis_names)
        if len(axes) != 2:
            raise ValueError(f"partition='2d' needs exactly two mesh axes "
                             f"(rows, cols); got {axes}")
        r, c = (int(mesh.shape[a]) for a in axes)
        if r * c != part.p:
            raise ValueError(f"mesh grid {r}x{c} does not multiply to the "
                             f"graph's p={part.p}")
        if isinstance(graph, ShardedGraph2D):
            # edge blocks are encoded for one specific grid shape; a
            # transposed/reshaped mesh would compile and silently traverse
            # wrong (gather indices clamp under jit)
            if (part.r, part.c) != (r, c):
                raise ValueError(
                    f"graph's edge blocks are laid out for a "
                    f"{part.r}x{part.c} grid; mesh is {r}x{c}")
            graph2d = graph
        else:
            graph2d = to_2d(graph, r, c)
        grid_args = (graph2d.part.n, r, c, s, 1)
        # sparse models take the plan's frontier density (cap relative to
        # the chunk size) so compressed twins price the same payload the
        # compiled loop ships
        sparse_args = (r, c, opts.queue_cap, 4,
                       opts.queue_cap / graph2d.part.shard_size)
        # the fold strategy resolves first: the fused-tail decision keys
        # off its resolved wire (the fused kernel consumes fold words)
        fold_strategy = _resolve_strategy(
            "fold_col", opts.fold_exchange, grid_args, opts.wire_format)
        return BFSPlan(
            graph=graph, opts=opts, mesh=mesh, axis=axes,
            axes_sizes=(r, c), num_sources=s,
            max_levels=opts.max_levels or part.n_logical,
            partition="2d", graph2d=graph2d,
            expand_strategy=_resolve_strategy(
                "expand_row", opts.expand_exchange, grid_args,
                opts.wire_format),
            fold_strategy=fold_strategy,
            expand_sparse_strategy=_resolve_strategy(
                "expand_row_sparse", opts.expand_sparse_exchange,
                sparse_args, opts.wire_format),
            fold_sparse_strategy=_resolve_strategy(
                "fold_col_sparse", opts.fold_sparse_exchange, sparse_args,
                opts.wire_format),
            bottom_up_wire=_resolve_bottom_up_wire(
                opts.wire_format, graph2d.part.n, part.p, s),
            sieve=_resolve_sieve(opts.sieve, opts.mode, part.p, s),
            use_fused_tail=_resolve_fused_tail(
                opts.use_fused_tail, opts.mode, fold_strategy.wire),
        )

    if isinstance(graph, ShardedGraph2D):
        raise ValueError("partition='1d' needs a 1-D ShardedGraph; this "
                         "graph holds 2-D edge blocks")

    if mesh is None:
        dev = jax.devices()[:1]
        mesh = Mesh(np.asarray(dev).reshape(1), ("bfs_p",))
        axis = "bfs_p"
        if part.p != 1:
            raise ValueError("pass a mesh whose total size equals part.p")
    axis = axis if axis is not None else tuple(mesh.axis_names)
    axis = tuple(axis) if isinstance(axis, (list, tuple)) else axis
    axes = axis if isinstance(axis, tuple) else (axis,)
    axes_sizes = tuple(mesh.shape[a] for a in axes)
    if int(np.prod(axes_sizes)) != part.p:
        raise ValueError(f"mesh axes {axes} of sizes {axes_sizes} do not "
                         f"multiply to the graph's p={part.p}")

    dense_strategy = _resolve_strategy(
        "dense", opts.dense_exchange,
        (part.n, part.p, s, 1, axes_sizes), opts.wire_format)
    return BFSPlan(
        graph=graph, opts=opts, mesh=mesh, axis=axis,
        axes_sizes=axes_sizes, num_sources=s,
        max_levels=opts.max_levels or part.n_logical,
        dense_strategy=dense_strategy,
        queue_strategy=_resolve_strategy(
            "queue", opts.queue_exchange,
            (part.p, opts.queue_cap, 4, opts.queue_cap / part.shard_size),
            opts.wire_format),
        bottom_up_wire=_resolve_bottom_up_wire(
            opts.wire_format, part.n, part.p, s),
        sieve=_resolve_sieve(opts.sieve, opts.mode, part.p, s),
        use_fused_tail=_resolve_fused_tail(
            opts.use_fused_tail, opts.mode, dense_strategy.wire),
    )


# ---------------------------------------------------------------------------
# Engine: AOT-compiled executables + device-resident graph buffers
# ---------------------------------------------------------------------------

class _BlockGroup:
    """Weakref-able holder for one group of uploaded device buffers.

    The per-graph dedup map (``graph._device_blocks``) stores these as
    *weak* values while each engine keeps a strong reference for its
    lifetime: concurrent engines of one graph share a single upload, and
    when the last engine holding a group dies (e.g. evicted from the
    serving ``EngineCache``) the device memory actually frees instead of
    being pinned forever by the graph object.
    """

    __slots__ = ("arrays", "__weakref__")

    def __init__(self, arrays):
        self.arrays = arrays


class BFSEngine:
    """A compiled traversal: run unlimited source sets with device-only work.

    Two AOT executables are built at construction:

      * ``_init_c(sources)``   — scatters the (S,) source vector into fresh
        (n, S) dist/frontier buffers on device.
      * ``_run_c(edges..., dist0, frontier0, valid)`` — the while-loop
        kernel.  ``dist0`` is donated: its (n, S) buffer is reused for the
        output distance matrix, so steady-state traversals allocate no new
        large buffers.  (``frontier0`` is not donated — the kernel has no
        same-shaped uint8 output to alias it to.)

    ``trace_count`` exposes how many times the kernel body has been traced;
    it must not grow across ``run()`` calls (asserted by the test suite).
    """

    def __init__(self, plan_: BFSPlan):
        self.plan = plan_
        _faults.fire("engine.compile", _faults.plan_tag(plan_))
        self._trace_count = 0
        opts, mesh = plan_.opts, plan_.mesh
        s = plan_.num_sources
        axis = plan_.axis

        # The two partition schemes differ only in the per-shard loop body
        # and the edge-block encoding; everything below the dispatch —
        # sharding specs, device buffer cache, AOT compile with the donated
        # dist buffer, on-device source scatter — is shared.
        if plan_.partition == "2d":
            buf_owner = plan_.graph2d
            part = buf_owner.part
            shard_fn = _make_shard_fn_2d(
                part, buf_owner.n_edges, s, axis[0], axis[1], opts,
                plan_.max_levels, plan_.expand_strategy, plan_.fold_strategy,
                plan_.expand_sparse_strategy, plan_.fold_sparse_strategy,
                bottom_up_wire=plan_.bottom_up_wire, sieve=plan_.sieve,
                fused=plan_.use_fused_tail, on_trace=self._bump_trace)
            # only the auto hybrid's bottom-up level reads the in-edge
            # blocks and out-degrees; dense/queue engines neither build
            # nor upload them.  Group names carry the partition kind: a
            # to_2d view shares its parent's device-buffer dict, and the
            # two schemes' "edges" payloads differ.
            edge_groups = [("edges_2d", buf_owner.flat)]
            if opts.mode == "auto":
                edge_groups.append(("bottom_up_2d", buf_owner.bottom_up_flat))
        else:
            buf_owner = plan_.graph
            part = buf_owner.part
            edge_groups = [("edges", buf_owner.flat)]
            expand_fn, expand_packed, n_kernel_args = None, False, 0
            if opts.use_kernel:
                # the per-shard blocked adjacency rides the same sharded
                # upload path as the edge blocks (one more device group)
                expand_fn, expand_packed, kernel_arrays = \
                    self._build_kernel_expand()
                edge_groups.append(("kernel_bsr", kernel_arrays))
                n_kernel_args = 3
            shard_fn = _make_shard_fn(
                part, buf_owner.n_edges, s, axis, plan_.axes_sizes, opts,
                plan_.max_levels, plan_.dense_strategy, plan_.queue_strategy,
                expand_fn=expand_fn, expand_emits_packed=expand_packed,
                n_kernel_args=n_kernel_args,
                bottom_up_wire=plan_.bottom_up_wire, sieve=plan_.sieve,
                fused=plan_.use_fused_tail, on_trace=self._bump_trace)
        n = part.n

        spec_edge = P(axis)
        spec_vert = P(axis, None)
        sh_edge = NamedSharding(mesh, spec_edge)
        sh_vert = NamedSharding(mesh, spec_vert)
        sh_repl = NamedSharding(mesh, P())
        self._sh_repl = sh_repl

        # Graph blocks + validity mask live on device for the engine's
        # lifetime; every run reuses them with zero H2D traffic.  They are
        # deduplicated per (mesh, axis, group) across engines — compiling
        # several option/S/mode variants of one graph must not duplicate
        # its largest buffers (a 2-D auto engine adds only the bottom-up
        # group on top of a dense engine's edge blocks).  The map holds
        # them *weakly* (engines hold the strong refs), so an evicted/
        # dropped engine set releases its device memory.  Engine compiles
        # run from multiple threads (EngineCache.get_or_compile holds no
        # lock while compiling), so the check-then-insert runs under the
        # cache's *per-graph* lock: concurrent engines of one graph
        # cannot upload a group twice, while compiles of unrelated
        # graphs never wait on each other's host bucketing + uploads.
        from repro.graphs.formats import device_block_cache

        self._block_holders = []
        blocks = device_block_cache(buf_owner)
        with blocks.lock:
            dev_cache = blocks.map

            def _cached(group, build):
                holder = dev_cache.get((mesh, axis, group))
                if holder is None:
                    holder = _BlockGroup(build())
                    dev_cache[(mesh, axis, group)] = holder
                self._block_holders.append(holder)
                return holder.arrays

            self._gbufs = ()
            for group, host_arrays in edge_groups:
                # dtype-preserving upload: edge/bottom-up blocks are int32,
                # the kernel group's adjacency tile values are float32
                self._gbufs += _cached(group, lambda ha=host_arrays: tuple(
                    jax.device_put(np.asarray(a), sh_edge)
                    for a in ha()))
            self._valid = _cached("valid", lambda: jax.device_put(
                np.arange(n) < part.n_logical, sh_edge))
        n_edge_in = len(self._gbufs)

        mapped = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(spec_edge,) * n_edge_in + (spec_vert, spec_vert,
                                                 spec_edge),
            out_specs=(spec_vert, P(), P(), P(), P(), P()),
            check_vma=False,
        )

        dist_sds = jax.ShapeDtypeStruct((n, s), jnp.int32, sharding=sh_vert)
        front_sds = jax.ShapeDtypeStruct((n, s), jnp.uint8, sharding=sh_vert)
        src_sds = jax.ShapeDtypeStruct((s,), jnp.int32, sharding=sh_repl)

        self._run_c = jax.jit(mapped, donate_argnums=(n_edge_in,)).lower(
            *self._gbufs, dist_sds, front_sds, self._valid).compile()

        def init_fn(sources):
            self._bump_trace()
            return fr.init_dist_frontier(sources, n, part.n_logical)

        self._init_c = jax.jit(
            init_fn, out_shardings=(sh_vert, sh_vert)).lower(src_sds).compile()

        # Traces spent building the two executables; run() must never add
        # to this (the engine-reuse tests pin trace_count to it).
        self.compile_traces = self._trace_count

    # ------------------------------------------------------------------ misc
    def estimated_device_bytes(self) -> int:
        """Device bytes this engine keeps live (plan-derived estimate;
        what the serving ``EngineCache`` charges against its budget)."""
        return self.plan.estimated_device_bytes()

    def compiled_hlo(self) -> str:
        """Optimized HLO text of the compiled traversal loop.

        What the wire-format benchmark parses (launch/hlo_stats
        ``collective_bytes``) to cross-check the analytic byte models
        against compiler-emitted collective buffer sizes — the measured
        half of the packed-vs-bytes ledger.
        """
        return self._run_c.as_text()

    def _bump_trace(self):
        self._trace_count += 1

    @property
    def trace_count(self) -> int:
        return self._trace_count

    def _build_kernel_expand(self):
        """Pallas bsr_spmm frontier expansion, per shard.

        Each device's 128x128-blocked *transposed* adjacency slice
        (rows = global candidate ids, cols = the shard's local sources;
        candidates = A_shard^T @ f_local on the MXU, boolean semiring via
        sum + >0) travels as a shard_map operand like the edge blocks, so
        ``use_kernel=True`` runs on every shard of the multi-device 1-D
        loop — the old single-shard restriction baked the adjacency into
        the trace as a replicated constant.  With a packed dense wire the
        kernel path emits the per-shard-blocked uint32 candidate words
        directly (``frontier_expand_packed``), so the packed exchange
        consumes them with no separate pack step.

        Returns ``(expand_fn, emits_packed, host_arrays_fn)``;
        ``expand_fn(frontier, blocks_flat, block_rows, block_cols)`` runs
        inside the shard body on that shard's slices.
        """
        from repro.kernels.bsr_spmm import ops as spmm_ops

        graph = self.plan.graph
        part = graph.part
        p, shard, n = part.p, part.shard_size, part.n
        blocks, brs, bcs, row_pad, col_pad = graph.bsr_shards()
        kmax, blk = blocks.shape[1], blocks.shape[2]
        packed = self.plan.dense_strategy.wire == "packed"

        def host_arrays():
            return (blocks.reshape(-1), brs.reshape(-1), bcs.reshape(-1))

        def expand_fn(frontier, kb_flat, kbr, kbc):
            kb = kb_flat.reshape(kmax, blk, blk)
            f = frontier                                   # (shard, S)
            if col_pad > shard:
                f = jnp.pad(f, ((0, col_pad - shard), (0, 0)))
            if packed:
                return spmm_ops.frontier_expand_packed(
                    kb, kbr, kbc, f, n_rows_pad=row_pad, n_valid=n,
                    n_blocks=p)
            cand = spmm_ops.frontier_expand(kb, kbr, kbc, f,
                                            n_rows_pad=row_pad)
            return cand[:n]

        return expand_fn, packed, host_arrays

    # ------------------------------------------------------------------- run
    def run_async(self, sources) -> BFSResult:
        """Dispatch one traversal; returns un-blocked device arrays.

        ``sources`` may hold 1..S vertex ids; unused engine columns stay
        empty (their dist columns are all-INF and are sliced off by
        ``dist_host``).
        """
        s = self.plan.num_sources
        src_arr = validate_sources(sources, self.plan.graph.part.n_logical,
                                   max_sources=s)
        n_req = int(src_arr.shape[0])
        # ids are bounded by n_logical, which must fit the int32 dist/
        # source buffers — guard rather than let numpy wrap silently
        if src_arr.max() > np.iinfo(np.int32).max:
            raise ValueError("source ids exceed int32 range; the engine's "
                             "distance/source buffers are int32")
        padded = np.full((s,), -1, dtype=np.int32)
        padded[:n_req] = src_arr
        _faults.fire("engine.dispatch", _faults.plan_tag(self.plan))
        src_dev = jax.device_put(padded, self._sh_repl)

        dist0, frontier0 = self._init_c(src_dev)
        dist, levels, comm_bytes, overflowed, modes, sieve_hits = self._run_c(
            *self._gbufs, dist0, frontier0, self._valid)
        return BFSResult(
            dist=dist,
            run_stats=BFSRunStats(levels=levels, comm_bytes=comm_bytes,
                                  overflowed=overflowed, mode_counts=modes,
                                  sieve_hits=sieve_hits),
            n_logical=self.plan.graph.part.n_logical,
            n_sources=n_req,
        )

    def run(self, sources) -> BFSResult:
        """Run one traversal to completion (blocks until device work ends)."""
        return self.run_async(sources).block()
