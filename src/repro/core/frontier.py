"""Frontier representations and the send-buffer builder (paper fig. 2).

Two statically-shaped frontier representations:

  * dense bitmap — (shard, S) mask; expansion scatters into a full-length
    (n+1, S) candidate mask.  TPU-native: expansion is a gather + scatter-
    max (or the blocked MXU kernel), and the exchange is a fixed-size
    collective.  Best when the frontier is a large fraction of V.

  * sparse queue — the paper's per-destination buffers (``tBuf_{ij}`` /
    ``SendBuf_j``, fig. 2 lines 8-19): a (p, cap) block of candidate global
    vertex ids bucketed by owner.  Payload scales with the frontier, not
    with n.  Best for the narrow first/last BFS levels.

``build_queue_buckets`` implements the paper's §5.1 optimization (1): with
``local_update=True``, candidates owned by the computing shard are applied
straight to the local bitmap and *excluded* from the send buffers ("added
conditional check to see if current processor is owner ... resulted into
relatively lower buffer size").
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.partition import Partition1D

INF = jnp.int32(2 ** 30)  # unreached sentinel (shared with bfs/engine/ref)


def init_dist_frontier(sources: jnp.ndarray, n: int, n_logical: int):
    """Device-side source injection: scatter an ``(S,)`` int32 id vector
    into fresh ``(n, S)`` distance / frontier-bitmap arrays.

    Slots with ``sources[j] < 0`` (or >= n_logical) are *empty* — their
    column stays all-INF / all-zero and terminates immediately.  Because
    the scatter runs under jit from a traced operand, a compiled BFS
    engine accepts arbitrary new source sets with zero retraces and no
    host-side (n, S) materialization.
    """
    s = sources.shape[0]
    cols = jnp.arange(s)
    ok = (sources >= 0) & (sources < n_logical)
    idx = jnp.clip(sources, 0, n - 1)
    # min/max scatters are no-ops for masked-off slots even when their
    # clipped indices collide with a live source's row.
    dist0 = jnp.full((n, s), INF, jnp.int32).at[idx, cols].min(
        jnp.where(ok, jnp.int32(0), INF))
    frontier0 = jnp.zeros((n, s), jnp.uint8).at[idx, cols].max(
        ok.astype(jnp.uint8))
    return dist0, frontier0


def expand_dense(frontier: jnp.ndarray, src_local: jnp.ndarray,
                 dst_global: jnp.ndarray, n: int) -> jnp.ndarray:
    """Top-down edge expansion into a full-length candidate mask.

    frontier: (shard, S) uint8.  src_local/dst_global: (E,) int32 padded
    COO (dst -1 = padding).  Returns (n, S) uint8 candidates.
    """
    valid = dst_global >= 0
    fvals = frontier[src_local] * valid[:, None].astype(frontier.dtype)  # (E, S)
    idx = jnp.where(valid, dst_global, n)
    cand = jnp.zeros((n + 1, frontier.shape[1]), dtype=frontier.dtype)
    cand = cand.at[idx].max(fvals)
    return cand[:n]


def expand_dense_2d(frontier_row: jnp.ndarray, src_rowlocal: jnp.ndarray,
                    dst_fold: jnp.ndarray, fold_len: int) -> jnp.ndarray:
    """2-D edge expansion into the *transposed* fold-phase layout.

    frontier_row: (c*b, S) uint8 — this grid row's frontier segment (the
    expand-phase allgather output).  src_rowlocal/dst_fold: (E,) int32
    padded COO local to this device's adjacency block; ``dst_fold`` indexes
    candidates as ``row_rank(owner(dst)) * b + local_id(dst)`` (-1 =
    padding) so the column all-to-all of the fold phase delivers each
    length-``b`` slice straight to its owner.  Returns (fold_len, S) uint8
    with ``fold_len = r*b``.
    """
    valid = dst_fold >= 0
    fvals = frontier_row[src_rowlocal] * valid[:, None].astype(
        frontier_row.dtype)                                        # (E, S)
    idx = jnp.where(valid, dst_fold, fold_len)
    cand = jnp.zeros((fold_len + 1, frontier_row.shape[1]),
                     dtype=frontier_row.dtype)
    cand = cand.at[idx].max(fvals)
    return cand[:fold_len]


def expand_bottom_up(frontier_global: jnp.ndarray, in_src_global: jnp.ndarray,
                     in_dst_local: jnp.ndarray, shard: int) -> jnp.ndarray:
    """Bottom-up: each local vertex checks whether any in-neighbor is in
    the (replicated) frontier.  Returns (shard, S) uint8 candidates."""
    valid = in_src_global >= 0
    src = jnp.where(valid, in_src_global, 0)
    vals = frontier_global[src] * valid[:, None].astype(frontier_global.dtype)
    idx = jnp.where(valid, in_dst_local, shard)
    cand = jnp.zeros((shard + 1, frontier_global.shape[1]),
                     dtype=frontier_global.dtype)
    cand = cand.at[idx].max(vals)
    return cand[:shard]


def build_queue_buckets(dst_global: jnp.ndarray, active: jnp.ndarray,
                        part: Partition1D, me: jnp.ndarray, cap: int,
                        local_update: bool = True, dedupe: bool = True):
    """Pack active edge targets into per-owner send buffers.

    dst_global: (E,) int32 targets; active: (E,) bool (source in frontier
    and edge valid).  Returns:
      buckets:   (p, cap) int32 global ids, -1 padded — ``SendBuf_j``.
      local_mask:(shard,) uint8 — candidates applied locally (opt 5.1-1);
                 all-zero when ``local_update=False`` (they go in buckets).
      n_sent:    () int32 — total ids placed in send buffers (for stats).
      overflow:  () bool — some bucket exceeded cap (caller escalates to
                 the dense representation).
    """
    p, shard = part.p, part.shard_size
    e = dst_global.shape[0]
    owner = jnp.where(active, dst_global // shard, p)

    if dedupe:
        # Drop duplicate targets before they hit the wire: sort by target,
        # keep first occurrence.  (Beyond-paper: the paper ships dupes and
        # dedupes at the owner via the d[u]=inf check.)
        tgt = jnp.where(active, dst_global, jnp.int32(part.n + 1))
        order = jnp.argsort(tgt)
        sorted_tgt = tgt[order]
        first = jnp.concatenate([jnp.array([True]),
                                 sorted_tgt[1:] != sorted_tgt[:-1]])
        keep = jnp.zeros((e,), bool).at[order].set(first)
        owner = jnp.where(keep, owner, p)

    local_mask = jnp.zeros((shard,), jnp.uint8)
    if local_update:
        mine = owner == me
        lid = jnp.where(mine, dst_global - me * shard, shard)
        local_mask = jnp.zeros((shard + 1,), jnp.uint8).at[lid].max(
            mine.astype(jnp.uint8))[:shard]
        owner = jnp.where(mine, p, owner)

    # Stable bucket packing: sort edges by owner, rank within bucket.
    sort_idx = jnp.argsort(owner)                      # (E,)
    owner_s = owner[sort_idx]
    dst_s = dst_global[sort_idx]
    starts = jnp.searchsorted(owner_s, jnp.arange(p + 1))  # bucket offsets
    rank = jnp.arange(e) - starts[jnp.clip(owner_s, 0, p)]
    sendable = owner_s < p
    in_cap = sendable & (rank < cap)
    slot = jnp.where(in_cap, owner_s * cap + rank, p * cap)
    buf = jnp.full((p * cap + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(in_cap, dst_s, -1).astype(jnp.int32))
    buckets = buf[: p * cap].reshape(p, cap)
    n_sent = in_cap.sum().astype(jnp.int32)
    overflow = (sendable & (rank >= cap)).any()
    return buckets, local_mask, n_sent, overflow


def apply_queue(recv: jnp.ndarray, me: jnp.ndarray, shard: int) -> jnp.ndarray:
    """Scatter received global ids into this shard's candidate bitmap."""
    flat = recv.reshape(-1)
    lid = flat - me * shard
    valid = (flat >= 0) & (lid >= 0) & (lid < shard)  # drop pads/foreign ids
    lid = jnp.where(valid, lid, shard)
    mask = jnp.zeros((shard + 1,), jnp.uint8).at[lid].max(
        valid.astype(jnp.uint8))
    return mask[:shard]


def frontier_nonzero(frontier: jnp.ndarray) -> jnp.ndarray:
    return frontier.max() > 0
