"""Frontier representations and the send-buffer builder (paper fig. 2).

Two statically-shaped frontier representations:

  * dense bitmap — (shard, S) mask; expansion scatters into a full-length
    (n+1, S) candidate mask.  TPU-native: expansion is a gather + scatter-
    max (or the blocked MXU kernel), and the exchange is a fixed-size
    collective.  Best when the frontier is a large fraction of V.

  * sparse queue — the paper's per-destination buffers (``tBuf_{ij}`` /
    ``SendBuf_j``, fig. 2 lines 8-19): a (p, cap) block of candidate global
    vertex ids bucketed by owner.  Payload scales with the frontier, not
    with n.  Best for the narrow first/last BFS levels.

``build_queue_buckets`` implements the paper's §5.1 optimization (1): with
``local_update=True``, candidates owned by the computing shard are applied
straight to the local bitmap and *excluded* from the send buffers ("added
conditional check to see if current processor is owner ... resulted into
relatively lower buffer size").

The 2-D edge partition reuses both representations per phase:
``pack_frontier_ids``/``unpack_row_frontier`` make the expand-phase row
allgather sparse (ship active ids, not the bitmap), and
``build_queue_buckets_2d`` buckets fold-layout candidates by column-owner
row rank — the §5.1 local-update exclusion and dense-escalation-on-
overflow contracts carry over unchanged.

``pack_bits``/``unpack_bits`` are the *packed-bitset* wire format of the
dense phases (Lv et al.'s "Compression and Sieve", Buluç & Madduri's
word-packed frontiers): 32 mask bytes collapse into one ``uint32`` word,
so every dense collective ships 8× fewer bytes and merges with bitwise
OR instead of a byte-wise max.  Packing is *blocked* — each owner's
segment packs into its own ``ceil(m/32)`` words — so block boundaries
stay word-aligned for any shard size and the per-shard slices of the
collectives (all-to-all splits, allgather offsets) remain static.  The
pad bits of a block's last word are zero by construction and OR-merges
preserve zeros, so padding can never leak a phantom candidate across the
merge (regression-pinned in tests/test_wire_format.py).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.partition import Partition1D

INF = jnp.int32(2 ** 30)  # unreached sentinel (shared with bfs/engine/ref)


def init_dist_frontier(sources: jnp.ndarray, n: int, n_logical: int):
    """Device-side source injection: scatter an ``(S,)`` int32 id vector
    into fresh ``(n, S)`` distance / frontier-bitmap arrays.

    Slots with ``sources[j] < 0`` (or >= n_logical) are *empty* — their
    column stays all-INF / all-zero and terminates immediately.  Because
    the scatter runs under jit from a traced operand, a compiled BFS
    engine accepts arbitrary new source sets with zero retraces and no
    host-side (n, S) materialization.
    """
    s = sources.shape[0]
    cols = jnp.arange(s)
    ok = (sources >= 0) & (sources < n_logical)
    idx = jnp.clip(sources, 0, n - 1)
    # min/max scatters are no-ops for masked-off slots even when their
    # clipped indices collide with a live source's row.
    dist0 = jnp.full((n, s), INF, jnp.int32).at[idx, cols].min(
        jnp.where(ok, jnp.int32(0), INF))
    frontier0 = jnp.zeros((n, s), jnp.uint8).at[idx, cols].max(
        ok.astype(jnp.uint8))
    return dist0, frontier0


def expand_dense(frontier: jnp.ndarray, src_local: jnp.ndarray,
                 dst_global: jnp.ndarray, n: int) -> jnp.ndarray:
    """Top-down edge expansion into a full-length candidate mask.

    frontier: (shard, S) uint8.  src_local/dst_global: (E,) int32 padded
    COO (dst -1 = padding).  Returns (n, S) uint8 candidates.
    """
    valid = dst_global >= 0
    fvals = frontier[src_local] * valid[:, None].astype(frontier.dtype)  # (E, S)
    idx = jnp.where(valid, dst_global, n)
    cand = jnp.zeros((n + 1, frontier.shape[1]), dtype=frontier.dtype)
    cand = cand.at[idx].max(fvals)
    return cand[:n]


def expand_dense_2d(frontier_row: jnp.ndarray, src_rowlocal: jnp.ndarray,
                    dst_fold: jnp.ndarray, fold_len: int) -> jnp.ndarray:
    """2-D edge expansion into the *transposed* fold-phase layout.

    frontier_row: (c*b, S) uint8 — this grid row's frontier segment (the
    expand-phase allgather output).  src_rowlocal/dst_fold: (E,) int32
    padded COO local to this device's adjacency block; ``dst_fold`` indexes
    candidates as ``row_rank(owner(dst)) * b + local_id(dst)`` (-1 =
    padding) so the column all-to-all of the fold phase delivers each
    length-``b`` slice straight to its owner.  Returns (fold_len, S) uint8
    with ``fold_len = r*b``.
    """
    valid = dst_fold >= 0
    fvals = frontier_row[src_rowlocal] * valid[:, None].astype(
        frontier_row.dtype)                                        # (E, S)
    idx = jnp.where(valid, dst_fold, fold_len)
    cand = jnp.zeros((fold_len + 1, frontier_row.shape[1]),
                     dtype=frontier_row.dtype)
    cand = cand.at[idx].max(fvals)
    return cand[:fold_len]


# ---------------------------------------------------------------------------
# Packed-bitset wire format (dense phases)
# ---------------------------------------------------------------------------

def packed_words(n_bits: int) -> int:
    """Words needed to hold ``n_bits`` mask bits (ceil(n_bits / 32))."""
    return -(-n_bits // 32)


def pack_bits(mask: jnp.ndarray, n_blocks: int = 1) -> jnp.ndarray:
    """Pack a ``(n_blocks * m, S)`` 0/1 mask into ``(n_blocks * W, S)``
    uint32 words, ``W = ceil(m / 32)``.

    Each length-``m`` block packs independently (bit ``i`` of word
    ``b*W + i//32`` is row ``b*m + i``), so block boundaries are always
    word-aligned regardless of ``m % 32`` — the per-owner slices of a
    packed collective stay static.  A block's trailing pad bits are zero.
    """
    total, s = mask.shape
    m = total // n_blocks
    assert m * n_blocks == total, (total, n_blocks)
    w = packed_words(m)
    x = (mask > 0).astype(jnp.uint32).reshape(n_blocks, m, s)
    if w * 32 != m:
        x = jnp.pad(x, ((0, 0), (0, w * 32 - m), (0, 0)))
    x = x.reshape(n_blocks, w, 32, s)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    words = (x << shifts[None, None, :, None]).sum(axis=2, dtype=jnp.uint32)
    return words.reshape(n_blocks * w, s)


def unpack_bits(words: jnp.ndarray, m: int, n_blocks: int = 1) -> jnp.ndarray:
    """Inverse of ``pack_bits``: ``(n_blocks * W, S)`` uint32 words back to
    a ``(n_blocks * m, S)`` uint8 0/1 mask.  Each block's trailing pad
    bits (rows ``m .. W*32``) are dropped, never surfaced as vertices.
    """
    total_w, s = words.shape
    w = total_w // n_blocks
    assert w * n_blocks == total_w, (total_w, n_blocks)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words.reshape(n_blocks, w, 1, s) >> shifts[None, None, :, None]
            ) & jnp.uint32(1)
    bits = bits.reshape(n_blocks, w * 32, s)[:, :m, :]
    return bits.reshape(n_blocks * m, s).astype(jnp.uint8)


def expand_dense_2d_packed(frontier_words: jnp.ndarray,
                           src_rowlocal: jnp.ndarray,
                           dst_fold: jnp.ndarray, fold_len: int,
                           m: int) -> jnp.ndarray:
    """2-D top-down expansion straight from the *packed* row frontier.

    ``frontier_words`` is the expand-phase allgather output kept packed:
    ``(c * W, S)`` uint32, block ``k`` = row peer ``k``'s ``pack_bits``
    output over its ``m``-vertex chunk (``W = packed_words(m)``).  Each
    edge gathers one word and extracts its source's bit, so the
    ``(c*b, S)`` row-frontier byte mask is never materialized between the
    collective and the edge scatter — the fused-tail twin of
    ``expand_bottom_up_packed`` for the expand phase.  Output matches
    ``expand_dense_2d(unpack_bits(frontier_words, m, c), ...)`` bitwise.
    """
    valid = dst_fold >= 0
    src = jnp.where(valid, src_rowlocal, 0)
    blk = src // m
    loc = src - blk * m
    widx = blk * packed_words(m) + loc // 32
    wvals = frontier_words[widx]                               # (E, S)
    bit = (loc % 32).astype(jnp.uint32)
    vals = ((wvals >> bit[:, None]) & jnp.uint32(1)).astype(jnp.uint8)
    vals = vals * valid[:, None].astype(jnp.uint8)
    idx = jnp.where(valid, dst_fold, fold_len)
    cand = jnp.zeros((fold_len + 1, frontier_words.shape[1]),
                     jnp.uint8).at[idx].max(vals)
    return cand[:fold_len]


def expand_bottom_up_packed(frontier_words: jnp.ndarray,
                            in_src_global: jnp.ndarray,
                            in_dst_local: jnp.ndarray, shard: int,
                            words_per_block: int) -> jnp.ndarray:
    """Bottom-up expansion straight from the *packed* replicated frontier.

    ``frontier_words`` is the allgather of every shard's packed frontier
    (``(p * W, S)`` uint32, block ``k`` = shard ``k``'s ``pack_bits``
    output).  Each in-edge gathers one word and extracts its source's bit
    — the ``(n, S)`` byte mask is never materialized, so the 8× wire
    saving of the packed gather is not given back to an unpack.  Same
    both-endpoints masking contract as ``expand_bottom_up``.
    """
    valid = ((in_src_global >= 0)
             & (in_dst_local >= 0) & (in_dst_local < shard))
    src = jnp.where(valid, in_src_global, 0)
    blk = src // shard
    loc = src - blk * shard
    widx = blk * words_per_block + loc // 32
    wvals = frontier_words[widx]                               # (E, S)
    bit = (loc % 32).astype(jnp.uint32)
    vals = ((wvals >> bit[:, None]) & jnp.uint32(1)).astype(jnp.uint8)
    vals = vals * valid[:, None].astype(jnp.uint8)
    idx = jnp.where(valid, in_dst_local, shard)
    cand = jnp.zeros((shard + 1, frontier_words.shape[1]),
                     jnp.uint8).at[idx].max(vals)
    return cand[:shard]


def expand_bottom_up(frontier_global: jnp.ndarray, in_src_global: jnp.ndarray,
                     in_dst_local: jnp.ndarray, shard: int) -> jnp.ndarray:
    """Bottom-up: each local vertex checks whether any in-neighbor is in
    the (replicated) frontier.  Returns (shard, S) uint8 candidates.

    An in-edge is live only when *both* endpoints are in range: a padded
    slot whose destination is the ``-1`` sentinel but whose source field
    happens to hold a valid id would otherwise wrap (``.at[-1]``) and
    scatter into the shard's last row — regression-pinned in
    tests/test_core_bfs.py.
    """
    valid = ((in_src_global >= 0)
             & (in_dst_local >= 0) & (in_dst_local < shard))
    src = jnp.where(valid, in_src_global, 0)
    vals = frontier_global[src] * valid[:, None].astype(frontier_global.dtype)
    idx = jnp.where(valid, in_dst_local, shard)
    cand = jnp.zeros((shard + 1, frontier_global.shape[1]),
                     dtype=frontier_global.dtype)
    cand = cand.at[idx].max(vals)
    return cand[:shard]


def _dedupe_owner(ids: jnp.ndarray, active: jnp.ndarray, owner: jnp.ndarray,
                  sentinel: int, n_owners: int) -> jnp.ndarray:
    """Mask ``owner`` to ``n_owners`` for every duplicate active id.

    Drop duplicate targets before they hit the wire: sort by target, keep
    first occurrence.  (Beyond-paper: the paper ships dupes and dedupes at
    the owner via the d[u]=inf check.)  ``sentinel`` must be the *padded*
    id-space size: every storable id is strictly below it, so it can never
    collide with a padding id at the last shard boundary — the old
    ``padded_size + 1`` sentinel also sat outside the id range but
    overflows int32 when the padded size is itself ``INT32_MAX``
    (regression-pinned in tests/test_partition_and_registry.py).
    """
    e = ids.shape[0]
    tgt = jnp.where(active, ids, jnp.int32(sentinel))
    order = jnp.argsort(tgt)
    sorted_tgt = tgt[order]
    first = jnp.concatenate([jnp.array([True]),
                             sorted_tgt[1:] != sorted_tgt[:-1]])
    keep = jnp.zeros((e,), bool).at[order].set(first)
    return jnp.where(keep, owner, n_owners)


def _pack_buckets(ids: jnp.ndarray, owner: jnp.ndarray, n_owners: int,
                  cap: int):
    """Stable bucket packing: sort ids by owner, rank within bucket.

    ``owner[k] == n_owners`` marks id ``k`` unsendable (inactive, deduped
    or locally applied).  Returns ((n_owners, cap) int32 buckets -1 padded,
    () int32 sent count, () bool overflow).
    """
    e = ids.shape[0]
    sort_idx = jnp.argsort(owner)                      # (E,)
    owner_s = owner[sort_idx]
    ids_s = ids[sort_idx]
    starts = jnp.searchsorted(owner_s, jnp.arange(n_owners + 1))
    rank = jnp.arange(e) - starts[jnp.clip(owner_s, 0, n_owners)]
    sendable = owner_s < n_owners
    in_cap = sendable & (rank < cap)
    slot = jnp.where(in_cap, owner_s * cap + rank, n_owners * cap)
    buf = jnp.full((n_owners * cap + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(in_cap, ids_s, -1).astype(jnp.int32))
    buckets = buf[: n_owners * cap].reshape(n_owners, cap)
    n_sent = in_cap.sum().astype(jnp.int32)
    overflow = (sendable & (rank >= cap)).any()
    return buckets, n_sent, overflow


def build_queue_buckets(dst_global: jnp.ndarray, active: jnp.ndarray,
                        part: Partition1D, me: jnp.ndarray, cap: int,
                        local_update: bool = True, dedupe: bool = True):
    """Pack active edge targets into per-owner send buffers.

    dst_global: (E,) int32 targets; active: (E,) bool (source in frontier
    and edge valid).  Returns:
      buckets:   (p, cap) int32 global ids, -1 padded — ``SendBuf_j``.
      local_mask:(shard,) uint8 — candidates applied locally (opt 5.1-1);
                 all-zero when ``local_update=False`` (they go in buckets).
      n_sent:    () int32 — total ids placed in send buffers (for stats).
      overflow:  () bool — some bucket exceeded cap (caller escalates to
                 the dense representation).
    """
    p, shard = part.p, part.shard_size
    owner = jnp.where(active, dst_global // shard, p)

    if dedupe:
        owner = _dedupe_owner(dst_global, active, owner, part.n, p)

    local_mask = jnp.zeros((shard,), jnp.uint8)
    if local_update:
        mine = owner == me
        lid = jnp.where(mine, dst_global - me * shard, shard)
        local_mask = jnp.zeros((shard + 1,), jnp.uint8).at[lid].max(
            mine.astype(jnp.uint8))[:shard]
        owner = jnp.where(mine, p, owner)

    buckets, n_sent, overflow = _pack_buckets(dst_global, owner, p, cap)
    return buckets, local_mask, n_sent, overflow


def build_queue_buckets_2d(dst_fold: jnp.ndarray, active: jnp.ndarray,
                           part2, me_row: jnp.ndarray, cap: int,
                           local_update: bool = True, dedupe: bool = True):
    """2-D analog of ``build_queue_buckets`` in the fold layout.

    Buckets active candidate targets by *column-owner row rank*
    (``dst_fold // b``): bucket ``rr`` travels down this device's grid
    column to the device at row rank ``rr``, which owns exactly the fold
    slice ``[rr*b, (rr+1)*b)``.  The §5.1 local-update exclusion applies
    with the device's own row rank (targets this device owns skip the
    wire); the dedupe sentinel is the padded fold-layout size ``r*b``
    (strictly above every storable fold index).  Returns
    (buckets (r, cap) int32 fold ids -1 padded, local_mask (b,) uint8,
    n_sent () int32, overflow () bool).
    """
    r, b = part2.r, part2.shard_size
    owner = jnp.where(active, dst_fold // b, r)

    if dedupe:
        owner = _dedupe_owner(dst_fold, active, owner, part2.fold_size, r)

    local_mask = jnp.zeros((b,), jnp.uint8)
    if local_update:
        mine = owner == me_row
        lid = jnp.where(mine, dst_fold - me_row * b, b)
        local_mask = jnp.zeros((b + 1,), jnp.uint8).at[lid].max(
            mine.astype(jnp.uint8))[:b]
        owner = jnp.where(mine, r, owner)

    buckets, n_sent, overflow = _pack_buckets(dst_fold, owner, r, cap)
    return buckets, local_mask, n_sent, overflow


def pack_frontier_ids(frontier: jnp.ndarray, cap: int):
    """Pack the active local frontier (single-source column) into a
    fixed-capacity id buffer for the sparse expand phase.

    frontier: (shard, 1) uint8.  Returns (ids (cap,) int32 local ids -1
    padded, count () int32, overflow () bool — more active vertices than
    ``cap``; the caller escalates the level to the dense representation).
    """
    shard = frontier.shape[0]
    act = frontier[:, 0] > 0
    lid = jnp.where(act, jnp.arange(shard), shard)
    if cap > shard:
        lid = jnp.concatenate(
            [lid, jnp.full((cap - shard,), shard, lid.dtype)])
    packed = jnp.sort(lid)[:cap]                 # active ids sort first
    ids = jnp.where(packed < shard, packed, -1).astype(jnp.int32)
    count = act.sum(dtype=jnp.int32)
    overflow = count > cap
    return ids, count, overflow


def unpack_row_frontier(all_ids: jnp.ndarray, c: int,
                        shard: int) -> jnp.ndarray:
    """Rebuild a grid row's frontier bitmap from c gathered id buffers.

    all_ids: (c*cap,) int32 — the row allgather of every row peer's
    ``pack_frontier_ids`` buffer, segment ``j`` holding local ids of the
    chunk at grid column ``j``.  Returns (c*shard, 1) uint8 — the same
    row-block layout ``expand_dense_2d`` consumes.
    """
    cap = all_ids.shape[0] // c
    seg = jnp.repeat(jnp.arange(c), cap)
    ok = (all_ids >= 0) & (all_ids < shard)
    pos = jnp.where(ok, all_ids + seg * shard, c * shard)
    frow = jnp.zeros((c * shard + 1,), jnp.uint8).at[pos].max(
        ok.astype(jnp.uint8))
    return frow[: c * shard][:, None]


def apply_queue(recv: jnp.ndarray, me: jnp.ndarray, shard: int) -> jnp.ndarray:
    """Scatter received global ids into this shard's candidate bitmap."""
    flat = recv.reshape(-1)
    lid = flat - me * shard
    valid = (flat >= 0) & (lid >= 0) & (lid < shard)  # drop pads/foreign ids
    lid = jnp.where(valid, lid, shard)
    mask = jnp.zeros((shard + 1,), jnp.uint8).at[lid].max(
        valid.astype(jnp.uint8))
    return mask[:shard]


def frontier_nonzero(frontier: jnp.ndarray) -> jnp.ndarray:
    return frontier.max() > 0


# ---------------------------------------------------------------------------
# Compressed sparse-id wire format (delta + varint, bitmap-adaptive)
# ---------------------------------------------------------------------------
# Sparse phases ship vertex *ids*; sorted ids delta-encode to small gaps
# and gaps varint-encode to ~1 byte each on typical frontiers ("Compression
# and Sieve", Lv et al.) — 4x fewer bytes than raw int32 before the ids
# even thin out.  Buffers stay statically shaped: a fixed byte capacity
# priced by ``compressed_capacity``, an overflow flag escalating to dense
# (the same predicate contract as the id-capacity overflow), and a
# bitmap-mode rescue when the whole id range packs smaller than the ids.

def varint_len(value: int) -> int:
    """Host-side: bytes a base-128 varint needs for ``value`` (>= 0)."""
    v = int(value)
    return (1 + (v >= 1 << 7) + (v >= 1 << 14) + (v >= 1 << 21)
            + (v >= 1 << 28))


def compressed_capacity(cap: int, id_range: int) -> int:
    """Static byte size of one compressed buffer for ``cap`` ids drawn
    from ``[0, id_range)``.

    The varint stream is sized for deltas averaging *twice* the uniform
    spacing (``2 * id_range / cap`` — headroom for clustering) plus a
    4-byte header and slack; burstier levels raise the overflow flag
    and escalate to dense.  When the packed bitset of the whole range
    is smaller than that, the buffer shrinks to bitset size instead —
    ids *lose* to the bitmap at high density, and a bitmap-capacity
    buffer can always represent any id set, so that regime is
    overflow-free.  Byte models price exactly this number, keeping
    modeled and shipped bytes equal by construction.
    """
    avg2 = max(1, (2 * max(1, id_range)) // max(1, cap))
    varint_cap = cap * varint_len(avg2) + 8
    bitmap_cap = 4 + 4 * packed_words(max(1, id_range))
    return min(varint_cap, bitmap_cap)


def _le_bytes(word: jnp.ndarray) -> jnp.ndarray:
    """() uint32 -> (4,) uint8 little-endian."""
    shifts = jnp.uint32(8) * jnp.arange(4, dtype=jnp.uint32)
    return ((word >> shifts) & jnp.uint32(0xFF)).astype(jnp.uint8)


def encode_delta_varint(ids: jnp.ndarray, byte_cap: int, id_range: int):
    """Encode a -1-padded id buffer into a fixed-size compressed payload.

    ids: (cap,) int32, valid entries in ``[0, id_range)``, -1 = padding,
    any order (bucket packing is owner-stable, not id-sorted — the ids
    are sorted here).  Returns ``(buf (byte_cap,) uint8, overflow ()
    bool)``.

    Layout: a 4-byte little-endian header word (bits 0-30 = id count,
    bit 31 = bitmap mode), then either the sorted ids' delta stream as
    LSB-first base-128 varints (high bit = continuation, <= 5 bytes per
    delta for ids < 2^30) or, in bitmap mode, the range's packed bitset
    words serialized LE.  Bitmap mode engages when it statically fits
    ``byte_cap`` and the varint stream runs longer; ``overflow`` is
    True only when the varints spill *and* no bitmap slot exists.
    """
    cap = ids.shape[0]
    valid = (ids >= 0) & (ids < id_range)
    count = valid.sum(dtype=jnp.int32)
    key = jnp.where(valid, ids, jnp.int32(id_range))
    srt = jnp.sort(key)
    k = jnp.arange(cap)
    live = k < count
    prev = jnp.where(k > 0, srt[jnp.maximum(k - 1, 0)], 0)
    delta = jnp.where(live, srt - prev, 0).astype(jnp.uint32)

    nlen = (jnp.int32(1)
            + (delta >= jnp.uint32(1 << 7)).astype(jnp.int32)
            + (delta >= jnp.uint32(1 << 14)).astype(jnp.int32)
            + (delta >= jnp.uint32(1 << 21)).astype(jnp.int32)
            + (delta >= jnp.uint32(1 << 28)).astype(jnp.int32))
    nlen = jnp.where(live, nlen, 0)
    off = jnp.cumsum(nlen) - nlen                      # exclusive
    total = 4 + nlen.sum()
    varint_ovf = total > byte_cap

    # slot k's group j (j < nlen[k]) lands at byte 4 + off[k] + j; spilled
    # or dead bytes divert to the dump slot at index byte_cap
    j = jnp.arange(5)
    emit = j[None, :] < nlen[:, None]                               # (cap, 5)
    grp = ((delta[:, None] >> (jnp.uint32(7) * j[None, :].astype(jnp.uint32)))
           & jnp.uint32(0x7F))
    cont = j[None, :] < (nlen - 1)[:, None]
    payload_bytes = jnp.where(cont, grp | jnp.uint32(0x80), grp)
    payload_bytes = jnp.where(emit, payload_bytes, 0).astype(jnp.uint8)
    pos = 4 + off[:, None] + j[None, :]
    pos = jnp.where(emit & (pos < byte_cap), pos, byte_cap)
    buf = jnp.zeros((byte_cap + 1,), jnp.uint8).at[pos.reshape(-1)].max(
        payload_bytes.reshape(-1))[:byte_cap]

    hdr = count.astype(jnp.uint32)
    w = packed_words(id_range)
    if 4 + 4 * w <= byte_cap:                # bitmap rescue statically fits
        mask = jnp.zeros((id_range + 1,), jnp.uint8).at[key].max(
            valid.astype(jnp.uint8))[:id_range]
        words = pack_bits(mask[:, None])[:, 0]                     # (w,)
        shifts = jnp.uint32(8) * jnp.arange(4, dtype=jnp.uint32)
        wbytes = ((words[:, None] >> shifts[None, :])
                  & jnp.uint32(0xFF)).astype(jnp.uint8).reshape(-1)
        bbuf = jnp.zeros((byte_cap,), jnp.uint8).at[4:4 + 4 * w].set(wbytes)
        use_bitmap = total > 4 + 4 * w
        buf = jnp.where(use_bitmap, bbuf, buf)
        hdr = hdr | (use_bitmap.astype(jnp.uint32) << 31)
        overflow = jnp.zeros((), bool)       # bitmap always representable
    else:
        overflow = varint_ovf
    return buf.at[:4].set(_le_bytes(hdr)), overflow


def decode_delta_varint(buf: jnp.ndarray, cap: int, id_range: int):
    """Inverse of ``encode_delta_varint``: (byte_cap,) uint8 payload ->
    (cap,) int32 sorted ids, -1 padded at the tail.

    Trailing zero bytes would decode as phantom zero-delta groups; the
    header count masks everything past the real ids to -1.
    """
    byte_cap = buf.shape[0]
    shifts = jnp.uint32(8) * jnp.arange(4, dtype=jnp.uint32)
    hdr = (buf[:4].astype(jnp.uint32) << shifts).sum(dtype=jnp.uint32)
    count = (hdr & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
    use_bitmap = (hdr >> 31) > 0
    data = buf[4:]
    d = data.shape[0]

    # group index per byte = exclusive count of terminators (high bit 0)
    # before it; within-group position from the previous terminator
    term = (data & jnp.uint8(0x80)) == 0
    g = jnp.cumsum(term.astype(jnp.int32)) - term.astype(jnp.int32)
    idx = jnp.arange(d)
    startm = lax.cummax(jnp.where(term, idx + 1, 0))
    start = jnp.concatenate([jnp.zeros((1,), startm.dtype), startm[:-1]])
    within = idx - start
    contrib = jnp.where(
        within <= 4,
        (data.astype(jnp.uint32) & jnp.uint32(0x7F))
        << (jnp.uint32(7) * jnp.minimum(within, 4).astype(jnp.uint32)),
        jnp.uint32(0))
    deltas = jnp.zeros((cap + 1,), jnp.uint32).at[jnp.minimum(g, cap)].add(
        contrib)[:cap]
    acc = jnp.cumsum(deltas.astype(jnp.int32))
    k = jnp.arange(cap)
    ids_varint = jnp.where(k < count, acc, -1).astype(jnp.int32)

    w = packed_words(id_range)
    if 4 + 4 * w <= byte_cap:                # bitmap mode statically possible
        wraw = data[: 4 * w].astype(jnp.uint32).reshape(w, 4)
        words = (wraw << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)
        mask = unpack_bits(words[:, None], id_range)[:, 0]
        lid = jnp.where(mask > 0, jnp.arange(id_range), id_range)
        if cap > id_range:
            lid = jnp.concatenate(
                [lid, jnp.full((cap - id_range,), id_range, lid.dtype)])
        packed = jnp.sort(lid)[:cap]
        ids_bitmap = jnp.where(packed < id_range, packed, -1).astype(jnp.int32)
        return jnp.where(use_bitmap, ids_bitmap, ids_varint)
    return ids_varint


# ---------------------------------------------------------------------------
# Visited sieve: replicated coarse visited summary ("Compression and Sieve")
# ---------------------------------------------------------------------------

SIEVE_MAX_BITS = 1024     # summary bits per shard (<= 32 words = 128 B)


def sieve_layout(shard: int):
    """``(bits, bucket, words)`` of one shard's visited summary: ``bits``
    buckets of ``bucket`` consecutive local vertices, packed into
    ``words`` uint32s.  Capped at ``SIEVE_MAX_BITS`` bits so the
    replicated summary stays negligible next to the id payload it
    prunes."""
    bits = min(SIEVE_MAX_BITS, max(1, shard))
    bucket = -(-shard // bits)
    bits = -(-shard // bucket)
    return bits, bucket, packed_words(bits)


def sieve_summary(dist_col: jnp.ndarray, bits: int,
                  bucket: int) -> jnp.ndarray:
    """(shard,) int32 distances -> (words,) uint32 summary; bit ``k`` is
    set iff *every* vertex of bucket ``k`` is visited.  A set bit means
    any candidate landing in the bucket is provably redundant — the
    filter is conservative, so sieving never changes a distance.  Pad
    slots of a straddling final bucket count as visited (they are never
    candidates), keeping the bit exact."""
    shard = dist_col.shape[0]
    visited = dist_col < INF
    if bits * bucket != shard:
        visited = jnp.concatenate(
            [visited, jnp.ones((bits * bucket - shard,), bool)])
    full = visited.reshape(bits, bucket).all(axis=1)
    return pack_bits(full[:, None].astype(jnp.uint8))[:, 0]


def sieve_lookup(gwords: jnp.ndarray, gids: jnp.ndarray, shard: int,
                 bits: int, bucket: int, words: int) -> jnp.ndarray:
    """Look candidate *global* ids up in the replicated summary.

    gwords: (n_shards * words,) uint32, block ``k`` = shard ``k``'s
    ``sieve_summary``.  gids: (...,) int32 candidates (negatives pass
    through unhit).  Returns a bool mask, True where the candidate's
    whole bucket is already visited — it can be sieved out before the
    exchange without changing any distance."""
    ok = gids >= 0
    gid = jnp.where(ok, gids, 0)
    owner = gid // shard
    bit = (gid - owner * shard) // bucket
    word = gwords[owner * words + bit // 32]
    hit = ((word >> (bit % 32).astype(jnp.uint32)) & jnp.uint32(1)) > 0
    return hit & ok
