"""Serial numpy BFS oracle (the 'single machine' baseline of paper §2).

Deliberately written against raw edge arrays with no shared code with the
distributed engine, so tests compare two independent implementations.
"""

from __future__ import annotations

import numpy as np

INF = 2 ** 30


def bfs_reference(src: np.ndarray, dst: np.ndarray, n: int, sources) -> np.ndarray:
    """Level-synchronous serial BFS. Returns (n, S) int32 distances."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    # CSR build
    order = np.argsort(src, kind="stable")
    src_s, dst_s = np.asarray(src)[order], np.asarray(dst)[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src_s, minlength=n), out=indptr[1:])

    out = np.full((n, sources.shape[0]), INF, dtype=np.int32)
    for j, s0 in enumerate(sources):
        dist = out[:, j]
        dist[s0] = 0
        frontier = [int(s0)]
        level = 1
        while frontier:
            nxt = []
            for u in frontier:
                for v in dst_s[indptr[u]:indptr[u + 1]]:
                    if dist[v] == INF:
                        dist[v] = level
                        nxt.append(int(v))
            frontier = nxt
            level += 1
    return out
