"""Serial numpy BFS oracles (the 'single machine' baseline of paper §2).

Deliberately written against raw edge arrays with no shared code with the
distributed engine, so tests compare two independent implementations.
``bfs_reference_2d`` additionally *simulates the 2-D algorithm's phase
structure* (r x c adjacency blocks, row-wise expand, column-wise fold) in
plain numpy, so the distributed 2-D engine is checked against an
independent host-side rendering of the same algorithm as well as against
the serial oracle.
"""

from __future__ import annotations

import numpy as np

INF = 2 ** 30


def bfs_reference(src: np.ndarray, dst: np.ndarray, n: int, sources) -> np.ndarray:
    """Level-synchronous serial BFS. Returns (n, S) int32 distances."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    # CSR build
    order = np.argsort(src, kind="stable")
    src_s, dst_s = np.asarray(src)[order], np.asarray(dst)[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src_s, minlength=n), out=indptr[1:])

    out = np.full((n, sources.shape[0]), INF, dtype=np.int32)
    for j, s0 in enumerate(sources):
        dist = out[:, j]
        dist[s0] = 0
        frontier = [int(s0)]
        level = 1
        while frontier:
            nxt = []
            for u in frontier:
                for v in dst_s[indptr[u]:indptr[u + 1]]:
                    if dist[v] == INF:
                        dist[v] = level
                        nxt.append(int(v))
            frontier = nxt
            level += 1
    return out


def bfs_reference_2d(src: np.ndarray, dst: np.ndarray, n: int, sources,
                     r: int, c: int) -> np.ndarray:
    """Host simulation of 2-D edge-partitioned BFS on an r x c grid.

    Per level: for every grid cell (i, j), expand cell-local edges through
    grid row i's frontier segment into a fold-ordered candidate array,
    OR-merge partial candidates down each grid column (the fold phase),
    then apply the owner-computes update chunk by chunk.  Returns (n, S)
    int32 distances (logical range only).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    p = r * c
    b = -(-n // p)                      # chunk size (ceil)
    n_pad = b * p
    row_blk = c * b                     # vertices per grid row

    # Bucket edges into grid cells with the engine's encodings: source
    # relative to its row block, target in the transposed fold layout.
    own_s, own_d = src // b, dst // b
    gi, gj = own_s // c, own_d % c
    u_row = src - gi * row_blk
    v_fold = (own_d // c) * b + (dst - own_d * b)
    cells = {}
    for i in range(r):
        for j in range(c):
            sel = (gi == i) & (gj == j)
            cells[i, j] = (u_row[sel], v_fold[sel])

    s_count = sources.shape[0]
    dist = np.full((n_pad, s_count), INF, dtype=np.int32)
    frontier = np.zeros((n_pad, s_count), dtype=bool)
    dist[sources, np.arange(s_count)] = 0
    frontier[sources, np.arange(s_count)] = True

    level = 1
    while frontier.any():
        new = np.zeros_like(frontier)
        for j in range(c):
            folded = np.zeros((r * b, s_count), dtype=bool)   # column merge
            for i in range(r):
                frow = frontier[i * row_blk:(i + 1) * row_blk]
                ul, vf = cells[i, j]
                cand = np.zeros((r * b, s_count), dtype=bool)
                np.logical_or.at(cand, vf, frow[ul])
                folded |= cand
            for rr in range(r):                                # owner update
                chunk = slice((rr * c + j) * b, (rr * c + j + 1) * b)
                upd = folded[rr * b:(rr + 1) * b] & (dist[chunk] == INF)
                dist[chunk][upd] = level
                new[chunk] |= upd
        frontier = new
        level += 1
    return dist[:n]
