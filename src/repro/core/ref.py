"""Serial numpy BFS oracles (the 'single machine' baseline of paper §2).

Deliberately written against raw edge arrays with no shared code with the
distributed engine, so tests compare two independent implementations.
``bfs_reference_2d`` additionally *simulates the 2-D algorithm's phase
structure* (r x c adjacency blocks, row-wise expand, column-wise fold) in
plain numpy, so the distributed 2-D engine is checked against an
independent host-side rendering of the same algorithm as well as against
the serial oracle.
"""

from __future__ import annotations

import numpy as np

INF = 2 ** 30


def bfs_reference(src: np.ndarray, dst: np.ndarray, n: int, sources) -> np.ndarray:
    """Level-synchronous serial BFS. Returns (n, S) int32 distances."""
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    # CSR build
    order = np.argsort(src, kind="stable")
    src_s, dst_s = np.asarray(src)[order], np.asarray(dst)[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src_s, minlength=n), out=indptr[1:])

    out = np.full((n, sources.shape[0]), INF, dtype=np.int32)
    for j, s0 in enumerate(sources):
        dist = out[:, j]
        dist[s0] = 0
        frontier = [int(s0)]
        level = 1
        while frontier:
            nxt = []
            for u in frontier:
                for v in dst_s[indptr[u]:indptr[u + 1]]:
                    if dist[v] == INF:
                        dist[v] = level
                        nxt.append(int(v))
            frontier = nxt
            level += 1
    return out


def bfs_reference_2d(src: np.ndarray, dst: np.ndarray, n: int, sources,
                     r: int, c: int, mode: str = "dense",
                     queue_cap: int = 1024, queue_threshold: float = 1 / 64,
                     bottom_up_threshold: float = 0.05,
                     local_update: bool = True, dedupe: bool = True,
                     return_schedule: bool = False):
    """Host simulation of 2-D edge-partitioned BFS on an r x c grid.

    ``mode="dense"`` simulates the two-phase level: for every grid cell
    (i, j), expand cell-local edges through grid row i's frontier segment
    into a fold-ordered candidate array, OR-merge partial candidates down
    each grid column (the fold phase), then apply the owner-computes
    update chunk by chunk.

    ``mode="queue"`` / ``mode="auto"`` additionally simulate the
    direction-optimizing hybrid schedule with the engine's per-level
    decision rule (replicated frontier vertex/edge statistics against the
    same cutoffs), the sparse level's §5.1 local-update exclusion and
    cap-bounded per-row-rank buckets with overflow escalation to dense,
    and the bottom-up level over owner-side in-edges.

    Returns (n, S) int32 distances (logical range only); with
    ``return_schedule=True`` also a list of per-level dicts
    ``{"level", "kind", "overflowed"}`` mirroring the engine's
    ``mode_counts`` / ``overflowed`` stats.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    s_count = sources.shape[0]
    if mode not in ("dense", "queue", "auto"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "queue" and s_count != 1:
        raise ValueError("queue frontier supports a single source")
    p = r * c
    b = -(-n // p)                      # chunk size (ceil)
    n_pad = b * p
    row_blk = c * b                     # vertices per grid row

    # Bucket edges into grid cells with the engine's encodings: source
    # relative to its row block, target in the transposed fold layout.
    own_s, own_d = src // b, dst // b
    gi, gj = own_s // c, own_d % c
    u_row = src - gi * row_blk
    v_fold = (own_d // c) * b + (dst - own_d * b)
    cells = {}
    for i in range(r):
        for j in range(c):
            sel = (gi == i) & (gj == j)
            cells[i, j] = (u_row[sel], v_fold[sel])

    # Owner-side in-edge buckets (bottom-up) + per-vertex out-degrees
    # (the frontier-edge statistic of the auto decision).
    in_cells = {k: (src[own_d == k], dst[own_d == k] - k * b)
                for k in range(p)}
    out_deg = np.bincount(src, minlength=n_pad)
    e_total = src.shape[0]
    q_cutoff = max(1, int(queue_threshold * e_total))
    bu_cutoff = max(1, int(bottom_up_threshold * n))

    dist = np.full((n_pad, s_count), INF, dtype=np.int32)
    frontier = np.zeros((n_pad, s_count), dtype=bool)
    dist[sources, np.arange(s_count)] = 0
    frontier[sources, np.arange(s_count)] = True

    def apply_owner_update(folded_by_col, level, new):
        # folded_by_col[j]: (r*b, S) column-merged fold-layout candidates
        for j in range(c):
            for rr in range(r):
                chunk = slice((rr * c + j) * b, (rr * c + j + 1) * b)
                upd = (folded_by_col[j][rr * b:(rr + 1) * b]
                       & (dist[chunk] == INF))
                dist[chunk][upd] = level
                new[chunk] |= upd

    def dense_level(level, new):
        folded = []
        for j in range(c):
            fold = np.zeros((r * b, s_count), dtype=bool)   # column merge
            for i in range(r):
                frow = frontier[i * row_blk:(i + 1) * row_blk]
                ul, vf = cells[i, j]
                cand = np.zeros((r * b, s_count), dtype=bool)
                np.logical_or.at(cand, vf, frow[ul])
                fold |= cand
            folded.append(fold)
        apply_owner_update(folded, level, new)

    def bottom_up_level(level, new):
        for k in range(p):
            sg, dl = in_cells[k]
            chunk = slice(k * b, (k + 1) * b)
            cand = np.zeros((b, s_count), dtype=bool)
            np.logical_or.at(cand, dl, frontier[sg])
            upd = cand & (dist[chunk] == INF)
            dist[chunk][upd] = level
            new[chunk] |= upd

    def queue_level(level, new):
        """Sparse level; returns True when any device overflowed (the
        engine then re-runs the whole level densely)."""
        overflow = any(frontier[k * b:(k + 1) * b, 0].sum() > queue_cap
                       for k in range(p))
        cand = np.zeros((n_pad,), dtype=bool)
        for i in range(r):
            frow = frontier[i * row_blk:(i + 1) * row_blk, 0]
            for j in range(c):
                ul, vf = cells[i, j]
                tgt = vf[frow[ul]]
                if dedupe:
                    tgt = np.unique(tgt)
                if local_update:
                    mine = tgt // b == i
                    cand[(i * c + j) * b + (tgt[mine] - i * b)] = True
                    tgt = tgt[~mine]
                for rr in range(r):
                    ids = tgt[tgt // b == rr]
                    if ids.shape[0] > queue_cap:
                        overflow = True
                        ids = ids[:queue_cap]
                    cand[(rr * c + j) * b + (ids - rr * b)] = True
        if overflow:
            return True
        upd = cand & (dist[:, 0] == INF)
        dist[upd, 0] = level
        new[upd, 0] = True
        return False

    schedule = []
    level = 1
    while frontier.any():
        f_verts = int(frontier.sum())
        f_edges = int((out_deg * frontier[:, 0]).sum())
        if mode == "dense":
            kind = "dense"
        elif mode == "queue":
            kind = "queue"
        else:
            big = f_verts > bu_cutoff
            tiny = f_edges < q_cutoff
            kind = ("bottom_up" if big else
                    "queue" if (tiny and s_count == 1) else "dense")
        new = np.zeros_like(frontier)
        overflowed = False
        if kind == "queue":
            overflowed = queue_level(level, new)
            if overflowed:      # escalate, still counted as a queue level
                new = np.zeros_like(frontier)
                dense_level(level, new)
        elif kind == "bottom_up":
            bottom_up_level(level, new)
        else:
            dense_level(level, new)
        schedule.append({"level": level, "kind": kind,
                         "overflowed": overflowed})
        frontier = new
        level += 1
    if return_schedule:
        return dist[:n], schedule
    return dist[:n]
