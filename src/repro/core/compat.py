"""Version-compat shims for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to ``jax.shard_map`` (where it is
``check_vma``).  Every shard_map call site in this repo goes through this
module so the whole framework runs on either side of the migration.
"""

from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # jax < 0.6: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with a stable signature across jax versions."""
    kwargs = {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the TPUCompilerParams ->
    CompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
