"""Distributed level-synchronous BFS with 1-D partitioning (paper fig. 2).

The engine is a single ``shard_map``-wrapped ``lax.while_loop``: every
iteration is one BFS level — local expansion (computation step, paper
§2.3) followed by an owner exchange (communication step) and the owner-side
distance update.  All shapes are static; termination is a replicated
``psum`` of the new-frontier population so every shard exits together.

Modes (``BFSOptions.mode``):
  * ``dense``  — bitmap frontier, candidate exchange via any strategy in
    ``exchange.DENSE_STRATEGIES``.  Supports batched multi-source BFS
    (S sources traversed simultaneously — the Graph500-style formulation
    that keeps the MXU busy; see kernels/bsr_spmm).
  * ``queue``  — the paper's sparse per-owner send buffers (S = 1).
  * ``auto``   — beyond-paper direction-optimizing hybrid: per level picks
    bottom-up (frontier huge), queue (frontier tiny) or dense top-down,
    from replicated frontier statistics.  This is the TPU adaptation of
    Beamer-style direction switching: on a systolic machine the win is in
    *bytes on the wire*, not early-exit branchiness.

The returned stats carry per-level analytic communication bytes so the
benchmarks can reproduce the paper's scalability contrast (computation vs
communication cost, §4) without real multi-host hardware.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import exchange as ex
from repro.core import frontier as fr
from repro.core.partition import Partition1D

if TYPE_CHECKING:  # graphs.formats imports core.partition; avoid the cycle
    from repro.graphs.formats import ShardedGraph

INF = jnp.int32(2 ** 30)


@dataclasses.dataclass(frozen=True)
class BFSOptions:
    mode: str = "dense"                       # dense | queue | auto
    dense_exchange: str = "alltoall_direct"   # see exchange.DENSE_STRATEGIES
    queue_exchange: str = "alltoall_direct"   # see exchange.QUEUE_STRATEGIES
    local_update: bool = True                 # paper §5.1 opt (1)
    dedupe: bool = True                       # drop dup targets pre-wire
    queue_cap: int = 1024                     # ids per destination bucket
    max_levels: int = 0                       # 0 -> derive from n
    # auto-mode thresholds (fractions of global E / V):
    queue_threshold: float = 1 / 64           # frontier edges below -> queue
    bottom_up_threshold: float = 0.05         # frontier verts above -> bottom-up
    use_kernel: bool = False                  # Pallas bsr_spmm expansion
                                              # (dense mode, single shard)

    def validate(self):
        assert self.mode in ("dense", "queue", "auto"), self.mode
        assert self.dense_exchange in ex.DENSE_STRATEGIES
        assert self.queue_exchange in ex.QUEUE_STRATEGIES


@dataclasses.dataclass
class BFSStats:
    levels: int
    visited: int
    comm_bytes: float          # analytic, summed over levels, per chip
    overflowed: bool           # a queue level overflowed (result still exact:
                               # engine falls back to dense for that level)
    mode_counts: dict


def _owned_update(dist, own_cand, level):
    """Owner-computes rule: only unvisited vertices take the new level."""
    unseen = dist == INF
    new = (own_cand > 0) & unseen
    dist = jnp.where(new, level, dist)
    return dist, new.astype(jnp.uint8)


def _make_shard_fn(part: Partition1D, e_total: int, s: int,
                   axis, axes_sizes, opts: BFSOptions, max_levels: int,
                   expand_fn=None):
    """Builds the per-shard BFS body (runs under shard_map)."""
    p, shard, n = part.p, part.shard_size, part.n
    itemsize = 1  # uint8 masks on the wire
    queue_edge_cutoff = max(1, int(opts.queue_threshold * e_total))
    bottom_up_cutoff = max(1, int(opts.bottom_up_threshold * part.n_logical))

    def dense_level(frontier, dist, level, src_local, dst_global):
        if expand_fn is not None:
            cand = expand_fn(frontier)
        else:
            cand = fr.expand_dense(frontier, src_local, dst_global, n)
        own = ex.exchange_dense(cand, axis, opts.dense_exchange)
        dist, new = _owned_update(dist, own, level)
        bytes_ = ex.dense_level_bytes(opts.dense_exchange, n, p, s, itemsize,
                                      axes_sizes)
        return dist, new, jnp.float32(bytes_)

    def bottom_up_level(frontier, dist, level, in_src_global, in_dst_local):
        fglob = ex.allgather_frontier(frontier, axis)      # (n, S)
        cand = fr.expand_bottom_up(fglob, in_src_global, in_dst_local, shard)
        dist, new = _owned_update(dist, cand, level)
        bytes_ = ex.bottomup_level_bytes(n, p, s, itemsize)
        return dist, new, jnp.float32(bytes_)

    def queue_level(frontier, dist, level, src_local, dst_global):
        me = lax.axis_index(axis)
        valid = dst_global >= 0
        active = (frontier[src_local, 0] > 0) & valid
        buckets, local_mask, _, overflow = fr.build_queue_buckets(
            dst_global, active, part, me, opts.queue_cap,
            local_update=opts.local_update, dedupe=opts.dedupe)
        # Exactness guarantee: if any shard's bucket overflowed, run the
        # whole level densely instead (the predicate is replicated, so all
        # shards take the same branch and collectives stay collective).
        overflow_any = lax.psum(overflow.astype(jnp.int32), axis) > 0

        def sparse_branch():
            recv = ex.exchange_queue(buckets, axis, opts.queue_exchange)
            own = jnp.maximum(fr.apply_queue(recv, me, shard), local_mask)
            d2, new = _owned_update(dist, own[:, None], level)
            return d2, new, jnp.float32(
                ex.queue_level_bytes(opts.queue_exchange, p, opts.queue_cap))

        def dense_branch():
            return dense_level(frontier, dist, level, src_local, dst_global)

        d2, new, bytes_ = lax.cond(overflow_any, dense_branch, sparse_branch)
        return d2, new, bytes_, overflow_any

    def body(state, src_local, dst_global, in_src_global, in_dst_local,
             valid_local):
        dist, frontier, level, _, bytes_acc, overflowed, modes = state

        if opts.mode == "dense":
            dist, new, b = dense_level(frontier, dist, level, src_local,
                                       dst_global)
            modes = modes.at[0].add(1)
            ovf = jnp.bool_(False)
        elif opts.mode == "queue":
            dist, new, b, ovf = queue_level(frontier, dist, level, src_local,
                                            dst_global)
            modes = modes.at[1].add(1)
        else:  # auto: direction-optimizing hybrid
            f_verts = lax.psum(frontier.sum(dtype=jnp.int32), axis)
            f_edges_local = jnp.where(
                dst_global >= 0, frontier[src_local, 0], 0).sum(dtype=jnp.int32)
            f_edges = lax.psum(f_edges_local, axis)
            big = f_verts > jnp.int32(bottom_up_cutoff)
            tiny = f_edges < jnp.int32(queue_edge_cutoff)

            def do_bottom_up():
                d, nw, b = bottom_up_level(frontier, dist, level,
                                           in_src_global, in_dst_local)
                return d, nw, b, jnp.bool_(False), jnp.int32(2)

            def do_queue():
                d, nw, b, ovf = queue_level(frontier, dist, level, src_local,
                                            dst_global)
                return d, nw, b, ovf, jnp.int32(1)

            def do_dense():
                d, nw, b = dense_level(frontier, dist, level, src_local,
                                       dst_global)
                return d, nw, b, jnp.bool_(False), jnp.int32(0)

            if s == 1:
                dist, new, b, ovf, which = lax.cond(
                    big, do_bottom_up,
                    lambda: lax.cond(tiny, do_queue, do_dense))
            else:
                dist, new, b, ovf, which = lax.cond(big, do_bottom_up, do_dense)
            modes = modes.at[which].add(1)

        # Mask padding vertices (ids >= n_logical can never be visited).
        new = new * valid_local[:, None].astype(new.dtype)
        dist = jnp.where(valid_local[:, None], dist, INF)
        active = lax.psum(new.sum(dtype=jnp.int32), axis) > 0
        return (dist, new, level + 1, active, bytes_acc + b,
                overflowed | ovf, modes)

    def shard_fn(src_local, dst_global, in_src_global, in_dst_local,
                 dist0, frontier0, valid_local):
        state0 = (dist0, frontier0, jnp.int32(1), jnp.bool_(True),
                  jnp.float32(0), jnp.bool_(False), jnp.zeros(3, jnp.int32))

        def cond(st):
            return st[3] & (st[2] <= max_levels)

        def body_fn(st):
            return body(st, src_local, dst_global, in_src_global,
                        in_dst_local, valid_local)

        dist, _, level, _, bytes_acc, overflowed, modes = lax.while_loop(
            cond, body_fn, state0)
        return dist, level - 1, bytes_acc, overflowed, modes

    return shard_fn


def bfs(graph: "ShardedGraph", sources, mesh: Optional[Mesh] = None,
        axis=None, opts: BFSOptions = BFSOptions()):
    """Run distributed BFS from ``sources`` (int or sequence -> batched).

    Returns (dist, stats): dist is (n_logical, S) int32 with INF for
    unreachable vertices; stats is a BFSStats.
    """
    opts.validate()
    part = graph.part
    sources = np.atleast_1d(np.asarray(sources, dtype=np.int64))
    s = int(sources.shape[0])
    if opts.mode == "queue":
        assert s == 1, "queue frontier supports a single source"
    p, shard, n = part.p, part.shard_size, part.n

    if mesh is None:
        dev = jax.devices()[:1]
        mesh = Mesh(np.asarray(dev).reshape(1), ("bfs_p",))
        axis = "bfs_p"
        assert p == 1, "pass a mesh whose total size equals part.p"
    axis = axis if axis is not None else tuple(mesh.axis_names)
    axes = axis if isinstance(axis, tuple) else (axis,)
    axes_sizes = [mesh.shape[a] for a in axes]
    assert int(np.prod(axes_sizes)) == p, (axes_sizes, p)

    max_levels = opts.max_levels or part.n_logical

    # initial state (host-side, then sharded by the jit partitioner)
    dist0 = np.full((n, s), int(INF), dtype=np.int32)
    frontier0 = np.zeros((n, s), dtype=np.uint8)
    for j, sv in enumerate(sources):
        dist0[sv, j] = 0
        frontier0[sv, j] = 1
    valid = (np.arange(n) < part.n_logical)

    src_local, dst_global, in_src_global, in_dst_local = graph.flat()

    expand_fn = None
    if opts.use_kernel:
        # Pallas bsr_spmm frontier expansion: block-CSR adjacency on the
        # MXU (boolean semiring via sum + >0).  Single-shard dense mode —
        # the multi-shard path keeps the segment-scatter expansion.
        assert p == 1 and opts.mode == "dense", \
            "use_kernel requires p == 1 and mode == 'dense'"
        from repro.graphs.formats import block_sparse_adjacency
        from repro.kernels.bsr_spmm import ops as spmm_ops
        valid_e = dst_global >= 0
        src_g = np.asarray(src_local)[valid_e]
        dst_g = np.asarray(dst_global)[valid_e]
        blocks, brr, bcc, n_pad_b = block_sparse_adjacency(
            dst_g, src_g, n)  # transposed: candidates = A^T @ f
        blocks_j = jnp.asarray(blocks)
        br_j = jnp.asarray(brr)
        bc_j = jnp.asarray(bcc)

        def expand_fn(frontier):  # (n, S) uint8 -> (n, S) uint8
            f = frontier
            if n_pad_b > n:
                f = jnp.pad(f, ((0, n_pad_b - n), (0, 0)))
            cand = spmm_ops.frontier_expand(
                blocks_j, br_j, bc_j, f, n_rows_pad=n_pad_b)
            return cand[:n]

    shard_fn = _make_shard_fn(part, graph.n_edges, s, axis,
                              axes_sizes, opts, max_levels,
                              expand_fn=expand_fn)

    spec_edge = P(axis)
    spec_vert = P(axis, None)
    mapped = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(spec_edge, spec_edge, spec_edge, spec_edge,
                  spec_vert, spec_vert, P(axis)),
        out_specs=(spec_vert, P(), P(), P(), P()),
        check_vma=False,
    )
    with mesh:
        dist, levels, comm_bytes, overflowed, modes = jax.jit(mapped)(
            jnp.asarray(src_local), jnp.asarray(dst_global),
            jnp.asarray(in_src_global), jnp.asarray(in_dst_local),
            jnp.asarray(dist0), jnp.asarray(frontier0), jnp.asarray(valid))
    dist = np.asarray(dist)[: part.n_logical]
    visited = int((dist < int(INF)).sum())
    stats = BFSStats(
        levels=int(levels), visited=visited,
        comm_bytes=float(comm_bytes), overflowed=bool(overflowed),
        mode_counts={"dense": int(modes[0]), "queue": int(modes[1]),
                     "bottom_up": int(modes[2])},
    )
    return dist, stats
