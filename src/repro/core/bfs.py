"""Distributed level-synchronous BFS with 1-D partitioning (paper fig. 2).

The traversal kernel is a single ``shard_map``-wrapped ``lax.while_loop``:
every iteration is one BFS level — local expansion (computation step, paper
§2.3) followed by an owner exchange (communication step) and the owner-side
distance update.  All shapes are static; termination is a replicated
``psum`` of the new-frontier population so every shard exits together.

This module holds the *kernel*: options, per-shard loop body builder and
source validation.  The public lifecycle lives in ``core/engine.py``::

    plan(graph, opts, mesh) -> BFSPlan -> .compile() -> BFSEngine -> .run()

``bfs()`` below is the deprecated one-shot wrapper over that lifecycle; it
resolves engines through the process-wide shared cache
(``repro.serve.engine_cache``) so legacy call sites no longer recompile on
every traversal and share compiled engines with the serving paths.

Modes (``BFSOptions.mode``):
  * ``dense``  — bitmap frontier, candidate exchange via any strategy
    registered under ``exchange.register_exchange("dense", ...)``.
    Supports batched multi-source BFS (S sources traversed simultaneously
    — the Graph500-style formulation that keeps the MXU busy; see
    kernels/bsr_spmm).
  * ``queue``  — the paper's sparse per-owner send buffers (S = 1).
  * ``auto``   — beyond-paper direction-optimizing hybrid: per level picks
    bottom-up (frontier huge), queue (frontier tiny) or dense top-down,
    from replicated frontier statistics.  This is the TPU adaptation of
    Beamer-style direction switching: on a systolic machine the win is in
    *bytes on the wire*, not early-exit branchiness.

All three modes exist under both partition schemes: the 2-D backend
(``_make_shard_fn_2d``) maps queue onto sparse expand/fold id exchanges
and bottom-up onto a both-axes frontier gather over the owner-side
in-edge blocks, switching per level exactly like the 1-D hybrid.

The returned stats carry per-level analytic communication bytes so the
benchmarks can reproduce the paper's scalability contrast (computation vs
communication cost, §4) without real multi-host hardware.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh

from repro.core import exchange as ex
from repro.core import frontier as fr
from repro.core.partition import Partition1D, Partition2D
from repro.kernels.fold_update import fold_update

if TYPE_CHECKING:  # graphs.formats imports core.partition; avoid the cycle
    from repro.graphs.formats import ShardedGraph

INF = fr.INF


@dataclasses.dataclass(frozen=True)
class BFSOptions:
    mode: str = "dense"                       # dense | queue | auto
    dense_exchange: str = "alltoall_direct"   # see exchange.DENSE_STRATEGIES
    queue_exchange: str = "alltoall_direct"   # see exchange.QUEUE_STRATEGIES
    # 2-D (partition="2d") phase strategies; "auto" picks the registered
    # strategy with the smallest modeled bytes (exchange.select_exchange).
    expand_exchange: str = "allgather"        # see exchange.EXPAND_ROW_STRATEGIES
    fold_exchange: str = "alltoall_reduce"    # see exchange.FOLD_COL_STRATEGIES
    # sparse (queue/auto) 2-D phase strategies: id buffers on the wire
    expand_sparse_exchange: str = "allgather"       # EXPAND_ROW_SPARSE_...
    fold_sparse_exchange: str = "alltoall_direct"   # FOLD_COL_SPARSE_...
    local_update: bool = True                 # paper §5.1 opt (1)
    dedupe: bool = True                       # drop dup targets pre-wire
    queue_cap: int = 1024                     # ids per destination bucket
    max_levels: int = 0                       # 0 -> derive from n
    # auto-mode thresholds (fractions of global E / V):
    queue_threshold: float = 1 / 64           # frontier edges below -> queue
    bottom_up_threshold: float = 0.05         # frontier verts above -> bottom-up
    use_kernel: bool = False                  # Pallas bsr_spmm expansion
                                              # (dense mode, 1-D partition;
                                              # runs per shard under the
                                              # multi-device loop)
    # Wire layout of the exchanges.  Dense phases: "packed" ships uint32
    # bitset words (8x smaller, OR merges), "bytes" the uint8 mask.
    # Sparse phases (queue / expand_row_sparse / fold_col_sparse):
    # "compressed" ships delta+varint id streams (frontier.encode_delta_
    # varint, ~1 byte per id, bitmap-capped) instead of raw int32 ids.
    # "auto" prices every layout per phase at plan time
    # (exchange.select_exchange / the _packed and _compressed strategy
    # twins) and picks the cheapest; "packed"/"compressed" pin the dense/
    # sparse tier each names and leave the other tier at its default.
    wire_format: str = "auto"       # packed | bytes | compressed | auto
    # Visited sieve ("Compression and Sieve"): filter candidate ids
    # against a replicated coarse visited summary *before* the sparse
    # exchange, so already-discovered vertices never occupy bucket slots
    # (fewer dense escalations as the traversal converges).  "auto"
    # enables it where the sparse paths exist: non-dense single-source
    # plans on a real mesh.
    sieve: object = "auto"          # True | False | "auto"
    # Fused fold/owner-update tail (kernels/fold_update): replace the
    # dense tail's unpack -> compare -> where op chain with one kernel
    # pass over the merged candidate words that also emits the next
    # frontier generation pre-packed, double-buffered in loop state so
    # word-consuming collectives of level L+1 need no pack after level
    # L's update.  Requires the dense (1-D) / fold (2-D) wire to resolve
    # packed; "auto" turns it on exactly there for dense/auto-mode plans
    # (queue-mode plans only benefit on escalated levels but would pay a
    # re-pack on every sparse level).  Resolved at plan time like
    # wire_format — the resolved flag keys into plan_key().
    use_fused_tail: object = "auto"  # True | False | "auto"

    def validate(self):
        if self.mode not in ("dense", "queue", "auto"):
            raise ValueError(f"unknown BFS mode {self.mode!r}; "
                             "expected dense | queue | auto")
        if self.wire_format not in ("packed", "bytes", "compressed", "auto"):
            raise ValueError(f"unknown wire_format {self.wire_format!r}; "
                             "expected packed | bytes | compressed | auto")
        if self.sieve not in (True, False, "auto"):
            raise ValueError(f"unknown sieve setting {self.sieve!r}; "
                             "expected True | False | 'auto'")
        if self.use_fused_tail not in (True, False, "auto"):
            raise ValueError(
                f"unknown use_fused_tail setting {self.use_fused_tail!r}; "
                "expected True | False | 'auto'")
        # get_exchange raises a ValueError naming the registered strategies;
        # "auto" defers to the byte-model selection at plan time.
        for kind, name in (("dense", self.dense_exchange),
                           ("queue", self.queue_exchange),
                           ("expand_row", self.expand_exchange),
                           ("fold_col", self.fold_exchange),
                           ("expand_row_sparse", self.expand_sparse_exchange),
                           ("fold_col_sparse", self.fold_sparse_exchange)):
            if name != "auto":
                ex.get_exchange(kind, name)
        if self.queue_cap <= 0:
            raise ValueError(f"queue_cap must be positive ({self.queue_cap})")
        if self.max_levels < 0:
            raise ValueError(f"max_levels must be >= 0 ({self.max_levels})")


@dataclasses.dataclass
class BFSStats:
    """Host-side summary of one traversal (legacy / ``bfs()`` interface).

    The engine API splits this into static plan metadata
    (``BFSPlan.describe()``) and per-run device stats (``BFSRunStats``,
    a pytree that stays on device until ``.block()``); this container is
    what ``BFSResult.stats()`` materializes for host consumers.
    """

    levels: int
    visited: int
    comm_bytes: float          # analytic, summed over levels, per chip
    overflowed: bool           # a queue level overflowed (result still exact:
                               # engine falls back to dense for that level)
    mode_counts: dict
    sieve_hits: int = 0        # candidates the visited-sieve dropped
                               # before they reached a collective


def validate_sources(sources, n_logical: int,
                     max_sources: Optional[int] = None) -> np.ndarray:
    """Validate BFS source ids; returns them as a 1-D int64 array.

    Rejects ids outside ``[0, n_logical)`` and duplicates with a clear
    ValueError (previously ``dist0[sv, j]`` either crashed cryptically or
    silently wrapped on negative ids).
    """
    arr = np.atleast_1d(np.asarray(sources))
    if arr.ndim != 1:
        raise ValueError(f"sources must be a scalar or 1-D sequence, "
                         f"got shape {arr.shape}")
    if arr.size == 0:
        raise ValueError("sources must contain at least one vertex id")
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(f"sources must be integer vertex ids, "
                         f"got dtype {arr.dtype}")
    arr = arr.astype(np.int64)
    bad = arr[(arr < 0) | (arr >= n_logical)]
    if bad.size:
        raise ValueError(f"source ids {bad.tolist()} outside "
                         f"[0, {n_logical})")
    uniq, counts = np.unique(arr, return_counts=True)
    dup = uniq[counts > 1]
    if dup.size:
        raise ValueError(f"duplicate source ids {dup.tolist()}; each "
                         "column of a batched traversal needs a distinct "
                         "source")
    if max_sources is not None and arr.size > max_sources:
        raise ValueError(f"{arr.size} sources exceed the engine's "
                         f"compiled capacity of {max_sources}; build a "
                         "plan with a larger num_sources")
    return arr


def _owned_update(dist, own_cand, level):
    """Owner-computes rule: only unvisited vertices take the new level."""
    unseen = dist == INF
    new = (own_cand > 0) & unseen
    dist = jnp.where(new, level, dist)
    return dist, new.astype(jnp.uint8)


def _make_shard_fn(part: Partition1D, e_total: int, s: int,
                   axis, axes_sizes, opts: BFSOptions, max_levels: int,
                   dense_strategy: ex.ExchangeStrategy,
                   queue_strategy: ex.ExchangeStrategy,
                   expand_fn=None, expand_emits_packed: bool = False,
                   n_kernel_args: int = 0, bottom_up_wire: str = "bytes",
                   sieve: bool = False, fused: bool = False, on_trace=None):
    """Builds the per-shard BFS body (runs under shard_map).

    Exchange strategies arrive pre-resolved from the registry (plan time),
    so the loop body never consults strategy names; the strategy's
    ``wire`` field decides whether candidates cross the exchange packed
    (uint32 bitset words, OR merges) or as the uint8 mask.  ``expand_fn``
    (the Pallas bsr_spmm path) receives the frontier plus
    ``n_kernel_args`` extra per-shard operands (the device-resident
    blocked adjacency); with ``expand_emits_packed`` its output is
    already the per-shard-blocked word array, so a packed exchange
    consumes it with no pack step.  ``on_trace`` is invoked once per
    trace — engines use it to prove compile-once reuse.

    ``fused`` (plan-time resolution of ``BFSOptions.use_fused_tail``;
    requires the dense wire to be packed) replaces the dense level's
    unpack → owner-update tail with the ``kernels/fold_update`` fused
    kernel and double-buffers the frontier: the loop state carries the
    packed word generation (``fwords``) alongside the byte mask, each
    level tail emits the next generation, and word-consuming collectives
    (the packed bottom-up gather here; the 2-D expand allgather in
    ``_make_shard_fn_2d``) read the *carried* words — their payload is
    ready the moment the previous level's fused tail retires, with no
    pack on the critical path between levels.
    """
    p, shard, n = part.p, part.shard_size, part.n
    itemsize = 1  # uint8 masks (the "bytes" wire format)
    w_shard = fr.packed_words(shard)
    queue_edge_cutoff = max(1, int(opts.queue_threshold * e_total))
    bottom_up_cutoff = max(1, int(opts.bottom_up_threshold * part.n_logical))
    # compressed queue wire: bucket row j encodes ids relative to j*shard
    # (range [0, shard)); the static byte capacity below is exactly what
    # the strategy's byte model prices at this plan's capacity density
    use_compressed = queue_strategy.wire == "compressed"
    q_byte_cap = fr.compressed_capacity(opts.queue_cap, shard)
    sv_bits, sv_bucket, sv_words = fr.sieve_layout(shard)
    sieve_gather_bytes = float((p - 1) * sv_words * 4) if sieve else 0.0
    dense_bytes = dense_strategy.bytes_model(n, p, s, itemsize, axes_sizes)
    queue_bytes = queue_strategy.bytes_model(
        p, opts.queue_cap, 4, opts.queue_cap / shard) + sieve_gather_bytes
    bottom_up_bytes = ex.bottomup_level_bytes(n, p, s, itemsize,
                                              wire=bottom_up_wire)

    def dense_level(frontier, dist, level, src_local, dst_global, kargs):
        if expand_fn is not None:
            cand = expand_fn(frontier, *kargs)
        else:
            cand = fr.expand_dense(frontier, src_local, dst_global, n)
        if dense_strategy.wire == "packed":
            # keep candidates packed through the collective: pack once
            # (unless the kernel already emitted words), OR-merge on the
            # wire payload, unpack only the owned W-word slice
            words = cand if (expand_fn is not None and expand_emits_packed
                             ) else fr.pack_bits(cand, n_blocks=p)
            merged = dense_strategy.impl(words, axis)
            if fused:
                # fused tail: one kernel pass bit-tests the merged words
                # against dist, writes depths and emits the next packed
                # frontier generation — no (shard, S) unpack between the
                # collective and the next level
                dist, new, nwords = fold_update(merged, dist, level)
                return dist, new, nwords, jnp.float32(dense_bytes)
            own = fr.unpack_bits(merged, shard)
        else:
            own = dense_strategy.impl(cand, axis)
        dist, new = _owned_update(dist, own, level)
        return dist, new, None, jnp.float32(dense_bytes)

    def bottom_up_level(frontier, fwords, dist, level, in_src_global,
                        in_dst_local):
        if bottom_up_wire == "packed":
            # gather the packed frontier (8x smaller) and read source
            # bits straight out of the words — no (n, S) unpack.  Fused
            # plans carry the packed generation in loop state (the
            # previous level's tail emitted it), so the gather payload is
            # ready with no pack on this level's critical path.
            fw = fwords if fused else fr.pack_bits(frontier)   # (W, S)
            fglob_w = ex.allgather_frontier(fw, axis)          # (p*W, S)
            cand = fr.expand_bottom_up_packed(fglob_w, in_src_global,
                                              in_dst_local, shard, w_shard)
        else:
            fglob = ex.allgather_frontier(frontier, axis)  # (n, S)
            cand = fr.expand_bottom_up(fglob, in_src_global, in_dst_local,
                                       shard)
        dist, new = _owned_update(dist, cand, level)
        nwords = fr.pack_bits(new) if fused else None
        return dist, new, nwords, jnp.float32(bottom_up_bytes)

    def queue_level(frontier, dist, level, src_local, dst_global, kargs):
        me = lax.axis_index(axis)
        valid = dst_global >= 0
        active = (frontier[src_local, 0] > 0) & valid
        hits = jnp.int32(0)
        if sieve:
            # replicate each shard's coarse visited summary and drop
            # candidates whose whole bucket is already visited — they
            # can never lower a distance, so they need not ship
            own_sum = fr.sieve_summary(dist[:, 0], sv_bits, sv_bucket)
            gsum = lax.all_gather(own_sum, axis, tiled=True)  # (p*words,)
            drop = fr.sieve_lookup(gsum, dst_global, shard, sv_bits,
                                   sv_bucket, sv_words) & active
            hits = lax.psum(drop.sum(dtype=jnp.int32), axis)
            active = active & ~drop
        buckets, local_mask, _, overflow = fr.build_queue_buckets(
            dst_global, active, part, me, opts.queue_cap,
            local_update=opts.local_update, dedupe=opts.dedupe)
        if use_compressed:
            base = jnp.arange(p, dtype=jnp.int32)[:, None] * shard
            rel = jnp.where(buckets >= 0, buckets - base, -1)
            payload, enc_ovf = jax.vmap(
                lambda row: fr.encode_delta_varint(row, q_byte_cap, shard)
            )(rel)
            overflow = overflow | enc_ovf.any()
        # Exactness guarantee: if any shard's bucket (or compressed
        # stream) overflowed, run the whole level densely instead (the
        # predicate is replicated, so all shards take the same branch and
        # collectives stay collective).
        overflow_any = lax.psum(overflow.astype(jnp.int32), axis) > 0

        def sparse_branch():
            if use_compressed:
                recv = queue_strategy.impl(payload, axis)  # (p, byte_cap)
                rec_ids = jax.vmap(
                    lambda row: fr.decode_delta_varint(row, opts.queue_cap,
                                                       shard))(recv)
                rec_ids = jnp.where(rec_ids >= 0, rec_ids + me * shard, -1)
            else:
                rec_ids = queue_strategy.impl(buckets, axis)
            own = jnp.maximum(fr.apply_queue(rec_ids, me, shard), local_mask)
            d2, new = _owned_update(dist, own[:, None], level)
            nwords = fr.pack_bits(new) if fused else None
            return d2, new, nwords, jnp.float32(queue_bytes)

        def dense_branch():
            d2, new, nwords, bb = dense_level(frontier, dist, level,
                                              src_local, dst_global, kargs)
            # the sieve gather (if any) already ran before escalation
            return d2, new, nwords, bb + jnp.float32(sieve_gather_bytes)

        d2, new, nwords, bytes_ = lax.cond(overflow_any, dense_branch,
                                           sparse_branch)
        return d2, new, nwords, bytes_, overflow_any, hits

    def body(state, src_local, dst_global, in_src_global, in_dst_local,
             kargs, valid_local, vwords):
        if fused:
            (dist, frontier, fwords, level, _, bytes_acc, overflowed,
             modes, hits_acc) = state
        else:
            (dist, frontier, level, _, bytes_acc, overflowed, modes,
             hits_acc) = state
            fwords = None
        hits = jnp.int32(0)

        if opts.mode == "dense":
            dist, new, nwords, b = dense_level(frontier, dist, level,
                                               src_local, dst_global, kargs)
            modes = modes.at[0].add(1)
            ovf = jnp.bool_(False)
        elif opts.mode == "queue":
            dist, new, nwords, b, ovf, hits = queue_level(
                frontier, dist, level, src_local, dst_global, kargs)
            modes = modes.at[1].add(1)
        else:  # auto: direction-optimizing hybrid
            f_verts = lax.psum(frontier.sum(dtype=jnp.int32), axis)
            f_edges_local = jnp.where(
                dst_global >= 0, frontier[src_local, 0], 0).sum(dtype=jnp.int32)
            f_edges = lax.psum(f_edges_local, axis)
            big = f_verts > jnp.int32(bottom_up_cutoff)
            tiny = f_edges < jnp.int32(queue_edge_cutoff)

            def do_bottom_up():
                d, nw, nwd, b = bottom_up_level(frontier, fwords, dist,
                                                level, in_src_global,
                                                in_dst_local)
                return (d, nw, nwd, b, jnp.bool_(False), jnp.int32(2),
                        jnp.int32(0))

            def do_queue():
                d, nw, nwd, b, ovf, h = queue_level(frontier, dist, level,
                                                    src_local, dst_global,
                                                    kargs)
                return d, nw, nwd, b, ovf, jnp.int32(1), h

            def do_dense():
                d, nw, nwd, b = dense_level(frontier, dist, level,
                                            src_local, dst_global, kargs)
                return (d, nw, nwd, b, jnp.bool_(False), jnp.int32(0),
                        jnp.int32(0))

            if s == 1:
                dist, new, nwords, b, ovf, which, hits = lax.cond(
                    big, do_bottom_up,
                    lambda: lax.cond(tiny, do_queue, do_dense))
            else:
                dist, new, nwords, b, ovf, which, hits = lax.cond(
                    big, do_bottom_up, do_dense)
            modes = modes.at[which].add(1)

        # Mask padding vertices (ids >= n_logical can never be visited).
        new = new * valid_local[:, None].astype(new.dtype)
        dist = jnp.where(valid_local[:, None], dist, INF)
        active = lax.psum(new.sum(dtype=jnp.int32), axis) > 0
        if fused:
            # next packed generation, pad bits cleared to match the masked
            # byte frontier exactly
            fwords = nwords & vwords
            return (dist, new, fwords, level + 1, active, bytes_acc + b,
                    overflowed | ovf, modes, hits_acc + hits)
        return (dist, new, level + 1, active, bytes_acc + b,
                overflowed | ovf, modes, hits_acc + hits)

    def shard_fn(src_local, dst_global, in_src_global, in_dst_local, *rest):
        if on_trace is not None:
            on_trace()
        kargs = rest[:n_kernel_args]
        dist0, frontier0, valid_local = rest[n_kernel_args:]
        tail0 = (jnp.int32(1), jnp.bool_(True), jnp.float32(0),
                 jnp.bool_(False), jnp.zeros(3, jnp.int32), jnp.int32(0))
        if fused:
            vwords = fr.pack_bits(valid_local.astype(jnp.uint8)[:, None])
            state0 = (dist0, frontier0, fr.pack_bits(frontier0)) + tail0
        else:
            vwords = None
            state0 = (dist0, frontier0) + tail0
        lvl_i, act_i = (3, 4) if fused else (2, 3)

        def cond(st):
            return st[act_i] & (st[lvl_i] <= max_levels)

        def body_fn(st):
            return body(st, src_local, dst_global, in_src_global,
                        in_dst_local, kargs, valid_local, vwords)

        st = lax.while_loop(cond, body_fn, state0)
        level = st[lvl_i]
        bytes_acc, overflowed, modes, sieve_hits = st[lvl_i + 2:lvl_i + 6]
        return st[0], level - 1, bytes_acc, overflowed, modes, sieve_hits

    return shard_fn


def _make_shard_fn_2d(part2: Partition2D, e_total: int, s: int,
                      row_axis, col_axis, opts: BFSOptions, max_levels: int,
                      expand_strategy: ex.ExchangeStrategy,
                      fold_strategy: ex.ExchangeStrategy,
                      expand_sparse_strategy: ex.ExchangeStrategy,
                      fold_sparse_strategy: ex.ExchangeStrategy,
                      bottom_up_wire: str = "bytes",
                      sieve: bool = False, fused: bool = False,
                      on_trace=None):
    """Per-device body of the 2-D two-phase BFS level loop (shard_map).

    Each dense level is expand -> local edge scatter -> fold -> owner
    update:

      1. expand (row phase): allgather this device's (b, S) frontier chunk
         across its grid row (the ``col_axis``, c participants) into the
         contiguous (c*b, S) row-block frontier.
      2. local expansion: scatter the device's edge block through the
         gathered frontier into the *transposed* (r*b, S) fold layout.
      3. fold (column phase): all-to-all+reduce the fold blocks across the
         grid column (the ``row_axis``, r participants); each device
         receives exactly its owned (b, S) candidate merge.
      4. owner-computes update + replicated termination psum over both
         grid axes — identical semantics to the 1-D loop, so BFSRunStats
         and the donated dist buffer behave the same.

    The direction-optimizing variants make both phases cheap when the
    frontier is narrow or huge (mirroring the 1-D hybrid):

      * queue  — the expand allgather ships active frontier *ids*
        (pack_frontier_ids, cap-bounded) instead of the bitmap, and the
        fold ships per-row-rank candidate id buckets
        (build_queue_buckets_2d, §5.1 local-update exclusion applied with
        the device's row rank).  Any pack/bucket overflow escalates the
        whole level to the dense representation under a replicated
        predicate, so results stay exact and collectives stay collective.
      * bottom-up — the frontier bitmap is gathered over *both* grid axes
        and each device checks the in-edges of the vertices it owns
        (the in-edge blocks on ShardedGraph2D); no fold exchange at all.
      * auto — per level picks bottom-up (frontier huge), queue (frontier
        edges tiny, S = 1) or dense, from replicated frontier statistics
        (the frontier-edge count uses the per-vertex out_degree block).

    ``fused`` (requires the fold wire packed) fuses the fold-merge +
    owner-update tail into the ``kernels/fold_update`` kernel and carries
    the packed frontier generation in loop state, exactly as in the 1-D
    builder — here the payoff is larger: the expand-phase allgather of
    level L+1 ships the carried words the fused tail of level L emitted,
    so XLA can issue that collective with no pack (and, via
    ``frontier.expand_dense_2d_packed``, no row-frontier unpack) between
    it and the previous level's update.
    """
    r, c, b = part2.r, part2.c, part2.shard_size
    p = part2.p
    fold_len = part2.fold_size
    w_chunk = fr.packed_words(b)
    grid_axes = (row_axis, col_axis)
    queue_edge_cutoff = max(1, int(opts.queue_threshold * e_total))
    bottom_up_cutoff = max(1, int(opts.bottom_up_threshold * part2.n_logical))
    # compressed sparse phases: both ship ids from [0, b) (expand: local
    # frontier ids; fold: bucket row rr relative to rr*b), so they share
    # one static byte capacity, matching the models' capacity density
    use_comp_expand = expand_sparse_strategy.wire == "compressed"
    use_comp_fold = fold_sparse_strategy.wire == "compressed"
    g_byte_cap = fr.compressed_capacity(opts.queue_cap, b)
    g_density = opts.queue_cap / b
    sv_bits, sv_bucket, sv_words = fr.sieve_layout(b)
    sieve_gather_bytes = jnp.float32(
        (p - 1) * sv_words * 4 if sieve else 0.0)
    dense_bytes = jnp.float32(
        expand_strategy.bytes_model(part2.n, r, c, s, 1) +
        fold_strategy.bytes_model(part2.n, r, c, s, 1))
    expand_sparse_bytes = jnp.float32(
        expand_sparse_strategy.bytes_model(r, c, opts.queue_cap, 4,
                                           g_density))
    sparse_bytes = expand_sparse_bytes + sieve_gather_bytes + jnp.float32(
        fold_sparse_strategy.bytes_model(r, c, opts.queue_cap, 4, g_density))
    bottom_up_bytes = jnp.float32(ex.bottomup_level_bytes(
        part2.n, p, s, 1, wire=bottom_up_wire))

    def dense_level(frontier, fwords, dist, level, src_rowlocal, dst_fold):
        if expand_strategy.wire == "packed":
            # ship the frontier chunk as words.  Fused plans gather the
            # *carried* packed generation (emitted by the previous
            # level's fused tail — double buffering: the collective's
            # payload has no compute dependency at the top of this level)
            # and read source bits straight from the gathered words; the
            # unfused path packs here and unpacks the c gathered segments
            # into the row frontier the expansion reads.
            payload = fwords if fused else fr.pack_bits(frontier)
            fw = expand_strategy.impl(payload, col_axis)
            if fused:
                cand = fr.expand_dense_2d_packed(fw, src_rowlocal,
                                                 dst_fold, fold_len, b)
            else:
                frow = fr.unpack_bits(fw, b, n_blocks=c)         # (c*b, S)
                cand = fr.expand_dense_2d(frow, src_rowlocal, dst_fold,
                                          fold_len)
        else:
            frow = expand_strategy.impl(frontier, col_axis)      # (c*b, S)
            cand = fr.expand_dense_2d(frow, src_rowlocal, dst_fold,
                                      fold_len)
        if fold_strategy.wire == "packed":
            cw = fold_strategy.impl(fr.pack_bits(cand, n_blocks=r), row_axis)
            if fused:
                # fused fold tail: merge words -> dist depths + next
                # packed generation in one kernel pass (no (b, S) unpack)
                dist, new, nwords = fold_update(cw, dist, level)
                return dist, new, nwords, dense_bytes
            own = fr.unpack_bits(cw, b)                          # (b, S)
        else:
            own = fold_strategy.impl(cand, row_axis)             # (b, S)
        dist, new = _owned_update(dist, own, level)
        return dist, new, None, dense_bytes

    def bottom_up_level(frontier, fwords, dist, level, in_src_global,
                        in_dst_local):
        # gather over (rows, cols) is chunk-id order: chunk k lives on
        # grid device (k // c, k % c), the same major-first linearization
        if bottom_up_wire == "packed":
            fw = fwords if fused else fr.pack_bits(frontier)     # (Wb, S)
            fglob_w = ex.allgather_frontier(fw, grid_axes)       # (p*Wb, S)
            cand = fr.expand_bottom_up_packed(fglob_w, in_src_global,
                                              in_dst_local, b, w_chunk)
        else:
            fglob = ex.allgather_frontier(frontier, grid_axes)   # (n, S)
            cand = fr.expand_bottom_up(fglob, in_src_global, in_dst_local, b)
        dist, new = _owned_update(dist, cand, level)
        nwords = fr.pack_bits(new) if fused else None
        return dist, new, nwords, bottom_up_bytes

    def queue_level(frontier, fwords, dist, level, src_rowlocal, dst_fold):
        me_row = lax.axis_index(row_axis)
        ids, _, pack_ovf = fr.pack_frontier_ids(frontier, opts.queue_cap)
        if use_comp_expand:
            pay, enc_ovf = fr.encode_delta_varint(ids, g_byte_cap, b)
            pack_ovf = pack_ovf | enc_ovf
            all_pay = expand_sparse_strategy.impl(pay, col_axis)
            all_ids = jax.vmap(
                lambda seg: fr.decode_delta_varint(seg, opts.queue_cap, b)
            )(all_pay.reshape(c, g_byte_cap)).reshape(-1)        # (c*cap,)
        else:
            all_ids = expand_sparse_strategy.impl(ids, col_axis)  # (c*cap,)
        frow = fr.unpack_row_frontier(all_ids, c, b)             # (c*b, 1)
        valid = dst_fold >= 0
        active = (frow[src_rowlocal, 0] > 0) & valid
        hits = jnp.int32(0)
        if sieve:
            # candidate dst_fold = rr*b + loc targets the vertex owned by
            # the grid device (rr, me_col), global chunk rr*c + me_col —
            # the both-axes summary gather is in exactly that chunk order
            own_sum = fr.sieve_summary(dist[:, 0], sv_bits, sv_bucket)
            gsum = lax.all_gather(own_sum, grid_axes, tiled=True)
            me_col = lax.axis_index(col_axis)
            df = jnp.where(active, dst_fold, 0)
            rr = df // b
            gid = (rr * c + me_col) * b + (df - rr * b)
            drop = fr.sieve_lookup(gsum, gid, b, sv_bits, sv_bucket,
                                   sv_words) & active
            hits = lax.psum(drop.sum(dtype=jnp.int32), grid_axes)
            active = active & ~drop
        buckets, local_mask, _, bucket_ovf = fr.build_queue_buckets_2d(
            dst_fold, active, part2, me_row, opts.queue_cap,
            local_update=opts.local_update, dedupe=opts.dedupe)
        if use_comp_fold:
            base = jnp.arange(r, dtype=jnp.int32)[:, None] * b
            rel = jnp.where(buckets >= 0, buckets - base, -1)
            fpay, fenc_ovf = jax.vmap(
                lambda row: fr.encode_delta_varint(row, g_byte_cap, b))(rel)
            bucket_ovf = bucket_ovf | fenc_ovf.any()
        # Exactness guarantee: if any device's frontier pack, send bucket
        # or compressed stream overflowed, run the whole level densely
        # instead (the predicate is replicated over both grid axes, so
        # every device takes the same branch and collectives stay
        # collective).
        overflow_any = lax.psum(
            (pack_ovf | bucket_ovf).astype(jnp.int32), grid_axes) > 0

        def sparse_branch():
            if use_comp_fold:
                recvp = fold_sparse_strategy.impl(fpay, row_axis)
                rec = jax.vmap(lambda row: fr.decode_delta_varint(
                    row, opts.queue_cap, b))(recvp)              # (r, cap)
                rec = jnp.where(rec >= 0, rec + me_row * b, -1)
            else:
                rec = fold_sparse_strategy.impl(buckets, row_axis)
            own = jnp.maximum(fr.apply_queue(rec, me_row, b), local_mask)
            d2, new = _owned_update(dist, own[:, None], level)
            nwords = fr.pack_bits(new) if fused else None
            return d2, new, nwords, sparse_bytes

        def dense_branch():
            # the sparse expand allgather (and sieve gather) above
            # already ran, so an escalated level pays their bytes on top
            # of the dense level's
            d2, new, nwords, bb = dense_level(frontier, fwords, dist, level,
                                              src_rowlocal, dst_fold)
            return d2, new, nwords, bb + expand_sparse_bytes + sieve_gather_bytes

        d2, new, nwords, bytes_ = lax.cond(overflow_any, dense_branch,
                                           sparse_branch)
        return d2, new, nwords, bytes_, overflow_any, hits

    def body(state, src_rowlocal, dst_fold, in_src_global, in_dst_local,
             out_degree, valid_local, vwords):
        if fused:
            (dist, frontier, fwords, level, _, bytes_acc, overflowed,
             modes, hits_acc) = state
        else:
            (dist, frontier, level, _, bytes_acc, overflowed, modes,
             hits_acc) = state
            fwords = None
        hits = jnp.int32(0)

        if opts.mode == "dense":
            dist, new, nwords, bb = dense_level(frontier, fwords, dist,
                                                level, src_rowlocal,
                                                dst_fold)
            modes = modes.at[0].add(1)
            ovf = jnp.bool_(False)
        elif opts.mode == "queue":
            dist, new, nwords, bb, ovf, hits = queue_level(
                frontier, fwords, dist, level, src_rowlocal, dst_fold)
            modes = modes.at[1].add(1)
        else:  # auto: direction-optimizing hybrid on the grid
            f_verts = lax.psum(frontier.sum(dtype=jnp.int32), grid_axes)
            f_edges = lax.psum(
                (out_degree * frontier[:, 0].astype(jnp.int32)
                 ).sum(dtype=jnp.int32), grid_axes)
            big = f_verts > jnp.int32(bottom_up_cutoff)
            tiny = f_edges < jnp.int32(queue_edge_cutoff)

            def do_bottom_up():
                d, nw, nwd, bb = bottom_up_level(frontier, fwords, dist,
                                                 level, in_src_global,
                                                 in_dst_local)
                return (d, nw, nwd, bb, jnp.bool_(False), jnp.int32(2),
                        jnp.int32(0))

            def do_queue():
                d, nw, nwd, bb, ovf, h = queue_level(frontier, fwords, dist,
                                                     level, src_rowlocal,
                                                     dst_fold)
                return d, nw, nwd, bb, ovf, jnp.int32(1), h

            def do_dense():
                d, nw, nwd, bb = dense_level(frontier, fwords, dist, level,
                                             src_rowlocal, dst_fold)
                return (d, nw, nwd, bb, jnp.bool_(False), jnp.int32(0),
                        jnp.int32(0))

            if s == 1:
                dist, new, nwords, bb, ovf, which, hits = lax.cond(
                    big, do_bottom_up,
                    lambda: lax.cond(tiny, do_queue, do_dense))
            else:
                dist, new, nwords, bb, ovf, which, hits = lax.cond(
                    big, do_bottom_up, do_dense)
            modes = modes.at[which].add(1)

        # Mask padding vertices (ids >= n_logical can never be visited).
        new = new * valid_local[:, None].astype(new.dtype)
        dist = jnp.where(valid_local[:, None], dist, INF)
        active = lax.psum(new.sum(dtype=jnp.int32), grid_axes) > 0
        if fused:
            # next packed generation, pad bits cleared to match the masked
            # byte frontier exactly
            fwords = nwords & vwords
            return (dist, new, fwords, level + 1, active, bytes_acc + bb,
                    overflowed | ovf, modes, hits_acc + hits)
        return (dist, new, level + 1, active, bytes_acc + bb,
                overflowed | ovf, modes, hits_acc + hits)

    def _run(src_rowlocal, dst_fold, in_src_global, in_dst_local,
             out_degree, dist0, frontier0, valid_local):
        if on_trace is not None:
            on_trace()
        tail0 = (jnp.int32(1), jnp.bool_(True), jnp.float32(0),
                 jnp.bool_(False), jnp.zeros(3, jnp.int32), jnp.int32(0))
        if fused:
            vwords = fr.pack_bits(valid_local.astype(jnp.uint8)[:, None])
            state0 = (dist0, frontier0, fr.pack_bits(frontier0)) + tail0
        else:
            vwords = None
            state0 = (dist0, frontier0) + tail0
        lvl_i, act_i = (3, 4) if fused else (2, 3)

        def cond(st):
            return st[act_i] & (st[lvl_i] <= max_levels)

        def body_fn(st):
            return body(st, src_rowlocal, dst_fold, in_src_global,
                        in_dst_local, out_degree, valid_local, vwords)

        st = lax.while_loop(cond, body_fn, state0)
        level = st[lvl_i]
        bytes_acc, overflowed, modes, sieve_hits = st[lvl_i + 2:lvl_i + 6]
        return st[0], level - 1, bytes_acc, overflowed, modes, sieve_hits

    if opts.mode == "auto":
        shard_fn = _run
    else:
        # dense/queue loops never read the bottom-up blocks; the engine
        # uploads only (src_rowlocal, dst_fold) for them
        def shard_fn(src_rowlocal, dst_fold, dist0, frontier0, valid_local):
            return _run(src_rowlocal, dst_fold, None, None, None,
                        dist0, frontier0, valid_local)

    return shard_fn


def bfs(graph: "ShardedGraph", sources, mesh: Optional[Mesh] = None,
        axis=None, opts: BFSOptions = BFSOptions()):
    """One-shot BFS from ``sources`` (int or sequence -> batched).

    .. deprecated::
        ``bfs()`` is a thin wrapper over the compile-once lifecycle —
        ``plan(graph, opts, mesh).compile().run(sources)`` — kept for
        existing call sites.  Engines resolve through the process-wide
        shared ``EngineCache`` (serve/engine_cache.py, LRU over
        ``plan_key()`` with a configurable device-byte budget), so
        repeated calls amortize the compile *and* share compiled engines
        with the serving paths; new code should hold a ``BFSEngine``
        directly (and use ``run_async`` for pipelined dispatch).

    Returns (dist, stats): dist is (n_logical, S) int32 with INF for
    unreachable vertices; stats is a BFSStats.
    """
    from repro.core import engine as _engine  # deferred: engine imports us
    from repro.serve.engine_cache import default_engine_cache

    warnings.warn(
        "repro.core.bfs.bfs() is deprecated; use "
        "plan(graph, opts, mesh=...).compile().run(sources)",
        DeprecationWarning, stacklevel=2)
    src_arr = validate_sources(sources, graph.part.n_logical)
    s = int(src_arr.shape[0])

    pl = _engine.plan(graph, opts, mesh=mesh, axis=axis, num_sources=s)
    eng = default_engine_cache().get_or_compile(pl)
    res = eng.run(src_arr)
    return res.dist_host, res.stats()
