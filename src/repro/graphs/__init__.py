from repro.graphs.formats import (ShardedGraph, ShardedGraph2D,
                                  block_sparse_adjacency, csr_from_coo,
                                  shard_graph, shard_graph_2d,
                                  shard_node_array, to_2d)
from repro.graphs.generators import (GENERATORS, batched_molecules,
                                     chain_graph, dedupe_edges, erdos_renyi,
                                     generate, rmat, small_world, star_graph,
                                     to_undirected)
