from repro.graphs.formats import (ShardedGraph, block_sparse_adjacency,
                                  csr_from_coo, shard_graph, shard_node_array)
from repro.graphs.generators import (GENERATORS, batched_molecules,
                                     dedupe_edges, erdos_renyi, generate,
                                     rmat, small_world, star_graph,
                                     to_undirected)
