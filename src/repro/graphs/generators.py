"""Host-side graph generators (paper §3: star, Erdős-Rényi, small-world).

The paper generates graphs with BOOST on the host and reports that naive
generation of 4M-vertex graphs OOMs (§3.1); their fix is chunked generation
("graph is generated for 1000000 vertices and then concatenated").  We keep
the same discipline: every generator below works in bounded-size chunks of
edges so peak host memory is O(chunk), never O(E) intermediates beyond the
output arrays themselves.

Generators return COO edge arrays ``(src, dst)`` as int64 numpy.  They are
host-side by design — real distributed systems build/load graphs outside
the accelerator hot loop (paper §6 suggests exactly this split as future
work: "by reading it from file ... free processors from graph production").

Also includes the Graph500 RMAT/Kronecker generator as a beyond-paper
workload (the scale-free family the paper motivates with Facebook-like
graphs in §1).
"""

from __future__ import annotations

import numpy as np

_CHUNK = 1_000_000  # edges per generation chunk (mirrors the paper's fix)


def _rng(seed):
    return np.random.default_rng(seed)


def to_undirected(src: np.ndarray, dst: np.ndarray):
    """Symmetrize an edge list (each undirected edge stored both ways)."""
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def dedupe_edges(src: np.ndarray, dst: np.ndarray, n: int, canonical: bool = True):
    """Remove duplicate edges and self loops. O(E log E) host-side.

    With ``canonical=True`` pairs are treated as undirected ((u,v)==(v,u)),
    so a later ``to_undirected`` cannot reintroduce duplicates.
    """
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if canonical:
        src, dst = np.minimum(src, dst), np.maximum(src, dst)
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    return src[idx], dst[idx]


def star_graph(n: int, seed: int = 0):
    """Star on n vertices: vertex 0 is the hub (paper §4.1 workload).

    Worst case for 1-D partitioning: every edge is incident to one vertex,
    so the hub's owner does O(n) expansion work in level 1 while everyone
    else idles — the paper's star table (fig. 3) is dominated by exactly
    this imbalance.
    """
    leaves = np.arange(1, n, dtype=np.int64)
    hub = np.zeros(n - 1, dtype=np.int64)
    return to_undirected(hub, leaves)


def chain_graph(n: int, seed: int = 0):
    """Path 0-1-2-...-(n-1), each edge stored both ways.

    The diameter extreme opposite the star: BFS runs n-1 levels with a
    single-vertex frontier, so per-level overheads (collective latency,
    loop fixed costs) dominate — a worst case for level-synchronous
    engines and the deepest traversal the parity tests exercise.
    """
    base = np.arange(n - 1, dtype=np.int64)
    return to_undirected(base, base + 1)


def erdos_renyi(n: int, avg_degree: float = 16.0, seed: int = 0):
    """G(n, M) Erdős-Rényi with M = n*avg_degree/2 undirected edges.

    Sampled in chunks; duplicates are removed at the end (for sparse
    graphs the duplicate rate is ~M/n^2, negligible).
    """
    rng = _rng(seed)
    m = int(n * avg_degree / 2)
    srcs, dsts = [], []
    left = m
    while left > 0:
        k = min(_CHUNK, left)
        srcs.append(rng.integers(0, n, size=k, dtype=np.int64))
        dsts.append(rng.integers(0, n, size=k, dtype=np.int64))
        left -= k
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    src, dst = dedupe_edges(src, dst, n)
    return to_undirected(src, dst)


def small_world(n: int, k: int = 8, beta: float = 0.1, seed: int = 0):
    """Watts-Strogatz small-world: ring lattice with k neighbors, rewire
    probability beta (paper §4.3 workload). Chunked over vertex ranges."""
    rng = _rng(seed)
    half = k // 2
    srcs, dsts = [], []
    for lo in range(0, n, _CHUNK):
        hi = min(lo + _CHUNK, n)
        base = np.arange(lo, hi, dtype=np.int64)
        for off in range(1, half + 1):
            s = base
            d = (base + off) % n
            rew = rng.random(hi - lo) < beta
            d = np.where(rew, rng.integers(0, n, size=hi - lo, dtype=np.int64), d)
            srcs.append(s)
            dsts.append(d)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    src, dst = dedupe_edges(src, dst, n)
    return to_undirected(src, dst)


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0):
    """Graph500 Kronecker generator: n = 2^scale, E = n*edge_factor.

    Produces the heavy-tailed degree distribution typical of the social
    graphs the paper targets.  Chunked: each chunk draws its bit decisions
    independently.
    """
    rng = _rng(seed)
    n = 1 << scale
    m = n * edge_factor
    srcs, dsts = [], []
    left = m
    while left > 0:
        kk = min(_CHUNK, left)
        s = np.zeros(kk, dtype=np.int64)
        d = np.zeros(kk, dtype=np.int64)
        for bit in range(scale):
            r = rng.random(kk)
            # quadrant probabilities (a, b, c, d)
            go_right = r >= a + c  # columns b+d
            go_down = ((r >= a) & (r < a + c)) | (r >= a + b + c)
            s |= go_down.astype(np.int64) << bit
            d |= go_right.astype(np.int64) << bit
        srcs.append(s)
        dsts.append(d)
        left -= kk
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    src, dst = dedupe_edges(src, dst, n)
    return to_undirected(src, dst)


def batched_molecules(n_nodes: int, n_edges: int, batch: int, d_feat: int, seed: int = 0):
    """A batch of random small graphs packed into one disjoint-union graph
    (for the ``molecule`` GNN shape cell). Returns (src, dst, feats, pos)."""
    rng = _rng(seed)
    srcs, dsts = [], []
    for g in range(batch):
        off = g * n_nodes
        s = rng.integers(0, n_nodes, size=n_edges // 2, dtype=np.int64) + off
        d = rng.integers(0, n_nodes, size=n_edges // 2, dtype=np.int64) + off
        srcs += [s, d]
        dsts += [d, s]
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    n_total = batch * n_nodes
    feats = rng.standard_normal((n_total, d_feat)).astype(np.float32)
    pos = rng.standard_normal((n_total, 3)).astype(np.float32)
    return src, dst, feats, pos


GENERATORS = {
    "star": star_graph,
    "chain": chain_graph,
    "erdos_renyi": erdos_renyi,
    "small_world": small_world,
    "rmat": rmat,
}


# short spec aliases accepted anywhere a graph kind is parsed
ALIASES = {"er": "erdos_renyi", "sw": "small_world"}


def generate(kind: str, n: int, seed: int = 0, **kw):
    kind = ALIASES.get(kind, kind)
    if kind == "star":
        return star_graph(n, seed=seed)
    if kind == "chain":
        return chain_graph(n, seed=seed)
    if kind == "erdos_renyi":
        return erdos_renyi(n, seed=seed, **kw)
    if kind == "small_world":
        return small_world(n, seed=seed, **kw)
    if kind == "rmat":
        scale = int(np.ceil(np.log2(max(n, 2))))
        src, dst = rmat(scale, seed=seed, **kw)
        keep = (src < n) & (dst < n)  # 2^scale may exceed the requested n
        return src[keep], dst[keep]
    raise KeyError(f"unknown graph kind {kind!r}; have {sorted(GENERATORS)}")
