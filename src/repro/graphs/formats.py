"""Partitioned, statically-shaped graph containers for SPMD consumption.

JAX/XLA requires static shapes, and TPU SPMD requires every shard to hold
the same-shaped block.  A ``ShardedGraph`` therefore stores, for each of the
``p`` shards, a fixed-capacity COO edge block padded with sentinel edges
(``dst == -1``).  Out-edges are partitioned by ``owner(src)`` (the paper's
1-D partitioning: the owner of a vertex expands it) and, for the
direction-optimizing bottom-up pass, in-edges are partitioned by
``owner(dst)``.

JAX sparse is BCOO-only; all message-passing/traversal over these blocks is
expressed as gather + ``segment``-scatter ops (or the Pallas ``bsr_spmm``
kernel for the blocked hot path) — see kernel_taxonomy §GNN.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import weakref

import numpy as np

from repro.core.partition import Partition1D, Partition2D

_ALIGN = 128  # pad per-shard edge capacity to a lane-aligned multiple

# Guards only the creation of each graph's to_2d conversion lock;
# conversions themselves run under the per-graph lock, so concurrent
# engine-cache compiles of one catalog graph dedup the (expensive)
# host bucketing while unrelated graphs convert in parallel.
_TO2D_CREATE_LOCK = threading.Lock()

# Guards only the *creation* of each graph's DeviceBlockCache; the
# upload dedup itself uses the cache's own per-graph lock so concurrent
# engine compiles of unrelated graphs never serialize each other's
# (expensive) host bucketing + H2D uploads.
_DEVICE_BLOCKS_CREATE_LOCK = threading.Lock()


class DeviceBlockCache:
    """Per-graph dedup state for uploaded device buffers: a *weak*
    per-(mesh, axis, group) map plus the lock that guards its
    check-then-insert.  Engines hold the strong references
    (core/engine.py ``_BlockGroup``); when the last engine using a group
    dies, its device memory frees.  A ``to_2d`` view shares its parent's
    instance, so the two partition schemes dedup against one map under
    one lock."""

    __slots__ = ("lock", "map")

    def __init__(self):
        self.lock = threading.Lock()
        self.map = weakref.WeakValueDictionary()

    def __len__(self) -> int:
        return len(self.map)


def device_block_cache(graph) -> DeviceBlockCache:
    """Get-or-create ``graph._device_blocks`` (race-free: every creation
    path — engine compile or ``to_2d`` — funnels through here)."""
    with _DEVICE_BLOCKS_CREATE_LOCK:
        m = graph.__dict__.get("_device_blocks")
        if m is None:
            m = DeviceBlockCache()
            graph.__dict__["_device_blocks"] = m
        return m


def _content_fingerprint(meta: tuple, arrays: tuple) -> tuple:
    """Stable content hash of a graph container: structural metadata plus
    a digest of the edge blocks.  Two independently built containers with
    identical blocks fingerprint equal, so the cross-graph engine cache
    (serve/engine_cache.py) keys on *content*, not object identity."""
    h = hashlib.sha1(repr(meta).encode())
    for a in arrays:
        h.update(np.ascontiguousarray(a).tobytes())
    return meta + (h.hexdigest(),)


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass
class ShardedGraph:
    """1-D partitioned graph in padded per-shard COO blocks.

    Attributes (all numpy; ``.jnp()`` views convert lazily):
      part: the vertex partition.
      src_local:  (p, e_cap) int32 — local id of edge source within shard.
      dst_global: (p, e_cap) int32 — global id of edge target; -1 = padding.
      in_src_global / in_dst_local: same for the in-edge (transposed)
        partitioning, used by bottom-up BFS and GNN aggregation.
      n_edges: true (unpadded) directed edge count.
    """

    part: Partition1D
    src_local: np.ndarray
    dst_global: np.ndarray
    in_src_global: np.ndarray
    in_dst_local: np.ndarray
    n_edges: int

    @property
    def p(self) -> int:
        return self.part.p

    @property
    def e_cap(self) -> int:
        return self.src_local.shape[1]

    @property
    def in_e_cap(self) -> int:
        return self.in_src_global.shape[1]

    def flat(self):
        """Arrays reshaped to (p * cap,) so shard_map can slice dim 0."""
        return (
            self.src_local.reshape(-1),
            self.dst_global.reshape(-1),
            self.in_src_global.reshape(-1),
            self.in_dst_local.reshape(-1),
        )

    def degrees(self) -> np.ndarray:
        """In-degree per (padded) global vertex."""
        deg = np.zeros(self.part.n, dtype=np.int64)
        d = self.dst_global[self.dst_global >= 0]
        np.add.at(deg, d, 1)
        return deg

    def edge_list(self):
        """Reconstruct the global COO edge list from the out-edge blocks.

        Order is shard-bucketed, not the original insertion order — fine
        for re-partitioning (the 2-D conversion below) and degree math.
        """
        shard_base = (np.arange(self.p, dtype=np.int64)[:, None]
                      * self.part.shard_size)
        valid = self.dst_global >= 0
        src = (self.src_local.astype(np.int64) + shard_base)[valid]
        dst = self.dst_global[valid].astype(np.int64)
        return src, dst

    def fingerprint(self) -> tuple:
        """Content identity for plan/engine cache keys (cached; the blocks
        are immutable once built)."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            fp = _content_fingerprint(
                ("sharded_graph_1d", self.part.n_logical, self.p,
                 self.e_cap, self.n_edges),
                (self.src_local, self.dst_global))
            self.__dict__["_fingerprint"] = fp
        return fp

    def bsr_shard_caps(self, block: int = 128):
        """``(kmax, block)`` of ``bsr_shards()`` without materializing the
        dense tiles — O(E) work and transient memory, so pricing a
        ``use_kernel`` plan (``estimated_device_bytes``, cache admission)
        never allocates the (p, K, 128, 128) host mirror of an engine
        that may never compile.  Reuses either cache when present."""
        built = self.__dict__.get("_bsr_shards")
        if built is not None and built[0].shape[2] == block:
            return built[0].shape[1], block
        caps = self.__dict__.setdefault("_bsr_shard_caps", {})
        kmax = caps.get(block)
        if kmax is None:
            nb = -(-self.part.n // block)
            kmax = 1
            for j in range(self.p):
                valid = self.dst_global[j] >= 0
                keys = ((self.dst_global[j][valid].astype(np.int64) // block)
                        * nb + self.src_local[j][valid] // block)
                kmax = max(kmax, np.unique(keys).size)
            caps[block] = kmax
        return kmax, block

    def bsr_shards(self, block: int = 128):
        """Per-shard blocked *transposed* adjacency for the Pallas
        ``bsr_spmm`` frontier expansion (built and cached on first use —
        non-kernel engines never pay the host tiling).

        Shard ``j``'s matrix has rows = global candidate ids (padded to a
        block multiple of ``part.n``) and cols = local source ids (padded
        to a block multiple of ``shard_size``), so ``A_j^T @ f_local``
        is the shard's dense expansion.  Shards are padded to a common
        tile count with all-zero tiles so the arrays shard uniformly
        under shard_map; a pad tile repeats the shard's last block row
        (never a *smaller* row — the kernel's ``row_changed`` accumulator
        reset fires on block-row transitions, and a backwards jump would
        re-zero a finished output tile).

        Returns ``(blocks (p, K, B, B) f32, block_rows (p, K) i32,
        block_cols (p, K) i32, n_rows_pad, n_cols_pad)``.
        """
        cached = self.__dict__.get("_bsr_shards")
        if cached is not None and cached[0].shape[2] == block:
            return cached
        part = self.part
        p, shard = self.p, part.shard_size
        n_rows_pad = _pad_to(part.n, block)
        n_cols_pad = _pad_to(shard, block)
        per_shard = []
        for j in range(p):
            valid = self.dst_global[j] >= 0
            src_l = self.src_local[j][valid].astype(np.int64)   # cols
            dst_g = self.dst_global[j][valid].astype(np.int64)  # rows
            blocks, brr, bcc, _ = block_sparse_adjacency(
                dst_g, src_l, part.n, block=block)
            per_shard.append((blocks, brr, bcc))
        # at least one (all-zero) tile so an edgeless shard still hands
        # the kernel a nonempty grid
        kmax = max(1, max(b.shape[0] for b, _, _ in per_shard))
        blocks_out = np.zeros((p, kmax, block, block), np.float32)
        br_out = np.zeros((p, kmax), np.int32)
        bc_out = np.zeros((p, kmax), np.int32)
        for j, (blocks, brr, bcc) in enumerate(per_shard):
            k = blocks.shape[0]
            blocks_out[j, :k] = blocks
            br_out[j, :k] = brr
            bc_out[j, :k] = bcc
            if k < kmax:                  # pad rows stay monotone (see doc)
                br_out[j, k:] = brr[-1] if k else 0
        cached = (blocks_out, br_out, bc_out, n_rows_pad, n_cols_pad)
        self.__dict__["_bsr_shards"] = cached
        return cached


def _bucket(key_owner: np.ndarray, p: int, arrays, e_cap: int, fills):
    """Stable-sort ``arrays`` by owner and pack into (p, e_cap) blocks."""
    order = np.argsort(key_owner, kind="stable")
    counts = np.bincount(key_owner, minlength=p)
    out = [np.full((p, e_cap), f, dtype=np.int32) for f in fills]
    start = 0
    offs = np.zeros(p + 1, dtype=np.int64)
    np.cumsum(counts, out=offs[1:])
    for j in range(p):
        sel = order[offs[j]:offs[j + 1]]
        k = sel.shape[0]
        if k > e_cap:
            raise ValueError(f"shard {j} has {k} edges > capacity {e_cap}")
        for o, a in zip(out, arrays):
            o[j, :k] = a[sel]
    return out, counts


def shard_graph(src: np.ndarray, dst: np.ndarray, n: int, p: int,
                e_cap: int | None = None) -> ShardedGraph:
    """Partition a COO edge list across ``p`` shards (paper §2.1).

    ``e_cap`` defaults to the max per-shard edge count rounded up to 128.
    For a star graph this is Θ(n) on the hub's shard — the same imbalance
    the paper observes (fig. 3); callers can inspect ``degrees()``.
    """
    part = Partition1D(n, p)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.size:
        assert src.max() < n and dst.max() < n and src.min() >= 0 and dst.min() >= 0

    own_src = np.asarray(part.owner(src))
    own_dst = np.asarray(part.owner(dst))
    max_out = int(np.bincount(own_src, minlength=p).max()) if src.size else 0
    max_in = int(np.bincount(own_dst, minlength=p).max()) if src.size else 0
    cap_out = e_cap or max(_pad_to(max(max_out, 1), _ALIGN), _ALIGN)
    cap_in = e_cap or max(_pad_to(max(max_in, 1), _ALIGN), _ALIGN)

    (s_loc, d_glob), _ = _bucket(
        own_src, p, [np.asarray(part.local_id(src)), dst], cap_out, fills=(0, -1))
    (in_s_glob, in_d_loc), _ = _bucket(
        own_dst, p, [src, np.asarray(part.local_id(dst))], cap_in, fills=(-1, 0))

    return ShardedGraph(
        part=part,
        src_local=s_loc, dst_global=d_glob,
        in_src_global=in_s_glob, in_dst_local=in_d_loc,
        n_edges=int(src.size),
    )


@dataclasses.dataclass
class ShardedGraph2D:
    """2-D edge-partitioned graph: one padded COO block per grid cell.

    Block ``(i, j)`` (stored at linear index ``i*c + j``) holds every edge
    whose source is owned by grid row ``i`` and whose target is owned by
    grid column ``j``.  Edges are pre-encoded for the two-phase BFS level:

      src_rowlocal: (p, e_cap) int32 — source id relative to the row block
        (an index into the expand-phase ``(c*b, S)`` gathered frontier).
      dst_fold:     (p, e_cap) int32 — target in the transposed fold layout
        ``row_rank(owner(dst)) * b + local_id(dst)``; -1 = padding.

    For the direction-optimizing bottom-up level, in-edges are bucketed a
    second time by the *owner cell of the target* (each device holds the
    in-edges of the vertices it owns, like the 1-D container).  Those
    blocks are derived lazily — ``bottom_up_blocks()`` builds and caches
    them on first use, so dense-mode engines never pay their host build
    time or device memory:

      in_src_global: (p, in_e_cap) int32 — global source id (an index into
        the fully gathered ``(n, S)`` frontier); -1 = padding.
      in_dst_local:  (p, in_e_cap) int32 — target local id in ``[0, b)``;
        -1 = padding.
      out_degree:    (p, b) int32 — out-degree of every owned (padded)
        vertex; drives the replicated frontier-edge statistic of the
        per-level ``auto`` mode decision.
    """

    part: Partition2D
    src_rowlocal: np.ndarray
    dst_fold: np.ndarray
    n_edges: int

    @property
    def p(self) -> int:
        return self.part.p

    @property
    def e_cap(self) -> int:
        return self.src_rowlocal.shape[1]

    def flat(self):
        """Arrays reshaped to (p * cap,) so shard_map can slice dim 0."""
        return (self.src_rowlocal.reshape(-1), self.dst_fold.reshape(-1))

    def edge_list(self):
        """Reconstruct the global COO edge list from the cell blocks.

        Order is cell-bucketed, not the original insertion order — fine
        for re-bucketing (the bottom-up blocks below) and degree math.
        """
        part = self.part
        b, c = part.shard_size, part.c
        cell = np.arange(self.p, dtype=np.int64)[:, None]       # (p, 1)
        valid = self.dst_fold >= 0
        src = (self.src_rowlocal.astype(np.int64)
               + (cell // c) * part.row_block_size)[valid]
        vf = self.dst_fold.astype(np.int64)
        # invert fold_index: owner = row_rank * c + grid_col(cell)
        dst = (((vf // b) * c + cell % c) * b + vf % b)[valid]
        return src, dst

    def bottom_up_in_cap(self) -> int:
        """Padded per-cell capacity of the bottom-up in-edge blocks.

        Exact (a bincount over the edge list, cached) without building
        the blocks themselves — under degree skew this exceeds ``e_cap``
        (a star hub's owner holds almost every in-edge), and the engine
        cache's byte budget must charge the real figure to stay an upper
        bound."""
        cached = self.__dict__.get("_bottom_up_blocks")
        if cached is not None:
            return cached[0].shape[1]
        cap = self.__dict__.get("_bottom_up_in_cap")
        if cap is None:
            src, dst = self.edge_list()
            own_d = np.asarray(self.part.owner(dst))
            max_in = (int(np.bincount(own_d, minlength=self.p).max())
                      if src.size else 0)
            cap = max(_pad_to(max(max_in, 1), _ALIGN), _ALIGN)
            self.__dict__["_bottom_up_in_cap"] = cap
        return cap

    def bottom_up_blocks(self):
        """(in_src_global, in_dst_local, out_degree) — built and cached on
        first use (the ``auto`` engine's bottom-up level needs them; the
        dense and queue level loops never do)."""
        cached = self.__dict__.get("_bottom_up_blocks")
        if cached is None:
            part = self.part
            src, dst = self.edge_list()
            own_d = np.asarray(part.owner(dst))
            cap_in = self.bottom_up_in_cap()
            (in_s_glob, in_d_loc), _ = _bucket(
                own_d, self.p, [src, np.asarray(part.local_id(dst))],
                cap_in, fills=(-1, -1))
            out_degree = np.bincount(src, minlength=part.n).reshape(
                self.p, part.shard_size).astype(np.int32)
            cached = (in_s_glob, in_d_loc, out_degree)
            self.__dict__["_bottom_up_blocks"] = cached
        return cached

    def bottom_up_flat(self):
        """``bottom_up_blocks()`` reshaped to (p * cap,) for shard_map."""
        return tuple(a.reshape(-1) for a in self.bottom_up_blocks())

    @property
    def in_src_global(self) -> np.ndarray:
        return self.bottom_up_blocks()[0]

    @property
    def in_dst_local(self) -> np.ndarray:
        return self.bottom_up_blocks()[1]

    @property
    def out_degree(self) -> np.ndarray:
        return self.bottom_up_blocks()[2]

    @property
    def in_e_cap(self) -> int:
        return self.in_src_global.shape[1]

    def fingerprint(self) -> tuple:
        """Content identity for plan/engine cache keys (cached).

        Pure content hash of the cell blocks, so plans built from the 1-D
        parent (``plan(g, partition="2d")``) and from its cached
        conversion (``plan(to_2d(g, r, c))``) — the *same* object, by the
        ``to_2d`` cache — key identically in the engine cache."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            fp = _content_fingerprint(
                ("sharded_graph_2d", self.part.n_logical, self.part.r,
                 self.part.c, self.e_cap, self.n_edges),
                (self.src_rowlocal, self.dst_fold))
            self.__dict__["_fingerprint"] = fp
        return fp


def shard_graph_2d(src: np.ndarray, dst: np.ndarray, n: int, r: int, c: int,
                   e_cap: int | None = None) -> ShardedGraph2D:
    """Partition a COO edge list over an ``r x c`` grid (2-D edge blocks).

    Edge ``(u, v)`` goes to grid cell ``(grid_row(owner(u)),
    grid_col(owner(v)))``; ``e_cap`` defaults to the max per-cell edge
    count rounded up to 128 (same padding discipline as ``shard_graph``).
    """
    part = Partition2D(n, r, c)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.size:
        assert src.max() < n and dst.max() < n and src.min() >= 0 and dst.min() >= 0

    own_s = np.asarray(part.owner(src))
    own_d = np.asarray(part.owner(dst))
    gi = np.asarray(part.grid_row(own_s))   # source's grid row
    gj = np.asarray(part.grid_col(own_d))   # target's grid column
    cell = gi * c + gj
    src_rowlocal = src - gi * part.row_block_size
    dst_fold = np.asarray(part.fold_index(dst))

    max_cell = int(np.bincount(cell, minlength=part.p).max()) if src.size else 0
    cap = e_cap or max(_pad_to(max(max_cell, 1), _ALIGN), _ALIGN)
    (s_row, d_fold), _ = _bucket(
        cell, part.p, [src_rowlocal, dst_fold], cap, fills=(0, -1))

    return ShardedGraph2D(part=part, src_rowlocal=s_row, dst_fold=d_fold,
                          n_edges=int(src.size))


def to_2d(graph: ShardedGraph, r: int, c: int) -> ShardedGraph2D:
    """Derive (and cache) the 2-D edge blocks of a 1-D sharded graph.

    ``plan(graph, ..., partition="2d")`` and ``GraphCatalog`` both route
    through this so callers keep one graph object regardless of partition
    scheme: the same ``ShardedGraph2D`` instance is returned for the same
    grid (thread-safe — engine-cache compiles may convert concurrently),
    and the conversion shares the parent's per-(mesh, axis) device-buffer
    cache so holding both a 1-D and a 2-D plan of one graph never uploads
    shared buffers (e.g. the validity mask) twice.  Requires ``r*c`` equal
    to the graph's shard count so the vertex chunks line up exactly.
    """
    if r * c != graph.part.p:
        raise ValueError(f"grid {r}x{c} does not match the graph's "
                         f"p={graph.part.p} vertex chunks")
    with _TO2D_CREATE_LOCK:
        lock = graph.__dict__.setdefault("_to2d_lock", threading.Lock())
    with lock:
        cache = graph.__dict__.setdefault("_graph2d", {})
        g2 = cache.get((r, c))
        if g2 is None:
            src, dst = graph.edge_list()
            g2 = shard_graph_2d(src, dst, graph.part.n_logical, r, c)
            # same weak dedup state as the parent (engine.py uploads hold
            # the strong refs), so shared buffers upload once across the
            # two partition views of this graph; g2 is not yet published,
            # so plain assignment cannot race
            g2.__dict__["_device_blocks"] = device_block_cache(graph)
            cache[(r, c)] = g2
    return g2


def shard_node_array(x: np.ndarray, part: Partition1D, fill=0.0) -> np.ndarray:
    """Pad a (n_logical, ...) vertex array to (part.n, ...) for sharding."""
    return part.pad_vertex_array(np.asarray(x), fill=fill)


def csr_from_coo(src: np.ndarray, dst: np.ndarray, n: int):
    """Host-side CSR (indptr, indices) sorted by src — used by the neighbor
    sampler and the blocked-adjacency builder for the Pallas kernel."""
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(src_s, minlength=n), out=indptr[1:])
    return indptr, dst_s.astype(np.int64)


def block_sparse_adjacency(src: np.ndarray, dst: np.ndarray, n: int,
                           block: int = 128):
    """Blocked 0/1 adjacency for the ``bsr_spmm`` Pallas kernel.

    Returns (blocks, block_rows, block_cols): ``blocks[k]`` is a dense
    (block, block) f32 tile of A[block_rows[k]*B :, block_cols[k]*B :].
    Only nonempty tiles are materialized (block-CSR, row-major order) —
    this is the TPU-native storage for the frontier-expansion hot loop
    (DESIGN.md §Hardware-adaptation).
    """
    nb = -(-n // block)
    n_pad = nb * block
    br = src // block
    bc = dst // block
    key = br * nb + bc
    uniq, inv = np.unique(key, return_inverse=True)
    k = uniq.shape[0]
    blocks = np.zeros((k, block, block), dtype=np.float32)
    blocks[inv, src % block, dst % block] = 1.0
    block_rows = (uniq // nb).astype(np.int32)
    block_cols = (uniq % nb).astype(np.int32)
    return blocks, block_rows, block_cols, n_pad
