"""Layered neighbor sampler (GraphSAGE-style) for the minibatch_lg cell.

Host-side numpy over CSR, as in production systems (samplers live in the
data pipeline, not on the accelerator).  Output is a padded, statically-
shaped subgraph batch matching ``data.synthetic.gnn_specs`` exactly:

  * layer 0: ``batch_nodes`` seed nodes,
  * layer k: ``fanout[k-1]`` sampled in-neighbors per layer-(k-1) node
    (with replacement when degree < fanout, standard GraphSAGE),
  * edges point child -> parent (messages flow toward the seeds),
  * node ids are batch-local (gathered features come along).

Determinism: a seed fully determines the sample — the trainer's
restart-replay contract extends through the sampler.
"""

from __future__ import annotations

import numpy as np


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray,
                 features: np.ndarray | None = None):
        self.indptr = indptr
        self.indices = indices
        self.features = features
        self.n = indptr.shape[0] - 1

    def sample(self, seeds: np.ndarray, fanouts, *, seed: int = 0,
               n_pad: int, e_pad: int, d_feat: int):
        rng = np.random.default_rng(seed)
        layers = [np.asarray(seeds, dtype=np.int64)]
        srcs, dsts = [], []
        offset = 0
        for f in fanouts:
            parents = layers[-1]
            deg = self.indptr[parents + 1] - self.indptr[parents]
            # sample f neighbors per parent (with replacement; isolated
            # parents self-loop so shapes stay static)
            draw = rng.integers(0, np.maximum(deg, 1)[:, None],
                                size=(parents.shape[0], f))
            base = self.indptr[parents][:, None]
            child = self.indices[base + draw]                  # (P, f)
            child = np.where(deg[:, None] > 0, child, parents[:, None])
            # local ids: parents live at [offset, offset+P); children are
            # appended as a new layer
            child_local = (offset + parents.shape[0]
                           + np.arange(parents.shape[0] * f))
            parent_local = offset + np.repeat(np.arange(parents.shape[0]), f)
            srcs.append(child_local)
            dsts.append(parent_local)
            offset += parents.shape[0]
            layers.append(child.reshape(-1))

        nodes = np.concatenate(layers)
        src = np.concatenate(srcs)
        dst = np.concatenate(dsts)
        n_real, e_real = nodes.shape[0], src.shape[0]
        assert n_real <= n_pad and e_real <= e_pad, (n_real, n_pad, e_real,
                                                     e_pad)

        if self.features is not None:
            feats = self.features[nodes].astype(np.float32)
        else:
            fr = np.random.default_rng(seed + 1)
            feats = fr.standard_normal((n_real, d_feat)).astype(np.float32)

        batch = {
            "node_feats": np.zeros((n_pad, d_feat), np.float32),
            "edge_src": np.zeros((e_pad,), np.int32),
            "edge_dst": np.full((e_pad,), -1, np.int32),
            "valid_nodes": np.zeros((n_pad,), bool),
            "global_ids": np.full((n_pad,), -1, np.int64),
        }
        batch["node_feats"][:n_real] = feats
        batch["edge_src"][:e_real] = src
        batch["edge_dst"][:e_real] = dst
        batch["valid_nodes"][:n_real] = True
        batch["global_ids"][:n_real] = nodes
        return batch
