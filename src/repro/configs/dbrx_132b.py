"""DBRX-132B: 16-expert fine-grained MoE, top-4 routing, GQA.
[hf:databricks/dbrx-base; unverified]"""

from repro.configs.base import LayerSpec, MoEConfig, TransformerConfig

FAMILY = "lm"
SOURCE = "hf:databricks/dbrx-base; unverified"

CONFIG = TransformerConfig(
    name="dbrx-132b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    pattern=(LayerSpec(moe=True),),
    moe=MoEConfig(n_experts=16, top_k=4, d_ff=10752),
)

REDUCED = TransformerConfig(
    name="dbrx-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=256,
    pattern=(LayerSpec(moe=True),),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=96),
    dtype="float32",
)
