"""Gemma-3 12B: dense, 5:1 local:global attention (1024-token sliding
window on local layers), 128k context. [hf:google/gemma-3-1b-pt; unverified]"""

from repro.configs.base import LayerSpec, TransformerConfig

FAMILY = "lm"
SOURCE = "hf:google/gemma-3-1b-pt; unverified"

_LOCAL = LayerSpec(window=1024)
_GLOBAL = LayerSpec(window=0)

CONFIG = TransformerConfig(
    name="gemma3-12b",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    rope_theta=1_000_000.0,
)

REDUCED = TransformerConfig(
    name="gemma3-reduced",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    pattern=(LayerSpec(window=16), LayerSpec(window=16), LayerSpec(window=16),
             LayerSpec(window=16), LayerSpec(window=16), LayerSpec(window=0)),
    dtype="float32",
)
