"""Llama-4 Maverick 400B-A17B: 128-expert top-1 MoE interleaved with dense
layers, one shared expert (early-fusion backbone; frontend stubbed).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.configs.base import LayerSpec, MoEConfig, TransformerConfig

FAMILY = "lm"
SOURCE = "hf:meta-llama/Llama-4-Scout-17B-16E; unverified"

CONFIG = TransformerConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    # Maverick alternates dense-FFN and MoE layers (interleave_moe=2)
    pattern=(LayerSpec(moe=False), LayerSpec(moe=True)),
    moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, shared_experts=1),
    rope_theta=500_000.0,
)

REDUCED = TransformerConfig(
    name="llama4-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256,
    pattern=(LayerSpec(moe=False), LayerSpec(moe=True)),
    moe=MoEConfig(n_experts=8, top_k=1, d_ff=64, shared_experts=1),
    dtype="float32",
)
