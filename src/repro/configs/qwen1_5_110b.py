"""Qwen1.5-110B: dense GQA with QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs.base import LayerSpec, TransformerConfig

FAMILY = "lm"
SOURCE = "hf:Qwen/Qwen1.5-0.5B; hf"

CONFIG = TransformerConfig(
    name="qwen1.5-110b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab=152064, qkv_bias=True,
    rope_theta=1_000_000.0,
)

REDUCED = TransformerConfig(
    name="qwen-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, qkv_bias=True, dtype="float32",
)
