"""GatedGCN (Bresson & Laurent): edge-gated message passing, 16 layers,
d=70. [arXiv:2003.00982; paper]"""

from repro.configs.base import GNNConfig

FAMILY = "gnn"
SOURCE = "arXiv:2003.00982; paper"

CONFIG = GNNConfig(
    name="gatedgcn", kind="gatedgcn",
    n_layers=16, d_hidden=70, aggregator="gated", d_out=1,
)

REDUCED = GNNConfig(
    name="gatedgcn-reduced", kind="gatedgcn",
    n_layers=2, d_hidden=16, aggregator="gated", d_out=1,
)
