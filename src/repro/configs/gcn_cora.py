"""GCN (Kipf & Welling) Cora configuration: 2 layers, d=16, mean/symmetric
normalization. [arXiv:1609.02907; paper]"""

from repro.configs.base import GNNConfig

FAMILY = "gnn"
SOURCE = "arXiv:1609.02907; paper"

CONFIG = GNNConfig(
    name="gcn-cora", kind="gcn",
    n_layers=2, d_hidden=16, aggregator="mean", norm="sym", d_out=7,
)

REDUCED = GNNConfig(
    name="gcn-reduced", kind="gcn",
    n_layers=2, d_hidden=8, aggregator="mean", norm="sym", d_out=3,
)
