"""DeepFM: 39 sparse fields x dim-10 embeddings, FM interaction + 400-400-400
deep MLP. Vocab per field set to 1M rows (Criteo-scale tables; the published
config gives field/dim/MLP only). [arXiv:1703.04247; paper]"""

from repro.configs.base import RecsysConfig

FAMILY = "recsys"
SOURCE = "arXiv:1703.04247; paper"

CONFIG = RecsysConfig(
    name="deepfm",
    n_sparse=39, n_dense=13, embed_dim=10, vocab_per_field=1_000_000,
    mlp_dims=(400, 400, 400),
)

REDUCED = RecsysConfig(
    name="deepfm-reduced",
    n_sparse=6, n_dense=4, embed_dim=8, vocab_per_field=100,
    mlp_dims=(32, 32),
)
