"""GraphCast: encoder-processor-decoder mesh GNN, 16 processor layers,
d=512, sum aggregation, 227 output variables.  mesh_refinement=6 describes
the native icosahedral mesh (40,962 nodes); the assigned shape cells supply
the actual graph per cell. [arXiv:2212.12794; unverified]"""

from repro.configs.base import GNNConfig

FAMILY = "gnn"
SOURCE = "arXiv:2212.12794; unverified"

CONFIG = GNNConfig(
    name="graphcast", kind="graphcast",
    n_layers=16, d_hidden=512, aggregator="sum",
    n_vars=227, mesh_refinement=6, d_out=227,
)

REDUCED = GNNConfig(
    name="graphcast-reduced", kind="graphcast",
    n_layers=2, d_hidden=32, aggregator="sum",
    n_vars=5, mesh_refinement=1, d_out=5,
)
