"""SchNet: continuous-filter convolutions over interatomic distances,
3 interaction blocks, d=64, 300 RBFs, 10A cutoff. [arXiv:1706.08566; paper]"""

from repro.configs.base import GNNConfig

FAMILY = "gnn"
SOURCE = "arXiv:1706.08566; paper"

CONFIG = GNNConfig(
    name="schnet", kind="schnet",
    n_layers=3, d_hidden=64, aggregator="sum",
    rbf=300, cutoff=10.0, d_out=1,
)

REDUCED = GNNConfig(
    name="schnet-reduced", kind="schnet",
    n_layers=2, d_hidden=16, aggregator="sum",
    rbf=16, cutoff=5.0, d_out=1,
)
