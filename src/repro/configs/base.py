"""Config dataclasses + the architecture/shape registry.

Every assigned architecture is a module ``repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published configuration) and ``REDUCED`` (a tiny
same-family config for CPU smoke tests).  ``registry()`` maps arch id ->
ArchSpec; shape cells are per-family (LM / GNN / RecSys / BFS).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional, Sequence


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden width
    capacity_factor: float = 1.25
    shared_experts: int = 0        # dense experts always active (Llama-4)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating layer pattern."""
    window: int = 0                # 0 = global attention; >0 = sliding window
    moe: bool = False


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # attention impl knobs (hillclimb levers; see EXPERIMENTS.md §Perf)
    attn_chunk: int = 1024         # kv-chunked online-softmax attention
    remat: str = "block"           # none | block | dots — bwd recompute policy
    tie_embeddings: bool = False   # untied: input table D-sharded (gather-
                                   # friendly), output head V-sharded

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: n_layers % pattern period != 0"
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    def param_count(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * dh
        dense_ffn = 3 * d * self.d_ff
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            spec = self.pattern[i % len(self.pattern)]
            total += attn + 2 * d
            if spec.moe and self.moe:
                m = self.moe
                total += d * m.n_experts                   # router
                total += m.n_experts * 3 * d * m.d_ff      # routed experts
                total += m.shared_experts * 3 * d * m.d_ff
            else:
                total += dense_ffn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        for i in range(self.n_layers):
            if self.pattern[i % len(self.pattern)].moe:
                total -= (m.n_experts - m.top_k) * 3 * d * m.d_ff
        return total


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    step: str            # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES = (
    LMShape("train_4k", "train", 4_096, 256),
    LMShape("prefill_32k", "prefill", 32_768, 32),
    LMShape("decode_32k", "decode", 32_768, 128),
    LMShape("long_500k", "decode", 524_288, 1),
)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                      # gcn | gatedgcn | schnet | graphcast
    n_layers: int
    d_hidden: int
    aggregator: str = "sum"        # sum | mean | gated
    d_out: int = 1
    # family extras
    rbf: int = 0                   # schnet radial basis size
    cutoff: float = 0.0            # schnet distance cutoff
    n_vars: int = 0                # graphcast output variables
    mesh_refinement: int = 0       # graphcast native icosahedral refinement
    norm: str = "none"             # gcn-cora: sym normalization
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    mode: str                      # full | sampled | batched
    n_nodes: int
    n_edges: int
    d_feat: int
    batch_nodes: int = 0           # sampled mode: seed nodes per step
    fanout: tuple = ()             # sampled mode: per-hop fanout
    batch_graphs: int = 0          # batched mode: graphs per batch


GNN_SHAPES = (
    GNNShape("full_graph_sm", "full", 2_708, 10_556, 1_433),
    # Reddit-scale sampled training; d_feat=602 (Reddit's feature width —
    # the cell spec gives counts only).  The step input is the sampled
    # subgraph: 1024 seeds, fanout 15 then 10.
    GNNShape("minibatch_lg", "sampled", 232_965, 114_615_892, 602,
             batch_nodes=1_024, fanout=(15, 10)),
    GNNShape("ogb_products", "full", 2_449_029, 61_859_140, 100),
    GNNShape("molecule", "batched", 30, 64, 32, batch_graphs=128),
)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_sparse: int                  # categorical fields
    n_dense: int                   # dense features (Criteo: 13)
    embed_dim: int
    vocab_per_field: int           # rows per field table
    mlp_dims: tuple
    interaction: str = "fm"
    dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.vocab_per_field


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    step: str                      # train | serve | retrieval
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = (
    RecsysShape("train_batch", "train", 65_536),
    RecsysShape("serve_p99", "serve", 512),
    RecsysShape("serve_bulk", "serve", 262_144),
    RecsysShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
)


# ---------------------------------------------------------------------------
# BFS workloads (the paper's own experiments, §4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BFSWorkload:
    name: str
    graph: str                     # generators.GENERATORS key
    n_vertices: int
    gen_kwargs: tuple = ()         # sorted (k, v) pairs
    n_sources: int = 1


BFS_WORKLOADS = (
    BFSWorkload("star_4m", "star", 4_000_000),
    BFSWorkload("erdos_renyi_100k", "erdos_renyi", 100_000,
                (("avg_degree", 16.0),)),
    BFSWorkload("small_world_100k", "small_world", 100_000,
                (("beta", 0.1), ("k", 16))),
    BFSWorkload("rmat_1m", "rmat", 1_048_576, (("edge_factor", 16),)),
)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # lm | gnn | recsys
    config: Any
    reduced: Any
    source: str                    # provenance note from the assignment

    @property
    def shapes(self) -> Sequence:
        return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                "recsys": RECSYS_SHAPES}[self.family]


ARCH_IDS = (
    "dbrx_132b", "llama4_maverick_400b_a17b", "gemma3_12b", "yi_34b",
    "qwen1_5_110b",
    "graphcast", "gatedgcn", "schnet", "gcn_cora",
    "deepfm",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def registry() -> dict:
    out = {}
    for arch_id in ARCH_IDS:
        mod = importlib.import_module(f"repro.configs.{arch_id}")
        out[arch_id] = ArchSpec(
            arch_id=arch_id, family=mod.FAMILY, config=mod.CONFIG,
            reduced=mod.REDUCED, source=mod.SOURCE)
    return out


def get_arch(arch_id: str) -> ArchSpec:
    arch_id = _ALIASES.get(arch_id, arch_id)
    return registry()[arch_id]


def get_shape(spec: ArchSpec, shape_name: str):
    for sh in spec.shapes:
        if sh.name == shape_name:
            return sh
    raise KeyError(f"{spec.arch_id} has no shape {shape_name!r}; "
                   f"have {[s.name for s in spec.shapes]}")


def all_cells():
    """All 40 assigned (arch, shape) cells."""
    for arch_id in ARCH_IDS:
        spec = get_arch(arch_id)
        for sh in spec.shapes:
            yield spec, sh
