"""Synthetic batch builders: concrete numpy batches for smoke tests /
examples, and ShapeDtypeStruct specs for the dry-run (no allocation).

Every builder comes in two flavours with identical pytree structure:
``*_batch`` (real arrays, reduced sizes ok) and ``*_specs`` (abstract).
The dry-run contract is that ``input_specs()`` stand-ins are weak-type
correct and shardable.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import (GNNConfig, GNNShape, LMShape, RecsysConfig,
                                RecsysShape, TransformerConfig)
from repro.graphs.generators import erdos_renyi

SDS = jax.ShapeDtypeStruct


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


# ------------------------------------------------------------------- LM
def lm_train_batch(cfg: TransformerConfig, batch: int, seq: int, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, cfg.vocab, (batch, seq + 1),
                                   dtype=np.int32)}


def lm_train_specs(cfg: TransformerConfig, shape: LMShape):
    return {"tokens": SDS((shape.global_batch, shape.seq_len + 1), jnp.int32)}


def lm_prefill_specs(cfg: TransformerConfig, shape: LMShape):
    return {"tokens": SDS((shape.global_batch, shape.seq_len), jnp.int32)}


def lm_decode_specs(cfg: TransformerConfig, shape: LMShape):
    from repro.models.transformer import init_cache
    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    return {
        "cache": cache,
        "pos": SDS((), jnp.int32),
        "last_token": SDS((shape.global_batch,), jnp.int32),
    }


# ------------------------------------------------------------------ GNN
def _gnn_dims(cfg: GNNConfig, shape: GNNShape, pad: int = 512):
    """Static padded (N, E) for the step input of each GNN mode."""
    if shape.mode == "sampled":
        n = shape.batch_nodes
        e = 0
        layer = shape.batch_nodes
        for f in shape.fanout:
            layer *= f
            n += layer
            e += layer
        return _pad_to(n, pad), _pad_to(e, pad)
    if shape.mode == "batched":
        return (_pad_to(shape.n_nodes * shape.batch_graphs, pad),
                _pad_to(shape.n_edges * shape.batch_graphs, pad))
    return _pad_to(shape.n_nodes, pad), _pad_to(shape.n_edges, pad)


def _gnn_target_fields(cfg: GNNConfig, shape: GNNShape, n: int, make):
    """Task head differs per arch/mode; see models/gnn/models.loss_fn."""
    out = {}
    if cfg.kind == "gcn":
        out["labels"] = make((n,), jnp.int32)
    elif shape.mode == "batched":
        out["graph_id"] = make((n,), jnp.int32)
        out["graph_targets"] = make((shape.batch_graphs, cfg.d_out), jnp.float32)
    else:
        out["targets"] = make((n, cfg.d_out), jnp.float32)
    return out


def gnn_specs(cfg: GNNConfig, shape: GNNShape, pad: int = 512):
    n, e = _gnn_dims(cfg, shape, pad)
    make = lambda s, d: SDS(s, d)
    batch = {
        "node_feats": SDS((n, shape.d_feat), jnp.float32),
        "edge_src": SDS((e,), jnp.int32),
        "edge_dst": SDS((e,), jnp.int32),
        "valid_nodes": SDS((n,), jnp.bool_),
    }
    if cfg.kind == "schnet":
        batch["pos"] = SDS((n, 3), jnp.float32)
    if cfg.kind in ("gatedgcn", "graphcast"):
        batch["edge_feats"] = SDS((e, 4 if cfg.kind == "graphcast" else 1),
                                  jnp.float32)
    batch.update(_gnn_target_fields(cfg, shape, n,
                                    lambda s, d=jnp.float32: SDS(s, d)))
    if "labels" in batch:
        batch["labels"] = SDS((n,), jnp.int32)
    return batch


def gnn_batch(cfg: GNNConfig, shape: GNNShape, seed=0, pad: int = 128):
    """Concrete reduced-size batch: real random graph + features."""
    rng = np.random.default_rng(seed)
    n, e = _gnn_dims(cfg, shape, pad)
    src, dst = erdos_renyi(n, avg_degree=min(8, max(2, e // max(n, 1))),
                           seed=seed)
    e_used = min(src.shape[0], e)
    es = np.zeros((e,), np.int32)
    ed = np.full((e,), -1, np.int32)
    es[:e_used] = src[:e_used]
    ed[:e_used] = dst[:e_used]
    batch = {
        "node_feats": rng.standard_normal((n, shape.d_feat)).astype(np.float32),
        "edge_src": es, "edge_dst": ed,
        "valid_nodes": np.ones((n,), bool),
    }
    if cfg.kind == "schnet":
        batch["pos"] = rng.standard_normal((n, 3)).astype(np.float32)
    if cfg.kind == "gatedgcn":
        batch["edge_feats"] = rng.standard_normal((e, 1)).astype(np.float32)
    if cfg.kind == "graphcast":
        batch["edge_feats"] = rng.standard_normal((e, 4)).astype(np.float32)
    if cfg.kind == "gcn":
        batch["labels"] = rng.integers(0, cfg.d_out, (n,)).astype(np.int32)
    elif shape.mode == "batched":
        batch["graph_id"] = np.minimum(
            np.arange(n) // max(shape.n_nodes, 1),
            shape.batch_graphs - 1).astype(np.int32)
        batch["graph_targets"] = rng.standard_normal(
            (shape.batch_graphs, cfg.d_out)).astype(np.float32)
    else:
        batch["targets"] = rng.standard_normal((n, cfg.d_out)).astype(np.float32)
    return batch


# --------------------------------------------------------------- recsys
def recsys_specs(cfg: RecsysConfig, shape: RecsysShape):
    if shape.step == "retrieval":
        return {
            "sparse": SDS((1, cfg.n_sparse), jnp.int32),
            "cand_ids": SDS((shape.n_candidates,), jnp.int32),
        }
    batch = {
        "sparse": SDS((shape.batch, cfg.n_sparse), jnp.int32),
        "dense": SDS((shape.batch, cfg.n_dense), jnp.float32),
    }
    if shape.step == "train":
        batch["label"] = SDS((shape.batch,), jnp.int32)
    return batch


def recsys_batch(cfg: RecsysConfig, batch_size: int, step: str = "train",
                 n_candidates: int = 0, seed=0):
    rng = np.random.default_rng(seed)
    if step == "retrieval":
        return {
            "sparse": rng.integers(0, cfg.vocab_per_field,
                                   (1, cfg.n_sparse)).astype(np.int32),
            "cand_ids": rng.integers(0, cfg.vocab_per_field,
                                     (n_candidates,)).astype(np.int32),
        }
    out = {
        "sparse": rng.integers(0, cfg.vocab_per_field,
                               (batch_size, cfg.n_sparse)).astype(np.int32),
        "dense": rng.standard_normal((batch_size, cfg.n_dense)).astype(np.float32),
    }
    if step == "train":
        out["label"] = rng.integers(0, 2, (batch_size,)).astype(np.int32)
    return out
