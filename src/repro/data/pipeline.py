"""Deterministic, resumable, prefetching data pipeline.

Batches are a pure function of (seed, step) — the restart-replay contract
(trainer restores step k, the pipeline regenerates batch k bit-identically).
A background thread keeps ``prefetch`` batches ahead of the consumer, the
standard host-side overlap with device compute.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class PrefetchingIterator:
    """Wraps a (step -> batch) function with background prefetch."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 prefetch: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                item = (step, self._make(step))
            except Exception as e:  # noqa: BLE001 — surface in consumer
                item = ("error", e)
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if item[0] == "error":
                return
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        if step == "error":
            raise RuntimeError("data pipeline worker failed") from batch
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def lm_token_stream(cfg, batch: int, seq: int, *, seed: int = 0,
                    start_step: int = 0, prefetch: int = 2):
    """Synthetic LM token batches, deterministic per (seed, step)."""
    from repro.data.synthetic import lm_train_batch

    return PrefetchingIterator(
        lambda step: lm_train_batch(cfg, batch, seq,
                                    seed=seed * 1_000_003 + step),
        start_step=start_step, prefetch=prefetch)


def recsys_stream(cfg, batch: int, *, seed: int = 0, start_step: int = 0,
                  prefetch: int = 2):
    from repro.data.synthetic import recsys_batch

    return PrefetchingIterator(
        lambda step: recsys_batch(cfg, batch, step="train",
                                  seed=seed * 1_000_003 + step),
        start_step=start_step, prefetch=prefetch)


def graph_minibatch_stream(sampler, batch_nodes: int, fanouts, *,
                           n_pad: int, e_pad: int, d_feat: int,
                           seed: int = 0, start_step: int = 0,
                           prefetch: int = 2):
    """Sampled-subgraph batches via graphs.sampler.NeighborSampler."""
    import numpy as np

    def make(step):
        rng = np.random.default_rng(seed * 7_777_777 + step)
        seeds = rng.integers(0, sampler.n, size=batch_nodes)
        return sampler.sample(seeds, fanouts, seed=seed * 13 + step,
                              n_pad=n_pad, e_pad=e_pad, d_feat=d_feat)

    return PrefetchingIterator(make, start_step=start_step,
                               prefetch=prefetch)
