"""Multi-tenant traversal service: one router, many graphs, one cache.

The serving counterpart of the compile-once lifecycle (core/engine.py),
rewritten as a multi-graph router.  Graphs register by name in a
``GraphCatalog``; each registered (graph, plan) pair is a *lane* — its
own ``SlotPool`` (serve/batcher.py) packing concurrent single-source
requests into the engine's source columns (Graph500-style batched
traversal as the serving batch dimension).  Requests carry a graph name
and are routed to their lane's queue.

Engines are never owned by the service: every lane resolves its compiled
engine through a shared ``EngineCache`` (serve/engine_cache.py) keyed by
``BFSPlan.plan_key()``, so

  * two services (or a service and the ``bfs()`` wrapper) serving the
    same graph/options share one compiled engine,
  * the cache's device-byte budget bounds total engine memory across all
    tenants — a lane whose engine was evicted transparently recompiles
    on its next step,
  * hit/miss/evict/compile-time counters account the whole fleet.

``step()`` round-robins the lanes, dispatching every lane with live
slots via ``run_async`` *before* blocking on any result, so device work
for graph B overlaps host-side unpacking for graph A.  A traversal
completes in a single engine run, so every admitted request finishes
within its step; the rotation only decides admission order under
sustained load.  Duplicate sources within a lane share one engine column.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.bfs import BFSOptions, INF, validate_sources
from repro.core.engine import pick_bucket, plan_ladder
from repro.serve.batcher import SlotPool
from repro.serve.engine_cache import (EngineCache, GraphCatalog,
                                      default_engine_cache)
from repro.serve.resilience import faults as _faults
from repro.serve.resilience.errors import StrandedRequestError

DEFAULT_GRAPH = "default"

_UNSET = object()   # distinguishes "inherit the service default" from an
                    # explicit None (which plan() interprets, e.g. axis)


@dataclasses.dataclass
class TraversalRequest:
    rid: int
    source: int
    graph: Optional[str] = None          # None -> the sole registered graph
    dist: Optional[np.ndarray] = None    # (n_logical,) int32 when done
    levels: int = 0                      # eccentricity of this source's tree
    visited: int = 0
    done: bool = False
    error: Optional[BaseException] = None   # typed rejection (stranded
                                            # drains set StrandedRequestError)


class _Lane:
    """One served graph: routing state, its slot pool, its plan ladder.

    Holds *plans* (cheap metadata), never engines — engines are
    re-resolved through the cache at every step so budget evictions stay
    transparent to the lane.  ``plans`` is the lane's batch-size bucket
    ladder ``{S: BFSPlan}`` ascending: a step (or ``traverse``) with k
    distinct sources routes to the smallest rung with S >= k, so a lane
    under arbitrary fan-out only ever occupies a bounded set of compiled
    executables (the serving front-end's pad-to-bucket contract).
    """

    def __init__(self, name: str, graph, plans: Dict[int, "object"]):
        self.name = name
        self.graph = graph
        self.plans = dict(sorted(plans.items()))
        self.ladder = tuple(self.plans)
        self.pool = SlotPool(self.ladder[-1])
        self.n_logical = self.plan.graph.part.n_logical

    @property
    def plan(self):
        """The largest rung's plan (the lane's full-capacity shape —
        what single-rung call sites held before ladders existed)."""
        return self.plans[self.ladder[-1]]

    def plan_for(self, n_sources: int):
        """The smallest rung fitting ``n_sources`` distinct sources."""
        return self.plans[pick_bucket(n_sources, self.ladder)]

    def pending(self) -> int:
        return len(self.pool.queue) + int(self.pool.live().sum())

    def drained(self) -> bool:
        return self.pool.drained()


class BFSService:
    """Route traversal requests across many registered graphs.

    ``graphs`` may be a single sharded graph (registered under
    ``"default"`` — the single-tenant form older call sites use), a
    ``{name: graph}`` dict, or None (register lanes later via
    ``add_graph``).  Constructor keywords are per-service defaults;
    ``add_graph`` can override any of them per lane, so one service can
    mix 1-D and 2-D partitions, meshes and option sets.
    """

    def __init__(self, graphs=None, opts: BFSOptions = BFSOptions(), *,
                 mesh=None, axis=None, batch_slots: int = 4,
                 batch_buckets=None, partition=None,
                 cache: Optional[EngineCache] = None,
                 catalog: Optional[GraphCatalog] = None):
        self.catalog = catalog if catalog is not None else GraphCatalog()
        self.cache = cache if cache is not None else default_engine_cache()
        self._defaults = dict(opts=opts, mesh=mesh, axis=axis,
                              batch_slots=batch_slots,
                              batch_buckets=batch_buckets,
                              partition=partition)
        self._lanes: Dict[str, _Lane] = {}
        self._order: List[str] = []      # registration order, for rotation
        self._rr = 0
        if graphs is None:
            pass
        elif isinstance(graphs, dict):
            for name, g in graphs.items():
                self.add_graph(name, g)
        else:
            self.add_graph(DEFAULT_GRAPH, graphs)

    # ------------------------------------------------------------ registry
    def add_graph(self, name: str, graph=None, *, opts=_UNSET, mesh=_UNSET,
                  axis=_UNSET, batch_slots=_UNSET, batch_buckets=_UNSET,
                  partition=_UNSET) -> str:
        """Register a graph (or adopt one already in the catalog) and
        open its serving lane.  Planning happens now — invalid options
        fail at registration; compiling waits for the first step that
        serves the lane (through the shared cache).  Passing any keyword
        (including an explicit None, e.g. ``mesh=None`` for a p=1 2-D
        lane) overrides the service default for this lane only.

        ``batch_buckets`` opens the lane with a batch-size bucket ladder
        (e.g. ``(1, 8, 64)``): one plan per rung, slot pool sized to the
        largest rung, every dispatch routed to the smallest rung fitting
        its distinct-source count.  Without it the lane is a one-rung
        ladder at ``batch_slots`` — the pre-bucket behavior exactly."""
        if name in self._lanes:
            raise ValueError(f"graph {name!r} already has a serving lane")
        if graph is None:
            graph = self.catalog.get(name)
        else:
            self.catalog.register(name, graph)
        d = self._defaults

        def pick(val, key):
            return d[key] if val is _UNSET else val

        opts = pick(opts, "opts")
        if opts.mode == "queue":
            raise ValueError("BFSService batches sources; queue mode is "
                             "single-source — use dense or auto")
        buckets = pick(batch_buckets, "batch_buckets")
        ladder = tuple(buckets) if buckets else (pick(batch_slots,
                                                      "batch_slots"),)
        lane_mesh = pick(mesh, "mesh")
        lane_axis = axis if axis is not _UNSET else (
            d["axis"] if lane_mesh is d["mesh"] else None)
        lane_plans = plan_ladder(
            graph, opts, mesh=lane_mesh, axis=lane_axis, ladder=ladder,
            partition=pick(partition, "partition"))
        self._lanes[name] = _Lane(name, graph, lane_plans)
        self._order.append(name)
        return name

    def graph_names(self) -> List[str]:
        return list(self._order)

    def lane(self, name: str) -> _Lane:
        try:
            return self._lanes[name]
        except KeyError:
            raise KeyError(f"no serving lane for graph {name!r}; lanes: "
                           f"{sorted(self._lanes)}") from None

    def _sole_lane(self) -> _Lane:
        if len(self._lanes) != 1:
            raise ValueError(
                f"service has {len(self._lanes)} lanes "
                f"({sorted(self._lanes)}); requests must name their graph")
        return self._lanes[self._order[0]]

    # single-tenant conveniences (the pre-router surface)
    @property
    def engine(self):
        """The sole lane's compiled engine (single-graph services)."""
        return self.cache.get_or_compile(self._sole_lane().plan)

    @property
    def pool(self) -> SlotPool:
        return self._sole_lane().pool

    @property
    def graph(self):
        return self._sole_lane().graph

    def cache_stats(self) -> dict:
        return self.cache.stats()

    # ------------------------------------------------------------- serving
    def submit(self, req: TraversalRequest) -> None:
        lane = (self.lane(req.graph) if req.graph is not None
                else self._sole_lane())
        req.graph = lane.name
        # Fail fast at the door instead of poisoning a whole batch.
        validate_sources([req.source], lane.n_logical)
        lane.pool.submit(req)

    def step(self) -> List[TraversalRequest]:
        """Serve one round: admit queued requests on every lane (rotating
        the start lane for fairness), dispatch all live lanes through
        ``run_async``, then collect.  Returns the finished requests."""
        if not self._order:
            return []
        k = len(self._order)
        rotation = [self._order[(self._rr + i) % k] for i in range(k)]
        self._rr = (self._rr + 1) % k

        inflight = []
        for name in rotation:
            lane = self._lanes[name]
            lane.pool.admit()
            live = lane.pool.live()
            if not live.any():
                continue
            # Requests for the same vertex share a source column.
            col_of = {}
            for i in np.where(live)[0]:
                src = lane.pool.slots[i].source
                if src not in col_of:
                    col_of[src] = len(col_of)
            uniq = sorted(col_of, key=col_of.get)
            # bucket routing: a round with few distinct sources runs on
            # the smallest fitting rung's engine, not the full-width plan
            engine = self.cache.get_or_compile(lane.plan_for(len(uniq)))
            # dispatch only; blocking waits until every lane is in flight
            inflight.append((lane, live, col_of, engine.run_async(uniq)))

        finished = []
        for lane, live, col_of, res in inflight:
            dist = res.block().dist_host       # (n_logical, len(uniq))
            for i in np.where(live)[0]:
                r = lane.pool.slots[i]
                # copy: columns are views into one shared result buffer,
                # and requests for the same source share a column
                col = dist[:, col_of[r.source]].copy()
                reached = col < int(INF)
                r.dist = col
                r.levels = int(col[reached].max()) if reached.any() else 0
                r.visited = int(reached.sum())
                r.done = True
                finished.append(r)
        return finished

    def traverse_async(self, name: Optional[str], sources):
        """Dispatch one multi-source traversal on a lane's smallest
        fitting bucket; returns the un-blocked ``BFSResult`` (the remote
        front-end's dispatch path — lanes overlap via these handles).

        Sources are validated *here*, at the door: range against the
        lane's logical vertex count, duplicate detection, and the lane's
        largest-rung capacity all raise ``ValueError`` before any device
        work, so bad remote input maps to a 400-style rejection instead
        of surfacing as a device-side error mid-``step()``.
        """
        lane = self.lane(name) if name is not None else self._sole_lane()
        srcs = validate_sources(sources, lane.n_logical,
                                max_sources=lane.ladder[-1])
        _faults.fire("service.dispatch", lane.name)
        plan_ = lane.plan_for(len(srcs))
        engine = self.cache.get_or_compile(plan_)
        return engine.run_async([int(s) for s in srcs]), plan_.num_sources

    def traverse(self, name: Optional[str], sources):
        """Blocking ``traverse_async``: returns ``(BFSResult, bucket)``
        with the result synced (``dist_host`` is the padding-stripped
        (n_logical, len(sources)) distance matrix)."""
        res, bucket = self.traverse_async(name, sources)
        return res.block(), bucket

    def drained(self) -> bool:
        return all(lane.drained() for lane in self._lanes.values())

    def pending_by_lane(self) -> Dict[str, int]:
        """Per-lane queued + in-slot request counts (nonzero lanes only)."""
        return {name: lane.pending() for name, lane in self._lanes.items()
                if lane.pending()}

    def reject_stranded(self, reason: str) -> List[TraversalRequest]:
        """Fail every queued / in-slot request with a typed
        ``StrandedRequestError`` and empty the pools.

        This is the shutdown path's leak stopper: a request object whose
        holder is still waiting observes ``done=True`` with ``error``
        set, instead of sitting in a dead pool forever.  Returns the
        rejected requests (callers fold them into their ledger)."""
        rejected: List[TraversalRequest] = []
        for name in self._order:
            pool = self._lanes[name].pool
            stranded = pool.queue + [
                r for r in pool.slots if r is not None and not r.done]
            pool.queue.clear()
            pool.slots[:] = [None] * len(pool.slots)
            for r in stranded:
                r.error = StrandedRequestError(
                    f"request {r.rid} on lane {name!r} stranded: {reason}")
                r.done = True
                rejected.append(r)
        return rejected

    def run_until_drained(self, max_steps: int = 10_000,
                          timeout_s: Optional[float] = None):
        """Step until every submitted request on every lane has finished.

        Raises ``RuntimeError`` if the queues are not drained within
        ``max_steps`` service steps — previously this returned the partial
        result list silently, so a caller could mistake a truncated drain
        for completion and never see the still-queued requests.
        ``timeout_s`` bounds the drain by wall clock as well (checked
        between steps; a single stuck engine run is not interrupted): a
        deep-traversal tenant can exhaust hours before it exhausts
        ``max_steps``, and serving shutdown paths need a time bound, not
        a step bound.  The error names each lane's pending count so a
        stuck lane is identifiable instead of one opaque total.

        On that timeout every still-pending request is *rejected*, not
        leaked: each gets ``done=True`` and a typed
        ``StrandedRequestError`` in ``.error`` (see ``reject_stranded``),
        so callers holding request objects always observe an outcome.
        """
        done = []
        deadline = (time.monotonic() + timeout_s
                    if timeout_s is not None else None)
        for _ in range(max_steps):
            if self.drained():
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
            done += self.step()
        if not self.drained():
            per_lane = ", ".join(f"{name}: {cnt}" for name, cnt
                                 in self.pending_by_lane().items())
            pending = sum(self.pending_by_lane().values())
            limit = (f"timeout_s={timeout_s}" if deadline is not None
                     and time.monotonic() >= deadline
                     else f"max_steps={max_steps}")
            self.reject_stranded(f"drain gave up at {limit}")
            raise RuntimeError(
                f"run_until_drained: {pending} request(s) still pending "
                f"after {limit} ({len(done)} finished, each rejected with "
                f"StrandedRequestError; per-lane pending: {per_lane}); "
                f"raise the bound or submit fewer requests")
        return done
