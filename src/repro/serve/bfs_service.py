"""Traversal-as-a-service: batched multi-source BFS over a compiled engine.

The serving counterpart of the compile-once lifecycle (core/engine.py):
one ``BFSEngine`` is compiled per (graph, opts, mesh) with a source-batch
capacity equal to the slot count, then concurrent single-source requests
are packed into the engine's source columns — one device dispatch serves
up to ``batch_slots`` requests (Graph500-style batched traversal as the
serving batch dimension).  Slot recycling reuses the LM server's
``SlotPool`` (serve/batcher.py): requests queue up, finished slots are
refilled without draining the batch.

Unlike token decoding, a traversal completes in a single engine run, so
every ``step()`` finishes all admitted requests; the pool earns its keep
under sustained load, where each step drains up to a full batch from the
queue.  Duplicate sources across concurrent requests share one engine
column (the engine itself rejects duplicate source *columns*).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.bfs import BFSOptions, INF, validate_sources
from repro.core.engine import plan
from repro.serve.batcher import SlotPool


@dataclasses.dataclass
class TraversalRequest:
    rid: int
    source: int
    dist: Optional[np.ndarray] = None    # (n_logical,) int32 when done
    levels: int = 0                      # eccentricity of this source's tree
    visited: int = 0
    done: bool = False


class BFSService:
    def __init__(self, graph, opts: BFSOptions = BFSOptions(), *,
                 mesh=None, axis=None, batch_slots: int = 4,
                 partition=None):
        if opts.mode == "queue":
            raise ValueError("BFSService batches sources; queue mode is "
                             "single-source — use dense or auto")
        self.graph = graph
        # partition passes straight through the lifecycle: serving over
        # the 2-D edge-partitioned engine is the same code path, and the
        # direction-optimizing mode="auto" works over grids too (per-level
        # dense/bottom-up switching; sparse levels need S=1, which batched
        # serving never compiles).
        self.engine = plan(graph, opts, mesh=mesh, axis=axis,
                           num_sources=batch_slots,
                           partition=partition).compile()
        self.pool = SlotPool(batch_slots)
        self._n_logical = graph.part.n_logical

    def submit(self, req: TraversalRequest) -> None:
        # Fail fast at the door instead of poisoning a whole batch.
        validate_sources([req.source], self._n_logical)
        self.pool.submit(req)

    def step(self) -> List[TraversalRequest]:
        """Admit queued requests and serve every live slot in one engine
        run; returns the finished requests (all live ones)."""
        self.pool.admit()
        live = self.pool.live()
        if not live.any():
            return []
        # Requests for the same vertex share a source column.
        col_of = {}
        for i in np.where(live)[0]:
            src = self.pool.slots[i].source
            if src not in col_of:
                col_of[src] = len(col_of)
        uniq = sorted(col_of, key=col_of.get)

        res = self.engine.run(uniq)
        dist = res.dist_host                       # (n_logical, len(uniq))

        finished = []
        for i in np.where(live)[0]:
            r = self.pool.slots[i]
            # copy: columns are views into one shared result buffer, and
            # requests for the same source share a column
            col = dist[:, col_of[r.source]].copy()
            reached = col < int(INF)
            r.dist = col
            r.levels = int(col[reached].max()) if reached.any() else 0
            r.visited = int(reached.sum())
            r.done = True
            finished.append(r)
        return finished

    def run_until_drained(self, max_steps: int = 10_000):
        """Step until every submitted request has finished.

        Raises ``RuntimeError`` if the queue is not drained within
        ``max_steps`` engine runs — previously this returned the partial
        result list silently, so a caller could mistake a truncated drain
        for completion and never see the still-queued requests.
        """
        done = []
        for _ in range(max_steps):
            if self.pool.drained():
                break
            done += self.step()
        if not self.pool.drained():
            pending = len(self.pool.queue) + int(self.pool.live().sum())
            raise RuntimeError(
                f"run_until_drained: {pending} request(s) still pending "
                f"after max_steps={max_steps} engine runs ({len(done)} "
                f"finished); raise max_steps or submit fewer requests")
        return done
