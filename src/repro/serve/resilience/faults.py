"""Deterministic fault injection for the serving stack (chaos layer).

A ``FaultPlan`` is a seeded, schedulable set of ``FaultSpec``s.  Code
under test consults the plan at *named injection points*::

    faults.fire("cache.compile", tag="S=4 wire=auto partition=1d")

``fire`` is a no-op returning ``None`` when no plan is installed — the
fault layer costs one global read on the hot path and changes nothing
with faults disabled (the acceptance bar: bitwise-identical traversals,
unchanged ``plan_key()``).  With a plan installed, the first armed spec
matching ``(site, tag)`` performs its action:

  * ``kind="fail"``  — raise the spec's typed exception (default
    ``InjectedError``): compile failures, device-dispatch exceptions.
  * ``kind="stall"`` — ``time.sleep(delay_s)``: dispatcher stalls and
    slow collectives (the watchdog's and deadline reaper's prey).
  * ``kind="storm"`` — call the site's ``storm=`` callback: the engine
    cache passes its evict-everything thunk (eviction storms).
  * ``kind="corrupt"`` — no side effect here; the caller receives the
    spec and applies ``corrupt_bytes`` to its payload (malformed wire
    bodies are built by the *sender*, so the receiving stack's 400/413
    mapping is what gets exercised).

Determinism: specs fire on exact hit windows (``after`` matches are
skipped, then ``times`` firings happen) and an optional seeded Bernoulli
draw (``p``) from a per-spec ``random.Random`` derived from the plan
seed — same plan + same call sequence -> same faults, which is what lets
the chaos regression suite replay a schedule and assert the exact
breaker/retry/deadline trajectory.

Installation points are harness-controlled (tests, launch/bfs_chaos),
never concurrent with each other; ``fire`` itself is thread-safe across
serving threads.  Sites in the tree today::

    cache.get        engine_cache.get_or_compile entry   (storm)
    cache.compile    before plan.compile() in the cache  (fail)
    engine.compile   BFSEngine.__init__                  (fail)
    engine.dispatch  BFSEngine.run_async pre-dispatch    (fail, stall)
    service.dispatch BFSService.traverse_async, tag=lane (fail, stall)
    frontend.loop    each dispatcher round               (stall)
    frontend.block   inside the watchdog-guarded sync    (stall)
    client.payload   chaos-harness request encoding      (corrupt)

Import-light (stdlib only) by the same contract as errors.py.
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time
from typing import Dict, List, Optional, Tuple, Type

from repro.serve.resilience.errors import InjectedError


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: where it fires, what it does, when.

    ``site`` must match the injection point exactly; ``match`` is a
    substring test against the point's ``tag`` (empty matches every
    tag).  Hit accounting is per-spec: the first ``after`` matching
    hits pass through, the next ``times`` fire (None = unlimited), each
    gated by a seeded Bernoulli draw of probability ``p``.
    """

    site: str
    kind: str = "fail"              # fail | stall | storm | corrupt
    match: str = ""                 # substring of the site's tag
    exc: Optional[Type[BaseException]] = None   # kind="fail" class
    message: str = ""
    delay_s: float = 0.05           # kind="stall" sleep
    p: float = 1.0                  # per-hit firing probability
    after: int = 0                  # matching hits to skip first
    times: Optional[int] = None     # firings before the spec disarms

    _KINDS = ("fail", "stall", "storm", "corrupt")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {self._KINDS}")
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"p must be in [0, 1] ({self.p})")
        if self.after < 0 or (self.times is not None and self.times < 1):
            raise ValueError(f"after must be >= 0 and times >= 1 "
                             f"(after={self.after}, times={self.times})")


class FaultPlan:
    """A seeded schedule of faults plus the record of what fired.

    ``records`` (one ``(site, tag, spec_index, kind)`` tuple per firing)
    and ``summary()`` are what the chaos harness ships in
    ``BENCH_chaos.json`` — the ground truth against which every
    response's typed status is checked.
    """

    def __init__(self, specs, *, seed: int = 0):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = int(seed)
        # guarded-by(_lock): _hits, _fired, records
        self._lock = threading.Lock()
        self._hits: List[int] = [0] * len(self.specs)
        self._fired: List[int] = [0] * len(self.specs)
        self.records: List[tuple] = []
        # per-spec deterministic streams, independent of firing order of
        # *other* specs (each spec draws only on its own matching hits)
        self._rngs = [random.Random(self.seed * 1_000_003 + i)
                      for i in range(len(self.specs))]

    def arm(self, site: str, tag: str) -> Optional[FaultSpec]:
        """First spec firing for this ``(site, tag)`` hit, with hit
        accounting updated; None when nothing fires."""
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.site != site or spec.match not in tag:
                    continue
                self._hits[i] += 1
                if self._hits[i] <= spec.after:
                    continue
                if spec.times is not None and self._fired[i] >= spec.times:
                    continue
                if spec.p < 1.0 and self._rngs[i].random() >= spec.p:
                    continue
                self._fired[i] += 1
                self.records.append((site, tag, i, spec.kind))
                return spec
        return None

    def summary(self) -> dict:
        """Per-spec and per-kind firing counts (chaos ledger rows)."""
        with self._lock:
            by_kind: Dict[str, int] = {}
            per_spec = []
            for i, spec in enumerate(self.specs):
                by_kind[spec.kind] = by_kind.get(spec.kind, 0) \
                    + self._fired[i]
                per_spec.append({
                    "site": spec.site, "kind": spec.kind,
                    "match": spec.match, "hits": self._hits[i],
                    "fired": self._fired[i],
                })
            return {"seed": self.seed, "fired_total": len(self.records),
                    "by_kind": by_kind, "specs": per_spec}


# ---------------------------------------------------------------------------
# The process-wide active plan (harness-installed; fire() reads it)
# ---------------------------------------------------------------------------

_active: Optional[FaultPlan] = None
_install_lock = threading.Lock()


def install(plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """Install ``plan`` as the process-wide fault schedule; returns the
    previous one.  ``None`` disables injection entirely."""
    global _active
    with _install_lock:
        prev, _active = _active, plan
        return prev


@contextlib.contextmanager
def active(plan: FaultPlan):
    """Scoped installation (tests / the chaos harness)."""
    prev = install(plan)
    try:
        yield plan
    finally:
        install(prev)


def fire(site: str, tag: str = "", **ctx) -> Optional[FaultSpec]:
    """Consult the active plan at one injection point.

    Raises / sleeps / storms per the matched spec's kind; returns the
    spec (callers of ``corrupt`` sites apply it themselves) or None.
    The no-plan fast path is one global read — serving threads pay
    nothing when chaos is off.
    """
    plan = _active
    if plan is None:
        return None
    spec = plan.arm(site, tag)
    if spec is None:
        return None
    if spec.kind == "fail":
        exc = spec.exc or InjectedError
        raise exc(spec.message
                  or f"injected {exc.__name__} at {site} (tag={tag!r})")
    if spec.kind == "stall":
        time.sleep(spec.delay_s)
    elif spec.kind == "storm":
        storm = ctx.get("storm")
        if storm is not None:
            storm()
    return spec


def plan_tag(plan) -> str:
    """The tag string plan-keyed injection points fire with, so specs
    can target one bucket / wire tier / partition scheme by substring
    (e.g. ``match="S=4"`` poisons only the 4-source rung's compiles)."""
    opts = plan.opts
    return (f"S={plan.num_sources} mode={opts.mode} "
            f"wire={opts.wire_format} partition={plan.partition}")


def corrupt_bytes(payload: bytes, spec: FaultSpec, seed: int = 0) -> bytes:
    """Deterministically mangle a wire payload (kind="corrupt" sites).

    Three corruption shapes, chosen by seed: truncation (framing lies),
    byte flips mid-body (invalid JSON), and a non-JSON prefix — each of
    which the receiving schema layer must answer with a 400-family
    status, never a crash or a hang.
    """
    rng = random.Random(seed)
    shape = rng.randrange(3)
    if shape == 0 and len(payload) > 2:
        return payload[: rng.randrange(1, len(payload))]
    if shape == 1 and payload:
        buf = bytearray(payload)
        for _ in range(1 + rng.randrange(3)):
            buf[rng.randrange(len(buf))] ^= 0xFF
        return bytes(buf)
    return b"\x00not-json\x00" + payload
