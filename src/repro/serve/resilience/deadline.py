"""Request deadlines that propagate admission -> queue -> dispatch.

A ``Deadline`` is an absolute point on the monotonic clock, carried on
the request from the HTTP layer down: the schema accepts a relative
``deadline_ms`` budget, ``submit`` pins it to an absolute instant, the
dispatcher reaps expired queue entries *before* any device work is
spent on them, and ``wait`` stops blocking the handler thread the
moment the deadline lapses — every stage raising the same typed
``DeadlineExceeded`` (HTTP 504) with the stage it expired at.

Monotonic and absolute on purpose: a relative budget re-measured per
stage would silently extend under queueing, which is exactly when the
deadline matters.
"""

from __future__ import annotations

import time
from typing import Optional

from repro.serve.resilience.errors import DeadlineExceeded

__all__ = ["Deadline", "DeadlineExceeded"]


class Deadline:
    """An absolute monotonic-clock deadline (immutable)."""

    __slots__ = ("t", "budget_s", "_clock")

    def __init__(self, t: float, *, budget_s: float = 0.0,
                 clock=time.monotonic):
        self.t = float(t)
        self.budget_s = float(budget_s)
        self._clock = clock

    @classmethod
    def after_ms(cls, budget_ms: float,
                 clock=time.monotonic) -> "Deadline":
        """Deadline ``budget_ms`` from now; the budget must be > 0."""
        ms = float(budget_ms)
        if not ms > 0:
            raise ValueError(f"deadline_ms must be > 0 ({budget_ms})")
        return cls(clock() + ms / 1e3, budget_s=ms / 1e3, clock=clock)

    def remaining_s(self) -> float:
        return self.t - self._clock()

    def expired(self) -> bool:
        return self._clock() >= self.t

    def check(self, stage: str, detail: str = "") -> None:
        """Raise ``DeadlineExceeded`` (504) if the deadline has passed."""
        over = self._clock() - self.t
        if over >= 0:
            raise DeadlineExceeded(
                f"deadline exceeded at {stage} "
                f"({self.budget_s * 1e3:.0f}ms budget, "
                f"{over * 1e3:.0f}ms over){': ' + detail if detail else ''}",
                stage=stage)

    def bound(self, timeout_s: Optional[float]) -> float:
        """The tighter of ``timeout_s`` and the remaining budget (>= 0),
        for handing to ``Event.wait``-style APIs."""
        rem = max(0.0, self.remaining_s())
        return rem if timeout_s is None else min(float(timeout_s), rem)

    def __repr__(self) -> str:
        return (f"Deadline(remaining={self.remaining_s() * 1e3:.1f}ms, "
                f"budget={self.budget_s * 1e3:.0f}ms)")
