"""Bounded retry with deterministic exponential backoff + jitter.

The dispatcher's answer to *transient* compile/dispatch failures: retry
up to ``max_attempts`` total attempts, sleeping
``base_s * multiplier**k`` (capped at ``max_s``) with seeded
proportional jitter between attempts.  Only ``TransientError``
subclasses (resilience/errors.py) are retried — deadline, breaker and
watchdog failures are rejections of work, not flaky work, and retrying
them would amplify exactly the overload they shed.

Deterministic by construction (the jitter stream comes from a seeded
``random.Random``), so the chaos regression suite can assert the exact
attempt count and backoff schedule a fault plan produces.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, Tuple, Type

from repro.serve.resilience.errors import TransientError


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Attempt budget + backoff shape for one call site.

    ``max_attempts=1`` means no retries (first failure propagates) —
    the zero-behavior-change default for callers that opt out.
    """

    max_attempts: int = 3
    base_s: float = 0.05
    max_s: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.5              # +- fraction of the backoff
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1 "
                             f"({self.max_attempts})")
        if self.base_s < 0 or self.max_s < 0 or self.multiplier < 1:
            raise ValueError("base_s/max_s must be >= 0 and "
                             f"multiplier >= 1 ({self})")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError(f"jitter must be in [0, 1] ({self.jitter})")

    def backoffs(self) -> list:
        """The (deterministic) sleep before each retry, in seconds —
        ``max_attempts - 1`` entries."""
        rng = random.Random(self.seed)
        out = []
        for k in range(self.max_attempts - 1):
            raw = min(self.max_s, self.base_s * self.multiplier ** k)
            out.append(raw * (1.0 + self.jitter * (2 * rng.random() - 1)))
        return out


def call_with_retry(fn: Callable, policy: RetryPolicy, *,
                    retryable: Tuple[Type[BaseException], ...]
                    = (TransientError,),
                    sleep: Callable[[float], None] = time.sleep,
                    on_retry: Optional[Callable] = None):
    """Run ``fn()`` under the policy; returns its value or re-raises.

    ``on_retry(attempt, exc, backoff_s)`` fires before each backoff
    sleep (metrics hook).  Non-retryable exceptions propagate
    immediately; the last retryable failure propagates once the attempt
    budget is spent.
    """
    backoffs = policy.backoffs()
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except retryable as exc:
            if attempt >= len(backoffs):
                raise
            delay = backoffs[attempt]
            if on_retry is not None:
                on_retry(attempt + 1, exc, delay)
            if delay > 0:
                sleep(delay)
