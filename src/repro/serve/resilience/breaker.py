"""Per-lane circuit breaker: fail fast while a lane is wedged.

The classic three-state machine, sized for the serving dispatcher:

* **closed** — normal serving; consecutive failures are counted and
  ``failure_threshold`` of them in a row open the circuit.
* **open** — every request is rejected immediately with a typed
  ``CircuitOpenError`` (HTTP 503 + Retry-After = remaining cooldown);
  no compile or device work is attempted, so one poisoned lane cannot
  absorb the fleet's dispatcher time.  After ``reset_timeout_s`` the
  breaker transitions to half-open.
* **half-open** — ``half_open_probes`` trial requests are let through;
  one success closes the circuit, one failure re-opens it (with a fresh
  cooldown).

All transitions are timestamped into a bounded ``transitions`` log so
the chaos harness can compute recovery latencies (open -> closed) and
``/metrics`` can show the trajectory, not just the current state.
Clock injection (``clock=``) keeps the state machine unit-testable
without sleeping.
"""

from __future__ import annotations

import threading
import time

from repro.serve.resilience.errors import CircuitOpenError

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

_MAX_TRANSITIONS = 256


class CircuitBreaker:
    """Thread-safe three-state breaker for one serving lane."""

    def __init__(self, *, failure_threshold: int = 5,
                 reset_timeout_s: float = 30.0, half_open_probes: int = 1,
                 name: str = "", clock=time.monotonic):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1 "
                             f"({failure_threshold})")
        if reset_timeout_s < 0:
            raise ValueError(f"reset_timeout_s must be >= 0 "
                             f"({reset_timeout_s})")
        if half_open_probes < 1:
            raise ValueError(f"half_open_probes must be >= 1 "
                             f"({half_open_probes})")
        self.failure_threshold = int(failure_threshold)
        self.reset_timeout_s = float(reset_timeout_s)
        self.half_open_probes = int(half_open_probes)
        self.name = name
        self._clock = clock
        # guarded-by(_lock): _state, _consecutive, _opened_at,
        # guarded-by(_lock): _probes_left, opened, rejected_fast,
        # guarded-by(_lock): transitions
        self._lock = threading.Lock()
        self._state = CLOSED
        self._consecutive = 0
        self._opened_at = 0.0
        self._probes_left = 0
        self.opened = 0              # total open transitions
        self.rejected_fast = 0       # requests shed while open
        self.transitions = [(CLOSED, 0.0)]

    # audit: allow(LK001) -- transition helper; every caller holds _lock
    def _set_state(self, state: str) -> None:
        if state != self._state:
            self._state = state
            self.transitions.append((state, self._clock()))
            del self.transitions[:-_MAX_TRANSITIONS]

    # audit: allow(LK001) -- cooldown check; every caller holds _lock
    def _tick(self) -> None:
        """Open -> half-open once the cooldown has elapsed."""
        if self._state == OPEN and \
                self._clock() - self._opened_at >= self.reset_timeout_s:
            self._set_state(HALF_OPEN)
            self._probes_left = self.half_open_probes

    # ------------------------------------------------------------- gating
    def admits(self) -> bool:
        """Non-consuming check (the admission door's fast 503): False
        only while hard-open.  Half-open admits — the admitted request
        becomes a probe at dispatch time."""
        with self._lock:
            self._tick()
            return self._state != OPEN

    def allow(self) -> bool:
        """Consuming check at dispatch: closed -> True; half-open ->
        True while probe slots remain; open -> False (counted)."""
        with self._lock:
            self._tick()
            if self._state == CLOSED:
                return True
            if self._state == HALF_OPEN and self._probes_left > 0:
                self._probes_left -= 1
                return True
            self.rejected_fast += 1
            return False

    def reject_error(self) -> CircuitOpenError:
        """The typed rejection for the current open period."""
        with self._lock:
            remaining = max(0.0, self.reset_timeout_s
                            - (self._clock() - self._opened_at))
            return CircuitOpenError(
                f"circuit breaker open on lane {self.name!r} after "
                f"{self.failure_threshold} consecutive failures; "
                f"half-open probe in {remaining:.2f}s",
                retry_after_s=max(0.05, remaining))

    # ------------------------------------------------------------ outcomes
    def record_success(self) -> None:
        with self._lock:
            self._consecutive = 0
            if self._state != CLOSED:
                self._set_state(CLOSED)

    def record_failure(self) -> None:
        with self._lock:
            self._tick()
            if self._state == HALF_OPEN:
                # a failed probe re-opens with a fresh cooldown
                self._set_state(OPEN)
                self._opened_at = self._clock()
                self.opened += 1
                return
            self._consecutive += 1
            if self._state == CLOSED and \
                    self._consecutive >= self.failure_threshold:
                self._set_state(OPEN)
                self._opened_at = self._clock()
                self.opened += 1

    # ------------------------------------------------------------- queries
    def state(self) -> str:
        with self._lock:
            self._tick()
            return self._state

    def snapshot(self) -> dict:
        with self._lock:
            self._tick()
            return {
                "state": self._state,
                "consecutive_failures": self._consecutive,
                "failure_threshold": self.failure_threshold,
                "reset_timeout_s": self.reset_timeout_s,
                "opened": self.opened,
                "rejected_fast": self.rejected_fast,
                "transitions": [(s, round(t, 4))
                                for s, t in self.transitions[-8:]],
            }

    def recovery_latencies_s(self) -> list:
        """Durations of completed open -> ... -> closed excursions."""
        with self._lock:
            out, t_open = [], None
            for state, t in self.transitions:
                if state == OPEN and t_open is None:
                    t_open = t
                elif state == CLOSED and t_open is not None:
                    out.append(t - t_open)
                    t_open = None
            return out
