"""Graceful-degradation arms: serve on a worse plan instead of failing.

When a lane's preferred engine cannot be produced (its compile keeps
failing — injected or real), the dispatcher walks this module's *arms*
in order of how much they give up, and serves the request on the first
one that works:

1. **bucket:<S>** — another rung of the lane's ladder that still fits
   the request in one run (larger S: padded columns cost device work,
   nothing else).
2. **split:<S>** — a *smaller* rung, the request split into
   ``ceil(k/S)`` sequential runs whose distance columns are stitched
   host-side.  Latency degrades by the split factor; results stay
   bitwise-correct (each chunk is an independent exact traversal).
3. **wire:bytes** — the preferred rung re-planned on the uncompressed
   wire tier (``wire_format="bytes"``), for when the packed/compressed
   twins are what's poisoned.  A distinct ``plan_key()``, so the cache
   compiles it independently of the broken preferred entry.

Every arm resolves through the same shared ``EngineCache`` (budget,
coalescing and counters all apply), and only ``TransientError``s move
the walk to the next arm — a real programming error still propagates.
The arm label is returned so metrics can count degraded serves per
shape (`/metrics` ``degraded``) and the chaos ledger can attribute
recoveries.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.resilience.errors import TransientError


class _HostRunStats:
    """Merged host-side stats of a split traversal (duck-types
    ``BFSRunStats``: ``block()`` no-ops, ``to_host()`` aggregates)."""

    def __init__(self, parts):
        self._merged = None
        self._parts = parts

    def block(self):
        return self

    def to_host(self) -> dict:
        if self._merged is None:
            hosts = [p.run_stats.to_host() for p in self._parts]
            modes = {ph: sum(h["mode_counts"][ph] for h in hosts)
                     for ph in ("dense", "queue", "bottom_up")}
            self._merged = {
                "levels": max(h["levels"] for h in hosts),
                "comm_bytes": float(sum(h["comm_bytes"] for h in hosts)),
                "overflowed": any(h["overflowed"] for h in hosts),
                "mode_counts": modes,
                "sieve_hits": sum(h["sieve_hits"] for h in hosts),
            }
        return self._merged


class StitchedResult:
    """A split-arm traversal: chunk results glued back into one
    (n_logical, k) distance matrix, in request source order.  Duck-types
    the slice of ``BFSResult`` the frontend consumes (``block()``,
    ``dist_host``, ``run_stats``)."""

    def __init__(self, parts, n_sources: int):
        self._parts = list(parts)
        self.n_sources = int(n_sources)
        self.n_logical = parts[0].n_logical
        self.run_stats = _HostRunStats(self._parts)

    def block(self) -> "StitchedResult":
        for p in self._parts:
            p.block()
        return self

    @property
    def dist_host(self) -> np.ndarray:
        return np.concatenate([p.dist_host for p in self._parts], axis=1)


def bytes_tier_plan(lane, bucket: int):
    """The lane rung's uncompressed-wire twin (planned lazily, cached
    on the lane).  None when the rung already serves the bytes tier."""
    from repro.core.engine import plan as plan_fn

    base = lane.plans[bucket]
    if base.opts.wire_format == "bytes":
        return None
    cache = getattr(lane, "_bytes_plans", None)
    if cache is None:
        cache = {}
        lane._bytes_plans = cache
    if bucket not in cache:
        opts = dataclasses.replace(base.opts, wire_format="bytes")
        cache[bucket] = plan_fn(
            lane.graph, opts, mesh=base.mesh, axis=base.axis,
            num_sources=bucket, partition=base.partition)
    return cache[bucket]


def degradation_arms(lane, n_sources: int):
    """Yield ``(label, plan, split_size)`` fallbacks, best first.
    ``split_size`` is None for single-run arms."""
    from repro.core.engine import pick_bucket

    preferred = pick_bucket(n_sources, lane.ladder)
    for s in lane.ladder:                          # other fitting rungs
        if s != preferred and s >= n_sources:
            yield f"bucket:{s}", lane.plans[s], None
    smaller = [s for s in lane.ladder if s < n_sources]
    for s in reversed(smaller):                    # fewest chunks first
        yield f"split:{s}", lane.plans[s], s
    safe = bytes_tier_plan(lane, preferred)
    if safe is not None:
        yield "wire:bytes", safe, None


def degraded_traverse(service, name: str, sources):
    """Serve ``sources`` on the first working arm of lane ``name``.

    Returns ``(result, bucket, arm_label)`` — result un-blocked for
    single-run arms (the dispatcher pipelines it like any other), fully
    synced for split arms.  Re-raises the last transient failure when
    every arm is exhausted.
    """
    lane = service.lane(name)
    srcs = [int(s) for s in sources]
    last_exc = None
    for label, plan_, split in degradation_arms(lane, len(srcs)):
        try:
            engine = service.cache.get_or_compile(plan_)
            if split is None:
                return engine.run_async(srcs), plan_.num_sources, label
            parts = [engine.run(srcs[i:i + split])
                     for i in range(0, len(srcs), split)]
            return StitchedResult(parts, len(srcs)), split, label
        except TransientError as exc:
            last_exc = exc
    if last_exc is None:
        last_exc = TransientError(
            f"lane {name!r} has no degradation arm for "
            f"{len(srcs)} sources")
    raise last_exc
