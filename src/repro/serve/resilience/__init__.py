"""Fault injection + resilience primitives for the serving stack.

Six pieces, one contract — every failure a caller can see is typed with
its HTTP status, every recovery path is deterministic enough to replay:

  * ``errors``   — the typed taxonomy (504 deadline / 503 breaker /
    500 watchdog / transient-vs-permanent retry classifier).
  * ``faults``   — the seeded ``FaultPlan`` registry and the named
    injection points (``faults.fire``) the engine, cache, service and
    frontend consult; a no-op costing one global read when disabled.
  * ``deadline`` — absolute monotonic request deadlines propagating
    admission -> queue -> dispatch (reaped before device work).
  * ``retry``    — bounded deterministic exponential backoff for
    transient compile/dispatch failures.
  * ``breaker``  — the per-lane circuit breaker (open / half-open /
    closed, fast 503s, recovery-latency log).
  * ``watchdog`` — bounded device rounds; a stuck round fails its
    batch with a typed error while other lanes keep serving.
  * ``degrade``  — graceful-degradation arms (other rungs, split over
    a smaller bucket, the uncompressed wire tier).

``launch/bfs_chaos.py`` drives the whole set under randomized fault
schedules to a bitwise-correct, no-deadlock, no-leak verdict.
"""

from repro.serve.resilience.breaker import CircuitBreaker
from repro.serve.resilience.deadline import Deadline
from repro.serve.resilience.degrade import (StitchedResult,
                                            degradation_arms,
                                            degraded_traverse)
from repro.serve.resilience.errors import (CircuitOpenError,
                                           DeadlineExceeded,
                                           InjectedCompileError,
                                           InjectedDispatchError,
                                           InjectedError, ResilienceError,
                                           StrandedRequestError,
                                           StuckDispatchError,
                                           TransientError)
from repro.serve.resilience.faults import (FaultPlan, FaultSpec,
                                           corrupt_bytes, fire, install)
from repro.serve.resilience.faults import active as faults_active
from repro.serve.resilience.retry import RetryPolicy, call_with_retry
from repro.serve.resilience.watchdog import DispatchWatchdog

__all__ = [
    "CircuitBreaker", "Deadline", "DispatchWatchdog",
    "FaultPlan", "FaultSpec", "RetryPolicy", "StitchedResult",
    "CircuitOpenError", "DeadlineExceeded", "InjectedCompileError",
    "InjectedDispatchError", "InjectedError", "ResilienceError",
    "StrandedRequestError", "StuckDispatchError", "TransientError",
    "call_with_retry", "corrupt_bytes", "degradation_arms",
    "degraded_traverse", "faults_active", "fire", "install",
]
