"""Typed failure taxonomy of the resilient serving stack.

Every failure the serving layers can surface to a caller is one of
these classes, each carrying the HTTP status the transport maps it to —
so the contract "504 deadline / 503 breaker / 429 admission" is encoded
in the type, not re-derived per call site, and the chaos harness can
assert that every injected fault resolved to exactly one of them.

``TransientError`` is the retry classifier: the dispatcher's bounded
retry-with-backoff (resilience/retry.py) retries *only* subclasses of
it.  Injected faults (resilience/faults.py) raise the ``Injected*``
subclasses, which are transient by construction — a retried compile or
dispatch may succeed on the next attempt once the scheduled fault has
burned its firing budget.  Permanent conditions (deadline passed,
breaker open, watchdog trip, stranded drain) are deliberately *not*
transient: retrying them in-process wastes the very capacity they
protect.

Import-light on purpose (stdlib only): core/engine.py and
serve/engine_cache.py consult the fault layer, so nothing here may pull
in jax or the serving stack.
"""

from __future__ import annotations


class ResilienceError(Exception):
    """Base of the typed serving-failure taxonomy.

    ``status`` is the HTTP code the transport answers with;
    ``retry_after_s`` (when > 0) becomes the ``Retry-After`` hint.
    """

    status = 500
    retry_after_s = 0.0


class TransientError(ResilienceError):
    """A failure worth one more attempt (the retry classifier)."""

    status = 503


class InjectedError(TransientError):
    """Generic fault-injection failure (chaos testing)."""


class InjectedCompileError(InjectedError):
    """Injected at a compile seam: ``plan.compile()`` 'failed'."""


class InjectedDispatchError(InjectedError):
    """Injected at a dispatch seam: the device round 'failed'."""


class DeadlineExceeded(ResilienceError):
    """The request's deadline passed before it was served (HTTP 504).

    Raised at admission (already expired), at queue reap time (expired
    while waiting — before any device work is spent on it), or by
    ``wait`` when the deadline lapses with the request still queued.
    """

    status = 504

    def __init__(self, message: str, *, stage: str = "queue"):
        super().__init__(message)
        self.stage = stage          # admit | queue | wait


class CircuitOpenError(ResilienceError):
    """The lane's circuit breaker is open; fast-fail (HTTP 503)."""

    status = 503

    def __init__(self, message: str, *, retry_after_s: float = 1.0):
        super().__init__(message)
        self.retry_after_s = max(0.0, float(retry_after_s))


class StuckDispatchError(ResilienceError):
    """The dispatcher watchdog timed out a device round (HTTP 500).

    The in-flight batch is failed with this error; the abandoned round
    keeps running on its worker thread until the device returns, and the
    lane's breaker records the failure so repeats open the circuit.
    """

    status = 500


class StrandedRequestError(ResilienceError):
    """``run_until_drained`` hit its bound with this request pending.

    Attached to each stranded request (and the request marked done) so
    in-process callers polling ``req.done`` never hang on work the
    service has given up on.
    """

    status = 503
