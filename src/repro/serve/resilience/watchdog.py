"""Dispatcher watchdog: bound a device round, abandon it if it wedges.

The frontend dispatcher used to block indefinitely on each lane's
result sync — one wedged device round (or injected stall) froze every
lane behind it.  ``DispatchWatchdog.guard(fn)`` runs ``fn`` on a
watched worker thread and waits at most ``timeout_s``: on time, the
value (or the callee's own exception) propagates exactly as a direct
call would; on timeout the in-flight batch entry is failed with a typed
``StuckDispatchError`` (HTTP 500) and the dispatcher moves on — other
lanes keep serving.

The abandoned worker cannot be killed (Python threads aren't), so it is
*tracked* instead: ``stuck()`` counts rounds still wedged right now,
which is what ``/readyz`` reports and what the chaos harness asserts
back to zero at the end of a soak (no-leak verdict).  Every guarded
call dispatches device work from exactly one thread at a time — the
dispatcher waits on the guard — so the engine-driving discipline the
frontend documents is preserved; only the *waiting* moved off-thread.
"""

from __future__ import annotations

import threading
import time

from repro.serve.resilience.errors import StuckDispatchError


class _Round:
    """One guarded call's shared cell (worker writes, guard reads)."""

    __slots__ = ("value", "error", "done", "abandoned")

    def __init__(self):
        self.value = None
        self.error = None
        self.done = threading.Event()
        self.abandoned = False


class DispatchWatchdog:
    """Timeout + stuck-round accounting for dispatcher device calls."""

    def __init__(self, timeout_s: float, *, name: str = "bfs-watchdog"):
        if not timeout_s > 0:
            raise ValueError(f"timeout_s must be > 0 ({timeout_s})")
        self.timeout_s = float(timeout_s)
        self.name = name
        # guarded-by(_lock): trips, _stuck, _completed_late
        self._lock = threading.Lock()
        self.trips = 0               # total timed-out rounds
        self._stuck = 0              # abandoned rounds still running
        self._completed_late = 0     # abandoned rounds that returned
        self._seq = 0

    def guard(self, fn, *, label: str = ""):
        """Run ``fn()`` with a timeout; raise ``StuckDispatchError`` on
        expiry (the worker keeps running, tracked via ``stuck()``)."""
        cell = _Round()

        def _worker():
            try:
                cell.value = fn()
            except BaseException as exc:   # delivered to the guard side
                cell.error = exc
            finally:
                cell.done.set()
                self._on_worker_done(cell)

        self._seq += 1
        t = threading.Thread(target=_worker, daemon=True,
                             name=f"{self.name}-{self._seq}")
        t.start()
        if not cell.done.wait(self.timeout_s) and \
                self._mark_abandoned(cell):
            raise StuckDispatchError(
                f"dispatch round{' ' + label if label else ''} exceeded "
                f"the {self.timeout_s:.2f}s watchdog timeout; batch "
                "failed, round abandoned to its worker thread")
        if cell.error is not None:
            raise cell.error
        return cell.value

    def _mark_abandoned(self, cell: _Round) -> bool:
        """Abandon a timed-out round unless its worker finished in the
        race window between wait expiry and this call (then the guard
        falls through and delivers the value as on-time)."""
        with self._lock:
            if cell.done.is_set():
                return False
            cell.abandoned = True
            self.trips += 1
            self._stuck += 1
            return True

    def _on_worker_done(self, cell: _Round) -> None:
        with self._lock:
            if cell.abandoned:
                self._stuck -= 1
                self._completed_late += 1

    # ------------------------------------------------------------- queries
    def stuck(self) -> int:
        """Abandoned rounds still running (readiness gate input)."""
        with self._lock:
            return self._stuck

    def wait_idle(self, timeout_s: float = 5.0) -> bool:
        """Block until no round is stuck (chaos no-leak verdict)."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if self.stuck() == 0:
                return True
            time.sleep(0.01)
        return self.stuck() == 0

    def snapshot(self) -> dict:
        with self._lock:
            return {"timeout_s": self.timeout_s, "trips": self.trips,
                    "stuck": self._stuck,
                    "completed_late": self._completed_late}
