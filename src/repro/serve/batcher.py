"""Continuous-batching serving loop over the framework's decode step.

Orca/vLLM-style scheduling on this framework's own cells: a fixed-size
decode batch whose slots are at *independent* sequence depths (the decode
step takes per-slot positions; each slot's KV rows land at its own depth
and attention masks per-slot lengths).  Finished slots are recycled for
queued requests without draining the batch.

Prefill here feeds prompt tokens through the decode step slot-locally
(token at a time); large-batch prompt ingestion is the separate
``prefill_32k`` cell.  Greedy sampling; deterministic by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class SlotPool:
    """Fixed-size slot scheduler: queued requests fill free slots, finished
    slots are recycled without draining the batch.

    The pool only requires items to expose a boolean ``done`` attribute.
    Shared by the LM continuous-batching ``Server`` below and the BFS
    traversal service (serve/bfs_service.py), which batches concurrent
    source requests into one multi-source engine run.
    """

    def __init__(self, n_slots: int):
        self.slots: List[Optional[Any]] = [None] * n_slots
        self.queue: List[Any] = []

    def __len__(self) -> int:
        return len(self.slots)

    def submit(self, item) -> None:
        self.queue.append(item)

    def admit(self) -> List[tuple]:
        """Fill free (empty or finished) slots from the queue in FIFO
        order; returns the (slot_index, item) placements made."""
        placed = []
        for i, cur in enumerate(self.slots):
            if (cur is None or cur.done) and self.queue:
                item = self.queue.pop(0)
                self.slots[i] = item
                placed.append((i, item))
        return placed

    def live(self) -> np.ndarray:
        """(n_slots,) bool — slots holding an unfinished item."""
        return np.array([r is not None and not r.done for r in self.slots])

    def drained(self) -> bool:
        return not self.queue and all(
            r is None or r.done for r in self.slots)


class Server:
    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.pool = SlotPool(batch_slots)
        self.n_slots = batch_slots
        self.max_len = max_len
        self.cache = tf.init_cache(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, dtype=np.int32)   # per-slot depth
        self._last_tok = np.zeros(batch_slots, dtype=np.int32)
        self._decode = jax.jit(
            lambda p, c, pos, tok: tf.decode_step(cfg, p, c, pos, tok))

    @property
    def slots(self) -> List[Optional[Request]]:
        return self.pool.slots

    def submit(self, req: Request):
        self.pool.submit(req)

    # --------------------------------------------------------------- core
    def _advance(self, active_mask: np.ndarray):
        """One decode step; slots advance at their own positions.  Inactive
        slots re-write their current position with their current token —
        a self-overwrite no-op — and their outputs are discarded."""
        pos = jnp.asarray(self.pos)
        tok = jnp.asarray(self._last_tok)
        logits, self.cache = self._decode(self.params, self.cache, pos, tok)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        self.pos = np.where(active_mask, self.pos + 1, self.pos)
        return nxt

    def _admit(self):
        for i, req in self.pool.admit():
            self.pos[i] = 0
            # slot-local prefill: stream prompt tokens through decode,
            # advancing only this slot
            mask = np.zeros(self.n_slots, bool)
            mask[i] = True
            for tok in req.prompt:
                self._last_tok[i] = int(tok)
                self._advance(mask)
            self._last_tok[i] = int(req.prompt[-1])

    def step(self):
        """Admit + one decode step for every live slot; returns finished."""
        self._admit()
        live = self.pool.live()
        if not live.any():
            return []
        nxt = self._advance(live)
        finished = []
        for i in np.where(live)[0]:
            r = self.slots[i]
            r.out.append(int(nxt[i]))
            self._last_tok[i] = int(nxt[i])
            if (len(r.out) >= r.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                r.done = True
                finished.append(r)
        return finished

    def run_until_drained(self, max_steps: int = 10_000):
        done = []
        for _ in range(max_steps):
            done += self.step()
            if self.pool.drained():
                break
        return done
