"""Cross-graph engine cache + graph catalog: the multi-tenant serving core.

The compile-once lifecycle (core/engine.py) amortizes one graph's
traversals; this module amortizes *across* graphs and entry points.  An
``EngineCache`` is a memory-bounded LRU of compiled ``BFSEngine``s keyed
by ``BFSPlan.plan_key()`` — graph content hash, options, mesh topology,
partition scheme, source capacity and resolved exchange strategies — so
every entry point (``BFSService`` lanes, the deprecated ``bfs()``
wrapper, launchers, benchmarks) shares one compiled-asset pool:

  * ``get_or_compile(plan)`` is thread-safe and coalescing: concurrent
    requests for one key get the same engine object and pay one compile
    (losers wait on the winner's in-flight event instead of recompiling).
  * Eviction is LRU over ``estimated_device_bytes()`` against a byte
    budget (``max_device_bytes``) and/or an entry cap (``max_entries``);
    pinned entries are never evicted.  Evicting drops the cache's
    reference — live holders keep their engine; its device buffers free
    when the last reference dies.
  * Counters (hits / misses / evictions / compile seconds) feed the
    serving benchmarks' amortization ledger and the launchers' stats
    lines.

``GraphCatalog`` is the name -> graph registry the multi-graph
``BFSService`` routes on.  It reuses ``graphs.formats.to_2d`` for lazy
1-D -> 2-D conversion, so a graph registered once serves 1-D and 2-D
plans from the same container (same blocks, shared device-buffer cache).

A process-wide default cache (``default_engine_cache``) backs ``bfs()``
and the launchers; ``BFS_ENGINE_CACHE_ENTRIES`` / ``BFS_ENGINE_CACHE_MB``
size it from the environment.
"""

from __future__ import annotations

import contextlib
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Optional

from repro.serve.resilience import faults as _faults


def _to_key(obj) -> tuple:
    """Accept a BFSPlan, a BFSEngine or a raw key tuple."""
    if hasattr(obj, "plan_key"):
        return obj.plan_key()
    if hasattr(obj, "plan"):
        return obj.plan.plan_key()
    return obj


@dataclass
class _Entry:
    engine: object
    device_bytes: int
    compile_s: float
    pinned: bool = False


class EngineCache:
    """Keyed LRU of compiled BFS engines with a device-byte budget.

    ``max_device_bytes=None`` / ``max_entries=None`` disable that bound;
    with both disabled the cache only deduplicates and counts.
    """

    def __init__(self, *, max_device_bytes: Optional[int] = None,
                 max_entries: Optional[int] = None):
        if max_device_bytes is not None and max_device_bytes <= 0:
            raise ValueError(f"max_device_bytes must be positive "
                             f"({max_device_bytes})")
        if max_entries is not None and max_entries <= 0:
            raise ValueError(f"max_entries must be positive ({max_entries})")
        self.max_device_bytes = max_device_bytes
        self.max_entries = max_entries
        # guarded-by(_lock): _entries, _building, hits, misses,
        # guarded-by(_lock): evictions, compile_s_total
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._building: Dict[tuple, threading.Event] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compile_s_total = 0.0

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, plan_or_key) -> bool:
        key = _to_key(plan_or_key)
        with self._lock:
            return key in self._entries

    def keys(self) -> list:
        """Current keys in LRU order (least recently used first)."""
        with self._lock:
            return list(self._entries)

    def device_bytes(self) -> int:
        with self._lock:
            return sum(e.device_bytes for e in self._entries.values())

    def stats(self) -> dict:
        with self._lock:
            total = sum(e.device_bytes for e in self._entries.values())
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "entries": len(self._entries),
                "pinned": sum(e.pinned for e in self._entries.values()),
                "device_bytes": total,
                "max_device_bytes": self.max_device_bytes,
                "max_entries": self.max_entries,
                "compile_s_total": self.compile_s_total,
                "hit_rate": (self.hits / (self.hits + self.misses)
                             if self.hits + self.misses else 0.0),
            }

    # ----------------------------------------------------------- lifecycle
    def get(self, plan_or_key):
        """Cached engine or None; a hit refreshes LRU recency."""
        key = _to_key(plan_or_key)
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return ent.engine

    def get_or_compile(self, plan, *, pin: bool = False):
        """The serving entry point: one compiled engine per plan key.

        Thread-safe with per-key coalescing — the first caller of a key
        compiles while holding no lock (compiles are seconds-long; other
        keys must proceed); late callers of the same key wait on its
        in-flight event and receive the same engine object.

        ``pin=True`` marks the entry pinned in the same locked section
        that returns it — the race-free way to pin a latency-critical
        tenant (a separate ``pin()`` call can lose the entry to an
        eviction in between).
        """
        key = plan.plan_key()
        # chaos: "storm" specs evict everything unpinned before the
        # lookup (cache-eviction storms); no-op without an active plan
        _faults.fire("cache.get", _faults.plan_tag(plan),
                     storm=self.clear_unpinned)
        while True:
            with self._lock:
                ent = self._entries.get(key)
                if ent is not None:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    if pin:
                        ent.pinned = True
                    return ent.engine
                ev = self._building.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._building[key] = ev
                    break
            # another thread is compiling this key; wait, then re-check
            # (if its entry was evicted before we woke, we become the
            # builder on the next loop)
            ev.wait()
        try:
            _faults.fire("cache.compile", _faults.plan_tag(plan))
            t0 = time.perf_counter()
            engine = plan.compile()
            dt = time.perf_counter() - t0
            with self._lock:
                self.misses += 1
                self.compile_s_total += dt
                self._entries[key] = _Entry(
                    engine=engine,
                    device_bytes=int(plan.estimated_device_bytes()),
                    compile_s=dt, pinned=pin)
                self._entries.move_to_end(key)
                self._evict_over_budget(keep=key)
            return engine
        finally:
            with self._lock:
                self._building.pop(key, None)
            ev.set()

    def put(self, plan, engine) -> None:
        """Insert an externally compiled engine (benchmarks, tests)."""
        key = plan.plan_key()
        with self._lock:
            self._entries[key] = _Entry(
                engine=engine,
                device_bytes=int(plan.estimated_device_bytes()),
                compile_s=0.0)
            self._entries.move_to_end(key)
            self._evict_over_budget(keep=key)

    # audit: allow(LK001) -- internal helper; every caller holds _lock
    def _evict_over_budget(self, keep: tuple) -> None:
        """Drop LRU unpinned entries until bounds hold (lock held).

        The just-touched ``keep`` entry is exempt: an engine the caller is
        about to receive must not be evicted out from under the in-flight
        waiters even when it alone exceeds the budget (the cache then
        temporarily runs over — the estimate is advisory for admission,
        binding for retention).
        """
        def over() -> bool:
            if (self.max_entries is not None
                    and len(self._entries) > self.max_entries):
                return True
            if self.max_device_bytes is not None:
                total = sum(e.device_bytes for e in self._entries.values())
                return total > self.max_device_bytes
            return False

        while over():
            victim = next((k for k, e in self._entries.items()
                           if not e.pinned and k != keep), None)
            if victim is None:
                return                      # only pinned/kept entries left
            del self._entries[victim]
            self.evictions += 1

    # -------------------------------------------------------------- pinning
    def pin(self, plan_or_key) -> bool:
        """Exempt a resident entry from eviction (latency-critical
        tenants); returns False if the key is not resident — e.g. it was
        evicted between a ``get_or_compile`` and this call.  For a
        race-free pin use ``get_or_compile(plan, pin=True)``."""
        key = _to_key(plan_or_key)
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return False
            ent.pinned = True
            return True

    def unpin(self, plan_or_key) -> None:
        key = _to_key(plan_or_key)
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None:
                ent.pinned = False

    def evict(self, plan_or_key) -> bool:
        """Explicitly drop one entry (pinned or not); True if it existed."""
        key = _to_key(plan_or_key)
        with self._lock:
            if key in self._entries:
                del self._entries[key]
                self.evictions += 1
                return True
            return False

    def clear(self) -> None:
        with self._lock:
            self.evictions += len(self._entries)
            self._entries.clear()

    def clear_unpinned(self) -> int:
        """Drop every unpinned entry (the eviction-storm hammer the
        chaos layer swings); returns the number dropped."""
        with self._lock:
            victims = [k for k, e in self._entries.items() if not e.pinned]
            for k in victims:
                del self._entries[k]
            self.evictions += len(victims)
            return len(victims)


# ---------------------------------------------------------------------------
# Graph catalog: the names the multi-graph service routes on
# ---------------------------------------------------------------------------

class GraphCatalog:
    """Registry of named graphs for multi-tenant serving.

    Holds 1-D ``ShardedGraph``s and/or pre-built ``ShardedGraph2D``s;
    ``get_2d`` converts a 1-D registration lazily through the cached
    ``to_2d`` so both partition schemes serve from one container.
    Re-registering a name is a no-op for the identical object and an
    error otherwise (silent replacement would orphan cached engines whose
    keys still fingerprint the old content).
    """

    def __init__(self):
        # guarded-by(_lock): _graphs
        self._graphs: Dict[str, object] = {}
        self._lock = threading.Lock()

    def register(self, name: str, graph):
        if not name:
            raise ValueError("graph name must be non-empty")
        with self._lock:
            cur = self._graphs.get(name)
            if cur is not None and cur is not graph:
                raise ValueError(
                    f"graph {name!r} is already registered with a "
                    "different object; unregister it first")
            self._graphs[name] = graph
        return graph

    def unregister(self, name: str) -> None:
        with self._lock:
            self._graphs.pop(name, None)

    def get(self, name: str):
        with self._lock:
            try:
                return self._graphs[name]
            except KeyError:
                raise KeyError(
                    f"graph {name!r} is not registered; catalog has "
                    f"{sorted(self._graphs)}") from None

    def get_2d(self, name: str, r: int, c: int):
        """The registered graph's 2-D edge blocks for an r x c grid —
        the same cached object ``plan(graph, partition='2d')`` uses."""
        from repro.graphs.formats import ShardedGraph2D, to_2d

        g = self.get(name)
        if isinstance(g, ShardedGraph2D):
            if (g.part.r, g.part.c) != (r, c):
                raise ValueError(
                    f"graph {name!r} holds {g.part.r}x{g.part.c} edge "
                    f"blocks; requested grid is {r}x{c}")
            return g
        return to_2d(g, r, c)

    def names(self) -> list:
        with self._lock:
            return sorted(self._graphs)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._graphs

    def __len__(self) -> int:
        with self._lock:
            return len(self._graphs)


# ---------------------------------------------------------------------------
# Process-wide default cache (bfs() wrapper, launchers)
# ---------------------------------------------------------------------------

_default_cache: Optional[EngineCache] = None
_default_lock = threading.Lock()


def _cache_from_env() -> EngineCache:
    # The default entry cap matches the old bfs() wrapper's 8-engine
    # memo: cache entries keep their engine -> plan -> graph chain alive
    # (host blocks included), so a generous default would pin dropped
    # graphs' memory for the process lifetime.  Serving deployments
    # should size their own EngineCache (byte budget) explicitly.
    entries = int(os.environ.get("BFS_ENGINE_CACHE_ENTRIES", "8"))
    mb = float(os.environ.get("BFS_ENGINE_CACHE_MB", "0"))
    return EngineCache(
        max_entries=entries if entries > 0 else None,
        max_device_bytes=int(mb * 2**20) if mb > 0 else None)


def default_engine_cache() -> EngineCache:
    """The process-wide shared cache (created on first use)."""
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = _cache_from_env()
        return _default_cache


def set_default_cache(cache: Optional[EngineCache]) -> Optional[EngineCache]:
    """Swap the process-wide cache; returns the previous one (None =
    reset, so the next ``default_engine_cache()`` re-reads the env)."""
    global _default_cache
    with _default_lock:
        prev, _default_cache = _default_cache, cache
        return prev


@contextlib.contextmanager
def use_default_cache(cache: EngineCache):
    """Temporarily install ``cache`` as the process default (tests)."""
    prev = set_default_cache(cache)
    try:
        yield cache
    finally:
        set_default_cache(prev)
