"""Remote serving front-end over the multi-tenant ``BFSService``.

The network-facing layer that turns the repo from a library into a
deployable service.  Four pieces, each its own module:

  * ``schema``    — the JSON request/response wire contract of
    ``POST /v1/traverse`` (+ host-side parent derivation), with
    400-style validation errors typed so the transport can map them.
  * ``admission`` — per-lane bounded admission: queue-depth and
    in-flight-byte gates, fast 429-style rejection with a retry-after
    hint, and the draining (503) state for graceful shutdown.
  * ``metrics``   — per-lane counters and latency histograms (queue
    wait, device time, end-to-end) plus per-bucket dispatch counts;
    rendered by ``GET /metrics`` next to the shared ``EngineCache``'s
    hit/evict counters.
  * ``server``    — the transport: a stdlib ``ThreadingHTTPServer``
    whose handler threads validate + admit, and a single dispatcher
    thread that routes admitted requests to batch-size buckets through
    ``BFSService.traverse_async`` (lanes overlap device work exactly
    like ``BFSService.step``).

``launch/bfs_serve.py --http HOST:PORT`` binds it; ``launch/bfs_client``
is the matching stdlib client.
"""

from repro.serve.frontend.admission import (AdmissionError, DrainingError,
                                            LaneGate)
from repro.serve.frontend.metrics import (FrontendMetrics, Histogram,
                                          LaneMetrics)
from repro.serve.frontend.schema import (RequestError, derive_parents,
                                         encode_traverse_response,
                                         parse_traverse_request)
from repro.serve.frontend.server import BFSFrontend, serve_http

__all__ = [
    "AdmissionError", "DrainingError", "LaneGate",
    "FrontendMetrics", "Histogram", "LaneMetrics",
    "RequestError", "derive_parents", "encode_traverse_response",
    "parse_traverse_request",
    "BFSFrontend", "serve_http",
]
