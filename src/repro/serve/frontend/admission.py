"""Per-lane admission control: bounded queues, fast rejection, drain.

The front-end's backpressure contract: a lane admits a request only if
(a) its queue holds fewer than ``max_queue_depth`` waiting requests and
(b) the response bytes of everything admitted-but-unfinished stay under
``max_inflight_bytes``.  Over either bound the request is rejected
*immediately* with a retry-after hint — a 429 in the transport — instead
of queuing unboundedly until the client times out anyway (the same
fast-fail shape as the engine cache's bounded budget: reject at the
door, never wedge the fleet).

One deliberate exception mirrors the cache's oversized-keep semantics:
a request whose cost alone exceeds the byte bound is still admitted when
the lane is otherwise *empty* — rejecting it then would make it
permanently unservable, and serving it serializes it against nothing.

``close()`` flips the gate into draining: new admissions raise
``DrainingError`` (503) while everything already admitted proceeds —
the graceful-shutdown half of the contract.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Optional


class AdmissionError(Exception):
    """Lane over its queue-depth or in-flight-byte bound (HTTP 429)."""

    status = 429

    def __init__(self, message: str, *, retry_after_s: float):
        super().__init__(message)
        self.retry_after_s = max(0.0, float(retry_after_s))


class DrainingError(Exception):
    """Gate is draining for shutdown; nothing new admitted (HTTP 503)."""

    status = 503


class LaneGate:
    """Bounded admission for one serving lane.

    ``try_admit`` / ``pop`` / ``complete`` form the request lifecycle:
    admitted requests sit in the FIFO until the dispatcher ``pop``s
    them; their byte cost stays charged against the in-flight budget
    until ``complete`` — so the budget covers queued *and* dispatched
    work (the response buffers both hold alive).
    """

    def __init__(self, *, max_queue_depth: int = 64,
                 max_inflight_bytes: int = 256 << 20):
        if max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1 "
                             f"({max_queue_depth})")
        if max_inflight_bytes < 1:
            raise ValueError(f"max_inflight_bytes must be >= 1 "
                             f"({max_inflight_bytes})")
        self.max_queue_depth = int(max_queue_depth)
        self.max_inflight_bytes = int(max_inflight_bytes)
        self._lock = threading.Lock()
        # guarded-by(_lock): _queue, _inflight_bytes, _inflight_reqs,
        # guarded-by(_lock): _closed, admitted, rejected
        self._queue: deque = deque()
        self._inflight_bytes = 0
        self._inflight_reqs = 0      # admitted and not yet completed
        self._closed = False
        self.admitted = 0
        self.rejected = 0

    # ------------------------------------------------------------ lifecycle
    def try_admit(self, item, cost_bytes: int,
                  retry_after_s: float = 0.1) -> None:
        """Admit ``item`` or raise; never blocks.

        ``retry_after_s`` is the caller's service-time hint (e.g. an
        EWMA of recent end-to-end latency) scaled here by the queue
        depth the retrying client would land behind.
        """
        cost = int(cost_bytes)
        with self._lock:
            if self._closed:
                raise DrainingError(
                    "lane is draining for shutdown; retry against a new "
                    "server instance")
            if len(self._queue) >= self.max_queue_depth:
                self.rejected += 1
                raise AdmissionError(
                    f"lane queue is full ({len(self._queue)}/"
                    f"{self.max_queue_depth} waiting)",
                    retry_after_s=retry_after_s * (len(self._queue) + 1))
            if (self._inflight_bytes + cost > self.max_inflight_bytes
                    and self._inflight_reqs > 0):
                self.rejected += 1
                raise AdmissionError(
                    f"lane in-flight budget is full ({self._inflight_bytes}"
                    f" + {cost} > {self.max_inflight_bytes} bytes)",
                    retry_after_s=retry_after_s * (self._inflight_reqs + 1))
            self._queue.append((item, cost))
            self._inflight_bytes += cost
            self._inflight_reqs += 1
            self.admitted += 1

    def pop(self) -> Optional[tuple]:
        """Next ``(item, cost_bytes)`` in FIFO order, or None.  The cost
        stays charged until ``complete(cost_bytes)``."""
        with self._lock:
            return self._queue.popleft() if self._queue else None

    def complete(self, cost_bytes: int) -> None:
        with self._lock:
            self._inflight_bytes -= int(cost_bytes)
            self._inflight_reqs -= 1

    # -------------------------------------------------------------- queries
    def depth(self) -> int:
        with self._lock:
            return len(self._queue)

    def inflight(self) -> int:
        with self._lock:
            return self._inflight_reqs

    def idle(self) -> bool:
        """No queued and no dispatched-but-unfinished work."""
        with self._lock:
            return not self._queue and self._inflight_reqs == 0

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "queued": len(self._queue),
                "inflight_requests": self._inflight_reqs,
                "inflight_bytes": self._inflight_bytes,
                "max_queue_depth": self.max_queue_depth,
                "max_inflight_bytes": self.max_inflight_bytes,
                "admitted": self.admitted,
                "rejected": self.rejected,
                "draining": self._closed,
            }

    # ----------------------------------------------------------------- drain
    def close(self) -> None:
        """Stop admitting (already-admitted work proceeds)."""
        with self._lock:
            self._closed = True

    def reopen(self) -> None:
        with self._lock:
            self._closed = False
