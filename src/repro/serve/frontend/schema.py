"""JSON wire contract of the traversal front-end (``/v1/traverse``).

One request is one batched traversal: a graph name plus 1..S distinct
source vertex ids (S = the lane's largest bucket).  The response carries
the per-source depth rows of the engine's distance matrix — raw int32
values including the ``INF`` unreached sentinel, so a client comparison
against an in-process ``BFSEngine.run`` is *bitwise*, never epsilon —
and, on request, a parent vector derived host-side from the depths.

Validation here is typed (``RequestError`` carries an HTTP status) so
the transport maps malformed input to 400s at the door; semantic source
validation (range, duplicates) happens in
``BFSService.traverse_async`` -> ``validate_sources`` and is mapped by
the server to the same 400 family.
"""

from __future__ import annotations

import json
from typing import Optional

import numpy as np

from repro.core.frontier import INF

#: wire value of an unreached vertex (``jnp.int32(2**30)`` on device);
#: echoed in every response so clients need not hard-code it
UNREACHED = int(INF)

#: hard cap on request body size (a traverse request is a name + a small
#: id list; anything near this is malformed or hostile)
MAX_BODY_BYTES = 1 << 20

#: hard cap on sources per request, independent of any lane's ladder —
#: bounds the work a single malformed request can queue
MAX_SOURCES_PER_REQUEST = 4096


class RequestError(ValueError):
    """Malformed request; ``status`` is the HTTP code the transport
    should answer with (400 unless stated otherwise)."""

    def __init__(self, message: str, status: int = 400):
        super().__init__(message)
        self.status = status


def parse_traverse_request(body: bytes) -> dict:
    """Decode + structurally validate a ``/v1/traverse`` body.

    Returns ``{"graph": str|None, "sources": [int, ...],
    "include_parents": bool, "deadline_ms": float|None}``.
    Range/duplicate checks are deferred to the service's submit-time
    ``validate_sources`` (they need the lane's vertex count); everything
    shape- and type-level fails here.
    """
    if len(body) > MAX_BODY_BYTES:
        raise RequestError(f"request body of {len(body)} bytes exceeds "
                           f"the {MAX_BODY_BYTES}-byte limit", status=413)
    try:
        obj = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise RequestError(f"request body is not valid JSON: {exc}")
    if not isinstance(obj, dict):
        raise RequestError("request body must be a JSON object with a "
                           "'sources' list (and optionally 'graph')")
    unknown = sorted(set(obj) - {"graph", "sources", "include_parents",
                                 "deadline_ms"})
    if unknown:
        raise RequestError(f"unknown request field(s) {unknown}; expected "
                           "graph, sources, include_parents, deadline_ms")

    graph = obj.get("graph")
    if graph is not None and not isinstance(graph, str):
        raise RequestError(f"'graph' must be a string lane name, got "
                           f"{type(graph).__name__}")

    sources = obj.get("sources")
    if not isinstance(sources, list) or not sources:
        raise RequestError("'sources' must be a non-empty list of vertex "
                           "ids")
    if len(sources) > MAX_SOURCES_PER_REQUEST:
        raise RequestError(f"{len(sources)} sources exceed the per-request "
                           f"limit of {MAX_SOURCES_PER_REQUEST}")
    for s in sources:
        # bool is an int subclass; reject it explicitly
        if isinstance(s, bool) or not isinstance(s, int):
            raise RequestError(f"source ids must be integers, got {s!r}")

    include_parents = obj.get("include_parents", False)
    if not isinstance(include_parents, bool):
        raise RequestError("'include_parents' must be a boolean")

    # request deadline: a *budget* in ms from admission, propagated
    # admission -> queue -> dispatch so expired work is reaped (504)
    # before it reaches the device
    deadline_ms = obj.get("deadline_ms")
    if deadline_ms is not None:
        if isinstance(deadline_ms, bool) or \
                not isinstance(deadline_ms, (int, float)):
            raise RequestError("'deadline_ms' must be a number of "
                               "milliseconds")
        if not deadline_ms > 0:
            raise RequestError(f"'deadline_ms' must be positive "
                               f"({deadline_ms})")
        deadline_ms = float(deadline_ms)
    return {"graph": graph, "sources": [int(s) for s in sources],
            "include_parents": include_parents, "deadline_ms": deadline_ms}


def derive_parents(src: np.ndarray, dst: np.ndarray,
                   depths: np.ndarray) -> np.ndarray:
    """A valid BFS parent matrix from the edge list + depth matrix.

    ``depths`` is the (n, S) distance matrix; the result is (n, S) int64
    with ``parents[v] = u`` for some arc ``u -> v`` on a shortest path
    (the smallest such ``u`` — deterministic), ``parents[source] =
    source`` and ``-1`` for unreached vertices.  Host-side O(E·S): the
    engine ships depths only, so parents are a front-end derivation, not
    a device output.
    """
    depths = np.asarray(depths)
    if depths.ndim == 1:
        depths = depths[:, None]
    n, s = depths.shape
    parents = np.full((n, s), -1, dtype=np.int64)
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    for j in range(s):
        d = depths[:, j]
        on_path = (d[src] + 1 == d[dst]) & (d[src] < UNREACHED)
        col = np.full(n, np.iinfo(np.int64).max, dtype=np.int64)
        np.minimum.at(col, dst[on_path], src[on_path])
        found = col != np.iinfo(np.int64).max
        parents[found, j] = col[found]
        parents[d == 0, j] = np.where(d == 0)[0]   # each source roots itself
    return parents


def encode_traverse_response(*, graph: str, sources, bucket: int,
                             depths: np.ndarray,
                             parents: Optional[np.ndarray],
                             run_stats: dict, timing_ms: dict) -> bytes:
    """Serialize one traversal result; ``depths`` is the engine's
    padding-stripped ``dist_host`` (n_logical, len(sources))."""
    depths = np.asarray(depths)
    payload = {
        "graph": graph,
        "sources": [int(s) for s in sources],
        "bucket": int(bucket),
        "n": int(depths.shape[0]),
        "unreached": UNREACHED,
        # row per source (column-major transpose of dist_host): the
        # natural client shape, and json encodes int32 exactly
        "depths": depths.T.tolist(),
        "stats": run_stats,
        "timing_ms": {k: round(float(v), 3) for k, v in timing_ms.items()},
    }
    if parents is not None:
        payload["parents"] = np.asarray(parents).T.tolist()
    return json.dumps(payload).encode("utf-8")
