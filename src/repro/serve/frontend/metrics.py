"""Serving observability: per-lane counters + latency histograms.

Three latency axes per lane, matching the request lifecycle the
dispatcher drives (server.py):

  * ``queue_wait`` — admission to dispatch (time spent behind the gate);
  * ``device``     — dispatch to result sync (engine ``run_async`` ->
    ``block``, i.e. device time plus the overlap window shared with
    other lanes);
  * ``e2e``        — admission to completion (what the client feels,
    minus transport).

Histograms use fixed log-spaced bucket bounds so snapshots are cheap,
mergeable, and stable across runs; percentile estimates are the bucket
upper bound (conservative).  All mutation is lock-guarded per lane —
handler threads and the dispatcher both record — so the counters obey
the same no-lost-updates contract the ``EngineCache`` stats do.
``FrontendMetrics.snapshot()`` is what ``GET /metrics`` returns, with
the shared cache's hit/evict counters attached by the caller.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional

#: log-spaced seconds; the last open bucket catches everything slower
DEFAULT_BOUNDS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                  0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

#: per-level step times sit 1-3 decades below request latencies (a
#: traversal is levels x step), so the per-level histogram extends the
#: default bounds downward into the sub-millisecond range
PER_LEVEL_BOUNDS = (0.0001, 0.00025, 0.0005) + DEFAULT_BOUNDS


class Histogram:
    """Fixed-bound latency histogram (seconds in, ms out).

    Not self-locking: the owning ``LaneMetrics`` serializes access —
    one lock per lane instead of three per observation.
    """

    def __init__(self, bounds=DEFAULT_BOUNDS):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing ({bounds})")
        self.counts = [0] * (len(self.bounds) + 1)   # +1: overflow bucket
        self.count = 0
        self.sum_s = 0.0

    def observe(self, seconds: float) -> None:
        s = float(seconds)
        i = 0
        for i, b in enumerate(self.bounds):
            if s <= b:
                break
        else:
            i = len(self.bounds)
        self.counts[i] += 1
        self.count += 1
        self.sum_s += s

    def quantile(self, q: float) -> Optional[float]:
        """Upper-bound estimate of the q-quantile in seconds (None when
        empty; +inf collapses to the largest finite bound)."""
        if not self.count:
            return None
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return (self.bounds[i] if i < len(self.bounds)
                        else self.bounds[-1])
        return self.bounds[-1]

    def snapshot(self) -> dict:
        out = {
            "count": self.count,
            "sum_ms": round(self.sum_s * 1e3, 3),
            "mean_ms": round(self.sum_s / self.count * 1e3, 3)
                        if self.count else None,
            "buckets": {},
        }
        cum = 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            out["buckets"][f"le_{b * 1e3:g}ms"] = cum
        out["buckets"]["le_inf"] = self.count
        for q, label in ((0.5, "p50_ms"), (0.95, "p95_ms"),
                         (0.99, "p99_ms")):
            v = self.quantile(q)
            out[label] = round(v * 1e3, 3) if v is not None else None
        return out


class LaneMetrics:
    """One lane's serving counters; all methods are thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        # guarded-by(_lock): queue_wait, device, per_level, e2e, completed,
        # guarded-by(_lock): failed, rejected, rejected_invalid,
        # guarded-by(_lock): bucket_counts, sources_served, wire_bytes,
        # guarded-by(_lock): _ewma_e2e_s, deadline_expired,
        # guarded-by(_lock): breaker_rejected, retries, degraded
        self.queue_wait = Histogram()
        self.device = Histogram()
        # per-level device step time: each completed run contributes one
        # observation per traversal level (device_s / levels), so deep
        # traversals weigh in proportion to the level iterations they ran
        # — the distribution the fused-tail work (ISSUE 9) shortens
        self.per_level = Histogram(PER_LEVEL_BOUNDS)
        self.e2e = Histogram()
        self.completed = 0
        self.failed = 0
        self.rejected = 0              # 429s (admission)
        self.rejected_invalid = 0      # 400s (validation)
        self.bucket_counts: Dict[int, int] = {}
        self.sources_served = 0
        # cumulative modeled per-chip wire bytes by phase (resolved
        # plan's per-level pricing x levels each run spent in the phase)
        self.wire_bytes: Dict[str, float] = {}
        self._ewma_e2e_s = None
        # resilience counters (server.py's deadline / breaker / retry /
        # degradation paths record here; /metrics surfaces them)
        self.deadline_expired = 0      # 504s (reaped or expired waits)
        self.breaker_rejected = 0      # 503s shed while the circuit is open
        self.retries = 0               # transient-failure retry attempts
        self.degraded: Dict[str, int] = {}   # serves per degradation arm

    # ------------------------------------------------------------ recording
    def record_rejected(self, *, invalid: bool = False) -> None:
        with self._lock:
            if invalid:
                self.rejected_invalid += 1
            else:
                self.rejected += 1

    def record_failed(self) -> None:
        with self._lock:
            self.failed += 1

    def record_deadline_expired(self) -> None:
        with self._lock:
            self.deadline_expired += 1

    def record_breaker_rejected(self) -> None:
        with self._lock:
            self.breaker_rejected += 1

    def record_retry(self) -> None:
        with self._lock:
            self.retries += 1

    def record_degraded(self, arm: str) -> None:
        with self._lock:
            self.degraded[arm] = self.degraded.get(arm, 0) + 1

    def record_completed(self, *, queue_wait_s: float, device_s: float,
                         e2e_s: float, bucket: int, n_sources: int,
                         wire_bytes: Optional[Dict[str, float]] = None,
                         levels: int = 0) -> None:
        with self._lock:
            for phase, b in (wire_bytes or {}).items():
                self.wire_bytes[phase] = self.wire_bytes.get(phase, 0.0) + b
            self.queue_wait.observe(queue_wait_s)
            self.device.observe(device_s)
            for _ in range(int(levels)):
                self.per_level.observe(device_s / levels)
            self.e2e.observe(e2e_s)
            self.completed += 1
            self.sources_served += int(n_sources)
            b = int(bucket)
            self.bucket_counts[b] = self.bucket_counts.get(b, 0) + 1
            # EWMA of end-to-end latency: the admission gate's
            # retry-after hint (alpha=0.3: reactive but not jittery)
            prev = self._ewma_e2e_s
            self._ewma_e2e_s = (e2e_s if prev is None
                                else 0.3 * e2e_s + 0.7 * prev)

    # -------------------------------------------------------------- queries
    def ewma_e2e_s(self, default: float = 0.1) -> float:
        with self._lock:
            return self._ewma_e2e_s if self._ewma_e2e_s is not None \
                else default

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "completed": self.completed,
                "failed": self.failed,
                "rejected": self.rejected,
                "rejected_invalid": self.rejected_invalid,
                "deadline_expired": self.deadline_expired,
                "breaker_rejected": self.breaker_rejected,
                "retries": self.retries,
                "degraded": dict(sorted(self.degraded.items())),
                "sources_served": self.sources_served,
                "buckets": {str(k): v for k, v
                            in sorted(self.bucket_counts.items())},
                "wire_bytes": {k: round(v, 1) for k, v
                               in sorted(self.wire_bytes.items())},
                "queue_wait": self.queue_wait.snapshot(),
                "device": self.device.snapshot(),
                "per_level_device": self.per_level.snapshot(),
                "e2e": self.e2e.snapshot(),
                "ewma_e2e_ms": round(self._ewma_e2e_s * 1e3, 3)
                                if self._ewma_e2e_s is not None else None,
            }


class FrontendMetrics:
    """The whole front-end's metrics tree (what ``/metrics`` serves)."""

    def __init__(self, lane_names):
        self.started = time.monotonic()
        self.lanes: Dict[str, LaneMetrics] = {
            name: LaneMetrics() for name in lane_names}

    def lane(self, name: str) -> LaneMetrics:
        return self.lanes[name]

    def snapshot(self, *, cache_stats: Optional[dict] = None,
                 gates: Optional[dict] = None,
                 draining: bool = False) -> dict:
        out = {
            "uptime_s": round(time.monotonic() - self.started, 3),
            "draining": draining,
            "lanes": {name: m.snapshot() for name, m in self.lanes.items()},
        }
        if gates is not None:
            for name, gate in gates.items():
                out["lanes"][name]["admission"] = gate.snapshot()
        if cache_stats is not None:
            out["engine_cache"] = dict(cache_stats)
        return out

    def stats_line(self, *, cache_stats: Optional[dict] = None) -> str:
        """One-line digest for the ``--stats-interval`` server log."""
        parts = []
        for name, m in self.lanes.items():
            snap = m.snapshot()
            p50 = snap["e2e"]["p50_ms"]
            wire = sum(snap["wire_bytes"].values())
            parts.append(
                f"{name}: ok={snap['completed']} 429={snap['rejected']} "
                f"400={snap['rejected_invalid']} "
                f"p50={p50 if p50 is not None else '-'}ms "
                f"wire={wire:.2e}B")
        if cache_stats:
            parts.append(f"cache: hit_rate={cache_stats['hit_rate']:.2f} "
                         f"evictions={cache_stats['evictions']}")
        return "stats: " + " | ".join(parts)
