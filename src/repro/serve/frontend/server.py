"""The HTTP transport + dispatcher over ``BFSService``.

Threading model: ``ThreadingHTTPServer`` handler threads do the cheap
host-side work — parse/validate the JSON body, admit against the lane's
gate, then block on the request's completion event and serialize the
response.  A single *dispatcher* thread owns all device interaction: it
round-robins the lanes, pops at most one admitted request per lane per
round, dispatches every popped request through
``BFSService.traverse_async`` (bucket routing happens there) *before*
blocking on any result — the same cross-lane device/host overlap
``BFSService.step`` pipelines — then completes the events.  One
dispatcher means the service and engines are only ever driven from one
thread, while N handler threads provide concurrent admission and
serialization.

Endpoints::

    POST /v1/traverse    {"graph": name, "sources": [ids...],
                          "include_parents": false}
    GET  /v1/graphs      lanes, ladders, admission config, graph specs
    GET  /healthz        liveness + draining flag
    GET  /metrics        per-lane histograms/counters + engine-cache stats
    POST /admin/shutdown graceful drain, then server stop

Error mapping: schema violations and source validation -> 400 (413 for
oversized bodies), unknown lane -> 404, admission bound -> 429 with a
``Retry-After`` header, draining -> 503.
"""

from __future__ import annotations

import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

from repro.serve.frontend import schema
from repro.serve.frontend.admission import (AdmissionError, DrainingError,
                                            LaneGate)
from repro.serve.frontend.metrics import FrontendMetrics


class _Pending:
    """One admitted request riding the dispatcher: timestamps + result."""

    __slots__ = ("graph", "sources", "include_parents", "cost_bytes",
                 "event", "result", "bucket", "error",
                 "t_admit", "t_dispatch", "t_done")

    def __init__(self, graph: str, sources, include_parents: bool,
                 cost_bytes: int):
        self.graph = graph
        self.sources = sources
        self.include_parents = include_parents
        self.cost_bytes = cost_bytes
        self.event = threading.Event()
        self.result = None           # BFSResult once served
        self.bucket = None
        self.error: Optional[Exception] = None
        self.t_admit = time.monotonic()
        self.t_dispatch = None
        self.t_done = None


class BFSFrontend:
    """Admission + dispatch + metrics over a configured ``BFSService``.

    Transport-agnostic: ``submit``/``wait`` drive it from the HTTP
    handler, tests, and the in-process serving benchmark alike.  Lanes
    must be registered on the service before construction (gates and
    metrics are built per existing lane).
    """

    def __init__(self, service, *, max_queue_depth: int = 64,
                 max_inflight_mb: float = 256.0,
                 stats_interval_s: float = 0.0,
                 graph_specs: Optional[dict] = None,
                 start_dispatcher: bool = True,
                 log=print):
        self.service = service
        self.graph_specs = dict(graph_specs or {})
        self._log = log
        names = service.graph_names()
        if not names:
            raise ValueError("service has no lanes; add_graph before "
                             "building a frontend")
        max_bytes = max(1, int(max_inflight_mb * 2**20))
        self.gates: Dict[str, LaneGate] = {
            name: LaneGate(max_queue_depth=max_queue_depth,
                           max_inflight_bytes=max_bytes)
            for name in names}
        self.metrics = FrontendMetrics(names)
        self._level_bytes: Dict[str, dict] = {}   # lane -> phase pricing
        # guarded-by(_cv): _running, _draining
        self._cv = threading.Condition()
        self._running = True
        self._draining = False
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="bfs-dispatch", daemon=True)
        self._stats_interval_s = float(stats_interval_s)
        self._stats_thread = None
        if start_dispatcher:
            self.start()

    # -------------------------------------------------------------- control
    def start(self) -> None:
        if not self._dispatcher.is_alive():
            self._dispatcher.start()
            if self._stats_interval_s > 0:
                self._stats_thread = threading.Thread(
                    target=self._stats_loop, name="bfs-stats", daemon=True)
                self._stats_thread.start()

    @property
    def draining(self) -> bool:
        with self._cv:
            return self._draining

    def drain(self, timeout_s: float = 60.0) -> bool:
        """Stop admitting; wait for admitted work to finish.  Returns
        True when every gate went idle within the timeout."""
        for gate in self.gates.values():
            gate.close()
        with self._cv:
            self._draining = True
            self._cv.notify_all()
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if all(g.idle() for g in self.gates.values()):
                return True
            time.sleep(0.01)
        return all(g.idle() for g in self.gates.values())

    def shutdown(self, timeout_s: float = 60.0) -> bool:
        """Graceful drain, then stop the dispatcher."""
        drained = self.drain(timeout_s)
        with self._cv:
            self._running = False
            self._cv.notify_all()
        if self._dispatcher.is_alive():
            self._dispatcher.join(timeout=5.0)
        return drained

    # ------------------------------------------------------------ admission
    def _resolve_lane(self, graph: Optional[str]):
        if graph is None:
            lane = self.service._sole_lane()   # raises ValueError if many
            return lane.name, lane
        return graph, self.service.lane(graph)  # raises KeyError if unknown

    def submit(self, graph: Optional[str], sources,
               include_parents: bool = False) -> _Pending:
        """Validate + admit one request; returns its pending handle.

        Raises ``KeyError`` (unknown lane), ``ValueError`` (bad
        sources), ``AdmissionError`` (bounds) or ``DrainingError`` —
        the transport maps each to its status code.
        """
        from repro.core.bfs import validate_sources

        name, lane = self._resolve_lane(graph)
        lane_metrics = self.metrics.lane(name)
        try:
            srcs = validate_sources(sources, lane.n_logical,
                                    max_sources=lane.ladder[-1])
        except ValueError:
            lane_metrics.record_rejected(invalid=True)
            raise
        # admission cost ~= response payload: one int32 depth row per
        # source (doubled when parents ride along), plus framing slack
        cost = (1 + bool(include_parents)) * lane.n_logical * 4 * len(srcs)
        cost += 1024
        pending = _Pending(name, [int(s) for s in srcs], include_parents,
                           cost)
        try:
            self.gates[name].try_admit(
                pending, cost, retry_after_s=lane_metrics.ewma_e2e_s())
        except AdmissionError:
            lane_metrics.record_rejected()
            raise
        with self._cv:
            self._cv.notify_all()
        return pending

    def wait(self, pending: _Pending,
             timeout_s: Optional[float] = None) -> "object":
        """Block until a pending request is served; returns its
        ``BFSResult`` or re-raises the dispatch error."""
        if not pending.event.wait(timeout_s):
            raise TimeoutError(
                f"request on lane {pending.graph!r} not served within "
                f"{timeout_s}s (queue depth "
                f"{self.gates[pending.graph].depth()})")
        if pending.error is not None:
            raise pending.error
        return pending.result

    def traverse(self, graph: Optional[str], sources, *,
                 include_parents: bool = False,
                 timeout_s: Optional[float] = 120.0) -> dict:
        """Submit + wait + shape the response payload (the in-process
        mirror of ``POST /v1/traverse``; benchmarks drive this)."""
        pending = self.submit(graph, sources, include_parents)
        result = self.wait(pending, timeout_s)
        return self._payload(pending, result)

    def _payload(self, pending: _Pending, result) -> dict:
        depths = result.dist_host
        parents = None
        if pending.include_parents:
            src, dst = self.service.lane(pending.graph).graph.edge_list()
            parents = schema.derive_parents(src, dst, depths)
        body = schema.encode_traverse_response(
            graph=pending.graph, sources=pending.sources,
            bucket=pending.bucket, depths=depths, parents=parents,
            run_stats=result.run_stats.to_host(),
            timing_ms={
                "queue_wait": (pending.t_dispatch - pending.t_admit) * 1e3,
                "device": (pending.t_done - pending.t_dispatch) * 1e3,
                "total": (pending.t_done - pending.t_admit) * 1e3,
            })
        return json.loads(body)

    # ------------------------------------------------------------- dispatch
    def _dispatch_loop(self) -> None:
        while True:
            batch = []
            for name in self.service.graph_names():
                popped = self.gates[name].pop()
                if popped is None:
                    continue
                pending, cost = popped
                pending.t_dispatch = time.monotonic()
                try:
                    res, bucket = self.service.traverse_async(
                        name, pending.sources)
                    pending.bucket = bucket
                    batch.append((name, pending, cost, res))
                except Exception as exc:   # compile/device failure
                    pending.error = exc
                    pending.t_done = time.monotonic()
                    self.metrics.lane(name).record_failed()
                    self.gates[name].complete(cost)
                    pending.event.set()
            for name, pending, cost, res in batch:
                try:
                    res.block()
                    pending.result = res
                except Exception as exc:
                    pending.error = exc
                    self.metrics.lane(name).record_failed()
                else:
                    pending.t_done = time.monotonic()
                    self.metrics.lane(name).record_completed(
                        queue_wait_s=pending.t_dispatch - pending.t_admit,
                        device_s=pending.t_done - pending.t_dispatch,
                        e2e_s=pending.t_done - pending.t_admit,
                        bucket=pending.bucket,
                        n_sources=len(pending.sources),
                        wire_bytes=self._run_wire_bytes(name, res),
                        levels=res.run_stats.to_host()["levels"])
                if pending.t_done is None:
                    pending.t_done = time.monotonic()
                self.gates[name].complete(cost)
                pending.event.set()
            if batch:
                continue          # keep draining queues while work exists
            with self._cv:
                if not self._running:
                    return
                if all(g.depth() == 0 for g in self.gates.values()):
                    self._cv.wait(timeout=0.1)

    def _run_wire_bytes(self, name: str, res) -> dict:
        """Modeled per-chip wire bytes one run moved, split by phase:
        the lane plan's resolved per-level pricing times the number of
        levels the run spent in each mode (already synced by block())."""
        pricing = self._level_bytes.get(name)
        if pricing is None:
            meta = self.service.lane(name).plan.describe()
            pricing = {ph: float(meta[f"{ph}_level_bytes"])
                       for ph in ("dense", "queue", "bottom_up")}
            self._level_bytes[name] = pricing
        counts = res.run_stats.to_host()["mode_counts"]
        return {ph: pricing[ph] * counts[ph]
                for ph in pricing if counts[ph]}

    def _stats_loop(self) -> None:
        while True:
            time.sleep(self._stats_interval_s)
            with self._cv:
                if not self._running:
                    return
            self._log(self.metrics.stats_line(
                cache_stats=self.service.cache_stats()))

    # -------------------------------------------------------------- queries
    def graphs_payload(self) -> dict:
        lanes = []
        for name in self.service.graph_names():
            lane = self.service.lane(name)
            plan_ = lane.plan
            meta = plan_.describe()
            info = {
                "name": name,
                "n": lane.n_logical,
                "partition": plan_.partition,
                "buckets": list(lane.ladder),
                "slots": len(lane.pool),
                "wire_formats": dict(meta["wire_formats"]),
                "sieve": meta["sieve"],
                "admission": self.gates[name].snapshot(),
            }
            if plan_.partition == "2d":
                info["grid"] = list(meta["grid"])
            if name in self.graph_specs:
                info["spec"] = self.graph_specs[name]
            lanes.append(info)
        return {"graphs": lanes}

    def metrics_payload(self) -> dict:
        return self.metrics.snapshot(
            cache_stats=self.service.cache_stats(), gates=self.gates,
            draining=self.draining)


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------

class _Handler(BaseHTTPRequestHandler):
    # one response per connection keeps the stdlib server simple and
    # avoids keep-alive bookkeeping in handler threads
    protocol_version = "HTTP/1.0"
    server_version = "repro-bfs-frontend/1"
    quiet = True

    @property
    def frontend(self) -> BFSFrontend:
        return self.server.frontend

    def log_message(self, fmt, *args):   # noqa: N802 (stdlib name)
        if not self.quiet:
            BaseHTTPRequestHandler.log_message(self, fmt, *args)

    # ------------------------------------------------------------- plumbing
    def _send_json(self, status: int, obj, extra_headers=()) -> None:
        body = obj if isinstance(obj, bytes) else json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in extra_headers:
            self.send_header(k, v)
        self.end_headers()
        try:
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError):
            pass                       # client gave up; nothing to unwind

    def _send_error_json(self, status: int, message: str,
                         extra_headers=(), **fields) -> None:
        self._send_json(status, {"error": message, **fields}, extra_headers)

    # ------------------------------------------------------------- routes
    def do_GET(self) -> None:          # noqa: N802 (stdlib name)
        fe = self.frontend
        if self.path == "/healthz":
            self._send_json(200, {"status": "draining" if fe.draining
                                  else "ok", "lanes": len(fe.gates)})
        elif self.path == "/v1/graphs":
            self._send_json(200, fe.graphs_payload())
        elif self.path == "/metrics":
            self._send_json(200, fe.metrics_payload())
        else:
            self._send_error_json(404, f"no route for GET {self.path}")

    def do_POST(self) -> None:         # noqa: N802 (stdlib name)
        if self.path == "/v1/traverse":
            self._traverse()
        elif self.path == "/admin/shutdown":
            self._shutdown()
        else:
            self._send_error_json(404, f"no route for POST {self.path}")

    def _read_body(self) -> bytes:
        length = int(self.headers.get("Content-Length") or 0)
        if length > schema.MAX_BODY_BYTES:
            raise schema.RequestError(
                f"request body of {length} bytes exceeds the "
                f"{schema.MAX_BODY_BYTES}-byte limit", status=413)
        return self.rfile.read(length)

    def _traverse(self) -> None:
        fe = self.frontend
        try:
            req = schema.parse_traverse_request(self._read_body())
            pending = fe.submit(req["graph"], req["sources"],
                                req["include_parents"])
        except schema.RequestError as exc:
            self._send_error_json(exc.status, str(exc))
            return
        except KeyError as exc:
            self._send_error_json(404, str(exc.args[0]) if exc.args
                                  else "unknown graph")
            return
        except ValueError as exc:
            self._send_error_json(400, str(exc))
            return
        except AdmissionError as exc:
            retry = max(1, math.ceil(exc.retry_after_s))
            self._send_error_json(
                429, str(exc), extra_headers=(("Retry-After", str(retry)),),
                retry_after_s=round(exc.retry_after_s, 3))
            return
        except DrainingError as exc:
            self._send_error_json(
                503, str(exc), extra_headers=(("Retry-After", "5"),))
            return
        try:
            result = fe.wait(pending, timeout_s=300.0)
        except TimeoutError as exc:
            self._send_error_json(504, str(exc))
            return
        except Exception as exc:       # dispatch-side failure
            self._send_error_json(500, f"{type(exc).__name__}: {exc}")
            return
        payload = fe._payload(pending, result)
        self._send_json(200, json.dumps(payload).encode())

    def _shutdown(self) -> None:
        fe = self.frontend
        self._send_json(200, {"status": "draining"})
        # drain + stop from a side thread: shutdown() must not run on a
        # handler thread the server is about to join
        threading.Thread(target=self.server.drain_and_stop,
                         daemon=True).start()


class _FrontendHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    frontend: BFSFrontend = None

    def drain_and_stop(self, timeout_s: float = 60.0) -> None:
        self.frontend.shutdown(timeout_s)
        self.shutdown()


def serve_http(service, host: str = "127.0.0.1", port: int = 0, *,
               max_queue_depth: int = 64, max_inflight_mb: float = 256.0,
               stats_interval_s: float = 0.0, graph_specs=None,
               start_dispatcher: bool = True, log=print):
    """Bind the front-end: returns ``(httpd, frontend)``.

    ``port=0`` binds an ephemeral port (``httpd.server_address[1]``
    holds the real one).  The caller owns the accept loop — call
    ``httpd.serve_forever()`` (blocking) or run it in a thread; stop
    via ``httpd.drain_and_stop()`` or ``POST /admin/shutdown``.
    """
    frontend = BFSFrontend(
        service, max_queue_depth=max_queue_depth,
        max_inflight_mb=max_inflight_mb,
        stats_interval_s=stats_interval_s, graph_specs=graph_specs,
        start_dispatcher=start_dispatcher, log=log)
    httpd = _FrontendHTTPServer((host, port), _Handler)
    httpd.frontend = frontend
    return httpd, frontend
