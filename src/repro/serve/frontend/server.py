"""The HTTP transport + dispatcher over ``BFSService``.

Threading model: ``ThreadingHTTPServer`` handler threads do the cheap
host-side work — parse/validate the JSON body, admit against the lane's
gate, then block on the request's completion event and serialize the
response.  A single *dispatcher* thread owns all device interaction: it
round-robins the lanes, pops at most one admitted request per lane per
round, dispatches every popped request through
``BFSService.traverse_async`` (bucket routing happens there) *before*
blocking on any result — the same cross-lane device/host overlap
``BFSService.step`` pipelines — then completes the events.  One
dispatcher means the service and engines are only ever driven from one
thread, while N handler threads provide concurrent admission and
serialization.

Endpoints::

    POST /v1/traverse    {"graph": name, "sources": [ids...],
                          "include_parents": false, "deadline_ms": 500}
    GET  /v1/graphs      lanes, ladders, admission config, graph specs
    GET  /healthz        liveness + draining flag
    GET  /readyz         readiness: 503 while draining, every lane's
                         breaker open, or a watchdog round is stuck
    GET  /metrics        per-lane histograms/counters + engine-cache
                         stats + breaker/deadline/retry/degrade counters
    POST /admin/shutdown graceful drain, then server stop

Error mapping: schema violations and source validation -> 400 (413 for
oversized bodies), unknown lane -> 404, admission bound -> 429 with a
``Retry-After`` header, draining or open circuit -> 503 (+Retry-After),
expired request deadline -> 504, stuck dispatch round -> 500.

Resilience (serve/resilience/): per-lane circuit breakers shed load at
the admission door and at dispatch; transient compile/dispatch failures
are retried with bounded exponential backoff, then served on a
degradation arm (another bucket, a split over a smaller bucket, the
uncompressed wire tier); request deadlines propagate admission ->
queue -> dispatch so expired entries are reaped before device work; a
watchdog bounds each device round so one wedged lane cannot freeze the
dispatcher.  All of it is driven by typed errors and is inert by
default (no deadline, no watchdog, retries only on ``TransientError``).
"""

from __future__ import annotations

import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

from repro.serve.frontend import schema
from repro.serve.frontend.admission import (AdmissionError, DrainingError,
                                            LaneGate)
from repro.serve.frontend.metrics import FrontendMetrics
from repro.serve.resilience import faults as _faults
from repro.serve.resilience.breaker import CircuitBreaker
from repro.serve.resilience.deadline import Deadline
from repro.serve.resilience.degrade import degraded_traverse
from repro.serve.resilience.errors import (DeadlineExceeded,
                                           ResilienceError, TransientError)
from repro.serve.resilience.retry import RetryPolicy, call_with_retry
from repro.serve.resilience.watchdog import DispatchWatchdog


class _Pending:
    """One admitted request riding the dispatcher: timestamps + result."""

    __slots__ = ("graph", "sources", "include_parents", "cost_bytes",
                 "event", "result", "bucket", "error", "deadline", "arm",
                 "t_admit", "t_dispatch", "t_done")

    def __init__(self, graph: str, sources, include_parents: bool,
                 cost_bytes: int, deadline: Optional[Deadline] = None):
        self.graph = graph
        self.sources = sources
        self.include_parents = include_parents
        self.cost_bytes = cost_bytes
        self.event = threading.Event()
        self.result = None           # BFSResult once served
        self.bucket = None
        self.error: Optional[Exception] = None
        self.deadline = deadline     # None = no time bound
        self.arm = None              # degradation arm label, if degraded
        self.t_admit = time.monotonic()
        self.t_dispatch = None
        self.t_done = None


class BFSFrontend:
    """Admission + dispatch + metrics over a configured ``BFSService``.

    Transport-agnostic: ``submit``/``wait`` drive it from the HTTP
    handler, tests, and the in-process serving benchmark alike.  Lanes
    must be registered on the service before construction (gates and
    metrics are built per existing lane).
    """

    def __init__(self, service, *, max_queue_depth: int = 64,
                 max_inflight_mb: float = 256.0,
                 stats_interval_s: float = 0.0,
                 graph_specs: Optional[dict] = None,
                 start_dispatcher: bool = True,
                 breaker_threshold: int = 5,
                 breaker_reset_s: float = 5.0,
                 retry_policy: Optional[RetryPolicy] = None,
                 watchdog_timeout_s: Optional[float] = None,
                 degrade: bool = True,
                 default_deadline_ms: Optional[float] = None,
                 log=print):
        self.service = service
        self.graph_specs = dict(graph_specs or {})
        self._log = log
        names = service.graph_names()
        if not names:
            raise ValueError("service has no lanes; add_graph before "
                             "building a frontend")
        max_bytes = max(1, int(max_inflight_mb * 2**20))
        self.gates: Dict[str, LaneGate] = {
            name: LaneGate(max_queue_depth=max_queue_depth,
                           max_inflight_bytes=max_bytes)
            for name in names}
        self.metrics = FrontendMetrics(names)
        # resilience: per-lane breakers, one shared retry policy, an
        # optional watchdog (None = unbounded device rounds, the
        # pre-resilience behavior), degradation arms on/off, and a
        # server-side default deadline for requests that carry none
        self.breakers: Dict[str, CircuitBreaker] = {
            name: CircuitBreaker(failure_threshold=breaker_threshold,
                                 reset_timeout_s=breaker_reset_s,
                                 name=name)
            for name in names}
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy())
        self.watchdog = (DispatchWatchdog(watchdog_timeout_s)
                         if watchdog_timeout_s else None)
        self.degrade_enabled = bool(degrade)
        self.default_deadline_ms = default_deadline_ms
        self._level_bytes: Dict[str, dict] = {}   # lane -> phase pricing
        # guarded-by(_cv): _running, _draining
        self._cv = threading.Condition()
        self._running = True
        self._draining = False
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="bfs-dispatch", daemon=True)
        self._stats_interval_s = float(stats_interval_s)
        self._stats_thread = None
        if start_dispatcher:
            self.start()

    # -------------------------------------------------------------- control
    def start(self) -> None:
        if not self._dispatcher.is_alive():
            self._dispatcher.start()
            if self._stats_interval_s > 0:
                self._stats_thread = threading.Thread(
                    target=self._stats_loop, name="bfs-stats", daemon=True)
                self._stats_thread.start()

    @property
    def draining(self) -> bool:
        with self._cv:
            return self._draining

    def drain(self, timeout_s: float = 60.0) -> bool:
        """Stop admitting; wait for admitted work to finish.  Returns
        True when every gate went idle within the timeout."""
        for gate in self.gates.values():
            gate.close()
        with self._cv:
            self._draining = True
            self._cv.notify_all()
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if all(g.idle() for g in self.gates.values()):
                return True
            time.sleep(0.01)
        return all(g.idle() for g in self.gates.values())

    def shutdown(self, timeout_s: float = 60.0) -> bool:
        """Graceful drain, then stop the dispatcher."""
        drained = self.drain(timeout_s)
        with self._cv:
            self._running = False
            self._cv.notify_all()
        if self._dispatcher.is_alive():
            self._dispatcher.join(timeout=5.0)
        return drained

    # ------------------------------------------------------------ admission
    def _resolve_lane(self, graph: Optional[str]):
        if graph is None:
            lane = self.service._sole_lane()   # raises ValueError if many
            return lane.name, lane
        return graph, self.service.lane(graph)  # raises KeyError if unknown

    def submit(self, graph: Optional[str], sources,
               include_parents: bool = False,
               deadline_ms: Optional[float] = None) -> _Pending:
        """Validate + admit one request; returns its pending handle.

        Raises ``KeyError`` (unknown lane), ``ValueError`` (bad
        sources), ``AdmissionError`` (bounds), ``DrainingError`` or
        ``CircuitOpenError`` (lane breaker open) — the transport maps
        each to its status code.  ``deadline_ms`` pins an absolute
        deadline the request carries through queue and dispatch.
        """
        from repro.core.bfs import validate_sources

        name, lane = self._resolve_lane(graph)
        lane_metrics = self.metrics.lane(name)
        try:
            srcs = validate_sources(sources, lane.n_logical,
                                    max_sources=lane.ladder[-1])
        except ValueError:
            lane_metrics.record_rejected(invalid=True)
            raise
        # the breaker's fast 503: an open circuit sheds at the door,
        # before the gate books queue/byte capacity for doomed work
        breaker = self.breakers[name]
        if not breaker.admits():
            lane_metrics.record_breaker_rejected()
            raise breaker.reject_error()
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        deadline = (Deadline.after_ms(deadline_ms)
                    if deadline_ms is not None else None)
        # admission cost ~= response payload: one int32 depth row per
        # source (doubled when parents ride along), plus framing slack
        cost = (1 + bool(include_parents)) * lane.n_logical * 4 * len(srcs)
        cost += 1024
        pending = _Pending(name, [int(s) for s in srcs], include_parents,
                           cost, deadline)
        try:
            self.gates[name].try_admit(
                pending, cost, retry_after_s=lane_metrics.ewma_e2e_s())
        except AdmissionError:
            lane_metrics.record_rejected()
            raise
        with self._cv:
            self._cv.notify_all()
        return pending

    def wait(self, pending: _Pending,
             timeout_s: Optional[float] = None) -> "object":
        """Block until a pending request is served; returns its
        ``BFSResult`` or re-raises the dispatch error.

        A request deadline tightens the wait: the handler thread stops
        blocking the moment the deadline lapses and raises the typed
        ``DeadlineExceeded`` (504) — the still-queued entry is reaped by
        the dispatcher before it can waste device work.
        """
        wait_s = (pending.deadline.bound(timeout_s)
                  if pending.deadline is not None else timeout_s)
        if not pending.event.wait(wait_s):
            if pending.deadline is not None and pending.deadline.expired():
                self.metrics.lane(pending.graph).record_deadline_expired()
                pending.deadline.check("wait",
                                       f"lane {pending.graph!r}")
            raise TimeoutError(
                f"request on lane {pending.graph!r} not served within "
                f"{timeout_s}s (queue depth "
                f"{self.gates[pending.graph].depth()})")
        if pending.error is not None:
            raise pending.error
        return pending.result

    def traverse(self, graph: Optional[str], sources, *,
                 include_parents: bool = False,
                 timeout_s: Optional[float] = 120.0,
                 deadline_ms: Optional[float] = None) -> dict:
        """Submit + wait + shape the response payload (the in-process
        mirror of ``POST /v1/traverse``; benchmarks drive this)."""
        pending = self.submit(graph, sources, include_parents, deadline_ms)
        result = self.wait(pending, timeout_s)
        return self._payload(pending, result)

    def _payload(self, pending: _Pending, result) -> dict:
        depths = result.dist_host
        parents = None
        if pending.include_parents:
            src, dst = self.service.lane(pending.graph).graph.edge_list()
            parents = schema.derive_parents(src, dst, depths)
        body = schema.encode_traverse_response(
            graph=pending.graph, sources=pending.sources,
            bucket=pending.bucket, depths=depths, parents=parents,
            run_stats=result.run_stats.to_host(),
            timing_ms={
                "queue_wait": (pending.t_dispatch - pending.t_admit) * 1e3,
                "device": (pending.t_done - pending.t_dispatch) * 1e3,
                "total": (pending.t_done - pending.t_admit) * 1e3,
            })
        return json.loads(body)

    # ------------------------------------------------------------- dispatch
    def _dispatch_loop(self) -> None:
        while True:
            if self._dispatch_round():
                continue          # keep draining queues while work exists
            with self._cv:
                if not self._running:
                    return
                if all(g.depth() == 0 for g in self.gates.values()):
                    self._cv.wait(timeout=0.1)

    def _fail(self, name: str, pending: _Pending, cost: int, exc,
              *, count_failed: bool = True) -> None:
        """Complete one pending request with an error (gate released,
        waiter woken)."""
        pending.error = exc
        pending.t_done = time.monotonic()
        if count_failed:
            self.metrics.lane(name).record_failed()
        self.gates[name].complete(cost)
        pending.event.set()

    def _pop_live(self, name: str):
        """Next queued request whose deadline has not lapsed; expired
        entries are reaped here — completed with ``DeadlineExceeded``
        (504) — so no device work is ever spent on dead requests."""
        while True:
            popped = self.gates[name].pop()
            if popped is None:
                return None
            pending, cost = popped
            if pending.deadline is None or not pending.deadline.expired():
                return pending, cost
            self.metrics.lane(name).record_deadline_expired()
            try:
                pending.deadline.check("queue", f"lane {name!r}")
            except DeadlineExceeded as exc:
                self._fail(name, pending, cost, exc, count_failed=False)

    def _dispatch_one(self, name: str, pending: _Pending):
        """Resolve + dispatch one request: bounded retry on transient
        failures, then the degradation arms.  Returns the un-blocked
        result handle + bucket; raises when every avenue is spent."""
        lane_metrics = self.metrics.lane(name)

        def on_retry(attempt, exc, backoff_s):
            lane_metrics.record_retry()

        try:
            res, bucket = call_with_retry(
                lambda: self.service.traverse_async(name, pending.sources),
                self.retry_policy, on_retry=on_retry)
            return res, bucket
        except TransientError:
            if not self.degrade_enabled:
                raise
        res, bucket, arm = degraded_traverse(self.service, name,
                                             pending.sources)
        pending.arm = arm
        lane_metrics.record_degraded(arm)
        return res, bucket

    def _block_result(self, name: str, res):
        """Sync one dispatched result, watchdog-bounded when enabled
        (a wedged device round fails its batch with a typed 500 and the
        dispatcher moves on; the round is tracked, not leaked)."""
        def sync():
            _faults.fire("frontend.block", name)
            res.block()
            return res

        if self.watchdog is None:
            return sync()
        return self.watchdog.guard(sync, label=f"lane {name!r}")

    def _dispatch_round(self) -> int:
        """One rotation: pop at most one live request per lane, dispatch
        them all, then sync them all (the cross-lane overlap window).
        Returns the number of requests taken off the queues."""
        _faults.fire("frontend.loop")
        taken = 0
        batch = []
        for name in self.service.graph_names():
            popped = self._pop_live(name)
            if popped is None:
                continue
            pending, cost = popped
            taken += 1
            breaker = self.breakers[name]
            if not breaker.allow():
                self.metrics.lane(name).record_breaker_rejected()
                self._fail(name, pending, cost, breaker.reject_error(),
                           count_failed=False)
                continue
            pending.t_dispatch = time.monotonic()
            try:
                if pending.deadline is not None:
                    pending.deadline.check("queue", f"lane {name!r}")
                res, bucket = self._dispatch_one(name, pending)
                pending.bucket = bucket
                batch.append((name, pending, cost, res))
            except DeadlineExceeded as exc:
                # expired mid-retry/backoff: a reap, not a lane failure
                self.metrics.lane(name).record_deadline_expired()
                self._fail(name, pending, cost, exc, count_failed=False)
            except Exception as exc:   # compile/device failure
                breaker.record_failure()
                self._fail(name, pending, cost, exc)
        for name, pending, cost, res in batch:
            breaker = self.breakers[name]
            try:
                self._block_result(name, res)
                pending.result = res
            except Exception as exc:
                breaker.record_failure()
                pending.error = exc
                self.metrics.lane(name).record_failed()
            else:
                breaker.record_success()
                pending.t_done = time.monotonic()
                self.metrics.lane(name).record_completed(
                    queue_wait_s=pending.t_dispatch - pending.t_admit,
                    device_s=pending.t_done - pending.t_dispatch,
                    e2e_s=pending.t_done - pending.t_admit,
                    bucket=pending.bucket,
                    n_sources=len(pending.sources),
                    wire_bytes=self._run_wire_bytes(name, res),
                    levels=res.run_stats.to_host()["levels"])
            if pending.t_done is None:
                pending.t_done = time.monotonic()
            self.gates[name].complete(cost)
            pending.event.set()
        return taken

    def _run_wire_bytes(self, name: str, res) -> dict:
        """Modeled per-chip wire bytes one run moved, split by phase:
        the lane plan's resolved per-level pricing times the number of
        levels the run spent in each mode (already synced by block())."""
        pricing = self._level_bytes.get(name)
        if pricing is None:
            meta = self.service.lane(name).plan.describe()
            pricing = {ph: float(meta[f"{ph}_level_bytes"])
                       for ph in ("dense", "queue", "bottom_up")}
            self._level_bytes[name] = pricing
        counts = res.run_stats.to_host()["mode_counts"]
        return {ph: pricing[ph] * counts[ph]
                for ph in pricing if counts[ph]}

    def _stats_loop(self) -> None:
        while True:
            time.sleep(self._stats_interval_s)
            with self._cv:
                if not self._running:
                    return
            self._log(self.metrics.stats_line(
                cache_stats=self.service.cache_stats()))

    # -------------------------------------------------------------- queries
    def graphs_payload(self) -> dict:
        lanes = []
        for name in self.service.graph_names():
            lane = self.service.lane(name)
            plan_ = lane.plan
            meta = plan_.describe()
            info = {
                "name": name,
                "n": lane.n_logical,
                "partition": plan_.partition,
                "buckets": list(lane.ladder),
                "slots": len(lane.pool),
                "wire_formats": dict(meta["wire_formats"]),
                "sieve": meta["sieve"],
                "admission": self.gates[name].snapshot(),
            }
            if plan_.partition == "2d":
                info["grid"] = list(meta["grid"])
            if name in self.graph_specs:
                info["spec"] = self.graph_specs[name]
            lanes.append(info)
        return {"graphs": lanes}

    def metrics_payload(self) -> dict:
        out = self.metrics.snapshot(
            cache_stats=self.service.cache_stats(), gates=self.gates,
            draining=self.draining)
        for name, breaker in self.breakers.items():
            out["lanes"][name]["breaker"] = breaker.snapshot()
        if self.watchdog is not None:
            out["watchdog"] = self.watchdog.snapshot()
        return out

    def ready(self) -> "tuple[bool, list]":
        """Readiness verdict + the reasons it fails (``/readyz``).

        Not ready while draining, while *every* lane's breaker is open
        (one open lane degrades, all open means nothing can be served),
        or while a watchdog-abandoned device round is still stuck.
        Liveness (``/healthz``) stays green through all of these — the
        process is up; a load balancer should just stop routing here.
        """
        reasons = []
        if self.draining:
            reasons.append("draining")
        states = {name: b.state() for name, b in self.breakers.items()}
        if states and all(s == "open" for s in states.values()):
            reasons.append("all lane breakers open")
        if self.watchdog is not None and self.watchdog.stuck() > 0:
            reasons.append(f"{self.watchdog.stuck()} stuck dispatch "
                           f"round(s)")
        return not reasons, reasons

    def readiness_payload(self) -> "tuple[int, dict]":
        ok, reasons = self.ready()
        body = {
            "ready": ok,
            "draining": self.draining,
            "breakers": {name: b.state()
                         for name, b in self.breakers.items()},
            "watchdog_stuck": (self.watchdog.stuck()
                               if self.watchdog is not None else 0),
        }
        if reasons:
            body["reasons"] = reasons
        return (200 if ok else 503), body


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------

class _Handler(BaseHTTPRequestHandler):
    # one response per connection keeps the stdlib server simple and
    # avoids keep-alive bookkeeping in handler threads
    protocol_version = "HTTP/1.0"
    server_version = "repro-bfs-frontend/1"
    quiet = True

    @property
    def frontend(self) -> BFSFrontend:
        return self.server.frontend

    def log_message(self, fmt, *args):   # noqa: N802 (stdlib name)
        if not self.quiet:
            BaseHTTPRequestHandler.log_message(self, fmt, *args)

    # ------------------------------------------------------------- plumbing
    def _send_json(self, status: int, obj, extra_headers=()) -> None:
        body = obj if isinstance(obj, bytes) else json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in extra_headers:
            self.send_header(k, v)
        self.end_headers()
        try:
            self.wfile.write(body)
        except (BrokenPipeError, ConnectionResetError):
            pass                       # client gave up; nothing to unwind

    def _send_error_json(self, status: int, message: str,
                         extra_headers=(), **fields) -> None:
        self._send_json(status, {"error": message, **fields}, extra_headers)

    # ------------------------------------------------------------- routes
    def do_GET(self) -> None:          # noqa: N802 (stdlib name)
        fe = self.frontend
        if self.path == "/healthz":
            self._send_json(200, {"status": "draining" if fe.draining
                                  else "ok", "lanes": len(fe.gates)})
        elif self.path == "/readyz":
            status, body = fe.readiness_payload()
            self._send_json(status, body)
        elif self.path == "/v1/graphs":
            self._send_json(200, fe.graphs_payload())
        elif self.path == "/metrics":
            self._send_json(200, fe.metrics_payload())
        else:
            self._send_error_json(404, f"no route for GET {self.path}")

    def do_POST(self) -> None:         # noqa: N802 (stdlib name)
        if self.path == "/v1/traverse":
            self._traverse()
        elif self.path == "/admin/shutdown":
            self._shutdown()
        else:
            self._send_error_json(404, f"no route for POST {self.path}")

    def _read_body(self) -> bytes:
        length = int(self.headers.get("Content-Length") or 0)
        if length > schema.MAX_BODY_BYTES:
            raise schema.RequestError(
                f"request body of {length} bytes exceeds the "
                f"{schema.MAX_BODY_BYTES}-byte limit", status=413)
        return self.rfile.read(length)

    def _send_resilience_error(self, exc: ResilienceError) -> None:
        """Map a typed serving failure to its status (+Retry-After)."""
        headers = ()
        fields = {}
        if exc.retry_after_s > 0:
            headers = (("Retry-After",
                        str(max(1, math.ceil(exc.retry_after_s)))),)
            fields["retry_after_s"] = round(exc.retry_after_s, 3)
        self._send_error_json(exc.status, str(exc), extra_headers=headers,
                              error_type=type(exc).__name__, **fields)

    def _traverse(self) -> None:
        fe = self.frontend
        try:
            req = schema.parse_traverse_request(self._read_body())
            pending = fe.submit(req["graph"], req["sources"],
                                req["include_parents"],
                                req["deadline_ms"])
        except schema.RequestError as exc:
            self._send_error_json(exc.status, str(exc))
            return
        except KeyError as exc:
            self._send_error_json(404, str(exc.args[0]) if exc.args
                                  else "unknown graph")
            return
        except ValueError as exc:
            self._send_error_json(400, str(exc))
            return
        except AdmissionError as exc:
            retry = max(1, math.ceil(exc.retry_after_s))
            self._send_error_json(
                429, str(exc), extra_headers=(("Retry-After", str(retry)),),
                retry_after_s=round(exc.retry_after_s, 3))
            return
        except DrainingError as exc:
            self._send_error_json(
                503, str(exc), extra_headers=(("Retry-After", "5"),))
            return
        except ResilienceError as exc:   # breaker open at the door
            self._send_resilience_error(exc)
            return
        try:
            result = fe.wait(pending, timeout_s=300.0)
        except ResilienceError as exc:   # 504 deadline / 503 breaker /
            self._send_resilience_error(exc)   # 500 watchdog, all typed
            return
        except TimeoutError as exc:
            self._send_error_json(504, str(exc))
            return
        except Exception as exc:       # dispatch-side failure
            self._send_error_json(500, f"{type(exc).__name__}: {exc}")
            return
        payload = fe._payload(pending, result)
        self._send_json(200, json.dumps(payload).encode())

    def _shutdown(self) -> None:
        fe = self.frontend
        self._send_json(200, {"status": "draining"})
        # drain + stop from a side thread: shutdown() must not run on a
        # handler thread the server is about to join
        threading.Thread(target=self.server.drain_and_stop,
                         daemon=True).start()


class _FrontendHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    frontend: BFSFrontend = None

    def drain_and_stop(self, timeout_s: float = 60.0) -> None:
        self.frontend.shutdown(timeout_s)
        self.shutdown()


def serve_http(service, host: str = "127.0.0.1", port: int = 0, *,
               max_queue_depth: int = 64, max_inflight_mb: float = 256.0,
               stats_interval_s: float = 0.0, graph_specs=None,
               start_dispatcher: bool = True,
               breaker_threshold: int = 5, breaker_reset_s: float = 5.0,
               retry_policy: Optional[RetryPolicy] = None,
               watchdog_timeout_s: Optional[float] = None,
               degrade: bool = True,
               default_deadline_ms: Optional[float] = None, log=print):
    """Bind the front-end: returns ``(httpd, frontend)``.

    ``port=0`` binds an ephemeral port (``httpd.server_address[1]``
    holds the real one).  The caller owns the accept loop — call
    ``httpd.serve_forever()`` (blocking) or run it in a thread; stop
    via ``httpd.drain_and_stop()`` or ``POST /admin/shutdown``.
    """
    frontend = BFSFrontend(
        service, max_queue_depth=max_queue_depth,
        max_inflight_mb=max_inflight_mb,
        stats_interval_s=stats_interval_s, graph_specs=graph_specs,
        start_dispatcher=start_dispatcher,
        breaker_threshold=breaker_threshold,
        breaker_reset_s=breaker_reset_s, retry_policy=retry_policy,
        watchdog_timeout_s=watchdog_timeout_s, degrade=degrade,
        default_deadline_ms=default_deadline_ms, log=log)
    httpd = _FrontendHTTPServer((host, port), _Handler)
    httpd.frontend = frontend
    return httpd, frontend
