"""Gradient compression for the cross-pod all-reduce.

At 512+ chips the pod-axis gradient all-reduce crosses the slow DCN links;
compressing it is the classic distributed-optimization trick.  Two methods:

  * ``bf16``  — cast gradients to bf16 before the (implicit) all-reduce;
    2x wire bytes, no state.
  * ``topk``  — keep the top-k fraction of entries per leaf by magnitude,
    accumulate the rest in an error-feedback buffer applied next step
    (Stich et al.; convergence-safe sparsification).  32x+ wire bytes at
    k=1/32.

Both are pure pytree transforms applied between backward and optimizer, so
they compose with any step function; the error-feedback buffer rides in the
train state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


def _topk_leaf(g, ef, k_frac: float):
    g32 = g.astype(jnp.float32) + ef
    flat = g32.reshape(-1)
    n = flat.shape[0]
    k = max(1, int(n * k_frac))
    if k >= n:
        return g32.astype(g.dtype), jnp.zeros_like(g32)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(g32) >= thresh).astype(jnp.float32)
    sent = g32 * mask
    new_ef = g32 - sent            # residual accumulates locally
    return sent.astype(g.dtype), new_ef


def init_error_feedback(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compress_topk(grads, ef_state, k_frac: float = 1 / 32):
    """Returns (compressed grads, new error-feedback state)."""
    pairs = jax.tree.map(lambda g, e: _topk_leaf(g, e, k_frac), grads,
                         ef_state)
    sent = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return sent, new_ef


def wire_bytes(grads, method: str, k_frac: float = 1 / 32) -> float:
    """Analytic wire-byte model for the pod-axis all-reduce (per step)."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    if method == "none":
        return total * 4.0
    if method == "bf16":
        return total * 2.0
    if method == "topk":
        return total * k_frac * 8.0  # value + index
    raise ValueError(method)
