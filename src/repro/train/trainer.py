"""Production training loop: checkpoint/restart, fault injection, straggler
watchdog, gradient compression, deterministic resumable data order.

The step function comes from launch/steps.build_bundle, so the same code
trains every family.  Fault tolerance contract:
  * checkpoint every ``ckpt_every`` steps (atomic, keep-k);
  * any step-time exception triggers restore-from-latest and replay —
    ``Trainer.run`` survives injected failures (tests/test_trainer.py);
  * data order is a pure function of (seed, step), so replayed steps see
    identical batches and training is bit-reproducible across restarts.

Straggler mitigation: a per-step wall-time EWMA; steps slower than
``straggler_factor``x the EWMA are logged and counted.  On a real cluster
this signal feeds the controller that re-schedules the slow host (we also
expose it programmatically); in-process we surface it as metrics.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.optim.adamw import AdamWConfig
from repro.train import compress as comp
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    grad_compression: str = "none"      # none | bf16 | topk
    topk_frac: float = 1 / 32
    straggler_factor: float = 3.0
    seed: int = 0


class Trainer:
    def __init__(self, bundle, tcfg: TrainerConfig,
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 fault_hook: Optional[Callable[[int], None]] = None):
        assert bundle.step_kind == "train", bundle.step_kind
        self.bundle = bundle
        self.tcfg = tcfg
        self.mgr = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.fault_hook = fault_hook or (lambda step: None)
        # gradient compression composes via make_compressed_train_step when
        # a bundle is built from a raw loss_fn; bundle.fn is the fused path.
        self._step_fn = jax.jit(bundle.fn)
        self.metrics_log = []
        self.straggler_events = []

    # ------------------------------------------------------------- run
    def run(self, init_state=None, resume: bool = True):
        t = self.tcfg
        state = init_state
        start_step = 0
        if state is None:
            params = self.bundle.init_params(jax.random.PRNGKey(t.seed))
            state = self.bundle.make_state(params)
        if resume:
            restored, step = self.mgr.restore(jax.tree.map(
                lambda x: np.asarray(x), state))
            if restored is not None:
                state = jax.tree.map(lambda a: jax.numpy.asarray(a), restored)
                start_step = step
        ewma = None
        step = start_step
        while step < t.num_steps:
            batch = self.bundle.make_batch(seed=t.seed * 1_000_003 + step)
            t0 = time.time()
            try:
                self.fault_hook(step)
                state, metrics = self._step_fn(state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
            except Exception as e:  # noqa: BLE001 — restart from checkpoint
                restored, ck_step = self.mgr.restore(
                    jax.tree.map(lambda x: np.asarray(x), state))
                if restored is None:
                    raise
                state = jax.tree.map(lambda a: jax.numpy.asarray(a), restored)
                self.metrics_log.append(
                    {"step": step, "event": "restart", "error": repr(e),
                     "restored_step": ck_step})
                step = ck_step
                continue
            dt = time.time() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > t.straggler_factor * ewma and step > start_step + 2:
                self.straggler_events.append({"step": step, "dt": dt,
                                              "ewma": ewma})
            step += 1
            if step % t.log_every == 0 or step == t.num_steps:
                self.metrics_log.append({"step": step, "loss": loss,
                                         "dt": dt})
            if step % t.ckpt_every == 0 or step == t.num_steps:
                self.mgr.save(step, state)
        self.mgr.wait()
        return state


def make_compressed_train_step(loss_fn, opt_cfg: AdamWConfig, method: str,
                               k_frac: float = 1 / 32):
    """Standalone compressed train step (state carries error feedback)."""
    from repro.optim.adamw import apply_updates, init_state

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def make_state(params):
        st = {"params": params, "opt": init_state(params)}
        if method == "topk":
            st["ef"] = comp.init_error_feedback(params)
        return st

    def step(state, batch):
        (loss, aux), grads = grad_fn(state["params"], batch)
        new_state = dict(state)
        if method == "bf16":
            grads = comp.compress_bf16(grads)
        elif method == "topk":
            grads, new_state["ef"] = comp.compress_topk(
                grads, state["ef"], k_frac)
        new_p, new_opt, m = apply_updates(opt_cfg, state["params"], grads,
                                          state["opt"])
        new_state.update(params=new_p, opt=new_opt)
        return new_state, {"loss": loss, **m}

    return make_state, step
