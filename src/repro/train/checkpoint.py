"""Checkpoint manager: atomic, versioned, resumable, keep-last-k.

Layout:  <dir>/step_<n>/  manifest.json + one .npy per pytree leaf.
Writes go to a temp directory and are renamed into place, so a failure
mid-save can never corrupt the latest checkpoint (restart safety, the
fault-tolerance contract the trainer relies on).  On a real multi-host
cluster each process would write only its addressable shards to a shared
filesystem; this single-process implementation fully materializes leaves
(numpy) — the manifest format is identical.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import numpy as np

import jax

_SEP = "/"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state) -> str:
        if self._thread is not None:
            self._thread.join()  # one outstanding async save at a time
            self._thread = None
        # snapshot to host memory synchronously (cheap vs device compute)
        flat, _ = _flatten_with_paths(state)
        host = [(k, np.asarray(v)) for k, v in flat]

        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)
        return os.path.join(self.dir, f"step_{step}")

    def _write(self, step: int, host_leaves):
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (key, arr) in enumerate(host_leaves):
            fname = f"leaf_{i:05d}.npy"
            logical = str(arr.dtype)
            if logical == "bfloat16":  # not a native numpy dtype: store raw
                np.save(os.path.join(tmp, fname), arr.view(np.uint16))
            else:
                np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": logical})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  Returns (state, step) with numpy leaves."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flatten_with_paths(like)
        by_key = {e["key"]: e for e in manifest["leaves"]}
        leaves = []
        for key, ref in flat:
            e = by_key[key]
            arr = np.load(os.path.join(path, e["file"]))
            if e["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            assert tuple(arr.shape) == tuple(ref.shape), (key, arr.shape,
                                                          ref.shape)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves), step
