"""Elastic scaling: re-shard live state when the healthy-device set changes.

Two levels:
  * array/state level — ``reshard_state`` re-places a pytree under a new
    mesh + spec assignment (jax.device_put handles the all-to-all); this is
    what the trainer calls after a checkpoint restore onto fewer/more pods.
  * BFS/graph level — the 1-D partition is a pure function of (n, p), so
    rescaling is ``repartition`` + re-bucketing the edge blocks; distance
    vectors re-slice (paper §2.1's partitioning makes this trivial — a key
    operational property the paper doesn't state but the design gives us).
"""

from __future__ import annotations

import numpy as np

import jax

from repro.core.partition import Partition1D
from repro.graphs.formats import ShardedGraph, shard_graph


def reshard_state(state, new_mesh, new_specs):
    """Re-place every leaf under the new mesh/spec (host-mediated when the
    device sets are disjoint; direct device-to-device otherwise)."""
    from repro.launch.shardings import to_named
    shardings = to_named(new_specs, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings)


def repartition_graph(g: ShardedGraph, new_p: int) -> ShardedGraph:
    """Rebuild per-shard edge blocks for a new shard count."""
    src_l, dst_g, _, _ = g.flat()
    valid = dst_g >= 0
    # reconstruct global COO from the out-edge blocks
    shard_ids = np.repeat(np.arange(g.p), g.e_cap)
    src_global = np.asarray(
        g.part.global_id(shard_ids, src_l))[valid]
    dst_global = np.asarray(dst_g)[valid]
    return shard_graph(src_global, dst_global, g.part.n_logical, new_p)


def repartition_vertex_array(x: np.ndarray, old: Partition1D,
                             new: Partition1D) -> np.ndarray:
    """Re-pad a (old.n, ...) vertex array for the new partition."""
    assert old.n_logical == new.n_logical
    logical = np.asarray(x)[: old.n_logical]
    return new.pad_vertex_array(logical)
