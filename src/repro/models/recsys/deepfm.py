"""DeepFM: sparse embedding tables + FM interaction + deep MLP.

The embedding tables are the hot path and the paper-technique carrier for
this family: rows are 1-D partitioned by owner exactly like BFS vertices
(all fields share one (n_fields * vocab, dim) table sharded on rows), and a
batch lookup is an owner-exchange — under pjit the row gather lowers to the
same direct all-to-all as the BFS frontier queues.  JAX has no native
EmbeddingBag; multi-hot bags use kernels/embedding_bag (gather +
segment_sum), single-valued fields use a plain row gather.

Steps: train (BCE), serve (sigmoid scores), retrieval (one query scored
against 10^6 candidate item rows as a single batched dot).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.gnn.common import apply_mlp, init_mlp


def field_offsets(cfg: RecsysConfig) -> jnp.ndarray:
    return (jnp.arange(cfg.n_sparse) * cfg.vocab_per_field).astype(jnp.int32)


def init_params(cfg: RecsysConfig, key):
    ks = jax.random.split(key, 4)
    rows = cfg.total_rows
    d = cfg.embed_dim
    mlp_in = cfg.n_sparse * d + cfg.n_dense
    return {
        "table": (jax.random.normal(ks[0], (rows, d)) * 0.01).astype(jnp.float32),
        "lin_table": jnp.zeros((rows, 1), jnp.float32),
        "lin_dense": jnp.zeros((cfg.n_dense,), jnp.float32),
        "bias": jnp.zeros((), jnp.float32),
        "mlp": init_mlp(ks[1], (mlp_in, *cfg.mlp_dims, 1)),
    }


def _embed(cfg: RecsysConfig, params, sparse_idx: jnp.ndarray):
    """sparse_idx: (B, F) field-local ids -> (B, F, D) rows of the shared
    row-partitioned table (the owner-exchange gather)."""
    flat = sparse_idx + field_offsets(cfg)[None, :]
    return params["table"][flat], flat


def forward(cfg: RecsysConfig, params, batch):
    """batch: sparse (B, F) int32, dense (B, n_dense) f32 -> logits (B,)."""
    emb, flat = _embed(cfg, params, batch["sparse"])       # (B, F, D)
    b = emb.shape[0]
    # first-order term
    lin = (params["lin_table"][flat][..., 0].sum(-1)
           + batch["dense"] @ params["lin_dense"] + params["bias"])
    # FM second-order: 0.5 * ((sum v)^2 - sum v^2)
    s = emb.sum(axis=1)
    fm = 0.5 * (jnp.square(s).sum(-1) - jnp.square(emb).sum(axis=(1, 2)))
    # deep branch
    mlp_in = jnp.concatenate([emb.reshape(b, -1), batch["dense"]], axis=-1)
    deep = apply_mlp(params["mlp"], mlp_in)[:, 0]
    return lin + fm + deep


def loss_fn(cfg: RecsysConfig, params, batch):
    logits = forward(cfg, params, batch)
    y = batch["label"].astype(jnp.float32)
    # numerically stable BCE-with-logits
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"loss": loss}


def serve_step(cfg: RecsysConfig, params, batch):
    return jax.nn.sigmoid(forward(cfg, params, batch))


def retrieval_step(cfg: RecsysConfig, params, batch):
    """Score one query against n_candidates item rows (field 0 is the item
    table).  batch: sparse (1, F) for the query context, cand_ids (Ncand,).
    Returns (Ncand,) scores — a single (1, D) x (D, Ncand) batched dot plus
    the per-item first-order weight; no per-candidate loop."""
    emb, _ = _embed(cfg, params, batch["sparse"])         # (1, F, D)
    user_vec = emb[:, 1:, :].sum(axis=1)                  # context fields
    cand = params["table"][batch["cand_ids"]]             # (Ncand, D)
    cand_lin = params["lin_table"][batch["cand_ids"]][:, 0]
    scores = (user_vec @ cand.T)[0] + cand_lin + params["bias"]
    return scores
