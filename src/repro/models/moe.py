"""Mixture-of-Experts layer with sort-based (owner-computes) dispatch.

The dispatch is deliberately the same bucket-packing used by the BFS queue
exchange (core/frontier.build_queue_buckets): tokens are "candidate
vertices", the expert index is the "owner", and capacity plays the role of
the send-buffer cap.  Sorting assignments by expert and scattering into an
(E, C, D) buffer keeps HLO FLOPs proportional to real expert compute —
unlike the GShard one-hot einsum dispatch, whose (T, E, C) tensors add
O(T^2) fake FLOPs that would pollute the roofline's compute term
(EXPERIMENTS.md §Perf discusses this choice).

Under pjit the buffer is sharded over the expert axis, so the scatter
becomes the token all-to-all of expert parallelism — the direct exchange
of paper §5.1-2 applied to tokens instead of vertices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.compat import shard_map
from repro.layers.core import swiglu
from repro.models import sharding_hints as hints


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_ff
    scale_in = d_model ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d_model, e), jnp.float32) * scale_in,
        "w_gate": (jax.random.normal(ks[1], (e, d_model, f)) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, f)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d_model)) * f ** -0.5).astype(dtype),
    }
    if cfg.shared_experts:
        fs = cfg.d_ff * cfg.shared_experts
        p["shared"] = {
            "w_gate": (jax.random.normal(ks[4], (d_model, fs)) * scale_in).astype(dtype),
            "w_up": (jax.random.normal(jax.random.fold_in(ks[4], 1),
                                       (d_model, fs)) * scale_in).astype(dtype),
            "w_down": (jax.random.normal(jax.random.fold_in(ks[4], 2),
                                         (fs, d_model)) * fs ** -0.5).astype(dtype),
        }
    return p


def capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_apply(params, x: jnp.ndarray, cfg: MoEConfig):
    """x: (T, D) -> (out, aux). Dispatches to the expert-parallel shard_map
    implementation when launcher sharding hints are active."""
    if hints.enabled():
        return moe_apply_sharded(params, x, cfg)
    return _moe_apply_local(params, x, cfg)


def _moe_apply_local(params, x: jnp.ndarray, cfg: MoEConfig):
    """Single-shard reference path (smoke tests, examples)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t, cfg)

    logits = x.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- bucket-pack assignments by expert (cf. BFS queue exchange) ---
    slot_expert = expert_idx.reshape(-1)                       # (T*K,)
    slot_token = jnp.repeat(jnp.arange(t), k)
    slot_gate = gate_vals.reshape(-1)
    order = jnp.argsort(slot_expert)                           # stable
    se, stok, sg = slot_expert[order], slot_token[order], slot_gate[order]
    starts = jnp.searchsorted(se, jnp.arange(e + 1))
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < c
    slot = jnp.where(keep, se * c + rank, e * c)               # drop -> pad row

    buf = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(x[stok])
    expert_in = hints.constrain_expert_buffer(buf[:-1].reshape(e, c, d))

    # --- per-expert SwiGLU (batched einsum over the expert dim) ---
    h = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                            params["w_down"])                  # (E, C, D)
    expert_out = hints.constrain_expert_buffer(expert_out)

    # --- combine: gather back and weight by gate ---
    flat_out = expert_out.reshape(e * c, d)
    slot_safe = jnp.minimum(slot, e * c - 1)
    contrib = flat_out[slot_safe] * (sg * keep)[:, None].astype(x.dtype)
    out = jax.ops.segment_sum(contrib, stok, num_segments=t)

    if cfg.shared_experts:
        sp = params["shared"]
        out = out + swiglu(x, sp["w_gate"], sp["w_up"], sp["w_down"])

    # Switch-style load-balance aux loss (fraction * mean prob per expert).
    frac = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), 0)
    mean_p = probs.mean(0)
    aux = {"lb_loss": e * jnp.sum(frac * mean_p),
           "dropped": (~keep).sum()}
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path (production): tokens sharded over the data
# axes, experts sharded over the model axis.  Each device routes its local
# tokens, runs only the experts it owns, and partial outputs are summed over
# the model axis — the owner-computes rule of the paper applied to experts.
# Dispatch buffers are per-shard (E_local, C_local, D), so nothing scales
# with the global token count on any one chip.
# ---------------------------------------------------------------------------

def _moe_local_experts(params_local, x_local, cfg: MoEConfig, e_local: int,
                       model_axis, dp_axes):
    """Runs on one shard: params_local holds this shard's expert slices."""
    import jax
    from jax import lax

    t_loc, d = x_local.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t_loc, cfg)

    logits = x_local.astype(jnp.float32) @ params_local["router"]  # (Tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    my_e0 = lax.axis_index(model_axis) * e_local
    slot_expert = expert_idx.reshape(-1)
    slot_token = jnp.repeat(jnp.arange(t_loc), k)
    slot_gate = gate_vals.reshape(-1)
    local_e = slot_expert - my_e0
    mine = (local_e >= 0) & (local_e < e_local)
    owner = jnp.where(mine, local_e, e_local)              # sentinel bucket

    order = jnp.argsort(owner)
    se, stok, sg = owner[order], slot_token[order], slot_gate[order]
    starts = jnp.searchsorted(se, jnp.arange(e_local + 1))
    rank = jnp.arange(t_loc * k) - starts[jnp.minimum(se, e_local)]
    keep = (se < e_local) & (rank < c)
    slot = jnp.where(keep, se * c + rank, e_local * c)

    # Index-based dispatch: scatter token *ids* into the buffer slots, then
    # gather features straight into (E_local, C, D).  Never materializes a
    # (T*K, D) duplicate-token tensor (the 6 GiB/buffer offender the value-
    # scatter version produced; EXPERIMENTS.md §Perf).
    buf_tok = jnp.full((e_local * c + 1,), t_loc, jnp.int32).at[slot].set(
        stok.astype(jnp.int32))[:-1]
    buf_gate = jnp.zeros((e_local * c + 1,), jnp.float32).at[slot].set(
        sg * keep)[:-1]
    x_pad = jnp.concatenate([x_local, jnp.zeros((1, d), x_local.dtype)], 0)
    expert_in = x_pad[buf_tok].reshape(e_local, c, d)

    h = jnp.einsum("ecd,edf->ecf", expert_in, params_local["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", expert_in, params_local["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                            params_local["w_down"])

    flat_out = expert_out.reshape(e_local * c, d)
    contrib = flat_out * buf_gate[:, None].astype(flat_out.dtype)
    partial = jnp.zeros((t_loc + 1, d), jnp.float32).at[buf_tok].add(
        contrib.astype(jnp.float32))[:t_loc]
    # owner-computes merge: sum expert partials over the model axis
    out = lax.psum(partial, model_axis).astype(x_local.dtype)

    if cfg.shared_experts:
        sp = params_local["shared"]
        out = out + swiglu(x_local, sp["w_gate"], sp["w_up"], sp["w_down"])

    frac = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), 0)
    mean_p = probs.mean(0)
    lb = e * jnp.sum(frac * mean_p)
    lb = lax.pmean(lb, dp_axes)
    dropped = lax.psum((~keep).sum() - (~mine).sum(), (*dp_axes, model_axis))
    return out, lb, dropped


def moe_apply_sharded(params, x: jnp.ndarray, cfg: MoEConfig):
    import functools
    import jax
    from jax.sharding import PartitionSpec as P

    st = hints._STATE
    mesh, dp, model = st["mesh"], st["dp"], st["model"]
    e = cfg.n_experts
    msize = mesh.shape[model]
    if e % msize != 0 or x.shape[0] % int(
            __import__("numpy").prod([mesh.shape[a] for a in dp])) != 0:
        return _moe_apply_local(params, x, cfg)
    e_local = e // msize

    pspecs = {"router": P(None, None),
              "w_gate": P(model, None, None),
              "w_up": P(model, None, None),
              "w_down": P(model, None, None)}
    if cfg.shared_experts:
        pspecs["shared"] = {"w_gate": P(None, None), "w_up": P(None, None),
                            "w_down": P(None, None)}
    fn = functools.partial(_moe_local_experts, cfg=cfg, e_local=e_local,
                           model_axis=model, dp_axes=dp)
    out, lb, dropped = shard_map(
        fn, mesh=mesh,
        in_specs=(pspecs, P(dp, None)),
        out_specs=(P(dp, None), P(), P()),
        check_vma=False,
    )(params, x)
    return out, {"lb_loss": lb, "dropped": dropped}
