"""LM-family transformer: dense / MoE / GQA / local-global, scan-over-groups.

Layer stacking: the repeating layer *pattern* (e.g. Gemma-3's 5 local + 1
global, Llama-4's dense/MoE alternation) is unrolled inside the scan body
and the scan runs over ``n_layers / period`` groups.  This keeps the HLO a
single while-loop regardless of depth — an 80-layer Qwen compiles as fast
as a 2-layer smoke model — which is what makes 80 dry-run lowerings per
sweep tractable.

Steps exposed (all pure functions of (params, batch)):
  * ``lm_loss``      — next-token CE for train_step,
  * ``prefill``      — logits + populated KV cache,
  * ``decode_step``  — one token for every sequence in the batch given the
    cache (the ``decode_32k`` / ``long_500k`` serve step).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import LayerSpec, TransformerConfig
from repro.layers.core import (chunked_attention, cross_entropy, rms_norm,
                               rope, swiglu)
from repro.models import moe as moe_lib
from repro.models import sharding_hints as hints


def _dtype(cfg: TransformerConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: TransformerConfig, key) -> dict:
    dt = _dtype(cfg)
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_groups
    keys = jax.random.split(key, len(cfg.pattern) + 2)

    def dense(k, shape, fan_in):
        return (jax.random.normal(k, shape) * fan_in ** -0.5).astype(dt)

    blocks = []
    for t, spec in enumerate(cfg.pattern):
        kt = jax.random.split(keys[t], 12)
        attn = {
            "wq": dense(kt[0], (g, d, hq, dh), d),
            "wk": dense(kt[1], (g, d, hkv, dh), d),
            "wv": dense(kt[2], (g, d, hkv, dh), d),
            "wo": dense(kt[3], (g, hq, dh, d), hq * dh),
        }
        if cfg.qkv_bias:
            attn["bq"] = jnp.zeros((g, hq, dh), dt)
            attn["bk"] = jnp.zeros((g, hkv, dh), dt)
            attn["bv"] = jnp.zeros((g, hkv, dh), dt)
        block = {
            "attn": attn,
            "ln1": jnp.zeros((g, d), dt),
            "ln2": jnp.zeros((g, d), dt),
        }
        if spec.moe and cfg.moe is not None:
            block["moe"] = jax.vmap(
                lambda k_: moe_lib.init_moe_params(k_, d, cfg.moe, dt))(
                    jax.random.split(kt[4], g))
        else:
            block["mlp"] = {
                "w_gate": dense(kt[5], (g, d, cfg.d_ff), d),
                "w_up": dense(kt[6], (g, d, cfg.d_ff), d),
                "w_down": dense(kt[7], (g, cfg.d_ff, d), cfg.d_ff),
            }
        blocks.append(block)

    out = {
        "embed": dense(keys[-2], (cfg.vocab, d), d),
        "blocks": blocks,
        "final_norm": jnp.zeros((d,), dt),
    }
    if not cfg.tie_embeddings:
        # untied output head: lets the input table shard over D (gather
        # stays local) and the head table over V (CE stays vocab-sharded)
        out["unembed"] = dense(keys[-1], (cfg.vocab, d), d)
    return out


def _head(params):
    return params.get("unembed", params["embed"])


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_apply(cfg: TransformerConfig, spec: LayerSpec, p: dict,
                h: jnp.ndarray, positions, *, cache=None, cache_pos=None):
    """h: (B, S, D). cache: dict(k, v) of (B, Hkv, Smax, Dh) or None."""
    q = jnp.einsum("bsd,dhe->bhse", h, p["wq"])
    k = jnp.einsum("bsd,dhe->bhse", h, p["wk"])
    v = jnp.einsum("bsd,dhe->bhse", h, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        o = chunked_attention(q, k, v, causal=True, window=spec.window,
                              chunk=cfg.attn_chunk)
        new_cache = {"k": k, "v": v}
    elif getattr(cache_pos, "ndim", 0) == 1:
        # per-sequence positions (continuous batching): scatter each
        # sequence's new kv row at its own depth
        bidx = jnp.arange(h.shape[0])
        ck = cache["k"].at[bidx, :, cache_pos].set(k[:, :, 0, :])
        cv = cache["v"].at[bidx, :, cache_pos].set(v[:, :, 0, :])
        o = chunked_attention(q, ck, cv, causal=True, window=spec.window,
                              chunk=cfg.attn_chunk, q_offset=cache_pos,
                              kv_len=cache_pos + 1)
        new_cache = {"k": ck, "v": cv}
    else:
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, axis=2)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, axis=2)
        o = chunked_attention(q, ck, cv, causal=True, window=spec.window,
                              chunk=cfg.attn_chunk, q_offset=cache_pos,
                              kv_len=cache_pos + h.shape[1])
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("bhse,hed->bsd", o, p["wo"])
    return out, new_cache


def _block_apply(cfg, spec, p, h, positions, cache=None, cache_pos=None):
    a, new_cache = _attn_apply(cfg, spec, p["attn"],
                               rms_norm(h, p["ln1"], cfg.norm_eps),
                               positions, cache=cache, cache_pos=cache_pos)
    h = h + hints.constrain_tokens_3d(a)
    x = rms_norm(h, p["ln2"], cfg.norm_eps)
    aux = {}
    if "moe" in p:
        b, s, d = x.shape
        y, aux = moe_lib.moe_apply(p["moe"], x.reshape(b * s, d), cfg.moe)
        y = y.reshape(b, s, d)
    else:
        y = swiglu(x, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return h + hints.constrain_tokens_3d(y), new_cache, aux


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _train_block(cfg, spec, p, h, positions):
    """Block body for training, optionally rematerialized: with
    remat='block' the backward pass recomputes attention/FFN internals
    instead of saving per-chunk softmax intermediates — O(layers) residuals
    instead of O(layers * S^2 / chunk) (the 300 GiB/device -> ~3 GiB/device
    step recorded in EXPERIMENTS.md §Perf)."""
    def body(p_, h_):
        h_ = hints.constrain_tokens_3d(h_)
        out, _, aux = _block_apply(cfg, spec, p_, h_, positions)
        out = hints.constrain_tokens_3d(out)
        return out, aux.get("lb_loss", jnp.float32(0)) if aux else jnp.float32(0)

    if cfg.remat == "none":
        return body(p, h)
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(body, policy=policy)(p, h)


def trunk(cfg: TransformerConfig, params: dict, tokens: jnp.ndarray):
    """tokens (B, S) -> final hidden states (B, S, D) + moe aux."""
    h = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])
    lb_total = jnp.float32(0)

    def group_body(carry, group_params):
        h, lb = carry
        for t, spec in enumerate(cfg.pattern):
            h, lb_t = _train_block(cfg, spec, group_params[t], h, positions)
            lb = lb + lb_t
        return (h, lb), None

    (h, lb_total), _ = lax.scan(group_body, (h, lb_total), params["blocks"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, {"lb_loss": lb_total / max(cfg.n_layers, 1)}


def forward(cfg: TransformerConfig, params: dict, tokens: jnp.ndarray):
    """tokens (B, S) -> logits (B, S, V); no cache (small-model paths)."""
    h, aux = trunk(cfg, params, tokens)
    logits = jnp.einsum("bsd,vd->bsv", h, _head(params))
    return logits, aux


def lm_loss(cfg: TransformerConfig, params: dict, tokens: jnp.ndarray,
            lb_coef: float = 0.01, loss_chunk: int = 512):
    """tokens (B, S+1): next-token CE + MoE balance loss.

    The vocab projection + CE run CHUNKED over the sequence inside a
    rematerialized scan, so the (B, S, V) fp32 logits tensor is never
    materialized (peak is (B, chunk, V/model) — the 49 GiB -> ~6 GiB/device
    step at train_4k shapes, EXPERIMENTS.md §Perf)."""
    h, aux = trunk(cfg, params, tokens[:, :-1])
    labels = tokens[:, 1:]
    b, s, d = h.shape
    ck = min(loss_chunk, s)
    assert s % ck == 0, (s, ck)
    nc = s // ck
    hc = jnp.moveaxis(h.reshape(b, nc, ck, d), 1, 0)        # (nc, B, ck, D)
    lc = jnp.moveaxis(labels.reshape(b, nc, ck), 1, 0)      # (nc, B, ck)

    head_w = hints.constrain_vocab_table(_head(params))

    def chunk_nll(h_c, l_c):
        logits = jnp.einsum("bsd,vd->bsv", h_c, head_w)
        logits = hints.constrain_logits_3d(logits).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return (lse - ll).sum()

    def body(tot, xs):
        h_c, l_c = xs
        return tot + jax.checkpoint(chunk_nll)(h_c, l_c), None

    total, _ = lax.scan(body, jnp.float32(0), (hc, lc))
    ce = total / (b * s)
    return ce + lb_coef * aux["lb_loss"], {"ce": ce, **aux}


def init_cache(cfg: TransformerConfig, batch: int, max_len: int) -> list:
    """KV cache: one (G, B, Hkv, Smax, Dh) pair per pattern position."""
    dt = _dtype(cfg)
    shape = (cfg.n_groups, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return [{"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
            for _ in cfg.pattern]


def prefill(cfg: TransformerConfig, params: dict, tokens: jnp.ndarray,
            max_len: int):
    """Run the prompt, return (last-token logits, cache, length)."""
    b, s = tokens.shape
    h = params["embed"][tokens]
    positions = jnp.arange(s)
    cache = init_cache(cfg, b, max_len)

    def group_body(h, xs):
        group_params, caches_in = xs
        new_caches = []
        for t, spec in enumerate(cfg.pattern):
            h, nc, _ = _block_apply(
                cfg, spec, group_params[t], h, positions,
                cache={"k": caches_in[t]["k"], "v": caches_in[t]["v"]},
                cache_pos=0)
            new_caches.append(nc)
        return h, new_caches

    h, cache = lax.scan(group_body, h, (params["blocks"], cache))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", h[:, -1], _head(params))
    return logits, cache, s


def decode_step(cfg: TransformerConfig, params: dict, cache: list,
                pos, last_token: jnp.ndarray):
    """One serve step: append one token per sequence.

    cache leaves are (G, B, Hkv, Smax, Dh); pos is the current length
    (traced scalar); last_token (B,). Returns (logits (B, V), new cache).
    """
    h = params["embed"][last_token][:, None, :]          # (B, 1, D)
    if getattr(pos, "ndim", 0) == 1:
        positions = pos[:, None] + jnp.arange(1)[None, :]  # (B, 1) per-seq
    else:
        positions = pos + jnp.arange(1)

    def group_body(h, xs):
        group_params, caches_in = xs
        new_caches = []
        for t, spec in enumerate(cfg.pattern):
            h, nc, _ = _block_apply(
                cfg, spec, group_params[t], h, positions,
                cache=caches_in[t], cache_pos=pos)
            new_caches.append(nc)
        return h, new_caches

    h, new_cache = lax.scan(group_body, h, (params["blocks"], cache))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", h[:, 0], _head(params))
    return logits, new_cache
