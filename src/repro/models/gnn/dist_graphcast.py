"""Owner-exchange GraphCast: the paper's §5 technique applied to GNN
message passing (the graphcast/ogb_products hillclimb, EXPERIMENTS.md §Perf).

The GSPMD baseline materializes an all-gather of the FULL (N, D) node
table per gather per layer — the 'aggregate everything everywhere' pattern
of the paper's baseline [2].  Here the exchange is explicit and direct:

  * vertices 1-D partitioned (core.partition), edges bucketed by the
    OWNER of their destination (owner-computes aggregation);
  * each shard statically knows which of its rows every peer needs
    (``serve_ids``, deduplicated — the unique sources of the peer's
    edges); one ``all_to_all`` per layer ships exactly those rows;
  * per-edge sources then index the received buffer locally.

Per-chip bytes per layer: p * r_cap * D * 4 (requested rows only) versus
the baseline's 2 * N * D * 4 table gathers — ~20x less at ogb_products
scale.  Locally-owned sources ride the same indexed buffer via the shard's
own all_to_all block (zero wire cost), which is the paper's §5.1-(1)
owner-local update.  Routing tables are static per graph — the
request/serve handshake happens once at build time, not per step.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import GNNConfig
from repro.core.compat import shard_map
from repro.core.partition import Partition1D
from repro.models.gnn import common as C
from repro.models.gnn.models import graphcast_init


# ---------------------------------------------------------------------------
# static routing construction (host-side, once per graph)
# ---------------------------------------------------------------------------

def build_routing(src: np.ndarray, dst: np.ndarray, n: int, p: int,
                  r_cap: int | None = None, e_cap: int | None = None):
    """Returns dict of stacked per-shard arrays:
      serve_ids (p, p, r_cap) int32 — [me, j]: MY local row ids peer j needs
      src_slot  (p, e_cap)    int32 — per edge: index into the (p*r_cap)
                                       received-row buffer
      dst_local (p, e_cap)    int32 — per edge: local destination (-1 pad)
      n_local, r_cap, e_cap
    """
    part = Partition1D(n, p)
    own_dst = np.asarray(part.owner(dst))
    own_src = np.asarray(part.owner(src))
    src_local_of = np.asarray(part.local_id(src))
    dst_local_of = np.asarray(part.local_id(dst))

    # per (dst-shard j, src-owner o): unique source rows requested
    requests = [[None] * p for _ in range(p)]
    max_r, max_e = 1, 1
    edge_data = []
    for j in range(p):
        sel = np.where(own_dst == j)[0]
        max_e = max(max_e, sel.shape[0])
        slot = np.zeros(sel.shape[0], np.int64)
        for o in range(p):
            esel = own_src[sel] == o
            uniq, inv = np.unique(src_local_of[sel][esel],
                                  return_inverse=True)
            requests[j][o] = uniq
            max_r = max(max_r, uniq.shape[0])
            slot[esel] = -1  # placeholder; filled after r_cap known
            requests[j][o] = (uniq, esel, inv)
        edge_data.append((sel, slot))

    r_cap = r_cap or -(-max_r // 64) * 64
    e_cap = e_cap or -(-max_e // 64) * 64

    serve = np.zeros((p, p, r_cap), np.int32)
    src_slot = np.zeros((p, e_cap), np.int32)
    dst_loc = np.full((p, e_cap), -1, np.int32)
    for j in range(p):
        sel, slot = edge_data[j]
        for o in range(p):
            uniq, esel, inv = requests[j][o]
            assert uniq.shape[0] <= r_cap, (uniq.shape[0], r_cap)
            serve[o, j, :uniq.shape[0]] = uniq  # shard o serves these to j
            slot[esel] = o * r_cap + inv
        k = sel.shape[0]
        src_slot[j, :k] = slot
        dst_loc[j, :k] = dst_local_of[sel]
    return {"serve_ids": serve, "src_slot": src_slot, "dst_local": dst_loc,
            "r_cap": r_cap, "e_cap": e_cap, "part": part}


def routing_specs(n: int, p: int, d_feat: int, cfg: GNNConfig,
                  r_cap: int, e_cap: int):
    """Abstract batch for the dry-run (ShapeDtypeStructs only)."""
    SDS = jax.ShapeDtypeStruct
    n_pad = Partition1D(n, p).n
    return {
        "node_feats": SDS((n_pad, d_feat), jnp.float32),
        "edge_feats": SDS((p * e_cap, 4), jnp.float32),
        "serve_ids": SDS((p, p, r_cap), jnp.int32),
        "src_slot": SDS((p, e_cap), jnp.int32),
        "dst_local": SDS((p, e_cap), jnp.int32),
        "valid_nodes": SDS((n_pad,), jnp.bool_),
        "targets": SDS((n_pad, cfg.d_out), jnp.float32),
    }


def routing_batch_specs(p_axes):
    """PartitionSpecs: everything row-sharded over the flattened mesh."""
    flat = p_axes
    return {
        "node_feats": P(flat, None),
        "edge_feats": P(flat, None),
        "serve_ids": P(flat, None, None),
        "src_slot": P(flat, None),
        "dst_local": P(flat, None),
        "valid_nodes": P(flat),
        "targets": P(flat, None),
    }


# ---------------------------------------------------------------------------
# sharded forward (runs under shard_map)
# ---------------------------------------------------------------------------

def _exchange_rows(h_loc, serve_ids, axis):
    """The direct exchange: ship exactly the rows peers need (one A2A)."""
    rows = h_loc[serve_ids]                       # (p, r_cap, D) to send
    recv = lax.all_to_all(rows, axis, split_axis=0, concat_axis=0,
                          tiled=True)             # (p, r_cap, D) received
    return recv.reshape(-1, h_loc.shape[-1])      # (p*r_cap, D)


def _shard_forward(params, batch_loc, cfg: GNNConfig, axis):
    h = C.apply_mlp(params["enc_h"], batch_loc["node_feats"])
    e = C.apply_mlp(params["enc_e"], batch_loc["edge_feats"])
    serve = batch_loc["serve_ids"][0]             # (p, r_cap)
    src_slot = batch_loc["src_slot"][0]           # (e_cap,)
    dst_local = batch_loc["dst_local"][0]
    n_loc = h.shape[0]
    emask = (dst_local >= 0)[:, None].astype(h.dtype)
    dst_idx = jnp.where(dst_local >= 0, dst_local, n_loc)

    def layer_fn(layer, h, e):
        h_src = _exchange_rows(h, serve, axis)[src_slot]      # (e_cap, D)
        h_dst = h[jnp.clip(dst_local, 0, n_loc - 1)]
        e_in = jnp.concatenate([e, h_src, h_dst], axis=-1)
        e = e + C.apply_layer_norm(layer["ln_e"],
                                   C.apply_mlp(layer["edge_mlp"], e_in))
        agg = jax.ops.segment_sum(e * emask, dst_idx,
                                  num_segments=n_loc + 1)[:n_loc]
        h_in = jnp.concatenate([h, agg], axis=-1)
        h = h + C.apply_layer_norm(layer["ln_h"],
                                   C.apply_mlp(layer["node_mlp"], h_in))
        return h, e

    for layer in params["layers"]:
        h, e = jax.checkpoint(layer_fn)(layer, h, e)
    pred = C.apply_mlp(params["dec"], h)

    w = batch_loc["valid_nodes"].astype(jnp.float32)
    se = (((pred - batch_loc["targets"]) ** 2).mean(-1) * w).sum()
    cnt = w.sum()
    loss = lax.psum(se, axis) / jnp.maximum(lax.psum(cnt, axis), 1.0)
    return loss


def make_loss_fn(cfg: GNNConfig, mesh, axis):
    """Owner-exchange loss with the same params pytree as models.graphcast."""
    pspec = None  # params replicated inside the shard_map

    def loss_fn(params, batch):
        param_specs = jax.tree.map(lambda _: P(), params)
        fn = functools.partial(_shard_forward, cfg=cfg, axis=axis)
        mapped = shard_map(
            fn, mesh=mesh,
            in_specs=(param_specs, {
                "node_feats": P(axis, None),
                "edge_feats": P(axis, None),
                "serve_ids": P(axis, None, None),
                "src_slot": P(axis, None),
                "dst_local": P(axis, None),
                "valid_nodes": P(axis),
                "targets": P(axis, None),
            }),
            out_specs=P(),
            check_vma=False,
        )
        loss = mapped(params, batch)
        return loss, {"loss": loss}

    return loss_fn


def init_params(cfg: GNNConfig, d_feat: int, key):
    return graphcast_init(cfg, d_feat, key)
