"""Shared GNN substrate: segment-op message passing + MLP blocks.

JAX sparse is BCOO-only, so all message passing here is explicit
gather-by-edge-index + ``jax.ops.segment_sum``/``segment_max`` scatter —
the same owner-computes dataflow as the BFS engine, expressed over feature
vectors instead of frontier bits (DESIGN.md §Arch-applicability).  Under
pjit the node/edge arrays are 1-D partitioned exactly like BFS vertices.

GraphBatch (dict of arrays, padded static shapes):
  node_feats (N, F) f32      valid_nodes (N,) bool
  edge_src, edge_dst (E,) int32 (-1 padding on dst)
  edge_feats (E, Fe) f32 | None     pos (N, 3) | None
  graph_id (N,) int32 (batched mode) | None
  targets / labels per task
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_src(x: jnp.ndarray, src: jnp.ndarray) -> jnp.ndarray:
    return x[jnp.maximum(src, 0)]


def edge_mask(dst: jnp.ndarray) -> jnp.ndarray:
    return (dst >= 0)


def aggregate(messages: jnp.ndarray, dst: jnp.ndarray, n: int,
              op: str = "sum") -> jnp.ndarray:
    """Scatter edge messages to destination nodes. messages: (E, D)."""
    m = edge_mask(dst)[:, None].astype(messages.dtype)
    idx = jnp.where(edge_mask(dst), dst, n)  # pad row
    summed = jax.ops.segment_sum(messages * m, idx, num_segments=n + 1)[:n]
    if op == "sum":
        return summed
    if op == "mean":
        deg = jax.ops.segment_sum(m[:, 0], idx, num_segments=n + 1)[:n]
        return summed / jnp.maximum(deg, 1.0)[:, None]
    if op == "max":
        neg = jnp.where(edge_mask(dst)[:, None], messages, -jnp.inf)
        mx = jax.ops.segment_max(neg, idx, num_segments=n + 1)[:n]
        return jnp.where(jnp.isfinite(mx), mx, 0.0)
    raise ValueError(op)


def degrees(src, dst, n):
    m = edge_mask(dst).astype(jnp.float32)
    idx_d = jnp.where(edge_mask(dst), dst, n)
    idx_s = jnp.where(edge_mask(dst), src, n)
    deg_in = jax.ops.segment_sum(m, idx_d, num_segments=n + 1)[:n]
    deg_out = jax.ops.segment_sum(m, idx_s, num_segments=n + 1)[:n]
    return deg_out, deg_in


# ------------------------------------------------------------------- MLPs
def init_mlp(key, dims, dtype=jnp.float32, bias: bool = True):
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, k in enumerate(ks):
        w = (jax.random.normal(k, (dims[i], dims[i + 1]))
             * dims[i] ** -0.5).astype(dtype)
        layers.append({"w": w, "b": jnp.zeros((dims[i + 1],), dtype)}
                      if bias else {"w": w})
    return layers


def apply_mlp(layers, x, act=jax.nn.relu, final_act: bool = False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + (l.get("b", 0.0))
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def init_layer_norm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_layer_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
            ).astype(x.dtype)


def node_mse(pred, targets, valid):
    err = ((pred - targets) ** 2).mean(-1)
    w = valid.astype(jnp.float32)
    return (err * w).sum() / jnp.maximum(w.sum(), 1.0)


def graph_pool(x, graph_id, n_graphs, op="sum"):
    if op == "sum":
        return jax.ops.segment_sum(x, graph_id, num_segments=n_graphs)
    if op == "mean":
        s = jax.ops.segment_sum(x, graph_id, num_segments=n_graphs)
        c = jax.ops.segment_sum(jnp.ones_like(graph_id, jnp.float32),
                                graph_id, num_segments=n_graphs)
        return s / jnp.maximum(c, 1.0)[:, None]
    raise ValueError(op)
