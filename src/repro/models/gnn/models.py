"""The four assigned GNN architectures over the shared segment-op substrate.

  gcn       — Kipf-Welling spectral conv, symmetric normalization.
  gatedgcn  — Bresson-Laurent edge-gated MPNN (LayerNorm in place of
              BatchNorm: batch statistics don't shard cleanly; noted in
              DESIGN.md §Hardware-adaptation).
  schnet    — continuous-filter convolution over RBF-expanded distances.
  graphcast — encoder / 16-layer interaction-network processor / decoder.

All expose init_params(cfg, d_feat, key) and forward(cfg, params, batch),
plus a family-level loss_fn used by train_step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.gnn import common as C
from repro.models import sharding_hints as hints


def _ckpt(fn):
    """Per-layer rematerialization: full-graph GNN backward otherwise saves
    every (E, D) edge tensor for all layers (241 GiB/device at ogb_products
    before this; EXPERIMENTS.md §Perf)."""
    return jax.checkpoint(fn)


# ----------------------------------------------------------------- GCN
def gcn_init(cfg: GNNConfig, d_feat: int, key):
    dims = [d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_out]
    ks = jax.random.split(key, cfg.n_layers)
    return {"layers": [C.init_mlp(k, dims[i:i + 2]) for i, k in enumerate(ks)]}


def gcn_forward(cfg: GNNConfig, params, batch):
    h = batch["node_feats"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = h.shape[0]
    deg_out, deg_in = C.degrees(src, dst, n)
    if cfg.norm == "sym":
        w = jax.lax.rsqrt(jnp.maximum(deg_out, 1.0))[jnp.maximum(src, 0)] * \
            jax.lax.rsqrt(jnp.maximum(deg_in, 1.0))[jnp.maximum(dst, 0)]
    else:
        w = jnp.ones_like(src, jnp.float32)
    def layer_fn(layer, h, last):
        h = hints.constrain_rows(h)
        h = C.apply_mlp([layer[0]], h)           # XW
        msg = C.gather_src(h, src) * w[:, None]
        h = C.aggregate(msg, dst, n, op="sum")
        if cfg.aggregator == "mean" and cfg.norm != "sym":
            h = h / jnp.maximum(deg_in, 1.0)[:, None]
        return h if last else jax.nn.relu(h)

    for i, layer in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        h = _ckpt(lambda l, x: layer_fn(l, x, last))(layer, h)
    return h


# ------------------------------------------------------------- GatedGCN
def gatedgcn_init(cfg: GNNConfig, d_feat: int, key, d_edge: int = 1):
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 6)
        layers.append({
            "U": C.init_mlp(kk[0], (d, d)), "V": C.init_mlp(kk[1], (d, d)),
            "A": C.init_mlp(kk[2], (d, d)), "B": C.init_mlp(kk[3], (d, d)),
            "E": C.init_mlp(kk[4], (d, d)),
            "ln_h": C.init_layer_norm(d), "ln_e": C.init_layer_norm(d),
        })
    return {
        "in_h": C.init_mlp(ks[-3], (d_feat, d)),
        "in_e": C.init_mlp(ks[-2], (d_edge, d)),
        "out": C.init_mlp(ks[-1], (d, cfg.d_out)),
        "layers": layers,
    }


def gatedgcn_forward(cfg: GNNConfig, params, batch):
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = batch["node_feats"].shape[0]
    h = C.apply_mlp(params["in_h"], batch["node_feats"])
    ef = batch.get("edge_feats")
    if ef is None:
        ef = jnp.ones((src.shape[0], 1), jnp.float32)
    e = C.apply_mlp(params["in_e"], ef)
    def layer_fn(layer, h, e):
        h, e = hints.constrain_rows(h), hints.constrain_rows(e)
        hi = C.gather_src(h, src)
        hj = h[jnp.maximum(dst, 0)]
        e_new = (C.apply_mlp([layer["A"][0]], e) +
                 C.apply_mlp([layer["B"][0]], hi) +
                 C.apply_mlp([layer["E"][0]], hj))
        eta = jax.nn.sigmoid(e_new)
        num = C.aggregate(eta * C.apply_mlp([layer["V"][0]], hi), dst, n, "sum")
        den = C.aggregate(eta, dst, n, "sum")
        h_new = C.apply_mlp([layer["U"][0]], h) + num / (den + 1e-6)
        h = h + jax.nn.relu(C.apply_layer_norm(layer["ln_h"], h_new))
        e = e + jax.nn.relu(C.apply_layer_norm(layer["ln_e"], e_new))
        return h, e

    for layer in params["layers"]:
        h, e = _ckpt(layer_fn)(layer, h, e)
    return C.apply_mlp(params["out"], h)


# --------------------------------------------------------------- SchNet
def _ssp(x):  # shifted softplus, SchNet's activation
    return jax.nn.softplus(x) - jnp.log(2.0)


def schnet_init(cfg: GNNConfig, d_feat: int, key):
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 2)
    inter = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 4)
        inter.append({
            "filter": C.init_mlp(kk[0], (cfg.rbf, d, d)),
            "w_in": C.init_mlp(kk[1], (d, d), bias=False),
            "post": C.init_mlp(kk[2], (d, d, d)),
        })
    return {
        "embed": C.init_mlp(ks[-2], (d_feat, d)),
        "inter": inter,
        "out": C.init_mlp(ks[-1], (d, d // 2, cfg.d_out)),
    }


def schnet_forward(cfg: GNNConfig, params, batch):
    src, dst = batch["edge_src"], batch["edge_dst"]
    pos = batch["pos"]
    n = pos.shape[0]
    h = C.apply_mlp(params["embed"], batch["node_feats"])
    # RBF expansion of interatomic distances
    d_ij = jnp.linalg.norm(pos[jnp.maximum(src, 0)] - pos[jnp.maximum(dst, 0)]
                           + 1e-12, axis=-1)
    mu = jnp.linspace(0.0, cfg.cutoff, cfg.rbf)
    gamma = 10.0 / cfg.cutoff
    rbf = jnp.exp(-gamma * (d_ij[:, None] - mu[None, :]) ** 2)   # (E, rbf)
    # smooth cutoff (cosine), zero past cfg.cutoff
    cut = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d_ij / cfg.cutoff, 0, 1)) + 1.0)
    def layer_fn(blk, h):
        h = hints.constrain_rows(h)
        w = C.apply_mlp(blk["filter"], hints.constrain_rows(rbf),
                        act=_ssp, final_act=True)
        w = w * cut[:, None]
        msg = C.apply_mlp(blk["w_in"], C.gather_src(h, src)) * w
        agg = C.aggregate(msg, dst, n, "sum")
        return h + C.apply_mlp(blk["post"], agg, act=_ssp)

    for blk in params["inter"]:
        h = _ckpt(layer_fn)(blk, h)
    return C.apply_mlp(params["out"], h, act=_ssp)


# ------------------------------------------------------------ GraphCast
def graphcast_init(cfg: GNNConfig, d_feat: int, key, d_edge: int = 4):
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 2)
        layers.append({
            "edge_mlp": C.init_mlp(kk[0], (3 * d, d, d)),
            "node_mlp": C.init_mlp(kk[1], (2 * d, d, d)),
            "ln_e": C.init_layer_norm(d), "ln_h": C.init_layer_norm(d),
        })
    return {
        "enc_h": C.init_mlp(ks[-3], (d_feat, d, d)),
        "enc_e": C.init_mlp(ks[-2], (d_edge, d, d)),
        "dec": C.init_mlp(ks[-1], (d, d, cfg.n_vars)),
        "layers": layers,
    }


def graphcast_forward(cfg: GNNConfig, params, batch):
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = batch["node_feats"].shape[0]
    h = C.apply_mlp(params["enc_h"], batch["node_feats"])
    ef = batch.get("edge_feats")
    if ef is None:
        ef = jnp.ones((src.shape[0], 4), jnp.float32)
    e = C.apply_mlp(params["enc_e"], ef)
    def layer_fn(layer, h, e):
        # interaction-network block (GraphCast processor, sum aggregation)
        h, e = hints.constrain_rows(h), hints.constrain_rows(e)
        e_in = jnp.concatenate([e, C.gather_src(h, src),
                                h[jnp.maximum(dst, 0)]], axis=-1)
        e = e + C.apply_layer_norm(layer["ln_e"],
                                   C.apply_mlp(layer["edge_mlp"], e_in))
        agg = C.aggregate(e, dst, n, cfg.aggregator)
        h_in = jnp.concatenate([h, agg], axis=-1)
        h = h + C.apply_layer_norm(layer["ln_h"],
                                   C.apply_mlp(layer["node_mlp"], h_in))
        return h, e

    for layer in params["layers"]:
        h, e = _ckpt(layer_fn)(layer, h, e)
    return C.apply_mlp(params["dec"], h)


# ------------------------------------------------------------- dispatch
_INIT = {"gcn": gcn_init, "gatedgcn": gatedgcn_init, "schnet": schnet_init,
         "graphcast": graphcast_init}
_FWD = {"gcn": gcn_forward, "gatedgcn": gatedgcn_forward,
        "schnet": schnet_forward, "graphcast": graphcast_forward}


def init_params(cfg: GNNConfig, d_feat: int, key):
    return _INIT[cfg.kind](cfg, d_feat, key)


def forward(cfg: GNNConfig, params, batch):
    return _FWD[cfg.kind](cfg, params, batch)


def loss_fn(cfg: GNNConfig, params, batch):
    pred = forward(cfg, params, batch)
    valid = batch["valid_nodes"]
    if "labels" in batch:  # node classification (gcn-cora)
        logits = pred.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
        nll = lse - ll
        w = valid.astype(jnp.float32)
        loss = (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
        return loss, {"loss": loss}
    if batch.get("graph_id") is not None:  # graph-level regression (molecule)
        pooled = C.graph_pool(pred * valid[:, None], batch["graph_id"],
                              batch["graph_targets"].shape[0], "sum")
        loss = ((pooled - batch["graph_targets"]) ** 2).mean()
        return loss, {"loss": loss}
    loss = C.node_mse(pred, batch["targets"], valid)
    return loss, {"loss": loss}
