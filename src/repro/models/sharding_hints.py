"""Ambient sharding hints for model code.

Model code is mesh-agnostic; the launcher opts into GSPMD constraint
injection by calling ``set_hints(mesh, dp, model)`` before tracing.  With
hints unset every ``constrain*`` is the identity, so smoke tests and
single-device runs never touch device state.

The key hint is *sequence-sharded activations* between transformer blocks
(Megatron sequence parallelism): residual activations live sharded over the
``model`` axis and GSPMD inserts the all-gather/reduce-scatter pairs around
attention/FFN.  This is what turns O(layers·B·S·D) checkpoint residuals
from ~54 GiB/device into ~3 GiB/device at the train_4k shapes
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "dp": None, "model": None, "flat": None,
          "seq_shard": True, "param_specs": None}


def set_hints(mesh, dp, model, flat=None, seq_shard=True, param_specs=None):
    _STATE.update(mesh=mesh, dp=tuple(dp) if dp else None, model=model,
                  flat=tuple(flat) if flat else None, seq_shard=seq_shard,
                  param_specs=param_specs)


def clear_hints():
    _STATE.update(mesh=None, dp=None, model=None, flat=None,
                  param_specs=None)


@contextlib.contextmanager
def hints(mesh, dp, model, flat=None, seq_shard=True, param_specs=None):
    set_hints(mesh, dp, model, flat, seq_shard, param_specs)
    try:
        yield
    finally:
        clear_hints()


def enabled() -> bool:
    return _STATE["mesh"] is not None


def _constrain(x, spec: P):
    if not enabled():
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE["mesh"], spec))


def constrain_tokens_3d(h):
    """(B, S, D) residual stream: batch over dp, sequence over model."""
    if not enabled():
        return h
    b, s, _ = h.shape
    dp, m = _STATE["dp"], _STATE["model"]
    mesh = _STATE["mesh"]
    import numpy as np
    dp_ok = b % int(np.prod([mesh.shape[a] for a in dp])) == 0
    s_ok = (_STATE["seq_shard"] and m not in dp
            and s % mesh.shape[m] == 0)
    return _constrain(h, P(dp if dp_ok else None, m if s_ok else None, None))


def constrain_logits_3d(x):
    """(B, S_chunk, V) logits: batch over dp, vocab over model — keeps the
    embed gradient vocab-sharded instead of letting GSPMD replicate the
    (V, D) fp32 accumulator (a 15 GiB/device saving at gemma3/train_4k)."""
    if not enabled():
        return x
    b, _, v = x.shape
    dp, m = _STATE["dp"], _STATE["model"]
    mesh = _STATE["mesh"]
    import numpy as np
    dp_ok = b % int(np.prod([mesh.shape[a] for a in dp])) == 0
    v_ok = m not in dp and v % mesh.shape[m] == 0
    return _constrain(x, P(dp if dp_ok else None, None, m if v_ok else None))


def constrain_expert_buffer(x):
    """(E, C, D) MoE buffers: experts over model."""
    if not enabled():
        return x
    m = _STATE["model"]
    mesh = _STATE["mesh"]
    e_ok = x.shape[0] % mesh.shape[m] == 0
    return _constrain(x, P(m if e_ok else None, None, None))


def constrain_vocab_table(w):
    """(V, D) head table inside the loss chunk: vocab over model.  The
    constraint's transpose pins the GRADIENT accumulator to the same
    sharding, preventing a replicated (V, D) fp32 carry in the loss scan."""
    if not enabled():
        return w
    m = _STATE["model"]
    mesh = _STATE["mesh"]
    if m in (_STATE["dp"] or ()):  # pure-FSDP: no vocab TP
        return w
    v_ok = w.shape[0] % mesh.shape[m] == 0
    return _constrain(w, P(m if v_ok else None, None))


def constrain_heads_4d(x):
    """(B, H, S, Dh) attention tensors: batch over dp, heads over model
    (when divisible).  Prevents GSPMD from trading the batch sharding away
    when resolving the S-sharded-activation x H-sharded-weight conflict."""
    if not enabled():
        return x
    b, h = x.shape[0], x.shape[1]
    dp, m = _STATE["dp"], _STATE["model"]
    mesh = _STATE["mesh"]
    import numpy as np
    dp_ok = b % int(np.prod([mesh.shape[a] for a in dp])) == 0
    h_ok = h % mesh.shape[m] == 0
    return _constrain(x, P(dp if dp_ok else None, m if h_ok else None,
                           None, None))


def constrain_rows(x):
    """(rows, ...) vertex/edge-partitioned arrays (GNN/BFS): rows over the
    flattened mesh — the paper's 1-D partitioning.  Keeps per-layer node and
    edge tensors sharded instead of letting gathers replicate them."""
    if not enabled() or _STATE["flat"] is None:
        return x
    flat = _STATE["flat"]
    mesh = _STATE["mesh"]
    import numpy as np
    ok = x.shape[0] % int(np.prod([mesh.shape[a] for a in flat])) == 0
    if not ok:
        return x
    return _constrain(x, P(flat, *([None] * (x.ndim - 1))))


def constrain_grads(grads):
    """Pin gradients to the parameter *storage* sharding before the
    optimizer.  Without this GSPMD may instead all-gather the fp32 moments
    to the gradient layout — six hoisted 7.5 GiB all-gathers at
    qwen/train_4k (EXPERIMENTS.md §Perf) — rather than reduce-scattering
    the (smaller, bf16) gradients."""
    specs = _STATE.get("param_specs")
    if not enabled() or specs is None:
        return grads
    import jax
    return jax.tree.map(lambda g, sp: _constrain(g, sp), grads, specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain_tokens_full(h):
    """(B, S, D) at block entry: batch over dp, sequence GATHERED (None).
    Paired with ``constrain_tokens_3d`` on block outputs this pins the
    Megatron-SP schedule — one all-gather at entry, one reduce-scatter at
    exit — instead of GSPMD's per-projection resharding (~5x collective
    reduction at qwen/train_4k; EXPERIMENTS.md §Perf)."""
    if not enabled() or not _STATE["seq_shard"]:
        return h
    b = h.shape[0]
    dp = _STATE["dp"]
    mesh = _STATE["mesh"]
    import numpy as np
    dp_ok = b % int(np.prod([mesh.shape[a] for a in dp])) == 0
    return _constrain(h, P(dp if dp_ok else None, None, None))
