"""Machine-readable audit results shared by the analysis passes.

Every pass (``hlo_audit``, ``lint``, ``locks``) emits an ``AuditReport``:
a named list of ``Violation`` rows plus a free-form ``info`` payload
(census tables, lock graphs, ...).  Rule ids are stable strings the
tests and CI gate match on:

  HA001-HA007  HLO plan auditor (analysis/hlo_audit.py)
  RX001-RX005  exchange-registry / compiled-loop lint (analysis/lint.py)
  LK001-LK003  serve/ lock discipline (analysis/locks.py)
  SUP001       malformed ``# audit: allow(...)`` suppression

A violation carrying ``suppressed=True`` was matched by an inline
``# audit: allow(<rule>) -- <reason>`` comment; it stays in the report
(the suppression inventory is part of the audit) but does not fail it.
"""

from __future__ import annotations

import dataclasses
import json
from typing import List

RULES = {
    # --- HLO plan auditor
    "HA001": "required collective missing from the compiled loop",
    "HA002": "loop collective not priced by any plan byte model",
    "HA003": "HLO collective bytes drift outside the model tolerance",
    "HA004": "dist buffer not input/output-aliased (donation lost)",
    "HA005": "host transfer inside the compiled while loop",
    "HA006": "engine retraced after compile (trace pinning broken)",
    "HA007": "collective replica-group size disagrees with the plan axis",
    # --- registry / compiled-loop lint
    "RX001": "register_exchange byte model has the wrong signature",
    "RX002": "register_exchange byte model is not pure Python (jnp/lax)",
    "RX003": "bytes-tier strategy lacks its packed/compressed twin",
    "RX004": "Python `if` over a traced jnp/lax expression in a loop module",
    "RX005": "host clock call inside a compiled-loop module",
    # --- lock discipline
    "LK001": "guarded attribute accessed outside `with <lock>:`",
    "LK002": "lock-acquisition ordering cycle",
    "LK003": "guarded-by annotation names an unknown lock",
    # --- suppression syntax
    "SUP001": "audit suppression without a `-- reason` string",
}


@dataclasses.dataclass
class Violation:
    rule: str
    message: str
    severity: str = "error"       # error | warning | info
    file: str = ""
    line: int = 0
    suppressed: bool = False
    suppress_reason: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        loc = f"{self.file}:{self.line}: " if self.file else ""
        sup = f" [suppressed: {self.suppress_reason}]" if self.suppressed \
            else ""
        return f"{loc}{self.rule}: {self.message}{sup}"


@dataclasses.dataclass
class AuditReport:
    name: str
    violations: List[Violation] = dataclasses.field(default_factory=list)
    info: dict = dataclasses.field(default_factory=dict)

    def add(self, rule: str, message: str, **kw) -> Violation:
        v = Violation(rule, message, **kw)
        self.violations.append(v)
        return v

    @property
    def failures(self) -> List[Violation]:
        return [v for v in self.violations
                if v.severity == "error" and not v.suppressed]

    def ok(self) -> bool:
        return not self.failures

    def rules(self) -> set:
        """Unsuppressed rule ids present — what the known-bad tests match."""
        return {v.rule for v in self.violations if not v.suppressed}

    def extend(self, other: "AuditReport") -> None:
        self.violations.extend(other.violations)

    def to_dict(self) -> dict:
        return {"name": self.name, "ok": self.ok(),
                "violations": [v.to_dict() for v in self.violations],
                "info": self.info}

    def to_json(self, indent=None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True,
                          default=str)

    def summary(self) -> str:
        n_sup = sum(1 for v in self.violations if v.suppressed)
        status = "ok" if self.ok() else \
            f"FAIL ({len(self.failures)} violation(s))"
        extra = f", {n_sup} suppressed" if n_sup else ""
        return f"[{self.name}] {status}{extra}"
