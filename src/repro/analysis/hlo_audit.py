"""HLO plan auditor: does the compiled loop match the plan's accounting?

The paper's contribution is disciplined communication — every level's
collective and its byte cost is known ahead of time — and the repo
encodes that as analytic byte models the planner trusts blindly.  This
pass closes the loop: it parses ``BFSEngine.compiled_hlo()`` into a
collective *census* (op kind, replica groups, payload bytes, loop
membership, source attribution) and statically asserts it against the
plan's resolved strategies:

  * every reachable exchange role (dense / queue / expand / fold /
    sparse twins / sieve gather / bottom-up gather) appears in the
    while body (HA001), and nothing unpriced does (HA002);
  * per role, the bytes a chip *receives* through the collective agree
    with the registered byte model within a documented tolerance
    (HA003) — the census converts HLO output-shape bytes to received
    bytes per op kind (all-gather/all-to-all: ``out*(g-1)/g``,
    reduce-scatter: ``out*(g-1)``, all-reduce ring: ``out*2*(g-1)/g``);
  * replica groups span the mesh axis the role runs over (HA007);
  * the dist buffer is really donated — ``input_output_alias`` maps
    output ``{0}`` back to the dist parameter, no hidden copy (HA004);
  * no infeed/outfeed/send/recv hides inside the loop (HA005);
  * optionally, two traversals from distinct sources leave
    ``trace_count`` pinned at ``compile_traces`` (HA006).

Small all-reduces (<= ``CONTROL_CUTOFF`` bytes) are the loop's control
plane — termination/overflow/mode psums — and are censused but never
priced.  Everything lands in an ``AuditReport`` consumed by tests,
``bfs_run --audit`` and the ``bfs_audit`` CI gate.
"""

from __future__ import annotations

import dataclasses
import math
import os
import re
from typing import List, Optional, Sequence

from repro.analysis.report import AuditReport
from repro.launch.hlo_parse import _shape_bytes, _split_computations

# Replicated scalar psums (termination, overflow, mode pick, sieve-hit
# and byte accumulators) are control flow, not payload; anything bigger
# than this many bytes must be priced by a byte model.
CONTROL_CUTOFF = 1024

# Documented tolerance on HLO-received vs modeled bytes per role.  The
# models are exact for every wire tier (verified per-strategy), so the
# band mostly absorbs dtype widening (bf16 reduce tiers) and backend
# padding; drift beyond it means a mis-registered model.
DEFAULT_TOLERANCE = (0.3, 3.0)

_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|all-to-all|"
    r"reduce-scatter|collective-permute-start|collective-permute)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{((?:\{[0-9,]*\},?)*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_SOURCE_RE = re.compile(r'source_file="([^"]*)".*?source_line=(\d+)')
_REF_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_ALIAS_RE = re.compile(r"input_output_alias=\{((?:[^{}]|\{[^{}]*\})*)\}")
_ALIAS_ENTRY_RE = re.compile(r"\{([0-9,\s]*)\}:\s*\((\d+)")
_PARAM_RE = re.compile(r"=\s*([a-z][a-z0-9]*)\[[^\]]*\]\S*\s+parameter\((\d+)\)")
_HOST_RE = re.compile(
    r"=\s*\S+\s+(infeed|outfeed|send-done|recv-done|send|recv)\(")


@dataclasses.dataclass
class CollectiveOp:
    """One collective instruction from the optimized HLO."""

    kind: str                 # all-gather | all-to-all | ... (-start folded)
    out_bytes: float          # output shape bytes (tuple ops: summed)
    recv_bytes: float         # bytes received per participant (see module doc)
    group_size: int           # replica group size (0 = no groups attribute)
    n_groups: int
    computation: str
    in_loop: bool
    source: str               # "exchange.py:351" attribution, best effort
    op_name: str = ""
    role: str = ""            # census role after matching ("" = unmatched)
    model_bytes: float = 0.0  # per-instance model of the matched role

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Role:
    """One exchange the plan prices: what the census must account for."""

    name: str
    kinds: tuple              # HLO op kinds this strategy may lower to
    model_bytes: float        # modeled bytes received per chip per instance
    group: Optional[int]      # expected replica-group size (None: skip)
    required: bool            # must appear in the loop at least once
    per_op: bool = True       # True: each op ~ model; False: sum(ops) ~ model
                              # (False for the chained both-axes gathers,
                              # whose staged received bytes telescope to the
                              # single-gather total)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _recv_bytes(kind: str, out_bytes: float, g: int) -> float:
    """Bytes received per participant given the op's output bytes."""
    if g <= 1:
        return 0.0
    if kind in ("all-gather", "all-to-all"):
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-reduce":
        return out_bytes * 2 * (g - 1) / g     # ring lower bound
    return out_bytes                            # collective-permute et al.


def _loop_computations(comps: dict) -> set:
    """Names of computations transitively reachable from any while body."""
    roots = set()
    for name, lines in comps.items():
        if name == "__entry_name__":
            continue
        for ln in lines:
            if "body=" in ln:
                roots.update(re.findall(r"body=%?([\w\.\-]+)", ln))
    seen: set = set()
    stack = list(roots)
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        for ln in comps[c]:
            stack.extend(r for r in _REF_RE.findall(ln) if r not in seen)
            bm = _BRANCH_RE.search(ln)
            if bm:
                stack.extend(x.strip().lstrip("%")
                             for x in bm.group(1).split(",") if x.strip())
    return seen


def _parse_groups(line: str):
    """(group_size, n_groups) from either replica_groups syntax; (0, 0)
    when the attribute is absent."""
    m = _GROUPS_RE.search(line)
    if m:
        groups = [g for g in re.findall(r"\{([0-9,]*)\}", m.group(0))]
        sizes = [len([x for x in g.split(",") if x]) for g in groups]
        if sizes:
            return max(sizes), len(sizes)
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2)), int(m.group(1))
    return 0, 0


def census(hlo_text: str) -> List[CollectiveOp]:
    """Parse every collective in the module into a CollectiveOp row."""
    comps = _split_computations(hlo_text)
    comps.pop("__entry_name__", None)
    loop = _loop_computations(comps)
    ops: List[CollectiveOp] = []
    for comp, lines in comps.items():
        for ln in lines:
            m = _OP_RE.search(ln)
            if not m:
                continue
            kind = m.group(2).replace("-start", "")
            out_bytes = float(_shape_bytes(m.group(1)))
            g, n_groups = _parse_groups(ln)
            op_name_m = _OPNAME_RE.search(ln)
            op_name = op_name_m.group(1) if op_name_m else ""
            src_m = _SOURCE_RE.search(ln)
            source = (f"{os.path.basename(src_m.group(1))}:{src_m.group(2)}"
                      if src_m else "")
            ops.append(CollectiveOp(
                kind=kind, out_bytes=out_bytes,
                recv_bytes=_recv_bytes(kind, out_bytes, g),
                group_size=g, n_groups=n_groups, computation=comp,
                in_loop=comp in loop or "/while/" in op_name,
                source=source, op_name=op_name))
    return ops


def _strategy_kinds(name: str) -> tuple:
    """HLO op kinds a registered exchange strategy may lower to.

    Packed reduce-scatter twins route word blocks via all_to_all (psum
    carries across bit lanes), so only the bytes-tier ``reduce_scatter``
    names lower to a reduce-scatter op.
    """
    if "hierarchical" in name:
        return ("all-to-all", "all-gather", "reduce-scatter", "all-reduce",
                "collective-permute")
    if "reduce_scatter" in name and not name.endswith("_packed"):
        return ("reduce-scatter", "all-reduce")
    if "alltoall" in name or "reduce_scatter" in name:
        return ("all-to-all",)
    if "allgather" in name:
        return ("all-gather",)
    return ("all-to-all", "all-gather", "reduce-scatter")


def roles_for_plan(plan) -> List[Role]:
    """Derive the expected census roles from a resolved BFSPlan.

    Reachability mirrors core/bfs.py: dense runs in every mode (it is
    the queue path's overflow escalation), the sparse path needs S=1,
    bottom-up exists only under ``auto``, and the sieve gather rides
    inside each queue level when the plan resolved it on.  A role with a
    zero byte model (p=1, or a peerless grid axis) is never required —
    XLA elides the degenerate collective entirely.

    Fused-tail plans (``use_fused_tail``) change the *compute* between
    collectives — the fold merge, owner update and next-frontier pack
    collapse into one kernel fed by the double-buffered word generation
    — but ship the same payloads through the same collectives, so the
    role set and every byte model are identical to the unfused twin.
    The 48-variant gate compiles both twins per wire x mode and this
    invariance is exactly what HA001-HA003 then verify.
    """
    from repro.core import frontier as fr
    from repro.core import exchange as ex

    d = plan.describe()
    mode, s = d["mode"], d["num_sources"]
    queue_reachable = mode == "queue" or (mode == "auto" and s == 1)
    roles: List[Role] = []

    def role(name, strategy_name, model, group, required, per_op=True,
             kinds=None):
        roles.append(Role(
            name=name,
            kinds=kinds or _strategy_kinds(strategy_name),
            model_bytes=float(model), group=group,
            required=bool(required and model > 0), per_op=per_op))

    if d["partition"] == "2d":
        r, c = d["grid"]
        p = r * c
        pb = d["phase_bytes"]
        role("expand", d["expand_exchange"], pb["expand"],
             c if c > 1 else None, True)
        role("fold", d["fold_exchange"], pb["fold"],
             r if r > 1 else None, True)
        if queue_reachable:
            role("expand_sparse", d["expand_sparse_exchange"],
                 pb["expand_sparse"], c if c > 1 else None, True)
            role("fold_sparse", d["fold_sparse_exchange"],
                 pb["fold_sparse"], r if r > 1 else None, True)
            if d["sieve"]:
                b = d["shard_size"]
                sieve_b = (p - 1) * fr.sieve_layout(b)[2] * 4
                role("sieve", "allgather", sieve_b, None, True,
                     per_op=False, kinds=("all-gather",))
        if mode == "auto":
            role("bottom_up", "allgather", d["bottom_up_level_bytes"],
                 None, True, per_op=False, kinds=("all-gather",))
    else:
        p = d["p"]
        role("dense", d["dense_exchange"], d["dense_level_bytes"],
             p if len(d["axes_sizes"]) == 1 else None, True)
        if queue_reachable:
            sieve_b = ((p - 1) * fr.sieve_layout(d["shard_size"])[2] * 4
                       if d["sieve"] else 0.0)
            role("queue", d["queue_exchange"],
                 d["queue_level_bytes"] - sieve_b, p, True)
            if d["sieve"]:
                role("sieve", "allgather", sieve_b, p, True,
                     per_op=False, kinds=("all-gather",))
        if mode == "auto":
            role("bottom_up", "allgather",
                 ex.bottomup_level_bytes(d["n"], p, s, 1,
                                         wire=plan.bottom_up_wire),
                 p, True, per_op=False, kinds=("all-gather",))
    return roles


def match_census(ops: Sequence[CollectiveOp], roles: Sequence[Role],
                 report: AuditReport,
                 tolerance=DEFAULT_TOLERANCE) -> dict:
    """Assign loop collectives to roles and assert the byte accounting.

    Greedy assignment: each non-control loop op goes to the candidate
    role (kind-compatible, nonzero model) whose model is nearest in log
    space.  Violations land on ``report``; returns {role: [ops]}.
    """
    lo, hi = tolerance
    assigned = {role.name: [] for role in roles}
    for op in ops:
        if not op.in_loop:
            op.role = "outside_loop"
            continue
        if op.kind == "all-reduce" and op.out_bytes <= CONTROL_CUTOFF:
            op.role = "control"
            continue
        if op.group_size <= 1 or op.recv_bytes <= 0:
            # a collective over a group of one moves no data; XLA keeps
            # some of these at p=1 instead of eliding them
            op.role = "degenerate"
            continue
        cands = [role for role in roles
                 if op.kind in role.kinds and role.model_bytes > 0]
        if not cands:
            op.role = "unpriced"
            report.add("HA002",
                       f"{op.kind} at {op.source or op.computation} "
                       f"({op.recv_bytes:.0f} B received, group "
                       f"{op.group_size}) matches no plan byte model")
            continue
        best = min(cands, key=lambda role: abs(
            math.log(max(op.recv_bytes, 1e-9) / role.model_bytes)))
        op.role = best.name
        op.model_bytes = best.model_bytes
        assigned[best.name].append(op)

    # exact size ties (e.g. the packed bottom-up gather and the sieve
    # gather both ship W uint32 words per shard) can strand a required
    # role while its twin collects both ops — let an empty required
    # role steal a tolerance-compatible op from a role holding several
    for role in roles:
        if assigned[role.name] or not role.required:
            continue
        donors = [op for other in roles
                  if other.name != role.name
                  and len(assigned[other.name]) > 1
                  for op in assigned[other.name]
                  if op.kind in role.kinds
                  and lo <= op.recv_bytes / role.model_bytes <= hi]
        if donors:
            op = min(donors, key=lambda o: abs(
                math.log(o.recv_bytes / role.model_bytes)))
            assigned[op.role].remove(op)
            op.role = role.name
            op.model_bytes = role.model_bytes
            assigned[role.name].append(op)

    for role in roles:
        matched = assigned[role.name]
        if not matched:
            if role.required:
                report.add("HA001",
                           f"role '{role.name}' (model "
                           f"{role.model_bytes:.0f} B, kinds "
                           f"{'/'.join(role.kinds)}) has no collective "
                           "in the compiled loop")
            continue
        if role.per_op:
            for op in matched:
                ratio = op.recv_bytes / role.model_bytes
                if not lo <= ratio <= hi:
                    report.add("HA003",
                               f"role '{role.name}' at "
                               f"{op.source or op.computation}: HLO "
                               f"{op.recv_bytes:.0f} B received vs model "
                               f"{role.model_bytes:.0f} B "
                               f"(ratio {ratio:.3f} outside "
                               f"[{lo}, {hi}])")
        else:
            total = sum(op.recv_bytes for op in matched)
            ratio = total / role.model_bytes
            if not lo <= ratio <= hi:
                report.add("HA003",
                           f"role '{role.name}': HLO {total:.0f} B "
                           f"received over {len(matched)} op(s) vs model "
                           f"{role.model_bytes:.0f} B (ratio {ratio:.3f} "
                           f"outside [{lo}, {hi}])")
        if role.group:
            for op in matched:
                if op.group_size and op.group_size != role.group:
                    report.add("HA007",
                               f"role '{role.name}' at "
                               f"{op.source or op.computation}: replica "
                               f"group size {op.group_size} != expected "
                               f"{role.group}")
    return assigned


def donation_check(hlo_text: str, report: AuditReport,
                   expected_dtype: str = "s32") -> None:
    """HA004: the dist buffer (output tuple index 0) must alias an input.

    The aliased parameter's declared element type must be the dist
    buffer's (``s32``).  The parameter *index* is not predictable from
    the Python signature because ``jit`` prunes unused edge buffers
    (``keep_unused=False``), but output ``{0}`` is dist by construction
    and aliasing requires a shape/type match, so any alias for output 0
    is the dist donation.
    """
    m = _ALIAS_RE.search(hlo_text)
    entries = _ALIAS_ENTRY_RE.findall(m.group(1)) if m else []
    dist = [int(param) for out, param in entries if out.strip() == "0"]
    if not dist:
        report.add("HA004",
                   "no input_output_alias entry for output {0}: the "
                   "donated dist buffer is copied, not aliased")
        return
    report.info.setdefault("donation", {})["dist_param"] = dist[0]
    if expected_dtype:
        comps = _split_computations(hlo_text)
        entry = comps.get(comps.get("__entry_name__", ""), ())
        dtypes = {int(mm.group(2)): mm.group(1) for mm in
                  (_PARAM_RE.search(ln) for ln in entry) if mm}
        got = dtypes.get(dist[0])
        if got is not None and got != expected_dtype:
            report.add("HA004",
                       f"dist output aliases parameter {dist[0]} of "
                       f"type {got}, expected the {expected_dtype} dist "
                       "buffer")


def host_transfer_check(hlo_text: str, report: AuditReport) -> None:
    """HA005: no infeed/outfeed/send/recv inside while-loop computations."""
    comps = _split_computations(hlo_text)
    comps.pop("__entry_name__", None)
    loop = _loop_computations(comps)
    for comp in loop:
        for ln in comps.get(comp, ()):
            m = _HOST_RE.search(ln)
            if m:
                report.add("HA005",
                           f"host transfer '{m.group(1)}' inside loop "
                           f"computation '{comp}'")


def retrace_check(engine, report: AuditReport) -> None:
    """HA006: two distinct-source runs must not grow the trace count."""
    n_logical = engine.plan.describe()["n_logical"]
    if n_logical < 2:
        return
    engine.run([0])
    engine.run([1])
    if engine.trace_count != engine.compile_traces:
        report.add("HA006",
                   f"trace_count {engine.trace_count} != compile_traces "
                   f"{engine.compile_traces} after two runs — the engine "
                   "retraced after compile")
    report.info["trace_count"] = engine.trace_count


def variant_name(plan) -> str:
    d = plan.describe()
    fused = ":fused" if getattr(plan, "use_fused_tail", False) else ""
    return (f"hlo:{d['partition']}:{d['mode']}:"
            f"{plan.opts.wire_format}:S{d['num_sources']}{fused}")


def audit_engine(engine, tolerance=DEFAULT_TOLERANCE,
                 run_check: bool = False,
                 name: Optional[str] = None) -> AuditReport:
    """Run every static HLO check against a compiled engine."""
    plan = engine.plan
    report = AuditReport(name or variant_name(plan))
    text = engine.compiled_hlo()
    ops = census(text)
    roles = roles_for_plan(plan)
    match_census(ops, roles, report, tolerance=tolerance)
    donation_check(text, report)
    host_transfer_check(text, report)
    if run_check:
        retrace_check(engine, report)
    d = plan.describe()
    report.info.update({
        "tolerance": list(tolerance),
        "census": [op.to_dict() for op in ops],
        "roles": [role.to_dict() for role in roles],
        "plan": {k: d[k] for k in ("mode", "partition", "p", "n",
                                   "num_sources", "sieve", "wire_formats",
                                   "use_fused_tail")},
        "collectives": {
            "loop_data": sum(1 for op in ops
                             if op.in_loop and op.role not in
                             ("control", "outside_loop", "degenerate")),
            "loop_control": sum(1 for op in ops if op.role == "control"),
            "outside_loop": sum(1 for op in ops if not op.in_loop),
        },
    })
    return report


def census_table(report: AuditReport) -> str:
    """Render a report's census next to the modeled bytes (CLI output)."""
    rows = ["role          kind               group  HLO recv B   "
            "model B      ratio  source"]
    for op in report.info.get("census", ()):
        if not op["in_loop"]:
            continue
        model = op["model_bytes"]
        ratio = (f"{op['recv_bytes'] / model:7.3f}" if model else "      -")
        rows.append(f"{op['role'] or '?':<13} {op['kind']:<18} "
                    f"{op['group_size']:>5}  {op['recv_bytes']:>10.0f}  "
                    f"{model:>10.0f}  {ratio}  {op['source']}")
    return "\n".join(rows)
