"""Static analysis passes over the plan/compile/serve stack.

Three auditors, one report format (``analysis.report.AuditReport``):

* ``analysis.hlo_audit`` — parse a compiled engine's HLO into a
  collective census and assert it against the plan's resolved
  strategies and byte models (plus donation / retrace / host-transfer
  checks).  Rules HA001-HA007.
* ``analysis.lint`` — AST lints over ``src/repro``: exchange-registry
  signature/purity/twin discipline and compiled-loop hygiene.  Rules
  RX001-RX005.
* ``analysis.locks`` — guarded-by annotation checking and lock-order
  cycle detection over ``serve/``.  Rules LK001-LK003.

CLI: ``python -m repro.launch.bfs_audit`` (the CI gate); inline:
``bfs_run --audit``.  Suppressions: ``# audit: allow(<rule>) -- reason``.
"""

from repro.analysis.report import AuditReport, Violation, RULES

__all__ = ["AuditReport", "Violation", "RULES"]
