"""Annotation-driven lock-discipline analysis for the serving layer.

The serve/ threading contract is documented per class with guard
annotations in the class body::

    class LaneGate:
        # guarded-by(_lock): _queue, _inflight_bytes, admitted

Each annotation maps a lock attribute (a ``threading.Lock`` / ``RLock``
/ ``Condition`` assigned in ``__init__``) to the instance attributes it
guards.  This pass then walks every method and flags:

* **LK001** — a read or write of a guarded attribute while the guarding
  lock is not statically held (not lexically inside ``with self.<lock>:``).
  ``__init__`` is exempt (construction is single-threaded by contract);
  helpers that run with the lock held by their caller carry a reasoned
  ``# audit: allow(LK001) -- ...`` suppression on (or above) their
  ``def`` line, which covers the whole function body.
* **LK002** — a cycle in the lock-acquisition graph.  Edges come from
  lexically nested ``with`` blocks *and* from ``self.method()`` calls
  made while a lock is held, where the callee acquires further locks
  (one level of indirection — enough for this codebase's helper
  pattern).  Re-acquiring a held non-reentrant lock is a self-edge and
  reports as a cycle too.
* **LK003** — an annotation naming a lock attribute that ``__init__``
  never assigns a Lock/RLock/Condition to.

Classes without annotations are skipped entirely: lock-free designs
(``BFSService``'s single-dispatcher contract) stay unflagged, and
adding the first annotation to a class is what opts it in.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.report import AuditReport
from repro.analysis.lint import Suppressions

_GUARD_RE = re.compile(r"#\s*guarded-by\((\w+)\):\s*([\w,\s]+)")
_LOCK_CTORS = {"Lock", "RLock", "Condition"}


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    """Attributes ``__init__`` assigns a Lock/RLock/Condition to."""
    locks: Set[str] = set()
    for node in cls.body:
        if not (isinstance(node, ast.FunctionDef)
                and node.name == "__init__"):
            continue
        for stmt in ast.walk(node):
            if not isinstance(stmt, ast.Assign):
                continue
            val = stmt.value
            if not isinstance(val, ast.Call):
                continue
            fn = val.func
            ctor = fn.attr if isinstance(fn, ast.Attribute) else \
                fn.id if isinstance(fn, ast.Name) else ""
            if ctor not in _LOCK_CTORS:
                continue
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Attribute) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id == "self":
                    locks.add(tgt.attr)
    return locks


def _annotations(cls: ast.ClassDef, src_lines: List[str]) -> Dict[str, str]:
    """{guarded_attr: lock_attr} from guarded-by comments in the class."""
    guarded: Dict[str, str] = {}
    end = max((getattr(n, "end_lineno", n.lineno) for n in cls.body),
              default=cls.lineno)
    for i in range(cls.lineno, min(end, len(src_lines)) + 1):
        m = _GUARD_RE.search(src_lines[i - 1])
        if not m:
            continue
        lock = m.group(1)
        for attr in m.group(2).split(","):
            attr = attr.strip()
            if attr:
                guarded[attr] = lock
    return guarded


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


class _MethodWalker(ast.NodeVisitor):
    """Tracks the statically-held lock set through one method body."""

    def __init__(self, owner: "_ClassAnalysis", fn: ast.FunctionDef):
        self.owner = owner
        self.fn = fn
        self.held: Tuple[str, ...] = ()
        self.def_lines = (fn.lineno, fn.lineno - 1)
        self.accesses: List[Tuple[str, int]] = []   # (attr, line) unguarded
        self.acquires: Set[str] = set()
        self.calls_under: List[Tuple[str, str, int]] = []  # (lock, meth, ln)

    def visit_With(self, node: ast.With) -> None:
        entered = []
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr in self.owner.locks:
                self.owner.add_edges(self.held, attr, node.lineno)
                entered.append(attr)
                self.acquires.add(attr)
        self.held = self.held + tuple(entered)
        for stmt in node.body:
            self.visit(stmt)
        if entered:
            self.held = self.held[:-len(entered)]
        for item in node.items:          # guards on the with-expr itself
            self.visit(item.context_expr)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr in self.owner.guarded:
            lock = self.owner.guarded[attr]
            if lock not in self.held:
                self.accesses.append((attr, node.lineno))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        meth = _self_attr(node.func)
        if meth and self.held:
            for lock in self.held:
                self.calls_under.append((lock, meth, node.lineno))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested closures inherit the lexically-held lock set (they run
        # where they are defined in this codebase's helper pattern)
        for stmt in node.body:
            self.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef


class _ClassAnalysis:
    def __init__(self, cls: ast.ClassDef, src_lines: List[str]):
        self.cls = cls
        self.locks = _lock_attrs(cls)
        self.guarded = _annotations(cls, src_lines)
        self.edges: Set[Tuple[str, str, int]] = set()   # (from, to, line)
        self.method_acquires: Dict[str, Set[str]] = {}

    def add_edges(self, held: Tuple[str, ...], acquired: str,
                  line: int) -> None:
        for h in held:
            self.edges.add((h, acquired, line))
        if acquired in held:             # re-acquire: self-edge = cycle
            self.edges.add((acquired, acquired, line))


def _find_cycle(edges: Set[Tuple[str, str]]) -> Optional[List[str]]:
    graph: Dict[str, Set[str]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
    state: Dict[str, int] = {}
    path: List[str] = []

    def dfs(node: str) -> Optional[List[str]]:
        state[node] = 1
        path.append(node)
        for nxt in sorted(graph.get(node, ())):
            if state.get(nxt) == 1:
                return path[path.index(nxt):] + [nxt]
            if state.get(nxt, 0) == 0:
                cyc = dfs(nxt)
                if cyc:
                    return cyc
        state[node] = 2
        path.pop()
        return None

    for node in sorted(graph):
        if state.get(node, 0) == 0:
            cyc = dfs(node)
            if cyc:
                return cyc
    return None


def analyze_lock_source(src: str, path: str,
                        report: Optional[AuditReport] = None) -> AuditReport:
    """Run the lock pass over one module's source."""
    report = report if report is not None else AuditReport(f"locks:{path}")
    sup = Suppressions(src, path, report)
    try:
        module = ast.parse(src)
    except SyntaxError as e:
        report.add("LK003", f"unparseable module: {e}", file=path,
                   line=e.lineno or 0)
        return report
    src_lines = src.splitlines()
    all_edges: List[dict] = []
    for cls in [n for n in ast.walk(module) if isinstance(n, ast.ClassDef)]:
        ana = _ClassAnalysis(cls, src_lines)
        if not ana.guarded:
            continue
        for attr, lock in sorted(ana.guarded.items()):
            if lock not in ana.locks:
                line = cls.lineno
                reason = sup.reason("LK003", line, line - 1)
                report.add("LK003",
                           f"{cls.name}: guarded-by({lock}) names no "
                           "Lock/RLock/Condition assigned in __init__",
                           file=path, line=line,
                           suppressed=reason is not None,
                           suppress_reason=reason or "")
        methods = [n for n in cls.body if isinstance(n, ast.FunctionDef)]
        walkers = []
        for fn in methods:
            walker = _MethodWalker(ana, fn)
            for stmt in fn.body:
                walker.visit(stmt)
            ana.method_acquires[fn.name] = walker.acquires
            walkers.append(walker)
        for fn, walker in zip(methods, walkers):
            # held-lock -> callee-acquired-lock edges (one hop)
            for lock, meth, line in walker.calls_under:
                for acq in ana.method_acquires.get(meth, ()):
                    ana.add_edges((lock,), acq, line)
            if fn.name == "__init__":
                continue
            for attr, line in walker.accesses:
                lock = ana.guarded[attr]
                reason = sup.reason("LK001", line, line - 1,
                                    *walker.def_lines)
                report.add("LK001",
                           f"{cls.name}.{fn.name}: `self.{attr}` "
                           f"accessed without holding `self.{lock}`",
                           file=path, line=line,
                           suppressed=reason is not None,
                           suppress_reason=reason or "")
        cyc = _find_cycle({(f"{cls.name}.{a}", f"{cls.name}.{b}")
                           for a, b, _ in ana.edges})
        if cyc:
            line = min((ln for _, _, ln in ana.edges), default=cls.lineno)
            reason = sup.reason("LK002", line, line - 1)
            report.add("LK002",
                       f"{cls.name}: lock acquisition cycle "
                       f"{' -> '.join(cyc)}",
                       file=path, line=line,
                       suppressed=reason is not None,
                       suppress_reason=reason or "")
        all_edges.extend({"from": f"{cls.name}.{a}", "to": f"{cls.name}.{b}",
                          "file": path, "line": ln}
                         for a, b, ln in sorted(ana.edges))
    report.info.setdefault("lock_edges", []).extend(all_edges)
    return report


SERVE_MODULES = ("engine_cache.py", "bfs_service.py",
                 os.path.join("frontend", "server.py"),
                 os.path.join("frontend", "admission.py"),
                 os.path.join("frontend", "metrics.py"),
                 os.path.join("resilience", "faults.py"),
                 os.path.join("resilience", "breaker.py"),
                 os.path.join("resilience", "watchdog.py"))


def analyze_serve(root: Optional[str] = None) -> AuditReport:
    """Run the lock pass over the serving layer (CI / CLI entry point)."""
    if root is None:
        from repro.analysis.lint import repo_root
        root = os.path.join(repo_root(), "serve")
    report = AuditReport("locks:serve")
    for rel in SERVE_MODULES:
        path = os.path.join(root, rel)
        with open(path, encoding="utf-8") as f:
            analyze_lock_source(f.read(), os.path.relpath(
                path, os.path.dirname(os.path.dirname(root))), report)
    return report
